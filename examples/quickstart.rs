//! Quickstart: run CPrune on ResNet-18 (ImageNet-scale) for a simulated
//! Kryo 385 CPU and print the before/after comparison.
//!
//!     cargo run --release --example quickstart

use cprune::accuracy::ProxyOracle;
use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::graph::stats;
use cprune::pruner::{cprune as run_cprune, CPruneConfig};
use cprune::tuner::TuneOptions;

fn main() {
    // 1. A workload from the zoo (graph IR + seeded weights).
    let model = Model::build(ModelKind::ResNet18ImageNet, 0);
    let (flops, params) = stats::flops_params(&model.graph);
    println!(
        "model: {} — {:.2} GMACs, {:.1}M params, {} convs",
        model.kind.name(),
        flops as f64 / 2e9,
        params as f64 / 1e6,
        model.graph.conv_ids().len()
    );

    // 2. A target device (analytic simulator standing in for the phone).
    let sim = Simulator::new(DeviceSpec::kryo385());
    println!("target: {}", sim.spec.name);

    // 3. CPrune: compiler-informed pruning to the accuracy budget.
    let cfg = CPruneConfig {
        target_accuracy: 0.66, // a_g: stop before dropping below 66% top-1
        max_iterations: 12,
        tune_opts: TuneOptions::quick(),
        ..Default::default()
    };
    let mut oracle = ProxyOracle::new();
    let result = run_cprune(&model, &sim, &mut oracle, &cfg);

    println!("\niterations accepted: {}", result.iterations.len());
    for it in &result.iterations {
        println!(
            "  iter {:>2}: pruned {:>3} filters of {:?} -> {:.2}x FPS, short-term top-1 {:.2}%",
            it.iteration,
            it.filters_removed,
            it.pruned_convs,
            it.fps_rate,
            it.short_accuracy * 100.0
        );
    }
    let (f2, p2) = stats::flops_params(&result.final_graph);
    println!(
        "\nresult: {:.2}x FPS vs TVM-auto-tune baseline ({:.1} -> {:.1} FPS)",
        result.fps_increase_rate,
        result.baseline.fps(),
        result.final_fps
    );
    println!(
        "        {:.2} -> {:.2} GMACs, {:.1}M -> {:.1}M params",
        flops as f64 / 2e9,
        f2 as f64 / 2e9,
        params as f64 / 1e6,
        p2 as f64 / 1e6
    );
    println!(
        "        final top-1 {:.2}% / top-5 {:.2}% (original 69.76% / 89.08%)",
        result.final_top1 * 100.0,
        result.final_top5 * 100.0
    );
}
