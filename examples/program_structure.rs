//! Fig. 5 demo: render the fastest vs slowest tuned program for one
//! ResNet-18 subgraph, and show the §3.5 minimum-prune-step calculation
//! for both (LCM rule: 32 for the fast structure, 4 for the slow one).
//!
//!     cargo run --release --example program_structure

use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::ops::OpKind;
use cprune::tir::{lower, Program, Workload};
use cprune::util::rng::Rng;

fn main() {
    // the paper's Fig. 5 subgraph: 7x7 conv, 512 filters (ResNet-18 tail
    // shape at CIFAR-ish spatial size)
    let w = Workload::from_conv(
        &OpKind::Conv2d { kh: 7, kw: 7, cin: 512, cout: 512, stride: 1, padding: 3, groups: 1 },
        [1, 7, 7, 512],
        vec!["bn", "relu"],
    );
    let sim = Simulator::new(DeviceSpec::kryo385());

    // sample many programs; keep the fastest and slowest
    let mut rng = Rng::new(0);
    let mut best: Option<(f64, Program)> = None;
    let mut worst: Option<(f64, Program)> = None;
    for _ in 0..2000 {
        let p = Program::sample(&w, &mut rng);
        let lat = sim.latency(&w, &p);
        if best.as_ref().map(|(l, _)| lat < *l).unwrap_or(true) {
            best = Some((lat, p.clone()));
        }
        if worst.as_ref().map(|(l, _)| lat > *l).unwrap_or(true) {
            worst = Some((lat, p));
        }
    }
    let (bl, bp) = best.unwrap();
    let (wl_, wp) = worst.unwrap();

    println!("=== fastest sampled program ({:.2} ms) ===", bl * 1e3);
    println!("{}", lower::render(&w, &bp));
    println!("=== slowest sampled program ({:.2} ms, {:.0}x slower) ===", wl_ * 1e3, wl_ / bl);
    println!("{}", lower::render(&w, &wp));
    println!(
        "CPrune preserves the FAST structure: it prunes {} filters at a time\n\
         (the slow structure would only require steps of {}, but locks in a\n\
         {:.0}x slower program — exactly the Fig. 5 trade-off).",
        bp.min_filter_prune_step(),
        wp.min_filter_prune_step(),
        wl_ / bl
    );
}
