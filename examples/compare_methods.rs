//! Compare pruning methods on one Table-1 cell (smoke scale):
//! Original / PQF / FPGM / NetAdapt / AMC / CPrune on ResNet-18, Kryo 385.
//!
//!     cargo run --release --example compare_methods [-- <device>]
//!     device ∈ {kryo280, kryo385, kryo585, mali-g72}

use cprune::device::DeviceSpec;
use cprune::exp::{device_by_name, table1, Scale};
use cprune::graph::model_zoo::ModelKind;
use cprune::util::bench::print_table;

fn main() {
    let device = std::env::args()
        .nth(1)
        .map(|n| device_by_name(&n))
        .unwrap_or_else(DeviceSpec::kryo385);
    let block = table1::run_cell(ModelKind::ResNet18ImageNet, device, Scale::Smoke, 7);
    let rows: Vec<Vec<String>> = block
        .rows
        .iter()
        .map(|r| {
            vec![
                r.method.clone(),
                format!("{:.2}", r.fps),
                format!("{:.2}x", r.fps_increase_rate),
                format!("{:.0}M", r.macs as f64 / 1e6),
                format!("{:.2}M", r.params as f64 / 1e6),
                format!("{:.2}%", r.top1 * 100.0),
                format!("{:.2}%", r.top5 * 100.0),
            ]
        })
        .collect();
    print_table(
        &format!("{} on {}", block.model, block.device),
        &["method", "FPS", "rate", "MACs", "params", "top-1", "top-5"],
        &rows,
    );
}
