//! Target-awareness demo (Fig. 8 shape): prune+tune MobileNetV2 for each
//! mobile target, then execute every model on every device.
//!
//!     cargo run --release --example cross_device

use cprune::exp::{fig8, Scale};
use cprune::util::bench::print_table;

fn main() {
    let rows = fig8::run(Scale::Smoke, 11);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.tuned_for.to_string(),
                r.run_on.to_string(),
                format!("{:.1}", r.fps),
                format!("{:.0}%", r.relative_to_native * 100.0),
            ]
        })
        .collect();
    print_table(
        "MobileNetV2 CPrune models across devices (FPS, % of native)",
        &["tuned for", "run on", "FPS", "vs native"],
        &table,
    );
    println!("\nDiagonal cells are native (100%); off-diagonal cells show the\ncost of running a model tuned for a different processor (Fig. 8).");
}
