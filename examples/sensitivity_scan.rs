//! Per-layer sensitivity scan (NetAdapt-style analysis): accuracy and
//! whole-model latency per layer per pruned fraction, plus the
//! latency-saved-per-accuracy-lost frontier.
//!
//!     cargo run --release --example sensitivity_scan

use cprune::accuracy::{sensitivity, ProxyOracle};
use cprune::compiler;
use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::tuner::{TuneOptions, TuningSession};
use cprune::util::bench::print_table;
use std::collections::HashMap;

fn main() {
    let model = Model::build(ModelKind::ResNet18Cifar, 0);
    let sim = Simulator::new(DeviceSpec::kryo585());
    let session = TuningSession::new(&sim, TuneOptions::quick(), 0);
    let mut oracle = ProxyOracle::new();
    let base = compiler::compile_tuned(&model.graph, &session, &HashMap::new());

    let points = sensitivity::scan(&model, &session, &mut oracle, &[0.25, 0.5]);
    let rows: Vec<Vec<String>> = points
        .iter()
        .map(|p| {
            vec![
                p.conv_name.clone(),
                format!("{:.0}%", p.pruned_fraction * 100.0),
                format!("{:.2}%", p.short_top1 * 100.0),
                format!("{:.2}ms", p.latency * 1e3),
            ]
        })
        .collect();
    print_table(
        "Layer sensitivity (ResNet-18/CIFAR-10, Kryo 585)",
        &["layer", "pruned", "short-term top-1", "model latency"],
        &rows,
    );

    let f = sensitivity::frontier(&points, base.latency(), model.kind.base_accuracy().0, 0.5);
    let rows: Vec<Vec<String>> = f
        .iter()
        .map(|(name, v)| vec![name.clone(), format!("{v:.1}")])
        .collect();
    print_table(
        "Pruning frontier at 50% (latency saved / accuracy lost — higher = better target)",
        &["layer", "score"],
        &rows,
    );
    println!("\nNote: CPrune reaches equivalent targeting through task impact\nordering without running this O(layers x fractions) sweep.");
}
