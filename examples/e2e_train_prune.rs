//! End-to-end driver (the full three-layer stack, no Python at runtime):
//!
//! L1/L2: the masked CIFAR CNN with Pallas GEMM hot-spots was AOT-lowered
//!        to `artifacts/*.hlo.txt` by `make artifacts`.
//! Here:  Rust loads those artifacts via PJRT, pre-trains the model on a
//!        synthetic CIFAR-like set (logging the loss curve), then runs the
//!        CPrune search where "short-term train and measure a_s" is REAL
//!        training through the compiled train step — while latency comes
//!        from the compiler substrate tuned for a Kryo 385.
//!
//!     make artifacts && cargo run --release --example e2e_train_prune

use cprune::accuracy::AccuracyOracle;
use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::graph::stats;
use cprune::pruner::{cprune as run_cprune, summarize, CPruneConfig};
use cprune::runtime::Runtime;
use cprune::train::{Dataset, TrainConfig, TrainedOracle, Trainer};
use cprune::tuner::TuneOptions;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    println!("== L2/L1: loading AOT artifacts via PJRT ==");
    let rt = Runtime::cpu(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    let cfg = TrainConfig { lr: 0.02, short_steps: 24, final_steps: 96, eval_batches: 2 };
    let mut trainer = Trainer::new(&rt, cfg)?;

    let (train_data, eval_data) = Dataset::synthetic(2448, 32, 10, 0).split(400);

    println!("\n== pre-training (Rust-driven, Pallas-GEMM train step) ==");
    let t0 = Instant::now();
    let steps = 120;
    let losses = trainer.train(&train_data, steps, 0.02)?;
    let acc0 = trainer.evaluate(&eval_data, 2)?;
    println!(
        "{} steps in {:.1}s ({:.2} s/step) — loss {:.3} -> {:.3}, eval top-1 {:.1}%",
        steps,
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64() / steps as f64,
        losses.first().unwrap(),
        losses.last().unwrap(),
        acc0 * 100.0
    );
    println!("loss curve (every 10th): {:?}",
        losses.iter().step_by(10).map(|l| (l * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    assert!(losses.last().unwrap() < losses.first().unwrap(), "training must reduce loss");

    println!("\n== CPrune with a REAL accuracy oracle (masked retraining) ==");
    let model = Model::build(ModelKind::ResNet8Cifar, 0);
    let sim = Simulator::new(DeviceSpec::kryo385());
    let mut oracle = TrainedOracle::new(&mut trainer, &train_data, &eval_data, &model);
    let cfg = CPruneConfig {
        max_iterations: 4,
        tune_opts: TuneOptions::quick(),
        alpha: 0.90, // real short-term accuracy is noisier than the proxy
        ..Default::default()
    };
    let t1 = Instant::now();
    let result = run_cprune(&model, &sim, &mut oracle, &cfg);
    println!("search took {:.1}s, accepted {} iterations", t1.elapsed().as_secs_f64(), result.iterations.len());
    for it in &result.iterations {
        println!(
            "  iter {}: removed {} filters {:?} -> {:.2}x FPS, measured top-1 {:.1}%",
            it.iteration, it.filters_removed, it.pruned_convs, it.fps_rate, it.short_accuracy * 100.0
        );
    }

    let (f0, p0) = stats::flops_params(&model.graph);
    let (f1, p1) = stats::flops_params(&result.final_graph);
    println!("\n== result ==");
    println!(
        "FPS (sim {}): {:.0} -> {:.0}  ({:.2}x)",
        sim.spec.name,
        result.baseline.fps(),
        result.final_fps,
        result.fps_increase_rate
    );
    println!(
        "MACs {:.1}M -> {:.1}M, params {:.0}k -> {:.0}k",
        f0 as f64 / 2e6, f1 as f64 / 2e6, p0 as f64 / 1e3, p1 as f64 / 1e3
    );
    let final_summary = summarize(&model, &result.final_state, cprune::accuracy::Criterion::L1Norm);
    let final_acc = oracle.top1(&final_summary, cprune::accuracy::TrainPhase::Final);
    println!(
        "final accuracy (real eval after final training): {:.1}% (baseline {:.1}%)",
        final_acc * 100.0,
        acc0 * 100.0
    );
    println!("\nEXPERIMENT e2e: fps_rate={:.2} base_acc={:.3} final_acc={:.3}",
        result.fps_increase_rate, acc0, final_acc);
    Ok(())
}
