"""Layer-2: JAX model + training step for the CPrune end-to-end driver.

A *masked* ResNet-8-style CNN for 32x32x3 inputs (CIFAR-scale).  Structured
pruning is expressed as per-conv **channel masks** passed as runtime inputs,
so the AOT-compiled HLO has static shapes: one artifact serves every pruning
state the Rust coordinator explores.  Zeroed mask entries kill the
corresponding output channels (the folded-BN scale/shift are masked, so the
channel is exactly 0 after the epilogue), which is numerically equivalent to
removing the filter; the *latency* effect of removal is modeled by the L3
device simulator, and the *accuracy* effect is measured here for real.

Every convolution lowers through the L1 Pallas GEMM hot-spot
(kernels.conv2d.conv2d_bn_act).  This module is build-time only: aot.py
lowers `train_step` / `eval_batch` / `predict` to HLO text and Rust drives
them via PJRT.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import conv2d as k

NUM_CLASSES = 10
IMG = 32

# (name, kh, kw, cin, cout, stride, relu) for every conv, in forward order.
CONV_SPECS = [
    ("stem",   3, 3,  3, 16, 1, True),
    ("b1c1",   3, 3, 16, 16, 1, True),
    ("b1c2",   3, 3, 16, 16, 1, False),
    ("b2c1",   3, 3, 16, 32, 2, True),
    ("b2c2",   3, 3, 32, 32, 1, False),
    ("b2proj", 1, 1, 16, 32, 2, False),
    ("b3c1",   3, 3, 32, 64, 2, True),
    ("b3c2",   3, 3, 64, 64, 1, False),
    ("b3proj", 1, 1, 32, 64, 2, False),
]

#: convs whose output-channel masks the pruner controls (order = mask input order)
MASKED_CONVS = [s[0] for s in CONV_SPECS]


def param_specs():
    """Flat, ordered (name, shape) list — the AOT calling convention."""
    specs = []
    for name, kh, kw, cin, cout, _, _ in CONV_SPECS:
        specs.append((f"{name}.w", (kh, kw, cin, cout)))
        specs.append((f"{name}.scale", (cout,)))
        specs.append((f"{name}.shift", (cout,)))
    specs.append(("fc.w", (64, NUM_CLASSES)))
    specs.append(("fc.b", (NUM_CLASSES,)))
    return specs


def mask_specs():
    return [(f"{name}.mask", (cout,)) for name, _, _, _, cout, _, _ in CONV_SPECS]


def init_params(seed: int = 0):
    """He-normal conv weights, unit scale, zero shift.  Returns dict name->array."""
    key = jax.random.PRNGKey(seed)
    params = {}
    for name, shape in param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(".w") and len(shape) == 4:
            fan_in = shape[0] * shape[1] * shape[2]
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(
                2.0 / fan_in
            )
        elif name.endswith(".w"):
            params[name] = jax.random.normal(sub, shape, jnp.float32) * jnp.sqrt(
                1.0 / shape[0]
            )
        elif name.endswith(".scale"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            params[name] = jnp.zeros(shape, jnp.float32)
    return params


def _conv(params, masks, x, name, kh, kw, cin, cout, stride, relu):
    pad = 1 if kh == 3 else 0
    m = masks[f"{name}.mask"]
    return k.conv2d_bn_act(
        x,
        params[f"{name}.w"],
        params[f"{name}.scale"] * m,
        params[f"{name}.shift"] * m,
        stride=stride,
        padding=pad,
        relu=relu,
    )


def forward(params, masks, x):
    """Masked ResNet-8 forward.  x: (B, 32, 32, 3) float32 -> (B, 10) logits."""
    spec = {s[0]: s for s in CONV_SPECS}

    def c(name, inp):
        _, kh, kw, cin, cout, stride, relu = spec[name]
        return _conv(params, masks, inp, name, kh, kw, cin, cout, stride, relu)

    h = c("stem", x)
    # stage 1: identity residual
    h = jnp.maximum(c("b1c2", c("b1c1", h)) + h, 0.0)
    # stage 2: projection residual (stride 2)
    h = jnp.maximum(c("b2c2", c("b2c1", h)) + c("b2proj", h), 0.0)
    # stage 3: projection residual (stride 2)
    h = jnp.maximum(c("b3c2", c("b3c1", h)) + c("b3proj", h), 0.0)
    h = k.avgpool_global(h)  # (B, 64)
    return h @ params["fc.w"] + params["fc.b"]


def loss_fn(params, masks, x, y):
    logits = forward(params, masks, x)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


MOMENTUM = 0.9
GRAD_CLIP = 5.0  # global-norm clip keeps long Rust-driven runs stable


def train_step(params, mom, masks, x, y, lr):
    """One SGD+momentum step with global-norm gradient clipping.

    Returns (params', mom', loss)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, masks, x, y)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()) + 1e-12)
    scale = jnp.minimum(1.0, GRAD_CLIP / gnorm)
    new_params, new_mom = {}, {}
    for name in params:
        v = MOMENTUM * mom[name] + grads[name] * scale
        new_mom[name] = v
        new_params[name] = params[name] - lr * v
    return new_params, new_mom, loss


def eval_batch(params, masks, x, y):
    """Returns (#correct as f32, mean loss) over the batch."""
    logits = forward(params, masks, x)
    pred = jnp.argmax(logits, axis=1)
    correct = jnp.sum((pred == y).astype(jnp.float32))
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return correct, nll


def predict(params, masks, x):
    return forward(params, masks, x)


# ---------------------------------------------------------------------------
# Flat-argument wrappers: the AOT boundary.  Rust passes arrays positionally
# in the order given by param_specs() / mask_specs(); these wrappers
# reassemble the dicts.
# ---------------------------------------------------------------------------

def _pack(names, flat):
    return dict(zip(names, flat))


def flat_train_step(*args):
    pnames = [n for n, _ in param_specs()]
    mnames = [n for n, _ in mask_specs()]
    np_, nm = len(pnames), len(mnames)
    params = _pack(pnames, args[:np_])
    mom = _pack(pnames, args[np_ : 2 * np_])
    masks = _pack(mnames, args[2 * np_ : 2 * np_ + nm])
    x, y, lr = args[2 * np_ + nm :]
    new_params, new_mom, loss = train_step(params, mom, masks, x, y, lr)
    out = [new_params[n] for n in pnames] + [new_mom[n] for n in pnames] + [loss]
    return tuple(out)


def flat_eval_batch(*args):
    pnames = [n for n, _ in param_specs()]
    mnames = [n for n, _ in mask_specs()]
    np_, nm = len(pnames), len(mnames)
    params = _pack(pnames, args[:np_])
    masks = _pack(mnames, args[np_ : np_ + nm])
    x, y = args[np_ + nm :]
    return eval_batch(params, masks, x, y)


def flat_predict(*args):
    pnames = [n for n, _ in param_specs()]
    mnames = [n for n, _ in mask_specs()]
    np_, nm = len(pnames), len(mnames)
    params = _pack(pnames, args[:np_])
    masks = _pack(mnames, args[np_ : np_ + nm])
    (x,) = args[np_ + nm :]
    return (predict(params, masks, x),)
