"""TPU resource model for the L1 Pallas GEMM: VMEM footprint + MXU
utilization estimates per block configuration.

interpret=True gives CPU-numpy timings only, so real-TPU performance is
*estimated structurally* (DESIGN.md §Perf): a block config is TPU-viable
when its tiles fit VMEM with double-buffering headroom, and its MXU
utilization is the fraction of each 128x128 systolic pass kept busy by the
tile shape.  The estimates below are what DESIGN.md §Perf quotes.
"""

from __future__ import annotations

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on current TPUs
MXU_DIM = 128                  # systolic array edge
F32 = 4


@dataclass
class BlockEstimate:
    """Resource estimate of one (block_m, K, block_n) GEMM tile."""

    block_m: int
    k: int
    block_n: int
    vmem_bytes: int
    vmem_ok: bool
    mxu_utilization: float
    macs_per_tile: int

    @property
    def summary(self) -> str:
        return (
            f"tile {self.block_m}x{self.k}x{self.block_n}: "
            f"VMEM {self.vmem_bytes / 1024:.0f} KiB "
            f"({'OK' if self.vmem_ok else 'OVER'}), "
            f"MXU util {self.mxu_utilization:.2f}"
        )


def estimate(block_m: int, k: int, block_n: int, *, double_buffer: bool = True) -> BlockEstimate:
    """VMEM + MXU estimate for one tile of matmul_scale_shift.

    VMEM holds: x tile (bm, K), w tile (K, bn), scale/shift (2, bn),
    output tile (bm, bn); double-buffering doubles the input tiles.
    MXU utilization: each (128,128)x(128,128) pass is fully used only when
    the tile dims are multiples of 128; fractional occupancy multiplies.
    """
    in_bytes = (block_m * k + k * block_n + 2 * block_n) * F32
    out_bytes = block_m * block_n * F32
    vmem = (2 * in_bytes if double_buffer else in_bytes) + out_bytes

    def occ(dim: int) -> float:
        full, rem = divmod(dim, MXU_DIM)
        passes = full + (1 if rem else 0)
        return dim / (passes * MXU_DIM)

    util = occ(block_m) * occ(k) * occ(block_n)
    return BlockEstimate(
        block_m=block_m,
        k=k,
        block_n=block_n,
        vmem_bytes=vmem,
        vmem_ok=vmem <= VMEM_BYTES,
        mxu_utilization=util,
        macs_per_tile=block_m * k * block_n,
    )


def best_tpu_blocks(m: int, k: int, n: int) -> BlockEstimate:
    """Pick the MXU-aligned block config a real-TPU lowering would use:
    largest (multiple-of-128) tiles that fit VMEM."""
    best = None
    for bm in (512, 256, 128):
        for bn_ in (512, 256, 128):
            if bm > max(m, MXU_DIM) or bn_ > max(n, MXU_DIM):
                continue
            e = estimate(min(bm, m), k, min(bn_, n))
            if not e.vmem_ok:
                continue
            score = (e.mxu_utilization, e.macs_per_tile)
            if best is None or score > (best.mxu_utilization, best.macs_per_tile):
                best = e
    return best or estimate(min(m, MXU_DIM), k, min(n, MXU_DIM))


def report_model_convs() -> list[str]:
    """Estimates for every conv GEMM of the L2 model (DESIGN.md §Perf)."""
    from compile import model

    lines = []
    batch = 32
    for name, kh, kw, cin, cout, stride, _ in model.CONV_SPECS:
        hw = 32 // (1 if name in ("stem", "b1c1", "b1c2") else (2 if name.startswith("b2") else 4))
        m = batch * hw * hw
        k = kh * kw * cin
        e = best_tpu_blocks(m, k, cout)
        lines.append(f"{name:8s} M={m:6d} K={k:4d} N={cout:3d} -> {e.summary}")
    return lines


if __name__ == "__main__":
    for line in report_model_convs():
        print(line)
