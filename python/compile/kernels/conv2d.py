"""Layer-1 Pallas kernels: the compute hot-spot of the CPrune stack.

A convolution is lowered (in L2, ``model.py``) to im2col + GEMM; the GEMM —
with its fused scale/shift (folded batch-norm) + ReLU epilogue — is the hot
spot, implemented here as a block-tiled Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper prunes filter
counts to stay compatible with the *iterator split tree* of TVM's fastest
schedule on a mobile target.  The TPU-side analog of that split tree is this
kernel's ``(block_m, block_n, block_k)`` tiling: the N (= output-channel)
dimension is covered by a grid of ``block_n``-wide tiles, so channel counts
that are multiples of ``block_n`` keep the HBM→VMEM schedule intact — exactly
the structural constraint CPrune's LCM rule preserves.  MXU-friendly defaults
are multiples of 128 where the problem is big enough; small CIFAR-scale
problems use smaller power-of-two tiles.

All kernels run with ``interpret=True`` so they lower to plain HLO and execute
on the CPU PJRT client (real TPU lowering emits Mosaic custom-calls the CPU
plugin cannot run).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_epilogue_kernel(x_ref, w_ref, scale_ref, shift_ref, o_ref, *, relu: bool):
    """One (block_m, block_n) output tile: full-K matmul + scale/shift [+ReLU].

    x_ref:     (block_m, K)  im2col patches tile
    w_ref:     (K, block_n)  filter tile
    scale_ref: (1, block_n)  folded-BN scale (broadcast over rows)
    shift_ref: (1, block_n)  folded-BN shift
    o_ref:     (block_m, block_n)
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    out = acc * scale_ref[...] + shift_ref[...]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


def _matmul_kernel(a_ref, b_ref, o_ref):
    """Plain tiled GEMM tile: o = a @ b (used by fwd z and all bwd matmuls)."""
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul_pallas(
    a: jax.Array, b: jax.Array, *, block_m: int = 128, block_n: int = 16
) -> jax.Array:
    """Block-tiled Pallas GEMM for arbitrary (M,K)x(K,N); pads M/N to tiles."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    pad_m = (-m) % bm if bm > 1 else 0
    pad_n = (-n) % bn if bn > 1 else 0
    # _pick_block guarantees divisibility, so pads are 0; keep the guard for
    # future block policies.
    ap = jnp.pad(a, ((0, pad_m), (0, 0))) if pad_m else a
    bp = jnp.pad(b, ((0, 0), (0, pad_n))) if pad_n else b
    mp, np_ = m + pad_m, n + pad_n
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap.astype(jnp.float32), bp.astype(jnp.float32))
    if pad_m or pad_n:
        out = out[:m, :n]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def matmul_scale_shift(
    x: jax.Array,
    w: jax.Array,
    scale: jax.Array,
    shift: jax.Array,
    relu: bool = True,
    block_m: int = 128,
    block_n: int = 16,
) -> jax.Array:
    """Tiled GEMM with fused affine epilogue: ``act((x @ w) * scale + shift)``.

    ``x``: (M, K) — im2col patch matrix.  ``w``: (K, N) — flattened filters.
    ``scale``/``shift``: (N,) — folded batch-norm.  M and N must be multiples
    of ``block_m``/``block_n`` (the L2 caller pads M; N is a channel count the
    pruner keeps block-aligned).  Differentiable via a custom VJP whose
    backward matmuls also run through the Pallas GEMM.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % block_m == 0, f"M={m} not a multiple of block_m={block_m}"
    assert n % block_n == 0, f"N={n} not a multiple of block_n={block_n}"
    scale2 = scale.reshape(1, n).astype(jnp.float32)
    shift2 = shift.reshape(1, n).astype(jnp.float32)

    grid = (m // block_m, n // block_n)
    kernel = functools.partial(_matmul_epilogue_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32), scale2, shift2)


def _mss_fwd(x, w, scale, shift, relu, block_m, block_n):
    z = matmul_pallas(x, w, block_m=block_m, block_n=block_n)
    u = z * scale.reshape(1, -1) + shift.reshape(1, -1)
    y = jnp.maximum(u, 0.0) if relu else u
    return y, (x, w, scale, z, u)


def _mss_bwd(relu, block_m, block_n, res, g):
    x, w, scale, z, u = res
    gu = jnp.where(u > 0.0, g, 0.0) if relu else g
    gshift = jnp.sum(gu, axis=0)
    gscale = jnp.sum(gu * z, axis=0)
    gz = gu * scale.reshape(1, -1)
    # dx = gz @ w.T  (M,N)x(N,K); dw = x.T @ gz  (K,M)x(M,N) — both via Pallas.
    gx = matmul_pallas(gz, w.T, block_m=block_m, block_n=block_n)
    gw = matmul_pallas(x.T, gz, block_m=block_m, block_n=block_n)
    return gx, gw, gscale, gshift


matmul_scale_shift.defvjp(_mss_fwd, _mss_bwd)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest power-of-two tile ≤ preferred that divides ``dim``."""
    b = preferred
    while b > 1 and dim % b != 0:
        b //= 2
    return max(b, 1)


def im2col(x: jax.Array, kh: int, kw: int, stride: int, padding: int) -> jax.Array:
    """NHWC image -> (N*OH*OW, KH*KW*C) patch matrix (pure jnp, fused by XLA)."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    oh = (h + 2 * padding - kh) // stride + 1
    ow = (w + 2 * padding - kw) // stride + 1
    # Gather all (kh, kw) shifted views; stack along a new patch axis.
    patches = []
    for i in range(kh):
        for j in range(kw):
            sl = xp[:, i : i + stride * oh : stride, j : j + stride * ow : stride, :]
            patches.append(sl)
    # (N, OH, OW, KH*KW, C) -> (N*OH*OW, KH*KW*C)
    pat = jnp.stack(patches, axis=3)
    return pat.reshape(n * oh * ow, kh * kw * c), (n, oh, ow)


def conv2d_bn_act(
    x: jax.Array,
    w: jax.Array,
    scale: jax.Array,
    shift: jax.Array,
    *,
    stride: int = 1,
    padding: int = 1,
    relu: bool = True,
    block_m: int | None = None,
    block_n: int = 16,
) -> jax.Array:
    """Conv2D (NHWC, HWIO weights) + folded-BN affine + optional ReLU.

    Lowers to im2col (L2/XLA territory) feeding the Pallas GEMM hot-spot.

    ``block_m=None`` (default) uses a full-M tile: the grid iterates only
    over the output-channel axis — the axis whose tiling the paper's §3.5
    reads — and the interpret-mode grid loop stays short (CPU-PJRT executes
    each grid step as plain HLO; fine-grained M-tiling there costs ~100×
    wall-clock for zero fidelity gain). On a real TPU lowering you would
    set ``block_m≈128`` for MXU-shaped tiles; see DESIGN.md §Perf.
    """
    kh, kw, cin, cout = w.shape
    cols, (n, oh, ow) = im2col(x, kh, kw, stride, padding)
    m = cols.shape[0]
    if block_m is None:
        bm = m
    elif m % block_m != 0:
        bm = _pick_block(m, block_m)
        if bm < 8:
            pad_rows = (-m) % block_m
            cols = jnp.pad(cols, ((0, pad_rows), (0, 0)))
            bm = block_m
            m = m + pad_rows
    else:
        bm = block_m
    m_orig = n * oh * ow
    bn_ = _pick_block(cout, block_n)
    wmat = w.reshape(kh * kw * cin, cout)
    out = matmul_scale_shift(cols, wmat, scale, shift, relu, bm, bn_)
    out = out[:m_orig] if out.shape[0] != m_orig else out
    return out.reshape(n, oh, ow, cout)


def avgpool_global(x: jax.Array) -> jax.Array:
    """Global average pool NHWC -> NC (pure jnp; not a hot spot)."""
    return jnp.mean(x, axis=(1, 2))
