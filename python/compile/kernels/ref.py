"""Pure-jnp correctness oracles for the Pallas kernels.

Every L1 kernel has a reference here written with plain jax.numpy /
jax.lax ops only — no Pallas — used by the pytest suite (exact math,
same dtype discipline) and by hypothesis sweeps over shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_scale_shift_ref(x, w, scale, shift, *, relu: bool = True):
    """Reference for kernels.conv2d.matmul_scale_shift."""
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    out = out * scale.reshape(1, -1) + shift.reshape(1, -1)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def conv2d_bn_act_ref(x, w, scale, shift, *, stride: int = 1, padding: int = 1, relu: bool = True):
    """Reference conv (NHWC, HWIO) + affine + ReLU via lax.conv_general_dilated."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out * scale.reshape(1, 1, 1, -1) + shift.reshape(1, 1, 1, -1)
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def avgpool_global_ref(x):
    return jnp.mean(x, axis=(1, 2))
