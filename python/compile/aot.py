"""AOT compile path: lower the L2 model to HLO *text* artifacts for Rust.

Run once by `make artifacts` (no Python on the request path):

    cd python && python -m compile.aot --out-dir ../artifacts

Outputs:
    train_step.hlo.txt   (params, mom, masks, x[B,32,32,3], y[B], lr) ->
                         (params', mom', loss)
    eval_batch.hlo.txt   (params, masks, x, y) -> (correct, loss)
    predict.hlo.txt      (params, masks, x[1,...]) -> (logits,)
    kernel_gemm.hlo.txt  standalone Pallas GEMM (smoke test for the runtime)
    manifest.json        argument order/shapes + init-param binary layout
    params_init.bin      raw little-endian f32 initial parameters

HLO text (NOT .serialize()) is the interchange format: jax>=0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import conv2d as k

TRAIN_BATCH = 32
EVAL_BATCH = 100


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def arg_specs(kind: str):
    """Positional ShapeDtypeStructs for each exported function."""
    pspecs = [_spec(s) for _, s in model.param_specs()]
    mspecs = [_spec(s) for _, s in model.mask_specs()]
    if kind == "train":
        x = _spec((TRAIN_BATCH, model.IMG, model.IMG, 3))
        y = _spec((TRAIN_BATCH,), jnp.int32)
        lr = _spec((), jnp.float32)
        return pspecs + pspecs + mspecs + [x, y, lr]
    if kind == "eval":
        x = _spec((EVAL_BATCH, model.IMG, model.IMG, 3))
        y = _spec((EVAL_BATCH,), jnp.int32)
        return pspecs + mspecs + [x, y]
    if kind == "predict":
        x = _spec((1, model.IMG, model.IMG, 3))
        return pspecs + mspecs + [x]
    raise ValueError(kind)


def lower(fn, kind: str) -> str:
    return to_hlo_text(jax.jit(fn).lower(*arg_specs(kind)))


def gemm_example() -> str:
    """Standalone Pallas GEMM artifact: (128,64)x(64,32) + affine + relu."""

    def fn(x, w, scale, shift):
        return (k.matmul_scale_shift(x, w, scale, shift, True, 64, 16),)

    specs = (_spec((128, 64)), _spec((64, 32)), _spec((32,)), _spec((32,)))
    return to_hlo_text(jax.jit(fn).lower(*specs))


def write_manifest(out_dir: str) -> None:
    params = model.init_params(seed=0)
    order = [n for n, _ in model.param_specs()]
    offset = 0
    entries = []
    with open(os.path.join(out_dir, "params_init.bin"), "wb") as f:
        for name in order:
            arr = np.asarray(params[name], dtype=np.float32)
            f.write(arr.tobytes())
            entries.append(
                {
                    "name": name,
                    "shape": list(arr.shape),
                    "offset": offset,
                    "numel": int(arr.size),
                }
            )
            offset += arr.size * 4

    manifest = {
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "img": model.IMG,
        "num_classes": model.NUM_CLASSES,
        "params": entries,
        "masks": [
            {"name": n, "shape": list(s)} for n, s in model.mask_specs()
        ],
        "convs": [
            {
                "name": name,
                "kh": kh, "kw": kw, "cin": cin, "cout": cout,
                "stride": stride, "relu": relu,
            }
            for name, kh, kw, cin, cout, stride, relu in model.CONV_SPECS
        ],
        "momentum": model.MOMENTUM,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", choices=["train", "eval", "predict", "gemm"],
                    default=None, help="export a single artifact (debugging)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    jobs = {
        "train": ("train_step.hlo.txt", lambda: lower(model.flat_train_step, "train")),
        "eval": ("eval_batch.hlo.txt", lambda: lower(model.flat_eval_batch, "eval")),
        "predict": ("predict.hlo.txt", lambda: lower(model.flat_predict, "predict")),
        "gemm": ("kernel_gemm.hlo.txt", gemm_example),
    }
    for key, (fname, thunk) in jobs.items():
        if args.only and key != args.only:
            continue
        text = thunk()
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")
    if not args.only:
        write_manifest(args.out_dir)
        print(f"wrote {os.path.join(args.out_dir, 'manifest.json')} + params_init.bin")


if __name__ == "__main__":
    main()
