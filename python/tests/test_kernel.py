"""L1 kernel correctness: Pallas vs pure-jnp oracle (the CORE signal)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as k
from compile.kernels import ref


def _rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("m,kk,n,bm,bn", [
    (128, 64, 32, 64, 16),
    (128, 27, 16, 128, 16),
    (64, 16, 16, 32, 8),
    (256, 9, 64, 128, 16),
    (32, 144, 32, 16, 16),
])
@pytest.mark.parametrize("relu", [True, False])
def test_matmul_scale_shift_matches_ref(m, kk, n, bm, bn, relu):
    rng = np.random.default_rng(m + kk + n)
    x, w = _rand(rng, m, kk), _rand(rng, kk, n)
    s, b = _rand(rng, n), _rand(rng, n)
    out = k.matmul_scale_shift(x, w, s, b, relu, bm, bn)
    want = ref.matmul_scale_shift_ref(x, w, s, b, relu=relu)
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)


def test_matmul_pallas_pads_non_aligned():
    rng = np.random.default_rng(7)
    a, b = _rand(rng, 100, 30), _rand(rng, 30, 20)
    out = k.matmul_pallas(a, b, block_m=64, block_n=16)
    np.testing.assert_allclose(out, a @ b, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("stride,pad,kh", [(1, 1, 3), (2, 1, 3), (1, 0, 1), (2, 0, 1)])
def test_conv2d_bn_act_matches_lax_conv(stride, pad, kh):
    rng = np.random.default_rng(stride * 10 + kh)
    x = _rand(rng, 2, 16, 16, 8)
    w = _rand(rng, kh, kh, 8, 16)
    s, b = _rand(rng, 16), _rand(rng, 16)
    out = k.conv2d_bn_act(x, w, s, b, stride=stride, padding=pad)
    want = ref.conv2d_bn_act_ref(x, w, s, b, stride=stride, padding=pad)
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)


def test_conv2d_no_relu():
    rng = np.random.default_rng(3)
    x = _rand(rng, 1, 8, 8, 4)
    w = _rand(rng, 3, 3, 4, 16)
    s, b = _rand(rng, 16), _rand(rng, 16)
    out = k.conv2d_bn_act(x, w, s, b, relu=False)
    want = ref.conv2d_bn_act_ref(x, w, s, b, relu=False)
    assert (np.asarray(out) < 0).any(), "no-relu output should have negatives"
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)


def test_custom_vjp_matches_ref_grads():
    rng = np.random.default_rng(11)
    x, w = _rand(rng, 64, 32), _rand(rng, 32, 16)
    s, b = _rand(rng, 16), _rand(rng, 16)

    def f_pal(x, w, s, b):
        return jnp.sum(k.matmul_scale_shift(x, w, s, b, True, 32, 16) ** 2)

    def f_ref(x, w, s, b):
        return jnp.sum(ref.matmul_scale_shift_ref(x, w, s, b) ** 2)

    got = jax.grad(f_pal, argnums=(0, 1, 2, 3))(x, w, s, b)
    want = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, w, s, b)
    for g1, g2 in zip(got, want):
        np.testing.assert_allclose(g1, g2, rtol=5e-3, atol=5e-3)


def test_im2col_shapes():
    rng = np.random.default_rng(0)
    x = _rand(rng, 2, 8, 8, 3)
    cols, (n, oh, ow) = k.im2col(x, 3, 3, 1, 1)
    assert cols.shape == (2 * 8 * 8, 27) and (n, oh, ow) == (2, 8, 8)
    cols, (n, oh, ow) = k.im2col(x, 3, 3, 2, 1)
    assert cols.shape == (2 * 4 * 4, 27) and (n, oh, ow) == (2, 4, 4)


def test_pick_block():
    assert k._pick_block(512, 128) == 128
    assert k._pick_block(48, 128) == 16
    assert k._pick_block(10, 16) == 2
    assert k._pick_block(7, 16) == 1


# -- hypothesis sweep over shapes/blocks: the pruner explores many channel
#    counts; the kernel must agree with the oracle on all of them. ----------
@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([16, 32, 64, 128]),
    kk=st.integers(1, 96),
    n=st.sampled_from([8, 16, 32, 48, 64]),
    bm=st.sampled_from([8, 16, 32, 64]),
    bn=st.sampled_from([8, 16]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis(m, kk, n, bm, bn, relu, seed):
    if m % bm or n % bn:
        bm, bn = k._pick_block(m, bm), k._pick_block(n, bn)
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, m, kk), _rand(rng, kk, n)
    s, b = _rand(rng, n), _rand(rng, n)
    out = k.matmul_scale_shift(x, w, s, b, relu, bm, bn)
    want = ref.matmul_scale_shift_ref(x, w, s, b, relu=relu)
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(
    hw=st.sampled_from([4, 8, 12]),
    cin=st.sampled_from([3, 4, 8]),
    cout=st.sampled_from([8, 16, 32]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_hypothesis(hw, cin, cout, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, 1, hw, hw, cin)
    w = _rand(rng, 3, 3, cin, cout)
    s, b = _rand(rng, cout), _rand(rng, cout)
    out = k.conv2d_bn_act(x, w, s, b, stride=stride, padding=1)
    want = ref.conv2d_bn_act_ref(x, w, s, b, stride=stride, padding=1)
    np.testing.assert_allclose(out, want, rtol=2e-3, atol=2e-3)
