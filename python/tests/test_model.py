"""L2 model invariants: shapes, masking semantics, training signal."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(0)
    masks = {n: jnp.ones(s, jnp.float32) for n, s in model.mask_specs()}
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 32, 32, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=(8,)).astype(np.int32))
    return params, masks, x, y


def test_forward_shape(setup):
    params, masks, x, _ = setup
    logits = model.forward(params, masks, x)
    assert logits.shape == (8, model.NUM_CLASSES)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_specs_cover_all_convs():
    names = {n for n, _ in model.param_specs()}
    for cname, *_ in model.CONV_SPECS:
        assert {f"{cname}.w", f"{cname}.scale", f"{cname}.shift"} <= names
    assert "fc.w" in names and "fc.b" in names


def test_masking_zeroes_channels(setup):
    """A masked-out stem channel must be exactly zero after the epilogue."""
    params, masks, x, _ = setup
    m = dict(masks)
    mm = np.ones(16, np.float32); mm[3] = 0.0; mm[7] = 0.0
    m["stem.mask"] = jnp.asarray(mm)
    spec = {s[0]: s for s in model.CONV_SPECS}
    _, kh, kw, cin, cout, stride, relu = spec["stem"]
    h = model._conv(params, m, x, "stem", kh, kw, cin, cout, stride, relu)
    h = np.asarray(h)
    assert np.all(h[..., 3] == 0.0) and np.all(h[..., 7] == 0.0)
    assert np.any(h[..., 0] != 0.0)


def test_full_mask_equals_unmasked_forward(setup):
    params, masks, x, _ = setup
    logits1 = model.forward(params, masks, x)
    logits2 = model.forward(params, {k: v * 1.0 for k, v in masks.items()}, x)
    np.testing.assert_allclose(logits1, logits2, rtol=1e-6)


def test_train_step_reduces_loss_on_fixed_batch(setup):
    params, masks, x, y = setup
    mom = {n: jnp.zeros_like(v) for n, v in params.items()}
    lr = jnp.float32(0.05)
    losses = []
    p, m = params, mom
    for _ in range(5):
        p, m, loss = model.train_step(p, m, masks, x, y, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"


def test_train_step_respects_masks(setup):
    """A masked channel stays exactly zero after a training step."""
    params, masks, x, y = setup
    m = dict(masks)
    mm = np.ones(16, np.float32); mm[0] = 0.0
    m["b1c1.mask"] = jnp.asarray(mm)
    mom = {n: jnp.zeros_like(v) for n, v in params.items()}
    p2, _, _ = model.train_step(params, mom, m, x, y, jnp.float32(0.1))
    h = model._conv(p2, m, model._conv(p2, m, x, "stem", 3, 3, 3, 16, 1, True),
                    "b1c1", 3, 3, 16, 16, 1, True)
    assert np.all(np.asarray(h)[..., 0] == 0.0)


def test_eval_batch(setup):
    params, masks, x, y = setup
    correct, loss = model.eval_batch(params, masks, x, y)
    assert 0.0 <= float(correct) <= x.shape[0]
    assert np.isfinite(float(loss))


def test_flat_wrappers_roundtrip(setup):
    params, masks, x, y = setup
    pnames = [n for n, _ in model.param_specs()]
    mnames = [n for n, _ in model.mask_specs()]
    mom = {n: jnp.zeros_like(params[n]) for n in pnames}
    args = ([params[n] for n in pnames] + [mom[n] for n in pnames]
            + [masks[n] for n in mnames] + [x, y, jnp.float32(0.1)])
    out = model.flat_train_step(*args)
    assert len(out) == 2 * len(pnames) + 1
    d_params, d_mom, d_loss = model.train_step(params, mom, masks, x, y, jnp.float32(0.1))
    np.testing.assert_allclose(out[-1], d_loss, rtol=1e-6)
    np.testing.assert_allclose(out[0], d_params[pnames[0]], rtol=1e-6)

    eargs = [params[n] for n in pnames] + [masks[n] for n in mnames] + [x, y]
    correct, loss = model.flat_eval_batch(*eargs)
    c2, l2 = model.eval_batch(params, masks, x, y)
    np.testing.assert_allclose(correct, c2)

    pargs = [params[n] for n in pnames] + [masks[n] for n in mnames] + [x[:1]]
    (logits,) = model.flat_predict(*pargs)
    np.testing.assert_allclose(logits, model.forward(params, masks, x[:1]), rtol=1e-6)
