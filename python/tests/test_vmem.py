"""TPU resource-model sanity: VMEM accounting and MXU occupancy."""

from compile.kernels import vmem


def test_small_tile_fits_vmem():
    e = vmem.estimate(128, 1152, 128)
    assert e.vmem_ok
    # 2*(128*1152 + 1152*128 + 2*128)*4 + 128*128*4 bytes
    expected = 2 * (128 * 1152 + 1152 * 128 + 2 * 128) * 4 + 128 * 128 * 4
    assert e.vmem_bytes == expected


def test_huge_tile_overflows_vmem():
    e = vmem.estimate(4096, 4096, 512)
    assert not e.vmem_ok


def test_mxu_full_alignment_is_1():
    e = vmem.estimate(128, 128, 128)
    assert abs(e.mxu_utilization - 1.0) < 1e-9
    e = vmem.estimate(256, 384, 128)
    assert abs(e.mxu_utilization - 1.0) < 1e-9


def test_mxu_misaligned_fraction():
    # 64 of 128 lanes busy in one pass on each misaligned dim
    e = vmem.estimate(64, 128, 128)
    assert abs(e.mxu_utilization - 0.5) < 1e-9
    e = vmem.estimate(192, 128, 128)  # 192 = 1.5 passes worth in 2 passes
    assert abs(e.mxu_utilization - 0.75) < 1e-9


def test_best_blocks_prefers_aligned():
    e = vmem.best_tpu_blocks(32 * 32 * 32, 27, 16)
    assert e.vmem_ok
    assert e.block_m % 128 == 0 or e.block_m == 32 * 32 * 32


def test_model_report_runs():
    lines = vmem.report_model_convs()
    assert len(lines) == 9
    assert all("MXU util" in l for l in lines)
