//! Supplementary ablation regenerator: α/β grid (the paper's supplementary
//! "finding reasonable α and β"). Run: cargo bench --bench ablation_alpha_beta

use cprune::exp::{ablation_alpha_beta, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let cells = ablation_alpha_beta::run(Scale::Full, 42);
    let rows: Vec<Vec<String>> = cells
        .iter()
        .map(|c| {
            vec![
                format!("{:.3}", c.alpha),
                format!("{:.3}", c.beta),
                format!("{:.2}x", c.fps_rate),
                format!("{:.2}%", c.final_top1 * 100.0),
                format!("{}", c.iterations),
                format!("{}", c.candidates),
            ]
        })
        .collect();
    print_table(
        "Supplementary — alpha/beta sweep (ResNet-18, Kryo 585, CIFAR-10)",
        &["alpha", "beta", "FPS rate", "top-1", "iterations", "candidates"],
        &rows,
    );
    println!("BENCH ablation_alpha_beta_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
