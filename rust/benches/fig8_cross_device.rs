//! Fig. 8 regenerator: CPrune model executed on its target processor vs
//! other processors. Run: cargo bench --bench fig8_cross_device

use cprune::exp::{fig8, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let rows = fig8::run(Scale::Full, 42);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.tuned_for.to_string(),
                r.run_on.to_string(),
                format!("{:.1}", r.fps),
                format!("{:.2}", r.relative_to_native),
            ]
        })
        .collect();
    print_table(
        "Fig.8 — MobileNetV2 CPrune model: tuned-for vs run-on (relative to native)",
        &["tuned for", "run on", "FPS", "vs native"],
        &table,
    );
    println!("BENCH fig8_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
