//! Fig. 6 regenerator: FPS increase rate + short-term accuracy per CPrune
//! iteration (ResNet-18/ImageNet-scale, Kryo 385).
//! Run: cargo bench --bench fig6_iterations

use cprune::exp::{fig6, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let r = fig6::run(Scale::Full, 42);
    let rows: Vec<Vec<String>> = r
        .series
        .iter()
        .map(|(it, rate, acc)| {
            vec![format!("{it}"), format!("{rate:.2}x"), format!("{:.2}%", acc * 100.0)]
        })
        .collect();
    print_table(
        "Fig.6 — CPrune iterations (ResNet-18, Kryo 385): FPS rate & short-term top-1",
        &["iteration", "FPS increase rate", "short-term top-1"],
        &rows,
    );
    println!(
        "\nfinal: {:.2}x FPS rate (paper: 1.96x), final top-1 {:.2}% / top-5 {:.2}% (paper: 88.34% top-5)",
        r.outcome.fps_increase_rate,
        r.outcome.top1 * 100.0,
        r.outcome.top5 * 100.0
    );
    println!("BENCH fig6_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
