//! Fig. 1 regenerator: 20 random-pruned VGG-16/CIFAR-10 variants on the
//! host GPU; FPS before vs after compiler optimization + correlation.
//! Run: cargo bench --bench fig1_pruning_vs_compile

use cprune::exp::{fig1, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let r = fig1::run(Scale::Full, 20, 42);
    let rows: Vec<Vec<String>> = r
        .rows
        .iter()
        .map(|v| {
            vec![
                format!("{}", v.id),
                format!("{:.2}%", v.top1 * 100.0),
                format!("{:.0}", v.fps_before),
                format!("{:.0}", v.fps_after),
                if v.meets_gate { "yes".into() } else { "no".into() },
                if v.id == r.best_before { "A (best before)".into() }
                else if v.id == r.best_after { "B (best after)".into() }
                else { String::new() },
            ]
        })
        .collect();
    print_table(
        "Fig.1 — random-pruned VGG-16/CIFAR-10, before vs after compiler optimization (RTX-class host)",
        &["variant", "top-1", "FPS before", "FPS after", ">=92.80%", "marker"],
        &rows,
    );
    println!(
        "\nbest-before = variant {}, best-after = variant {} ({})",
        r.best_before,
        r.best_after,
        if r.best_before == r.best_after { "SAME — unexpected" } else { "DIFFERENT — paper's claim holds" }
    );
    println!("pearson r = {:.3}, spearman rho = {:.3} (paper: no strong correlation)", r.pearson_r, r.spearman_rho);
    println!("BENCH fig1_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
