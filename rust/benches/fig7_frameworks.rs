//! Fig. 7 regenerator: CPrune+TVM vs TVM vs TFLite-like library FPS across
//! models and devices. Run: cargo bench --bench fig7_frameworks

use cprune::exp::{fig7, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let rows = fig7::run(Scale::Full, 42);
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.to_string(),
                r.device.to_string(),
                format!("{:.1}", r.fps_tflite),
                format!("{:.1}", r.fps_tvm),
                format!("{:.1}", r.fps_cprune),
                format!("{:.2}x", r.fps_cprune / r.fps_tvm),
            ]
        })
        .collect();
    print_table(
        "Fig.7 — FPS by framework (library default vs TVM auto-tune vs CPrune)",
        &["model", "device", "TFLite-like", "TVM", "CPrune", "CPrune/TVM"],
        &table,
    );
    for r in &rows {
        assert!(r.fps_cprune > r.fps_tflite, "CPrune must beat the library path");
    }
    println!("BENCH fig7_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
