//! Fleet compilation bench: tune MobileNetV2 for every mobile target in
//! one FleetSession (pilot-seeded), then repeat warm to show the
//! persistent cache's programs-measured savings. The device set and model
//! come from the perf harness (DESIGN.md §10), so this bench and
//! `cprune bench --tier full`'s BENCH_tuner.json measure the same fleet
//! workload.
//! Run: cargo bench --bench fleet_tuning

use cprune::graph::model_zoo::Model;
use cprune::perf::{fleet_devices, fleet_model, Tier};
use cprune::tuner::{FleetDeviceResult, FleetOptions, FleetResult, FleetSession, TuneOptions};
use cprune::util::bench::print_table;
use std::time::Instant;

fn device_rows(r: &FleetResult) -> Vec<Vec<String>> {
    r.devices.iter().map(|d| d.table_row()).collect()
}

fn main() {
    let model = Model::build(fleet_model(Tier::Full), 42);
    let mut fleet = FleetSession::new(
        fleet_devices(Tier::Full),
        FleetOptions { tune: TuneOptions::default(), ..Default::default() },
        42,
    );

    let t0 = Instant::now();
    let cold = fleet.tune_graph(&model.graph);
    let cold_s = t0.elapsed().as_secs_f64();
    print_table(
        "Fleet tuning — MobileNetV2, cold (pilot-seeded cross-device search)",
        &FleetDeviceResult::TABLE_HEADERS,
        &device_rows(&cold),
    );

    let t1 = Instant::now();
    let warm = fleet.tune_graph(&model.graph);
    let warm_s = t1.elapsed().as_secs_f64();
    print_table(
        "Fleet tuning — MobileNetV2, warm (persistent per-device caches)",
        &FleetDeviceResult::TABLE_HEADERS,
        &device_rows(&warm),
    );

    let saved_pct = if cold.total_measured() > 0 {
        100.0 * (1.0 - warm.total_measured() as f64 / cold.total_measured() as f64)
    } else {
        0.0
    };
    println!(
        "\ncold: {} programs measured in {:.1}s | warm: {} measured in {:.2}s \
         ({:.0}% hit rate, {} measurements avoided, {:.1}% saved)",
        cold.total_measured(),
        cold_s,
        warm.total_measured(),
        warm_s,
        warm.hit_rate() * 100.0,
        warm.total_measured_saved(),
        saved_pct
    );
    println!("BENCH fleet_cold_seconds {cold_s:.2}");
    println!("BENCH fleet_warm_seconds {warm_s:.2}");
    println!("BENCH fleet_measured_saved_pct {saved_pct:.1}");
}
