//! L3 microbenchmarks: tuner throughput, simulator latency-model speed,
//! partition + task extraction, tuned compile on the small model.
//! These are the §Perf hot paths (DESIGN.md §10); the same workloads run
//! under `cprune bench` into BENCH_tuner.json, so numbers here line up
//! with the recorded perf trajectory.
//! Run: cargo bench --bench tuner_micro

use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::perf::hot_conv_workload;
use cprune::relay::partition::extract_tasks;
use cprune::tir::Program;
use cprune::tuner::search::tune_task_reference;
use cprune::tuner::{tune_task, TuneOptions, TuningSession};
use cprune::util::bench::{bench_auto, print_table};
use cprune::util::rng::Rng;
use std::collections::HashMap;

fn main() {
    let w = hot_conv_workload();
    let sim = Simulator::new(DeviceSpec::kryo385());

    let mut rng = Rng::new(0);
    let progs: Vec<Program> = (0..256).map(|_| Program::sample(&w, &mut rng)).collect();
    let mut i = 0;
    let r = bench_auto("sim_latency_single_call", 400, || {
        i = (i + 1) % progs.len();
        std::hint::black_box(sim.latency(&w, &progs[i]));
    });
    r.report();
    println!("  -> {:.0} latency-model evaluations / second", 1e9 / r.median_ns);

    let mut seed = 0u64;
    let r = bench_auto("tune_task_quick", 3000, || {
        seed += 1;
        let mut rng = Rng::new(seed);
        std::hint::black_box(tune_task(&w, &sim, &TuneOptions::quick(), &mut rng, None));
    });
    r.report();

    // The pre-optimization search (comparator-time scoring, full-history
    // re-sort, allocation-per-program evolution) on identical seeds: the
    // reported ratio is the hot-loop speedup the optimized path buys.
    let mut seed_ref = 0u64;
    let r_ref = bench_auto("tune_task_quick_reference", 3000, || {
        seed_ref += 1;
        let mut rng = Rng::new(seed_ref);
        std::hint::black_box(tune_task_reference(&w, &sim, &TuneOptions::quick(), &mut rng, None));
    });
    r_ref.report();
    println!("BENCH tune_task_speedup_vs_reference {:.2}", r_ref.median_ns / r.median_ns);

    let m = Model::build(ModelKind::ResNet18ImageNet, 0);
    let r = bench_auto("partition_resnet18", 2000, || {
        std::hint::black_box(extract_tasks(&m.graph));
    });
    r.report();

    let small = Model::build(ModelKind::ResNet8Cifar, 0);
    let r = bench_auto("compile_tuned_resnet8_fresh_session", 3000, || {
        let session = TuningSession::new(&sim, TuneOptions::quick(), 7);
        std::hint::black_box(cprune::compiler::compile_tuned(&small.graph, &session, &HashMap::new()));
    });
    r.report();

    print_table("tuner_micro complete", &["metric"], &[vec!["see BENCH lines".into()]]);
}
