//! L3 microbenchmarks: tuner throughput, simulator latency-model speed,
//! partition + task extraction, tuned compile on the small model.
//! These are the §Perf hot paths. Run: cargo bench --bench tuner_micro

use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::graph::ops::OpKind;
use cprune::relay::partition::extract_tasks;
use cprune::tir::{Program, Workload};
use cprune::tuner::{tune_task, TuneOptions, TuningSession};
use cprune::util::bench::{bench_auto, print_table};
use cprune::util::rng::Rng;
use std::collections::HashMap;

fn main() {
    let w = Workload::from_conv(
        &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: 256, stride: 1, padding: 1, groups: 1 },
        [1, 28, 28, 256],
        vec!["bn", "relu"],
    );
    let sim = Simulator::new(DeviceSpec::kryo385());

    let mut rng = Rng::new(0);
    let progs: Vec<Program> = (0..256).map(|_| Program::sample(&w, &mut rng)).collect();
    let mut i = 0;
    let r = bench_auto("sim_latency_single_call", 400, || {
        i = (i + 1) % progs.len();
        std::hint::black_box(sim.latency(&w, &progs[i]));
    });
    r.report();
    println!("  -> {:.0} latency-model evaluations / second", 1e9 / r.median_ns);

    let mut seed = 0u64;
    let r = bench_auto("tune_task_quick", 3000, || {
        seed += 1;
        let mut rng = Rng::new(seed);
        std::hint::black_box(tune_task(&w, &sim, &TuneOptions::quick(), &mut rng, None));
    });
    r.report();

    let m = Model::build(ModelKind::ResNet18ImageNet, 0);
    let r = bench_auto("partition_resnet18", 2000, || {
        std::hint::black_box(extract_tasks(&m.graph));
    });
    r.report();

    let small = Model::build(ModelKind::ResNet8Cifar, 0);
    let r = bench_auto("compile_tuned_resnet8_fresh_session", 3000, || {
        let session = TuningSession::new(&sim, TuneOptions::quick(), 7);
        std::hint::black_box(cprune::compiler::compile_tuned(&small.graph, &session, &HashMap::new()));
    });
    r.report();

    print_table("tuner_micro complete", &["metric"], &[vec!["see BENCH lines".into()]]);
}
