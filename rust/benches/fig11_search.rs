//! Fig. 11 regenerator: selective (CPrune) vs exhaustive (NetAdapt-style)
//! search cost. Run: cargo bench --bench fig11_search

use cprune::exp::{fig11, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let r = fig11::run(Scale::Full, 42);
    print_table(
        "Fig.11 — selective vs exhaustive search (ResNet-18, Kryo 585)",
        &["search", "FPS", "candidates", "main-step seconds"],
        &[
            vec![
                "CPrune (selective)".into(),
                format!("{:.1}", r.cprune_fps),
                format!("{}", r.cprune_candidates),
                format!("{:.1}", r.cprune_seconds),
            ],
            vec![
                "Exhaustive (NetAdapt-style)".into(),
                format!("{:.1}", r.exhaustive_fps),
                format!("{}", r.exhaustive_candidates),
                format!("{:.1}", r.exhaustive_seconds),
            ],
        ],
    );
    println!(
        "\nselective cost = {:.0}% of exhaustive (paper: ~10%)",
        100.0 * r.cprune_candidates as f64 / r.exhaustive_candidates.max(1) as f64
    );
    println!("BENCH fig11_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
