//! Table 1 regenerator: method comparison (Original/PQF/FPGM/NetAdapt/
//! AMC/CPrune) per model x device. Run: cargo bench --bench table1_methods

use cprune::exp::{table1, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    for (kind, spec) in table1::paper_cells() {
        let block = table1::run_cell(kind, spec, Scale::Full, 42);
        let rows: Vec<Vec<String>> = block
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.method.clone(),
                    format!("{:.2} ({:.2}x)", r.fps, r.fps_increase_rate),
                    format!("{:.0}M", r.macs as f64 / 1e6),
                    format!("{:.2}M", r.params as f64 / 1e6),
                    format!("{:.2}%", r.top1 * 100.0),
                    format!("{:.2}%", r.top5 * 100.0),
                ]
            })
            .collect();
        print_table(
            &format!("Table 1 — {} on {}", block.model, block.device),
            &["method", "FPS (rate)", "MACs", "params", "top-1", "top-5"],
            &rows,
        );
    }
    println!("BENCH table1_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
