//! Fig. 9 regenerator: associated-subgraphs vs single-subgraph pruning —
//! main-step time + FPS + accuracy. Run: cargo bench --bench fig9_associated

use cprune::exp::{fig9_10, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let rows = fig9_10::run(Scale::Full, 42);
    let table: Vec<Vec<String>> = rows
        .iter()
        .filter(|r| !r.variant.contains("tuning"))
        .map(|r| {
            vec![
                r.variant.to_string(),
                format!("{:.1}", r.fps),
                format!("{:.2}x", r.fps_increase_rate),
                format!("{:.2}%", r.top1 * 100.0),
                format!("{:.1}s", r.main_step_seconds),
                format!("{}", r.candidates_tried),
            ]
        })
        .collect();
    print_table(
        "Fig.9 — associated vs single-subgraph pruning (ResNet-18, Kryo 585, CIFAR-10)",
        &["variant", "FPS", "rate", "top-1", "main-step time", "candidates"],
        &table,
    );
    println!("BENCH fig9_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
