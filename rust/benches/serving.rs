//! Serving-layer bench: regenerate the throughput-vs-SLO table from
//! per-device CPrune Pareto frontiers, and time the simulator itself.
//! Run: cargo bench --bench serving

use cprune::exp::{serving, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    let rows = serving::run(Scale::Full, 42);
    let total_s = t0.elapsed().as_secs_f64();

    print_table(
        "Serving — ResNet-8 fleet, throughput vs. SLO (Pareto-frontier policy)",
        &serving::ServingRow::TABLE_HEADERS,
        &rows.iter().map(|r| r.table_row()).collect::<Vec<_>>(),
    );

    // Grepable summary: the tightest-SLO / highest-load corner and the
    // best sustained throughput across the sweep.
    let peak = rows
        .iter()
        .max_by(|a, b| a.throughput_rps.total_cmp(&b.throughput_rps))
        .expect("sweep is non-empty");
    let worst = rows
        .iter()
        .max_by(|a, b| a.violation_rate.total_cmp(&b.violation_rate))
        .expect("sweep is non-empty");
    println!("\nBENCH serving_peak_throughput_rps {:.1}", peak.throughput_rps);
    println!("BENCH serving_peak_p99_ms {:.2}", peak.p99_ms);
    println!("BENCH serving_worst_violation_pct {:.2}", worst.violation_rate * 100.0);
    println!("BENCH serving_sweep_seconds {total_s:.2}");
}
