//! Table 2 regenerator: ResNet-18/CIFAR-10 on Kryo 280 & 585 with CPrune
//! ablations. Run: cargo bench --bench table2_cifar

use cprune::exp::{table2, Scale};
use cprune::util::bench::print_table;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    for block in table2::run(Scale::Full, 42) {
        let rows: Vec<Vec<String>> = block
            .rows
            .iter()
            .map(|r| {
                vec![
                    r.method.clone(),
                    format!("{:.2} ({:.2}x)", r.fps, r.fps_increase_rate),
                    format!("{:.0}M", r.macs as f64 / 1e6),
                    format!("{:.2}M", r.params as f64 / 1e6),
                    format!("{:.2}%", r.top1 * 100.0),
                ]
            })
            .collect();
        print_table(
            &format!("Table 2 — ResNet-18/CIFAR-10 on {}", block.device),
            &["method", "FPS (rate)", "MACs", "params", "top-1"],
            &rows,
        );
    }
    println!("BENCH table2_total_seconds {:.1}", t0.elapsed().as_secs_f64());
}
