//! Fixture tests pinning every rule's positive (fails) and
//! suppressed-negative (annotated-allowed passes) behavior, plus the
//! deny-by-default sweep: the real workspace must be clean.
//!
//! Fixtures live under `tests/fixtures/` — a directory the workspace
//! walker skips — and are checked here under synthetic workspace paths
//! so path-scoped rules see the scope they police.

use cprune_lint::rules::check_source;
use std::path::Path;

/// Library-code scope (CPL002 iteration, CPL005).
const LIB: &str = "rust/src/fixture.rs";
/// Deterministic-module scope (CPL003, CPL004).
const DET: &str = "rust/src/tuner/fixture.rs";
/// Neither scope: only the global rules apply.
const BENCH: &str = "rust/benches/fixture.rs";
/// Deterministic scope carrying the CPL003 clock carve-out (the remote
/// measurement plane's IO edge, DESIGN.md §14).
const REMOTE: &str = "rust/src/device/remote/fixture.rs";

fn ids(path: &str, src: &str) -> Vec<&'static str> {
    check_source(path, src).iter().map(|d| d.rule.id()).collect()
}

#[test]
fn cpl000_malformed_annotation_is_reported() {
    assert_eq!(ids(LIB, include_str!("fixtures/cpl000_malformed.rs")), ["CPL000"]);
}

#[test]
fn cpl000_unknown_rule_is_reported() {
    assert_eq!(ids(LIB, include_str!("fixtures/cpl000_unknown_rule.rs")), ["CPL000"]);
}

#[test]
fn cpl001_partial_cmp_unwrap() {
    // BENCH scope so the companion `.unwrap()` finding (CPL005, library
    // scope only) stays out of the way — CPL001 itself is global.
    assert_eq!(ids(BENCH, include_str!("fixtures/cpl001_fail.rs")), ["CPL001"]);
    assert_eq!(ids(BENCH, include_str!("fixtures/cpl001_allowed.rs")), Vec::<&str>::new());
}

#[test]
fn cpl002_hash_iteration() {
    assert_eq!(ids(LIB, include_str!("fixtures/cpl002_fail.rs")), ["CPL002"]);
    assert_eq!(ids(LIB, include_str!("fixtures/cpl002_allowed.rs")), Vec::<&str>::new());
}

#[test]
fn cpl003_wall_clock() {
    assert_eq!(ids(DET, include_str!("fixtures/cpl003_fail.rs")), ["CPL003"]);
    assert_eq!(ids(DET, include_str!("fixtures/cpl003_allowed.rs")), Vec::<&str>::new());
    // Outside the deterministic modules the same source is fine.
    assert_eq!(ids(BENCH, include_str!("fixtures/cpl003_fail.rs")), Vec::<&str>::new());
}

#[test]
fn cpl003_clock_carve_out_is_scoped_to_the_remote_plane() {
    // `rust/src/device/remote/` is the remote plane's IO edge: its
    // deadline/backoff `Instant` reads are the one documented CPL003
    // clock exemption (DESIGN.md §14).
    assert_eq!(ids(REMOTE, include_str!("fixtures/cpl003_fail.rs")), Vec::<&str>::new());
    // The carve-out is surgical: environment reads (CPL003's other
    // arm) and the float rules still apply under the exempt prefix.
    assert_eq!(ids(REMOTE, include_str!("fixtures/cpl003_env_fail.rs")), ["CPL003"]);
    assert_eq!(ids(REMOTE, include_str!("fixtures/cpl004_fail.rs")), ["CPL004"]);
    // And elsewhere in the device layer the clock arm still fires.
    let det_device = "rust/src/device/fixture.rs";
    assert_eq!(ids(det_device, include_str!("fixtures/cpl003_fail.rs")), ["CPL003"]);
}

#[test]
fn cpl004_f32_in_measurement_path() {
    assert_eq!(ids(DET, include_str!("fixtures/cpl004_fail.rs")), ["CPL004"]);
    assert_eq!(ids(DET, include_str!("fixtures/cpl004_allowed.rs")), Vec::<&str>::new());
    assert_eq!(ids(LIB, include_str!("fixtures/cpl004_fail.rs")), Vec::<&str>::new());
}

#[test]
fn sparsity_cost_joins_the_deterministic_scope() {
    // The masked-latency pricer is measurement-plane code; the f32
    // weight-scoring modules next to it (pattern/block selection over
    // synthetic f32 weights) deliberately are not.
    let cost = "rust/src/sparsity/cost.rs";
    assert_eq!(ids(cost, include_str!("fixtures/cpl004_fail.rs")), ["CPL004"]);
    assert_eq!(ids(cost, include_str!("fixtures/cpl003_fail.rs")), ["CPL003"]);
    let pattern = "rust/src/sparsity/pattern.rs";
    assert_eq!(ids(pattern, include_str!("fixtures/cpl004_fail.rs")), Vec::<&str>::new());
}

#[test]
fn cpl005_library_unwrap() {
    assert_eq!(ids(LIB, include_str!("fixtures/cpl005_fail.rs")), ["CPL005"]);
    assert_eq!(ids(LIB, include_str!("fixtures/cpl005_allowed.rs")), Vec::<&str>::new());
    // Bins and benches may unwrap freely.
    let bin = "rust/src/main.rs";
    assert_eq!(ids(bin, include_str!("fixtures/cpl005_fail.rs")), Vec::<&str>::new());
    assert_eq!(ids(BENCH, include_str!("fixtures/cpl005_fail.rs")), Vec::<&str>::new());
}

#[test]
fn cpl006_lossy_casts() {
    // `seconds as f32` is both a lossy cast and an f32 type use, so the
    // middle line carries CPL004 and CPL006 together.
    assert_eq!(
        ids(DET, include_str!("fixtures/cpl006_fail.rs")),
        ["CPL006", "CPL004", "CPL006", "CPL006"]
    );
    assert_eq!(ids(DET, include_str!("fixtures/cpl006_allowed.rs")), Vec::<&str>::new());
    // Outside the deterministic modules lossy casts are not policed.
    assert_eq!(ids(LIB, include_str!("fixtures/cpl006_fail.rs")), Vec::<&str>::new());
}

#[test]
fn cpl007_direct_writes() {
    assert_eq!(
        ids(LIB, include_str!("fixtures/cpl007_fail.rs")),
        ["CPL007", "CPL007"]
    );
    assert_eq!(ids(LIB, include_str!("fixtures/cpl007_allowed.rs")), Vec::<&str>::new());
    // The atomic-write seam itself is the one sanctioned caller, and
    // bins, benches and integration tests may write files directly.
    let seam = "rust/src/util/io.rs";
    assert_eq!(ids(seam, include_str!("fixtures/cpl007_fail.rs")), Vec::<&str>::new());
    let bin = "rust/src/main.rs";
    assert_eq!(ids(bin, include_str!("fixtures/cpl007_fail.rs")), Vec::<&str>::new());
    assert_eq!(ids(BENCH, include_str!("fixtures/cpl007_fail.rs")), Vec::<&str>::new());
}

#[test]
fn workspace_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let diags = cprune_lint::check_workspace(&root).expect("workspace walk failed");
    let rendered: Vec<String> = diags
        .iter()
        .map(|(p, d)| format!("{p}:{}: {}: {}", d.line, d.rule.id(), d.message))
        .collect();
    assert!(
        diags.is_empty(),
        "cprune-lint must run clean over the workspace; found:\n{}",
        rendered.join("\n")
    );
}
