pub fn env_marker() -> Option<String> {
    std::env::var("CPRUNE_THREADS").ok()
}
