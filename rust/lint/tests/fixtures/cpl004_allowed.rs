// cprune-lint: allow(CPL004, reason="interop with an f32 on-disk format; widened immediately")
pub fn widen(x: f32) -> f64 {
    x as f64
}
