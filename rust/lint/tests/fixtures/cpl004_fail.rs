pub fn widen(x: f32) -> f64 {
    x as f64
}
