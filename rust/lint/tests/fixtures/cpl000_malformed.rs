// A typo'd allow (missing the reason) must surface as CPL000 — never be
// silently ignored, never suppress anything.
// cprune-lint: allow(CPL005)
pub fn f() {}
