pub fn now_marker() {
    let _ = std::time::Instant::now();
}
