// Well-formed grammar, but the rule ID does not exist: CPL000.
// cprune-lint: allow(CPL999, reason="no such rule")
pub fn f() {}
