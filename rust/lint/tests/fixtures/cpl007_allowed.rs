pub fn persist(path: &str, doc: &str) -> std::io::Result<()> {
    std::fs::write(path, doc) // cprune-lint: allow(CPL007, reason="escape hatch demo")
}

pub fn open_sink(path: &str) -> std::io::Result<std::fs::File> {
    std::fs::File::create(path) // cprune-lint: allow(CPL007, reason="escape hatch demo")
}
