use std::collections::HashMap;

pub fn dump_keys(m: &HashMap<String, u64>) -> Vec<String> {
    // cprune-lint: allow(CPL002, reason="caller sorts before the order can escape")
    m.keys().cloned().collect()
}
