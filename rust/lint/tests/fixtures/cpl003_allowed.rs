pub fn now_marker() {
    // cprune-lint: allow(CPL003, reason="wall-clock used for logging only, never measurement")
    let _ = std::time::Instant::now();
}
