pub fn persist(path: &str, doc: &str) -> std::io::Result<()> {
    std::fs::write(path, doc)
}

pub fn open_sink(path: &str) -> std::io::Result<std::fs::File> {
    std::fs::File::create(path)
}
