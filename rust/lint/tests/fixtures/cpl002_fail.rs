use std::collections::HashMap;

pub fn dump_keys(m: &HashMap<String, u64>) -> Vec<String> {
    m.keys().cloned().collect()
}
