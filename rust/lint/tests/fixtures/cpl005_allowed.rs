pub fn head(v: &[u64]) -> u64 {
    *v.first().unwrap() // cprune-lint: allow(CPL005, reason="callers guarantee non-empty input")
}
