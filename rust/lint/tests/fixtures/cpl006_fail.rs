pub fn truncate(latency: f64) -> usize {
    latency as usize
}

pub fn narrow(seconds: f64) -> f64 {
    let narrowed = seconds as f32;
    narrowed as f64
}

pub fn literal() -> u64 {
    1.5e3 as u64
}
