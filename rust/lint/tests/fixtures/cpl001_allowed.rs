pub fn sort_scores(v: &mut [f64]) {
    // cprune-lint: allow(CPL001, reason="inputs are clamped upstream; NaN is impossible")
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
