pub fn floor_bin(latency: f64) -> usize {
    // cprune-lint: allow(CPL006, reason="floor is the intended binning semantics")
    latency as usize
}
