//! The `cprune-lint` binary: walk a workspace, print diagnostics, exit
//! nonzero on any finding (deny-by-default — CI fails on exit status).
//!
//! Usage: `cprune-lint [ROOT]` (default `.`), or `cprune-lint --rules`
//! to list the rule IDs and what they enforce.

use cprune_lint::rules::Rule;
use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let arg = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    if arg == "--rules" {
        for rule in Rule::ALL {
            println!("{}  {}", rule.id(), rule.summary());
        }
        return ExitCode::SUCCESS;
    }
    if arg.starts_with('-') {
        eprintln!("usage: cprune-lint [ROOT] | cprune-lint --rules");
        return ExitCode::from(2);
    }
    match cprune_lint::check_workspace(Path::new(&arg)) {
        Ok(diags) if diags.is_empty() => {
            eprintln!("cprune-lint: workspace is clean");
            ExitCode::SUCCESS
        }
        Ok(diags) => {
            for (path, d) in &diags {
                println!("{path}:{}: {}: {}", d.line, d.rule.id(), d.message);
            }
            eprintln!("cprune-lint: {} diagnostic(s)", diags.len());
            ExitCode::FAILURE
        }
        Err(err) => {
            eprintln!("cprune-lint: error: {err}");
            ExitCode::from(2)
        }
    }
}
