//! `cprune-lint` — the workspace's in-tree determinism & float-safety
//! analysis pass (DESIGN.md §12 "Enforced invariants").
//!
//! CPrune's pruning decisions are only as trustworthy as the
//! bit-identical tuner/replay infrastructure underneath them, and the
//! project's worst historical bugs — NaN-panicking
//! `partial_cmp().unwrap()` sorts, `DefaultHasher` nondeterminism, `f32`
//! drift in the measurement noise path — were all invariant violations a
//! machine could have caught. This crate makes those invariants
//! machine-checked: a small hand-rolled lexer ([`lexer`]) feeds a set of
//! token-level rules ([`rules`]) with stable IDs (`CPL000`–`CPL007`),
//! `file:line` diagnostics and a per-site allow-annotation escape hatch.
//! CI runs the pass deny-by-default over the whole workspace.
//!
//! The pass is deliberately a *lint*, not a type checker: rules operate
//! on token patterns, scoped by path (library code vs. tests/bins,
//! deterministic modules vs. the rest). False positives are expected to
//! be rare and carry an annotation documenting why the flagged pattern
//! is safe; false negatives are accepted.

pub mod lexer;
pub mod rules;

use rules::Diagnostic;
use std::fs;
use std::path::{Path, PathBuf};

/// Directory names the workspace walker never descends into. `fixtures`
/// keeps the linter's own intentionally-failing test inputs out of the
/// deny-by-default sweep.
pub const SKIP_DIRS: [&str; 3] = ["target", ".git", "fixtures"];

/// Walk every `.rs` file under `root` (the workspace root) and run all
/// rules. Returns `(workspace-relative path, diagnostic)` pairs, sorted
/// by path then line, already filtered through allow-annotations.
pub fn check_workspace(root: &Path) -> Result<Vec<(String, Diagnostic)>, String> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in &files {
        let rel = relative_path(root, path)?;
        let src = fs::read_to_string(path)
            .map_err(|e| format!("failed to read {}: {e}", path.display()))?;
        for diag in rules::check_source(&rel, &src) {
            out.push((rel.clone(), diag));
        }
    }
    Ok(out)
}

/// Recursively gather `.rs` files, skipping [`SKIP_DIRS`] directories.
fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries = fs::read_dir(dir).map_err(|e| format!("failed to list {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("failed to list {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if !SKIP_DIRS.contains(&name.as_ref()) {
                collect_rs_files(&path, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// `path` relative to `root`, `/`-separated regardless of platform —
/// the form every rule's path scoping expects.
fn relative_path(root: &Path, path: &Path) -> Result<String, String> {
    let rel = path
        .strip_prefix(root)
        .map_err(|_| format!("{} is not under {}", path.display(), root.display()))?;
    let parts: Vec<String> =
        rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
    Ok(parts.join("/"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_paths_are_slash_separated() {
        let root = Path::new("/repo");
        let rel = relative_path(root, Path::new("/repo/rust/src/lib.rs")).unwrap();
        assert_eq!(rel, "rust/src/lib.rs");
        assert!(relative_path(root, Path::new("/elsewhere/x.rs")).is_err());
    }
}
