//! The rule set: each rule has a stable ID, a path scope, and a checker
//! over the token stream. DESIGN.md §12 documents the rationale (which
//! historical bug motivated each rule) and the allow-annotation grammar.
//!
//! Scoping vocabulary:
//!
//! * **library code** — `rust/src/**` and `rust/lint/src/**` minus
//!   `main.rs`, minus `#[cfg(test)]` / `#[test]` spans. Test code is
//!   allowed to unwrap; the binary may exit however it likes.
//! * **deterministic modules** — the measurement plane and everything
//!   that feeds it: `tuner/`, `device/`, `serve/`, `compiler/`. A wall
//!   clock, environment read or `f32` round-trip in these modules can
//!   silently change tuning decisions between two "identical" runs.
//! * **wall-clock exemption** — `device/remote/` is the remote plane's
//!   IO edge (DESIGN.md §14): it may read `Instant` for deadlines and
//!   retry backoff, because timeouts only decide *which worker* computes
//!   a value, never the value itself (jitter is RNG-drawn client-side
//!   and results reassemble by batch index). Only the `Instant`/
//!   `SystemTime` arm of CPL003 is exempt there — environment reads,
//!   `f32` and lossy casts stay policed.

use crate::lexer::{lex, TokKind, Token};
use std::collections::BTreeSet;

/// One lint rule. IDs are stable and never reused.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    /// CPL000 — a lint allow-annotation that does not parse, or names
    /// an unknown rule. Not suppressible: a typo in an allow must never
    /// silently disable checking.
    BadAnnotation,
    /// CPL001 — `.partial_cmp(..).unwrap()/.expect()`: panics on NaN
    /// (the pre-PR-2 experiment-killer). Use `f64::total_cmp`.
    FloatOrd,
    /// CPL002 — `DefaultHasher`/`RandomState` anywhere, or iteration
    /// over a `HashMap`/`HashSet` binding in library code: hash order is
    /// seed-randomized and release-dependent (the PR-1 `stable_hash`
    /// bug class). Use `BTreeMap` or sort before order escapes.
    HashOrder,
    /// CPL003 — `Instant`/`SystemTime`/`env::var` inside a deterministic
    /// module: measurement must depend only on (inputs, RNG stream).
    WallClock,
    /// CPL004 — the `f32` type inside a deterministic module: the PR-5
    /// noise-path drift bug. Latency math is f64 end-to-end.
    F32Measure,
    /// CPL005 — `.unwrap()`/`.expect()` in library code without an
    /// annotation documenting why the panic is an invariant, not an
    /// error path.
    LibUnwrap,
    /// CPL006 — a lossy numeric cast inside a deterministic module:
    /// `as f32` (narrows f64 measurement math), or a float value cast
    /// to an integer type with `as` (silent truncation — `64.5 as usize`
    /// is 64, the exact bug class `verify`'s canonical-key check hunts
    /// in persisted artifacts). Use `round()`/checked conversions, or
    /// keep the value in f64.
    LossyCast,
    /// CPL007 — a direct `std::fs::write` or `File::create` in library
    /// code outside `util/io.rs`: every persisted artifact must go
    /// through the atomic-write seam (temp + fsync + rename, fault
    /// injectable — DESIGN.md §15) so a crash leaves the old document or
    /// the new one, never a torn half.
    FsWrite,
}

impl Rule {
    pub const ALL: [Rule; 8] = [
        Rule::BadAnnotation,
        Rule::FloatOrd,
        Rule::HashOrder,
        Rule::WallClock,
        Rule::F32Measure,
        Rule::LibUnwrap,
        Rule::LossyCast,
        Rule::FsWrite,
    ];

    /// The stable diagnostic ID.
    pub fn id(self) -> &'static str {
        match self {
            Rule::BadAnnotation => "CPL000",
            Rule::FloatOrd => "CPL001",
            Rule::HashOrder => "CPL002",
            Rule::WallClock => "CPL003",
            Rule::F32Measure => "CPL004",
            Rule::LibUnwrap => "CPL005",
            Rule::LossyCast => "CPL006",
            Rule::FsWrite => "CPL007",
        }
    }

    /// One-line summary for `cprune-lint --rules`.
    pub fn summary(self) -> &'static str {
        match self {
            Rule::BadAnnotation => "malformed or unknown cprune-lint allow-annotation",
            Rule::FloatOrd => "float ordering via partial_cmp().unwrap(); use total_cmp",
            Rule::HashOrder => "hash-ordered state (DefaultHasher/RandomState/HashMap iteration)",
            Rule::WallClock => "wall clock or environment read in a deterministic module",
            Rule::F32Measure => "f32 in a measurement/latency path; latency math is f64",
            Rule::LibUnwrap => "unannotated unwrap()/expect() in library code",
            Rule::LossyCast => {
                "lossy numeric cast (as f32, float-to-int as) in a deterministic module"
            }
            Rule::FsWrite => {
                "direct fs::write/File::create in library code; use util::io::atomic_write"
            }
        }
    }

    /// Parse an ID as written in an allow-annotation. CPL000 itself is
    /// excluded: the bad-annotation rule cannot be suppressed.
    pub fn suppressible_from_id(id: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.id() == id && *r != Rule::BadAnnotation)
    }
}

/// One finding, reported as `path:line: ID message` by the driver.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diagnostic {
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

/// Path prefixes of the deterministic modules (workspace-root-relative,
/// `/`-separated). `serve/` is wider than the issue's `serve/sim` on
/// purpose: the whole layer reports deterministic statistics.
pub const DETERMINISTIC_PREFIXES: [&str; 5] = [
    "rust/src/tuner/",
    "rust/src/device/",
    "rust/src/serve/",
    "rust/src/compiler/",
    // Masked-latency pricing only — `sparsity/pattern.rs`/`block.rs`
    // legitimately score f32 weights, and `mod.rs` casts channel counts.
    "rust/src/sparsity/cost.rs",
];

/// True for library (non-test-crate, non-bin) source paths.
pub fn is_library_path(rel: &str) -> bool {
    (rel.starts_with("rust/src/") || rel.starts_with("rust/lint/src/"))
        && !rel.ends_with("/main.rs")
}

/// True for paths inside the deterministic measurement plane.
pub fn is_deterministic_path(rel: &str) -> bool {
    DETERMINISTIC_PREFIXES.iter().any(|p| rel.starts_with(p))
}

/// Path prefixes where the `Instant`/`SystemTime` arm of CPL003 is
/// exempt: the remote measurement plane's IO edge (DESIGN.md §14) reads
/// the clock for deadlines and retry backoff, which never feed a
/// measured value. Environment reads and CPL004/CPL006 stay policed.
pub const WALLCLOCK_EXEMPT_PREFIXES: [&str; 1] = ["rust/src/device/remote/"];

/// True for deterministic-module paths that may still read the wall
/// clock (see [`WALLCLOCK_EXEMPT_PREFIXES`]).
pub fn is_wallclock_exempt_path(rel: &str) -> bool {
    WALLCLOCK_EXEMPT_PREFIXES.iter().any(|p| rel.starts_with(p))
}

/// The one library module sanctioned to call `std::fs::write`/
/// `File::create` directly: the atomic-write seam itself (CPL007,
/// DESIGN.md §15).
pub const FSWRITE_EXEMPT_PATH: &str = "rust/src/util/io.rs";

/// Run every rule over one file. `rel` is the workspace-root-relative
/// path with `/` separators — rule scoping keys off it. Returned
/// diagnostics are sorted by (line, rule) and already filtered through
/// the allow-annotations.
pub fn check_source(rel: &str, src: &str) -> Vec<Diagnostic> {
    let lexed = lex(src);
    let toks = &lexed.tokens;
    let in_tests = test_lines(toks);
    let in_lib = is_library_path(rel);
    let in_det = is_deterministic_path(rel);
    let clock_exempt = is_wallclock_exempt_path(rel);
    let float_names = if in_det { collect_float_names(toks) } else { BTreeSet::new() };
    let mut diags: Vec<Diagnostic> = Vec::new();

    for (line, why) in &lexed.bad_annotations {
        diags.push(Diagnostic { line: *line, rule: Rule::BadAnnotation, message: why.clone() });
    }
    for (line, id) in &lexed.allows {
        if Rule::suppressible_from_id(id).is_none() {
            diags.push(Diagnostic {
                line: *line,
                rule: Rule::BadAnnotation,
                message: format!("allow({id}, ...) names an unknown or unsuppressible rule"),
            });
        }
    }

    let emit = |rule: Rule, line: usize, message: String, diags: &mut Vec<Diagnostic>| {
        if !in_tests.contains(&line) {
            diags.push(Diagnostic { line, rule, message });
        }
    };

    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident {
            continue;
        }
        let prev = text_at(toks, i.wrapping_sub(1));
        let next = text_at(toks, i + 1);
        match t.text {
            "partial_cmp" if prev == "." && next == "(" => {
                if let Some(close) = matching_paren(toks, i + 1) {
                    if text_at(toks, close + 1) == "."
                        && matches!(text_at(toks, close + 2), "unwrap" | "expect")
                    {
                        emit(
                            Rule::FloatOrd,
                            t.line,
                            "partial_cmp().unwrap() panics on NaN; use f64::total_cmp".to_string(),
                            &mut diags,
                        );
                    }
                }
            }
            "DefaultHasher" | "RandomState" => emit(
                Rule::HashOrder,
                t.line,
                format!(
                    "{} is seed-randomized/release-dependent; use util::rng::stable_hash \
                     or a BTreeMap",
                    t.text
                ),
                &mut diags,
            ),
            "Instant" | "SystemTime" if in_det && !clock_exempt => emit(
                Rule::WallClock,
                t.line,
                format!("{} in a deterministic module; measurement depends on it", t.text),
                &mut diags,
            ),
            "env" if in_det && is_env_read(toks, i) => emit(
                Rule::WallClock,
                t.line,
                "environment read in a deterministic module".to_string(),
                &mut diags,
            ),
            "f32" if in_det && prev != "." && prev != "fn" => emit(
                Rule::F32Measure,
                t.line,
                "f32 in a measurement/latency path; latency math is f64 end-to-end".to_string(),
                &mut diags,
            ),
            "as" if in_det && next == "f32" => emit(
                Rule::LossyCast,
                t.line,
                "`as f32` narrows f64 measurement math in a deterministic module".to_string(),
                &mut diags,
            ),
            "as" if in_det
                && INT_TYPES.contains(&next)
                && toks
                    .get(i.wrapping_sub(1))
                    .map(|p| match p.kind {
                        TokKind::Number => is_float_literal(p.text),
                        TokKind::Ident => float_names.contains(p.text),
                        _ => false,
                    })
                    .unwrap_or(false) =>
            {
                emit(
                    Rule::LossyCast,
                    t.line,
                    format!(
                        "float-to-{next} `as` cast silently truncates in a deterministic \
                         module; use round() or a checked conversion"
                    ),
                    &mut diags,
                )
            }
            "fs" if in_lib && rel != FSWRITE_EXEMPT_PATH && is_fs_write(toks, i) => emit(
                Rule::FsWrite,
                t.line,
                "std::fs::write bypasses atomic persistence; use util::io::atomic_write"
                    .to_string(),
                &mut diags,
            ),
            "File"
                if in_lib
                    && rel != FSWRITE_EXEMPT_PATH
                    && text_at(toks, i + 1) == ":"
                    && text_at(toks, i + 2) == ":"
                    && text_at(toks, i + 3) == "create" =>
            {
                emit(
                    Rule::FsWrite,
                    t.line,
                    "File::create bypasses atomic persistence; use util::io::atomic_write \
                     or create_sink"
                        .to_string(),
                    &mut diags,
                )
            }
            "unwrap" | "expect" if in_lib && prev == "." && next == "(" => emit(
                Rule::LibUnwrap,
                t.line,
                format!(
                    ".{}() in library code; return an error or annotate the invariant",
                    t.text
                ),
                &mut diags,
            ),
            _ => {}
        }
    }

    if in_lib {
        check_hash_iteration(toks, &mut |line, message| {
            if !in_tests.contains(&line) {
                diags.push(Diagnostic { line, rule: Rule::HashOrder, message });
            }
        });
    }

    // Allow-annotations on the diagnostic's own line or the line above
    // suppress it; CPL000 is never suppressible.
    diags.retain(|d| {
        d.rule == Rule::BadAnnotation
            || !lexed.allows.iter().any(|(line, id)| {
                (*line == d.line || *line + 1 == d.line)
                    && Rule::suppressible_from_id(id) == Some(d.rule)
            })
    });
    diags.sort();
    diags
}

fn text_at<'a>(toks: &[Token<'a>], i: usize) -> &'a str {
    toks.get(i).map(|t| t.text).unwrap_or("")
}

/// Integer types a float must not be `as`-cast into (CPL006).
const INT_TYPES: [&str; 12] = [
    "i8", "i16", "i32", "i64", "i128", "isize", "u8", "u16", "u32", "u64", "u128", "usize",
];

/// True for a float literal token: has a decimal point or an exponent
/// (hex literals like `0x1E` lex as one token and are excluded).
fn is_float_literal(text: &str) -> bool {
    if text.starts_with("0x") || text.starts_with("0X") {
        return false;
    }
    text.contains('.') || text.contains('e') || text.contains('E')
}

/// CPL006's name half: bindings known to hold floats — `name: f64`/`f32`
/// typed declarations (params, fields, lets) and `let name = <float
/// literal>` initializers. Per-file and type-blind, like CPL002's
/// HashMap tracking: false negatives are acceptable in a lint.
fn collect_float_names<'a>(toks: &[Token<'a>]) -> BTreeSet<&'a str> {
    let mut names = BTreeSet::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Ident && (t.text == "f64" || t.text == "f32") {
            // `name : f64` — but not a `::f64` path segment.
            if i >= 2
                && text_at(toks, i - 1) == ":"
                && toks[i - 2].kind == TokKind::Ident
                && (i < 3 || text_at(toks, i - 3) != ":")
            {
                names.insert(toks[i - 2].text);
            }
        }
        if t.kind == TokKind::Ident && t.text == "let" {
            let mut k = i + 1;
            if text_at(toks, k) == "mut" {
                k += 1;
            }
            if toks.get(k).map(|n| n.kind == TokKind::Ident).unwrap_or(false)
                && text_at(toks, k + 1) == "="
                && toks
                    .get(k + 2)
                    .map(|v| v.kind == TokKind::Number && is_float_literal(v.text))
                    .unwrap_or(false)
            {
                names.insert(toks[k].text);
            }
        }
    }
    names
}

/// True when the ident at `i` begins an `fs::write` path (CPL007).
fn is_fs_write(toks: &[Token<'_>], i: usize) -> bool {
    text_at(toks, i + 1) == ":"
        && text_at(toks, i + 2) == ":"
        && text_at(toks, i + 3) == "write"
}

/// True when the ident at `i` begins an `env::var`/`var_os`/`vars` path.
fn is_env_read(toks: &[Token<'_>], i: usize) -> bool {
    text_at(toks, i + 1) == ":"
        && text_at(toks, i + 2) == ":"
        && matches!(text_at(toks, i + 3), "var" | "var_os" | "vars")
}

/// `toks[open]` is `(`; returns the index of its matching `)`.
fn matching_paren(toks: &[Token<'_>], open: usize) -> Option<usize> {
    let mut depth = 0usize;
    for (i, t) in toks.iter().enumerate().skip(open) {
        match t.text {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// Lines covered by `#[cfg(test)] mod { .. }` or `#[test]`-attributed
/// items (including `#[should_panic]` companions).
fn test_lines(toks: &[Token<'_>]) -> BTreeSet<usize> {
    let mut lines = BTreeSet::new();
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text != "#" || text_at(toks, i + 1) != "[" {
            i += 1;
            continue;
        }
        let attr = attr_tokens(toks, i);
        let after = skip_attr(toks, i);
        let is_cfg_test = attr == ["[", "cfg", "(", "test", ")"];
        let is_test_attr = attr.len() >= 2 && matches!(attr[1], "test" | "should_panic");
        if !(is_cfg_test || is_test_attr) {
            i = after;
            continue;
        }
        // Skip any further attributes, then find the item's opening `{`
        // (a `;` first means a declaration with no body — nothing to span).
        let mut k = after;
        while k < toks.len() && toks[k].text == "#" && text_at(toks, k + 1) == "[" {
            k = skip_attr(toks, k);
        }
        let mut open = None;
        while k < toks.len() {
            match toks[k].text {
                "{" => {
                    open = Some(k);
                    break;
                }
                ";" => break,
                _ => k += 1,
            }
        }
        let Some(mut k) = open else {
            i = after;
            continue;
        };
        let mut depth = 0usize;
        while k < toks.len() {
            lines.insert(toks[k].line);
            match toks[k].text {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        i = k + 1;
    }
    lines
}

/// The token texts of the `#[...]` starting at `toks[i]` (the `#`),
/// opening bracket included, closing bracket excluded.
fn attr_tokens<'a>(toks: &[Token<'a>], i: usize) -> Vec<&'a str> {
    let mut out = Vec::new();
    let mut k = i + 1;
    if text_at(toks, k) != "[" {
        return out;
    }
    let mut depth = 0usize;
    while k < toks.len() {
        match toks[k].text {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
        out.push(toks[k].text);
        k += 1;
    }
    out
}

/// Index just past the `#[...]` starting at `toks[i]`.
fn skip_attr(toks: &[Token<'_>], i: usize) -> usize {
    let mut k = i + 1;
    if text_at(toks, k) != "[" {
        return k;
    }
    let mut depth = 0usize;
    while k < toks.len() {
        match toks[k].text {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
        k += 1;
    }
    k
}

/// CPL002's iteration half: collect the file's `HashMap`/`HashSet`
/// binding names (typed declarations and `= HashMap::new()` initializers),
/// then flag ordered-iteration entry points on them. Name tracking is
/// per-file and type-blind — false negatives are acceptable (this is a
/// lint, not a type checker); false positives carry an annotation
/// explaining why order does not escape.
fn check_hash_iteration(toks: &[Token<'_>], emit: &mut dyn FnMut(usize, String)) {
    const WRAPPERS: [&str; 10] =
        ["<", "&", "mut", "Mutex", "Arc", "Rc", "RefCell", "Option", "Box", "Vec"];
    const ITER_METHODS: [&str; 7] =
        ["iter", "iter_mut", "keys", "values", "values_mut", "into_iter", "drain"];

    let mut names: BTreeSet<&str> = BTreeSet::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
            continue;
        }
        // Walk back over a `std::collections::` path prefix...
        let mut j = i.wrapping_sub(1);
        while j >= 1 && j < toks.len() && toks[j].text == ":" && toks[j - 1].text == ":" {
            j = j.wrapping_sub(2);
            if j < toks.len() && toks[j].kind == TokKind::Ident {
                j = j.wrapping_sub(1);
            }
        }
        // ...and over type wrappers (`Mutex<`, `&mut`, ...).
        while j < toks.len() && WRAPPERS.contains(&toks[j].text) {
            j = j.wrapping_sub(1);
        }
        if j >= 1 && j < toks.len() {
            let at = toks[j].text;
            let before = &toks[j - 1];
            if at == ":" && before.kind == TokKind::Ident && (j < 2 || toks[j - 2].text != ":") {
                names.insert(before.text);
            } else if at == "=" && before.kind == TokKind::Ident {
                names.insert(before.text);
            }
        }
    }
    if names.is_empty() {
        return;
    }

    for (i, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Ident
            && names.contains(t.text)
            && text_at(toks, i + 1) == "."
            && ITER_METHODS.contains(&text_at(toks, i + 2))
        {
            emit(
                t.line,
                format!(
                    "iteration over hash-ordered `{}`; use a BTreeMap or sort before \
                     the order can escape",
                    t.text
                ),
            );
        }
        if t.text == "for" && t.kind == TokKind::Ident {
            // Find the `in` of this for-loop, then flag tracked names
            // consumed directly (not via a method call) before the `{`.
            let mut j = i + 1;
            while j < toks.len() && !matches!(toks[j].text, "in" | "{" | ";") {
                j += 1;
            }
            if j >= toks.len() || toks[j].text != "in" {
                continue;
            }
            let mut k = j + 1;
            while k < toks.len() && toks[k].text != "{" {
                if toks[k].kind == TokKind::Ident
                    && names.contains(toks[k].text)
                    && text_at(toks, k + 1) != "."
                {
                    emit(
                        toks[k].line,
                        format!(
                            "for-loop over hash-ordered `{}`; use a BTreeMap or sort \
                             before the order can escape",
                            toks[k].text
                        ),
                    );
                }
                k += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lib(src: &str) -> Vec<Diagnostic> {
        check_source("rust/src/sample.rs", src)
    }

    fn det(src: &str) -> Vec<Diagnostic> {
        check_source("rust/src/tuner/sample.rs", src)
    }

    fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule.id()).collect()
    }

    #[test]
    fn cpl001_fires_on_partial_cmp_unwrap() {
        let src = "fn f(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }";
        // In library scope the bare unwrap is flagged too, independently.
        assert_eq!(ids(&lib(src)), ["CPL001", "CPL005"]);
        let ok = "fn f(v: &mut Vec<f64>) { v.sort_by(f64::total_cmp); }";
        assert!(lib(ok).is_empty());
    }

    #[test]
    fn cpl001_fires_outside_library_scope_too() {
        let src = "fn f(a: f64, b: f64) { a.partial_cmp(&b).expect(\"no NaN\"); }";
        assert_eq!(ids(&check_source("rust/benches/sample.rs", src)), ["CPL001"]);
    }

    #[test]
    fn cpl002_bans_default_hasher_everywhere() {
        let src = "use std::collections::hash_map::DefaultHasher;";
        assert_eq!(ids(&check_source("rust/tests/sample.rs", src)), ["CPL002"]);
    }

    #[test]
    fn cpl002_flags_hash_iteration_in_lib_code() {
        let src = "fn f(m: std::collections::HashMap<u32, u32>) -> Vec<u32> {\n\
                   m.keys().copied().collect()\n}";
        assert_eq!(ids(&lib(src)), ["CPL002"]);
        let forloop = "fn f() { let mut s = std::collections::HashSet::new();\n\
                       s.insert(1u32);\nfor x in &s { drop(x); } }";
        assert_eq!(ids(&lib(forloop)), ["CPL002"]);
    }

    #[test]
    fn cpl002_lookups_are_fine() {
        let src = "fn f(m: std::collections::HashMap<u32, u32>) -> Option<u32> {\n\
                   m.get(&1).copied()\n}";
        assert!(lib(src).is_empty());
    }

    #[test]
    fn cpl003_scoped_to_deterministic_modules() {
        let src = "fn f() -> std::time::Instant { std::time::Instant::now() }";
        assert!(!det(src).is_empty());
        assert!(lib(src).is_empty());
        let env = "fn f() -> Option<String> { std::env::var(\"X\").ok() }";
        assert_eq!(ids(&det(env)), ["CPL003"]);
    }

    #[test]
    fn cpl003_clock_arm_is_exempt_in_device_remote_only() {
        let clock = "fn f() -> std::time::Instant { std::time::Instant::now() }";
        // the remote plane's IO edge may read the clock for deadlines...
        assert!(check_source("rust/src/device/remote/transport.rs", clock).is_empty());
        // ...but the rest of device/ (exemption boundary) may not
        assert_eq!(ids(&check_source("rust/src/device/target.rs", clock)), ["CPL003"]);
        assert_eq!(ids(&check_source("rust/src/device/replay.rs", clock)), ["CPL003"]);
        // and the exemption does not reach the other CPL003 arm or CPL004/6
        let env = "fn f() -> Option<String> { std::env::var(\"X\").ok() }";
        assert_eq!(ids(&check_source("rust/src/device/remote/pool.rs", env)), ["CPL003"]);
        let f32src = "fn f(x: f32) -> f32 { x }";
        assert_eq!(
            ids(&check_source("rust/src/device/remote/pool.rs", f32src)),
            ["CPL004", "CPL004"]
        );
    }

    #[test]
    fn cpl004_flags_f32_type_but_not_rng_method() {
        assert_eq!(ids(&det("fn f(x: f32) -> f64 { x as f64 }")), ["CPL004"]);
        assert!(det("fn f(rng: &mut Rng) -> bool { rng.f32() < 0.5 }").is_empty());
        assert!(lib("fn f(x: f32) -> f32 { x }").is_empty());
    }

    #[test]
    fn cpl005_scoped_to_library_code() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(ids(&lib(src)), ["CPL005"]);
        assert!(check_source("rust/src/main.rs", src).is_empty());
        assert!(check_source("rust/benches/sample.rs", src).is_empty());
    }

    #[test]
    fn cpl005_skips_test_modules() {
        let src = "pub fn f() {}\n\
                   #[cfg(test)]\nmod tests {\n#[test]\nfn t() { None::<u32>.unwrap(); }\n}";
        assert!(lib(src).is_empty());
        let not_test = "pub fn f() {}\n\
                        #[cfg(not(test))]\nmod m {\npub fn g() { None::<u32>.unwrap(); }\n}";
        assert_eq!(ids(&lib(not_test)), ["CPL005"]);
    }

    #[test]
    fn allow_annotation_suppresses_same_and_next_line() {
        let same = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() } \
                    // cprune-lint: allow(CPL005, reason=\"demo\")";
        assert!(lib(same).is_empty());
        let above = "// cprune-lint: allow(CPL005, reason=\"demo\")\n\
                     pub fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert!(lib(above).is_empty());
        let distant = "// cprune-lint: allow(CPL005, reason=\"demo\")\n\n\
                       pub fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(ids(&lib(distant)), ["CPL005"]);
    }

    #[test]
    fn wrong_rule_annotation_does_not_suppress() {
        let src = "// cprune-lint: allow(CPL001, reason=\"wrong rule\")\n\
                   pub fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert_eq!(ids(&lib(src)), ["CPL005"]);
    }

    #[test]
    fn cpl000_fires_on_malformed_and_unknown_annotations() {
        let src = "// cprune-lint: allow(CPL005)\npub fn f() {}";
        assert_eq!(ids(&lib(src)), ["CPL000"]);
        let unknown = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() } \
                       // cprune-lint: allow(CPL999, reason=\"typo\")";
        let diags = lib(unknown);
        assert_eq!(ids(&diags), ["CPL000", "CPL005"]);
    }

    #[test]
    fn cpl000_is_not_suppressible() {
        let src = "// cprune-lint: allow(CPL000, reason=\"nice try\")\npub fn f() {}";
        assert_eq!(ids(&lib(src)), ["CPL000"]);
    }

    #[test]
    fn cpl006_flags_as_f32_in_deterministic_modules() {
        // `x as f32` is both a lossy cast (CPL006) and an f32 type use
        // (CPL004) — two independent findings on the same line.
        let src = "fn f(x: f64) { let _ = x as f32; }";
        assert_eq!(ids(&det(src)), ["CPL004", "CPL006"]);
        assert!(lib(src).is_empty());
    }

    #[test]
    fn cpl006_flags_float_to_int_casts() {
        assert_eq!(ids(&det("fn f(x: f64) -> usize { x as usize }")), ["CPL006"]);
        assert_eq!(ids(&det("fn f() -> u64 { 1.5e3 as u64 }")), ["CPL006"]);
        let let_bound = "fn f() -> usize { let mut y = 2.5; y as usize }";
        assert_eq!(ids(&det(let_bound)), ["CPL006"]);
    }

    #[test]
    fn cpl006_ignores_int_and_untracked_casts() {
        assert!(det("fn f(x: usize) -> u64 { x as u64 }").is_empty());
        assert!(det("fn f() -> u64 { 0x1E as u64 }").is_empty());
        // type-blind tracking: an untracked ident is a false negative
        assert!(det("fn f(x: SomeOpaque) -> u64 { x.raw as u64 }").is_empty());
        // f64 widening is lossless for the usize ranges we hold
        assert!(det("fn f(x: usize) -> f64 { x as f64 }").is_empty());
        assert!(lib("fn f(x: f64) -> usize { x as usize }").is_empty());
    }

    #[test]
    fn cpl007_flags_direct_writes_outside_util_io() {
        let w = "pub fn f() { std::fs::write(\"x\", \"y\").ok(); }";
        assert_eq!(ids(&lib(w)), ["CPL007"]);
        let c = "pub fn f() { let _ = std::fs::File::create(\"x\"); }";
        assert_eq!(ids(&lib(c)), ["CPL007"]);
        // the atomic-write seam itself is the one sanctioned caller
        assert!(check_source("rust/src/util/io.rs", w).is_empty());
        assert!(check_source("rust/src/util/io.rs", c).is_empty());
        // test crates and test modules may write fixtures freely
        assert!(check_source("rust/tests/sample.rs", w).is_empty());
        let in_test =
            "#[cfg(test)]\nmod tests {\n#[test]\nfn t() { std::fs::write(\"x\", \"y\").ok(); }\n}";
        assert!(lib(in_test).is_empty());
        // reads and OpenOptions appends are not writes-through-the-seam
        assert!(lib("pub fn f() { let _ = std::fs::read(\"x\"); }").is_empty());
        assert!(lib("pub fn f() { let _ = std::fs::OpenOptions::new(); }").is_empty());
    }

    #[test]
    fn rule_ids_are_stable() {
        let ids: Vec<&str> = Rule::ALL.iter().map(|r| r.id()).collect();
        assert_eq!(
            ids,
            ["CPL000", "CPL001", "CPL002", "CPL003", "CPL004", "CPL005", "CPL006", "CPL007"]
        );
    }
}
