//! A hand-rolled lexer for the subset of Rust surface syntax the lint
//! rules need (in the spirit of `cprune`'s hand-rolled `util::json`).
//!
//! The lexer does three things:
//!
//! 1. strips comments, string/char literals and lifetimes, so rules
//!    never match inside prose or data;
//! 2. produces a flat token stream — identifiers, numeric literals and
//!    single-character punctuation — each tagged with its 1-based line;
//! 3. captures `allow(RULE, reason="...")` lint annotations out of the
//!    comments it strips (the escape hatch of DESIGN.md §12), reporting
//!    malformed ones so a typo cannot silently disable a rule.
//!
//! (The literal marker string is [`ANNOTATION_MARKER`]; these docs avoid
//! spelling it so the linter does not parse its own documentation.)
//!
//! It is deliberately not a full Rust lexer: nested generics, macros and
//! attributes all come out as plain punctuation, which is exactly the
//! level the rules operate at. Known holes (documented in DESIGN.md
//! §12): float-suffix literals like `1.0f32` lex as one `Number` token,
//! and non-ASCII identifier tails are truncated — neither occurs in this
//! codebase.

/// Token class. Rules mostly dispatch on `Ident` text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Number,
    Punct,
}

/// One lexed token: class, source text and 1-based source line.
#[derive(Clone, Copy, Debug)]
pub struct Token<'a> {
    pub kind: TokKind,
    pub text: &'a str,
    pub line: usize,
}

/// Everything the lexer extracts from one source file.
#[derive(Debug, Default)]
pub struct Lexed<'a> {
    pub tokens: Vec<Token<'a>>,
    /// Well-formed `(line, rule_id)` allow-annotations.
    pub allows: Vec<(usize, String)>,
    /// `(line, why)` for annotations that failed to parse.
    pub bad_annotations: Vec<(usize, String)>,
}

/// The marker every annotation starts with.
pub const ANNOTATION_MARKER: &str = "cprune-lint:";

/// Lex `src` into tokens plus the annotations found in its comments.
pub fn lex(src: &str) -> Lexed<'_> {
    let bytes = src.as_bytes();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < bytes.len() {
        let c = bytes[i];
        match c {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if bytes.get(i + 1) == Some(&b'/') => {
                let end = memchr_newline(bytes, i);
                scan_annotations(&src[i..end], line, &mut out);
                i = end;
            }
            b'/' if bytes.get(i + 1) == Some(&b'*') => {
                let start_line = line;
                let (end, newlines) = skip_block_comment(bytes, i);
                scan_annotations(&src[i..end], start_line, &mut out);
                line += newlines;
                i = end;
            }
            b'r' | b'b' if is_raw_string_start(bytes, i) => {
                let (end, newlines) = skip_raw_string(bytes, i);
                line += newlines;
                i = end;
            }
            b'"' => {
                let (end, newlines) = skip_string(bytes, i);
                line += newlines;
                i = end;
            }
            b'b' if bytes.get(i + 1) == Some(&b'"') => {
                let (end, newlines) = skip_string(bytes, i + 1);
                line += newlines;
                i = end;
            }
            b'\'' => {
                if is_lifetime_start(bytes, i) {
                    i += 1;
                    while i < bytes.len() && is_ident_byte(bytes[i]) {
                        i += 1;
                    }
                } else {
                    i = skip_char_literal(bytes, i);
                }
            }
            _ if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                while i < bytes.len() && is_ident_byte(bytes[i]) {
                    i += 1;
                }
                out.tokens.push(Token { kind: TokKind::Ident, text: &src[start..i], line });
            }
            _ if c.is_ascii_digit() => {
                let start = i;
                while i < bytes.len() && is_ident_byte(bytes[i]) {
                    i += 1;
                }
                // Decimal tail (`1.5`, `1.5e3`) — but not `1.iter()`.
                if i + 1 < bytes.len() && bytes[i] == b'.' && bytes[i + 1].is_ascii_digit() {
                    i += 1;
                    while i < bytes.len() && is_ident_byte(bytes[i]) {
                        i += 1;
                    }
                }
                out.tokens.push(Token { kind: TokKind::Number, text: &src[start..i], line });
            }
            _ if c.is_ascii() => {
                out.tokens.push(Token { kind: TokKind::Punct, text: &src[i..i + 1], line });
                i += 1;
            }
            // Non-ASCII outside strings/comments: skip the whole scalar so
            // we never slice mid-character.
            _ => {
                let mut j = i + 1;
                while j < bytes.len() && (bytes[j] & 0b1100_0000) == 0b1000_0000 {
                    j += 1;
                }
                i = j;
            }
        }
    }
    out
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn memchr_newline(bytes: &[u8], mut i: usize) -> usize {
    while i < bytes.len() && bytes[i] != b'\n' {
        i += 1;
    }
    i
}

/// `i` sits on `/*`; returns (index past the matching `*/`, newlines seen).
/// Block comments nest, as in real Rust.
fn skip_block_comment(bytes: &[u8], mut i: usize) -> (usize, usize) {
    let mut depth = 0usize;
    let mut newlines = 0usize;
    while i < bytes.len() {
        if bytes[i] == b'\n' {
            newlines += 1;
            i += 1;
        } else if bytes[i] == b'/' && bytes.get(i + 1) == Some(&b'*') {
            depth += 1;
            i += 2;
        } else if bytes[i] == b'*' && bytes.get(i + 1) == Some(&b'/') {
            depth -= 1;
            i += 2;
            if depth == 0 {
                break;
            }
        } else {
            i += 1;
        }
    }
    (i, newlines)
}

/// True when `i` starts `r"`, `r#"`, `br"`, `br#"`, ... (a raw string).
fn is_raw_string_start(bytes: &[u8], i: usize) -> bool {
    let mut j = i;
    if bytes[j] == b'b' {
        j += 1;
    }
    if bytes.get(j) != Some(&b'r') {
        return false;
    }
    j += 1;
    while bytes.get(j) == Some(&b'#') {
        j += 1;
    }
    bytes.get(j) == Some(&b'"')
}

/// `i` sits on the `r`/`b` of a raw string; returns (index past the
/// closing quote+hashes, newlines seen).
fn skip_raw_string(bytes: &[u8], mut i: usize) -> (usize, usize) {
    if bytes[i] == b'b' {
        i += 1;
    }
    i += 1; // the `r`
    let mut hashes = 0usize;
    while bytes.get(i) == Some(&b'#') {
        hashes += 1;
        i += 1;
    }
    i += 1; // the opening quote
    let mut newlines = 0usize;
    while i < bytes.len() {
        if bytes[i] == b'\n' {
            newlines += 1;
            i += 1;
            continue;
        }
        if bytes[i] == b'"' {
            let mut j = i + 1;
            let mut seen = 0usize;
            while seen < hashes && bytes.get(j) == Some(&b'#') {
                seen += 1;
                j += 1;
            }
            if seen == hashes {
                return (j, newlines);
            }
        }
        i += 1;
    }
    (i, newlines)
}

/// `i` sits on the opening quote; returns (index past the closing quote,
/// newlines seen).
fn skip_string(bytes: &[u8], mut i: usize) -> (usize, usize) {
    i += 1;
    let mut newlines = 0usize;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'"' => return (i + 1, newlines),
            b'\n' => {
                newlines += 1;
                i += 1;
            }
            _ => i += 1,
        }
    }
    (i, newlines)
}

/// Distinguish `'a` / `'_` (lifetime) from `'x'` / `'\n'` (char literal):
/// a lifetime's first byte is identifier-ish and is NOT followed by a
/// closing quote.
fn is_lifetime_start(bytes: &[u8], i: usize) -> bool {
    match (bytes.get(i + 1), bytes.get(i + 2)) {
        (Some(&c), Some(&n)) => (c.is_ascii_alphabetic() || c == b'_') && n != b'\'',
        _ => false,
    }
}

/// `i` sits on the opening quote of a char literal; returns the index
/// past the closing quote.
fn skip_char_literal(bytes: &[u8], mut i: usize) -> usize {
    i += 1;
    while i < bytes.len() {
        match bytes[i] {
            b'\\' => i += 2,
            b'\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Parse every [`ANNOTATION_MARKER`] occurrence inside one comment's
/// text. Each marker must be followed by a well-formed
/// `allow(RULE, reason="non-empty")`; anything else is recorded as a bad
/// annotation so rule CPL000 can surface it.
fn scan_annotations(comment: &str, line: usize, out: &mut Lexed<'_>) {
    let mut rest = comment;
    while let Some(pos) = rest.find(ANNOTATION_MARKER) {
        let after = &rest[pos + ANNOTATION_MARKER.len()..];
        match parse_allow(after) {
            Ok(rule) => out.allows.push((line, rule)),
            Err(why) => out.bad_annotations.push((line, why)),
        }
        rest = after;
    }
}

/// Grammar: `allow(<RULE>, reason="<non-empty>")`, leading whitespace
/// allowed. Returns the rule id as written.
fn parse_allow(s: &str) -> Result<String, String> {
    let s = s.trim_start();
    let s = match s.strip_prefix("allow(") {
        Some(rest) => rest,
        None => return Err("expected `allow(RULE, reason=\"...\")` after marker".to_string()),
    };
    let comma = match s.find(',') {
        Some(c) => c,
        None => return Err("allow(...) is missing the `, reason=\"...\"` part".to_string()),
    };
    let rule = s[..comma].trim();
    if rule.is_empty() || !rule.bytes().all(|b| b.is_ascii_alphanumeric()) {
        return Err(format!("bad rule id '{rule}' in allow(...)"));
    }
    let s = s[comma + 1..].trim_start();
    let s = match s.strip_prefix("reason") {
        Some(rest) => rest.trim_start(),
        None => return Err("allow(...) requires `reason=\"...\"`".to_string()),
    };
    let s = match s.strip_prefix('=') {
        Some(rest) => rest.trim_start(),
        None => return Err("allow(...) requires `reason=\"...\"`".to_string()),
    };
    let s = match s.strip_prefix('"') {
        Some(rest) => rest,
        None => return Err("allow(...) reason must be a \"quoted\" string".to_string()),
    };
    let close = match s.find('"') {
        Some(c) => c,
        None => return Err("allow(...) reason string is unterminated".to_string()),
    };
    if s[..close].trim().is_empty() {
        return Err("allow(...) reason must not be empty".to_string());
    }
    if !s[close + 1..].trim_start().starts_with(')') {
        return Err("allow(...) is missing its closing ')'".to_string());
    }
    Ok(rule.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<&str> {
        lex(src)
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let src = "// unwrap() in a comment\n\
                   /* HashMap in /* a nested */ block */\n\
                   let x = \"partial_cmp inside a string\";\n";
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap"));
        assert!(!ids.contains(&"HashMap"));
        assert!(!ids.contains(&"partial_cmp"));
        assert!(ids.contains(&"let"));
    }

    #[test]
    fn raw_strings_are_stripped() {
        let src = "let s = r#\"unwrap() HashMap\"#; let t = r\"Instant\"; done();";
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap"));
        assert!(!ids.contains(&"HashMap"));
        assert!(!ids.contains(&"Instant"));
        assert!(ids.contains(&"done"));
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { m('x', '\\n', '\\''); }";
        let ids = idents(src);
        // the lifetime ident is skipped entirely, char contents never leak
        assert!(!ids.contains(&"a"));
        // the parameter `x` survives; the 'x' char literal does not
        assert_eq!(ids.iter().filter(|s| **s == "x").count(), 1);
        assert!(ids.contains(&"m"));
    }

    #[test]
    fn lines_are_tracked_through_multiline_constructs() {
        let src = "let a = \"two\nlines\";\n/* block\ncomment */\nlet b = 1;";
        let lexed = lex(src);
        let b = lexed.tokens.iter().find(|t| t.text == "b");
        assert_eq!(b.map(|t| t.line), Some(5));
    }

    #[test]
    fn numbers_do_not_swallow_method_calls() {
        let src = "for i in 0..n { x.0.lock(); let f = 1.5e3; }";
        let lexed = lex(src);
        let texts: Vec<&str> = lexed.tokens.iter().map(|t| t.text).collect();
        assert!(texts.contains(&"lock"));
        assert!(texts.contains(&"0"));
    }

    #[test]
    fn well_formed_annotations_parse() {
        let src = "let x = 1; // cprune-lint: allow(CPL005, reason=\"documented invariant\")";
        let lexed = lex(src);
        assert_eq!(lexed.allows, vec![(1, "CPL005".to_string())]);
        assert!(lexed.bad_annotations.is_empty());
    }

    #[test]
    fn malformed_annotations_are_reported() {
        for bad in [
            "// cprune-lint: allow(CPL005)",
            "// cprune-lint: allow(CPL005, reason=\"\")",
            "// cprune-lint: allow(CPL005, reason=unquoted)",
            "// cprune-lint: suppress(CPL005)",
            "// cprune-lint: allow(CPL005, reason=\"x\"",
        ] {
            let lexed = lex(bad);
            assert!(lexed.allows.is_empty(), "{bad} parsed as well-formed");
            assert_eq!(lexed.bad_annotations.len(), 1, "{bad} not reported");
        }
    }

    #[test]
    fn multiple_annotations_on_one_line() {
        let src = "x(); // cprune-lint: allow(CPL002, reason=\"a\") cprune-lint: allow(CPL005, reason=\"b\")";
        let lexed = lex(src);
        assert_eq!(lexed.allows.len(), 2);
    }
}
