//! Typed stub of the `xla` crate's PJRT surface (see rust/shims/xla/Cargo.toml).
//!
//! Mirrors exactly the API `runtime/` and `train::driver` consume:
//! `PjRtClient`, `PjRtLoadedExecutable`, `HloModuleProto`,
//! `XlaComputation`, and `Literal`. Host-side literal plumbing
//! (construction, reshape, readback) genuinely works; anything that needs
//! a real PJRT backend (`PjRtClient::cpu`) returns an error explaining
//! that this build uses the stub.

use std::fmt;

/// Stub error type (the real crate's `Error` is also a plain enum that
/// implements `std::error::Error`).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "PJRT is unavailable: this binary was built against the in-tree \
         `xla` stub (rust/shims/xla). Point the path dependency at the real \
         xla crate (xla_extension 0.5.1) to execute AOT artifacts."
            .to_string(),
    )
}

/// Element types a [`Literal`] can hold host-side.
pub trait NativeType: Copy + Sized {
    fn make_literal(data: &[Self]) -> Literal;
    fn read_literal(lit: &Literal) -> Result<Vec<Self>>;
}

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: element buffer + dimensions (scalar = empty dims).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::make_literal(data)
    }

    /// Scalar f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { data: Data::F32(vec![v]), dims: Vec::new() }
    }

    fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    /// Reinterpret the buffer with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.len() {
            return Err(Error(format!(
                "reshape to {:?} ({} elems) from {} elems",
                dims,
                want,
                self.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Read the buffer back as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read_literal(self)
    }

    /// Decompose a tuple literal. The stub never produces tuples (they only
    /// come back from real PJRT execution), so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error("stub literal is not a tuple".to_string()))
    }
}

impl NativeType for f32 {
    fn make_literal(data: &[Self]) -> Literal {
        Literal { data: Data::F32(data.to_vec()), dims: vec![data.len() as i64] }
    }

    fn read_literal(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            Data::I32(_) => Err(Error("literal holds i32, asked for f32".to_string())),
        }
    }
}

impl NativeType for i32 {
    fn make_literal(data: &[Self]) -> Literal {
        Literal { data: Data::I32(data.to_vec()), dims: vec![data.len() as i64] }
    }

    fn read_literal(lit: &Literal) -> Result<Vec<Self>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            Data::F32(_) => Err(Error("literal holds f32, asked for i32".to_string())),
        }
    }
}

/// Parsed HLO module (the stub only retains the text).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file (parsing is deferred to the real backend).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation ready for compilation.
pub struct XlaComputation {
    #[allow(dead_code)]
    proto: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { proto: () }
    }
}

/// Device-resident buffer handle returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given inputs: one buffer list per device. (The
    /// real crate is generic over the input buffer type; callers here pass
    /// `Literal`.)
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// A PJRT client for one platform.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real crate dlopens the PJRT CPU plugin here; the stub cannot.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_reports_stub() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"));
    }
}
