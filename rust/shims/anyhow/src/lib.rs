//! Minimal API-compatible stand-in for the `anyhow` crate.
//!
//! The offline build environment cannot fetch crates.io, so the `pjrt`
//! feature links this shim instead. It covers exactly the surface the
//! repo's PJRT path uses: [`Error`], [`Result`], the [`anyhow!`] macro,
//! [`Error::msg`], and the [`Context`] extension trait on `Result` and
//! `Option`. Error context is flattened into the message eagerly (the real
//! crate keeps a source chain; nothing in this repo inspects it).

use std::fmt::{self, Debug, Display};

/// A flattened error message. Unlike `std` errors this type deliberately
/// does NOT implement `std::error::Error`, which is what allows the
/// blanket `From<E: std::error::Error>` conversion below (same trick as
/// the real crate).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display + Debug + Send + Sync + 'static>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on the real crate prints the whole context chain; ours is
        // already flattened, so both forms print the same thing.
        f.write_str(&self.msg)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Attach context to a fallible value (extension trait on `Result` and
/// `Option`, mirroring `anyhow::Context`).
pub trait Context<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.map_err(|e| Error { msg: format!("{context}: {e}") })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error { msg: context.to_string() })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        let plain = anyhow!("plain");
        assert_eq!(plain.to_string(), "plain");
        let x = 3;
        let fmt = anyhow!("value {} and {x}", 2);
        assert_eq!(fmt.to_string(), "value 2 and 3");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"));
        let e = r.context("reading file").unwrap_err();
        assert_eq!(e.to_string(), "reading file: boom");
        let n: Option<u8> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(inner().is_err());
    }
}
