//! The run layer's contract (DESIGN.md §9):
//!
//! 1. **Equivalence** — `RunBuilder` + `Pruner` runs reproduce the
//!    legacy `cprune`/`cprune_with_session`/`baselines::*` free-function
//!    results bit-for-bit for fixed seeds (the free functions are shims
//!    over the trait, and the builder wiring must not perturb them);
//! 2. **Events** — a seeded run with a JSONL sink produces a parseable
//!    log whose `finished` event matches the returned `PruneOutcome`;
//! 3. **Schema** — the JSONL event serialization is pinned by a golden
//!    file (`tests/golden/run_events.jsonl`, `cprune-run-events` v1).

use cprune::accuracy::ProxyOracle;
use cprune::baselines::amc::{amc, AmcConfig};
use cprune::baselines::fpgm::fpgm_prune;
use cprune::baselines::magnitude::magnitude_prune;
use cprune::baselines::netadapt::{netadapt, NetAdaptConfig};
use cprune::baselines::pqf::pqf;
use cprune::baselines::{original_row, Outcome};
use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::pruner::{cprune, CPruneConfig};
use cprune::run::{
    pruner_by_name, Amc, CPrune, Fpgm, JsonlSink, Magnitude, NetAdapt, Pqf, Pruner,
    RegistryPublisher, RunBuilder, RunEvent,
};
use cprune::serve::Checkpoint;
use cprune::tuner::{TuneOptions, TuningSession};
use cprune::util::json::{self, Json};
use std::collections::BTreeMap;

#[test]
fn run_builder_reproduces_legacy_cprune_bit_for_bit() {
    let seed = 3;
    let cfg = CPruneConfig { max_iterations: 6, seed, ..Default::default() };
    let model = Model::build(ModelKind::ResNet8Cifar, seed);
    let sim = Simulator::new(DeviceSpec::kryo385());
    let mut oracle = ProxyOracle::new();
    let legacy = cprune(&model, &sim, &mut oracle, &cfg);

    let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo385")
        .seed(seed)
        .build()
        .unwrap();
    let out = run.execute(&CPrune::with_cfg(cfg)).unwrap();

    assert_eq!(out.final_latency, legacy.final_latency);
    assert_eq!(out.final_fps, legacy.final_fps);
    assert_eq!(out.fps_increase_rate, legacy.fps_increase_rate);
    assert_eq!(out.top1, legacy.final_top1);
    assert_eq!(out.top5, legacy.final_top5);
    assert_eq!(out.channels, legacy.final_state.cout);
    assert_eq!(out.search_candidates, legacy.candidates_tried);
    assert_eq!(out.pareto, legacy.pareto);
    assert_eq!(out.iterations.len(), legacy.iterations.len());
    for (a, b) in out.iterations.iter().zip(&legacy.iterations) {
        assert_eq!(a.iteration, b.iteration);
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.short_accuracy, b.short_accuracy);
        assert_eq!(a.pruned_convs, b.pruned_convs);
        assert_eq!(a.filters_removed, b.filters_removed);
    }
}

#[test]
fn run_builder_reproduces_legacy_one_shot_baselines_bit_for_bit() {
    let seed = 5;
    let kind = ModelKind::Vgg16Cifar;
    let model = Model::build(kind, seed);
    let sim = Simulator::new(DeviceSpec::kryo385());
    let session = TuningSession::new(&sim, TuneOptions::quick(), seed);
    let mut oracle = ProxyOracle::new();
    let (_, base_latency) = original_row(&model, &session);
    let pairs: Vec<(Outcome, Box<dyn Pruner>)> = vec![
        (
            magnitude_prune(&model, 0.3, &session, &mut oracle, base_latency),
            Box::new(Magnitude::at(0.3)),
        ),
        (
            fpgm_prune(&model, 0.25, &session, &mut oracle, base_latency),
            Box::new(Fpgm::at(0.25)),
        ),
        (
            amc(&model, &session, &mut oracle, &AmcConfig::default(), base_latency),
            Box::new(Amc::default()),
        ),
        (pqf(&model, &session, &sim, base_latency), Box::new(Pqf)),
    ];

    let mut run = RunBuilder::new(kind).device("kryo385").seed(seed).build().unwrap();
    for (legacy, pruner) in &pairs {
        let out = run.execute(pruner.as_ref()).unwrap();
        assert_eq!(out.method, legacy.method);
        assert_eq!(out.final_fps, legacy.fps, "{}", legacy.method);
        assert_eq!(out.fps_increase_rate, legacy.fps_increase_rate, "{}", legacy.method);
        assert_eq!(out.macs, legacy.macs, "{}", legacy.method);
        assert_eq!(out.params, legacy.params, "{}", legacy.method);
        assert_eq!(out.top1, legacy.top1, "{}", legacy.method);
        assert_eq!(out.top5, legacy.top5, "{}", legacy.method);
        assert_eq!(out.baseline_latency, base_latency, "{}", legacy.method);
    }
}

#[test]
fn run_builder_reproduces_legacy_netadapt_bit_for_bit() {
    let seed = 2;
    let kind = ModelKind::ResNet8Cifar;
    let model = Model::build(kind, seed);
    let sim = Simulator::new(DeviceSpec::kryo385());
    let session = TuningSession::new(&sim, TuneOptions::quick(), seed);
    let mut oracle = ProxyOracle::new();
    let cfg = NetAdaptConfig {
        target_latency_ratio: 0.8,
        max_iterations: 6,
        ..Default::default()
    };
    let legacy = netadapt(&model, &session, &sim, &mut oracle, &cfg);

    let mut run = RunBuilder::new(kind).device("kryo385").seed(seed).build().unwrap();
    let out = run.execute(&NetAdapt::with(cfg)).unwrap();
    assert_eq!(out.final_fps, legacy.outcome.fps);
    assert_eq!(out.fps_increase_rate, legacy.outcome.fps_increase_rate);
    assert_eq!(out.top1, legacy.outcome.top1);
    assert_eq!(out.search_candidates, legacy.candidates_tried);
    assert_eq!(out.iterations.len(), legacy.iterations);
    assert_eq!(out.channels, legacy.state.cout);
}

#[test]
fn registry_selects_algorithms_uniformly_with_no_wiring_branches() {
    // The acceptance loop: every registered name runs through identical
    // builder wiring and returns a servable outcome.
    let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo585")
        .seed(4)
        .max_iterations(3)
        .build()
        .unwrap();
    for name in ["cprune", "magnitude", "fpgm", "netadapt", "amc", "pqf"] {
        let pruner = pruner_by_name(name).expect(name);
        let out = run.execute(pruner.as_ref()).unwrap();
        assert_eq!(out.pruner, name);
        assert_eq!(out.device, "Kryo 585 (Galaxy S20+)");
        assert!(out.final_fps > 0.0 && out.final_fps.is_finite(), "{name}");
        assert!(!out.pareto.is_empty(), "{name}");
    }
}

#[test]
fn seeded_run_with_events_produces_parseable_jsonl_matching_the_outcome() {
    let path = std::env::temp_dir().join("cprune_run_api_events_test.jsonl");
    let _ = std::fs::remove_file(&path);
    let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo385")
        .seed(1)
        .max_iterations(4)
        .observer(Box::new(JsonlSink::create(&path).unwrap()))
        .build()
        .unwrap();
    let out = run.execute(&CPrune::default()).unwrap();

    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 3, "header + events + finished expected");
    let header = json::parse(lines[0]).unwrap();
    assert_eq!(header.get("format").and_then(Json::as_str), Some("cprune-run-events"));
    assert_eq!(header.get("version").and_then(Json::as_usize), Some(1));

    let mut accepted = 0usize;
    let mut checkpoints = 0usize;
    let mut baseline_tuned = 0usize;
    let mut finished: Option<Json> = None;
    for line in &lines[1..] {
        let j = json::parse(line).unwrap_or_else(|e| panic!("bad event line {line}: {e}"));
        match j.get("event").and_then(Json::as_str).expect("event tag") {
            "iteration_accepted" => accepted += 1,
            "checkpoint_emitted" => checkpoints += 1,
            "baseline_tuned" => baseline_tuned += 1,
            "finished" => finished = Some(j.clone()),
            _ => {}
        }
    }
    assert_eq!(baseline_tuned, 1);
    assert_eq!(accepted, out.iterations.len());
    // iteration-0 baseline checkpoint + one per accepted iteration
    assert_eq!(checkpoints, out.iterations.len() + 1);

    let fin = finished.expect("finished event present");
    assert_eq!(fin.get("pruner").and_then(Json::as_str), Some("cprune"));
    assert_eq!(fin.get("final_latency").unwrap().as_f64().unwrap(), out.final_latency);
    assert_eq!(fin.get("final_fps").unwrap().as_f64().unwrap(), out.final_fps);
    assert_eq!(
        fin.get("fps_increase_rate").unwrap().as_f64().unwrap(),
        out.fps_increase_rate
    );
    assert_eq!(fin.get("top1").unwrap().as_f64().unwrap(), out.top1);
    assert_eq!(fin.get("iterations").unwrap().as_usize().unwrap(), out.iterations.len());
    assert_eq!(fin.get("pareto_points").unwrap().as_usize().unwrap(), out.pareto.len());
    // the finished event is the log's last line
    assert_eq!(
        json::parse(lines.last().unwrap()).unwrap().get("event").and_then(Json::as_str),
        Some("finished")
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn registry_publisher_accumulates_exactly_the_run_frontier() {
    let model_name = ModelKind::ResNet8Cifar.name();
    let publisher = RegistryPublisher::new(model_name, "kryo385");
    let registry = publisher.registry();
    let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo385")
        .seed(2)
        .max_iterations(4)
        .observer(Box::new(publisher))
        .build()
        .unwrap();
    let out = run.execute(&CPrune::default()).unwrap();
    let reg = registry.borrow();
    let set = reg.get(model_name, "kryo385").expect("auto-published frontier");
    assert_eq!(set, &out.pareto);
}

/// The events this crate promises to serialize stably — must stay in
/// sync with `tests/golden/run_events.jsonl` (one object per line, after
/// the header). When the schema changes intentionally, bump
/// `EVENTS_VERSION` and regenerate the golden file.
fn golden_events() -> Vec<RunEvent> {
    let mut channels = BTreeMap::new();
    channels.insert(3usize, 16usize);
    channels.insert(11, 32);
    vec![
        RunEvent::BaselineTuned { latency: 0.25, fps: 4.0 },
        RunEvent::CandidateMeasured {
            iteration: 1,
            latency: 0.125,
            latency_target: 0.25,
            candidates_tried: 1,
            scheme: None,
        },
        RunEvent::IterationRejected {
            iteration: 1,
            latency: 0.5,
            latency_target: 0.25,
            short_accuracy: None,
            accuracy_gate: None,
            reason: cprune::run::RejectReason::LatencyGate,
        },
        RunEvent::IterationAccepted {
            iteration: 1,
            latency: 0.125,
            latency_target: 0.25,
            short_accuracy: 0.75,
            accuracy_gate: 0.5,
            filters_removed: 8,
            scheme: None,
        },
        RunEvent::TaskBanned { conv: 7, reason: "accuracy_gate".to_string() },
        RunEvent::CheckpointEmitted {
            checkpoint: Checkpoint {
                iteration: 1,
                latency: 0.125,
                accuracy: 0.75,
                channels,
                schemes: BTreeMap::new(),
            },
        },
        RunEvent::Finished {
            pruner: "cprune".to_string(),
            method: "CPrune".to_string(),
            model: "resnet-8".to_string(),
            device: "kryo385".to_string(),
            final_latency: 0.125,
            final_fps: 8.0,
            fps_increase_rate: 2.0,
            top1: 0.75,
            top5: 0.875,
            macs: 1000,
            params: 100,
            iterations: 1,
            search_candidates: 1,
            pareto_points: 2,
        },
    ]
}

#[test]
fn golden_file_pins_the_jsonl_event_schema() {
    let golden = include_str!("golden/run_events.jsonl");
    let lines: Vec<&str> = golden.lines().collect();
    let events = golden_events();
    assert_eq!(
        lines.len(),
        events.len() + 1,
        "golden file must hold the header plus one line per pinned event"
    );
    assert_eq!(
        RunEvent::header_json().to_string(),
        lines[0],
        "header drifted from the golden file"
    );
    for (ev, line) in events.iter().zip(&lines[1..]) {
        assert_eq!(
            ev.to_json().to_string(),
            *line,
            "event schema drifted from the golden file ({}); bump EVENTS_VERSION \
             and regenerate tests/golden/run_events.jsonl if intentional",
            ev.kind()
        );
        // every golden line is canonical writer output (parse → rewrite
        // is the identity), so the file doubles as a parser fixture
        let parsed = json::parse(line).unwrap_or_else(|e| panic!("bad golden line {line}: {e}"));
        assert_eq!(parsed.to_string(), *line);
    }
}
