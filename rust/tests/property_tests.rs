//! Randomized property tests (hand-rolled quickcheck-style over the
//! in-tree PCG RNG — proptest is unavailable offline). Each property runs
//! across many seeded cases; failures print the seed for replay.

use cprune::accuracy::{Criterion, ProxyOracle, TrainPhase};
use cprune::accuracy::AccuracyOracle;
use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::graph::prune::{apply, PruneState};
use cprune::graph::shape_infer;
use cprune::graph::stats;
use cprune::graph::ops::OpKind;
use cprune::pruner::summarize;
use cprune::relay::partition::{extract_tasks, partition};
use cprune::tir::{Program, Workload};
use cprune::tuner::search::tune_task_reference;
use cprune::tuner::{tune_task, TuneOptions, TuningSession};
use cprune::util::rng::Rng;
use cprune::util::lcm;
use std::collections::HashMap;

fn random_state(model: &Model, rng: &mut Rng) -> PruneState {
    let mut st = PruneState::full(model);
    for &conv in &model.prunable {
        if rng.f32() < 0.6 {
            let total = st.remaining(conv);
            let k = rng.below(total.max(1));
            st.shrink(conv, k);
        }
    }
    st
}

#[test]
fn prop_pruned_graphs_always_shape_infer() {
    // Any sequence of shrink() calls on prunable convs yields a valid graph.
    for kind in [ModelKind::Vgg16Cifar, ModelKind::ResNet18ImageNet,
                 ModelKind::MobileNetV2ImageNet, ModelKind::MnasNet10ImageNet,
                 ModelKind::ResNet8Cifar] {
        let model = Model::build(kind, 1);
        for seed in 0..20u64 {
            let mut rng = Rng::new(seed);
            let st = random_state(&model, &mut rng);
            let g = apply(&model.graph, &st.cout)
                .unwrap_or_else(|e| panic!("{kind:?} seed {seed}: {e}"));
            shape_infer::infer(&g).unwrap_or_else(|e| panic!("{kind:?} seed {seed}: {e}"));
            let (f1, p1) = stats::flops_params(&g);
            let (f0, p0) = stats::flops_params(&model.graph);
            assert!(f1 <= f0 && p1 <= p0, "{kind:?} seed {seed}: cost grew");
        }
    }
}

#[test]
fn prop_partition_is_a_partition() {
    // Every conv/dense anchored exactly once, on arbitrary pruned graphs.
    let model = Model::build(ModelKind::MobileNetV2ImageNet, 2);
    for seed in 0..15u64 {
        let mut rng = Rng::new(seed);
        let st = random_state(&model, &mut rng);
        let g = apply(&model.graph, &st.cout).unwrap();
        let part = partition(&g);
        let mut seen = std::collections::BTreeSet::new();
        for sg in &part.subgraphs {
            for &n in &sg.nodes {
                assert!(seen.insert(n), "seed {seed}: node {n} claimed twice");
            }
        }
        let anchors: std::collections::BTreeSet<usize> =
            part.subgraphs.iter().map(|s| s.anchor).collect();
        for &c in &g.conv_ids() {
            assert!(anchors.contains(&c), "seed {seed}: conv {c} unanchored");
        }
    }
}

#[test]
fn prop_task_dedup_conserves_subgraphs() {
    for kind in [ModelKind::ResNet18ImageNet, ModelKind::Vgg16Cifar] {
        let model = Model::build(kind, 3);
        for seed in 0..10u64 {
            let mut rng = Rng::new(seed);
            let st = random_state(&model, &mut rng);
            let g = apply(&model.graph, &st.cout).unwrap();
            let (part, table) = extract_tasks(&g);
            let covered: usize = table.tasks().map(|t| t.subgraphs.len()).sum();
            assert_eq!(covered, part.subgraphs.len(), "{kind:?} seed {seed}");
            // each subgraph belongs to exactly one task
            let mut seen = std::collections::BTreeSet::new();
            for t in table.tasks() {
                for &sg in &t.subgraphs {
                    assert!(seen.insert(sg), "{kind:?} seed {seed}: subgraph {sg} in 2 tasks");
                }
            }
        }
    }
}

#[test]
fn prop_min_step_formula_matches_direct_lcm() {
    // min_filter_prune_step == LCM(prod/max over both filter trees).
    let mut rng = Rng::new(7);
    for _ in 0..500 {
        let ff = *rng.choose(&[16usize, 32, 64, 96, 128, 256, 512]);
        let w = Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec![],
        );
        let p = Program::sample(&w, &mut rng);
        let direct = {
            let f = |s: &[usize]| {
                let prod: u64 = s.iter().map(|&x| x as u64).product();
                prod / s.iter().copied().max().unwrap() as u64
            };
            lcm(f(&p.ff_splits), f(&p.ax3_splits)) as usize
        };
        assert_eq!(p.min_filter_prune_step(), direct);
    }
}

#[test]
fn prop_structure_preserved_after_step_prune() {
    // For exact (unpadded) programs, pruning exactly the minimum step keeps
    // the split-tree shape reconstructible (with_pruned_filters succeeds).
    let mut rng = Rng::new(11);
    let mut checked = 0;
    while checked < 200 {
        let ff = *rng.choose(&[32usize, 64, 128, 256, 512]);
        let w = Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec![],
        );
        let p = Program::sample(&w, &mut rng);
        let exact = p.ff_splits.iter().product::<usize>() == ff
            && p.ax3_splits.iter().product::<usize>() == ff;
        if !exact {
            continue;
        }
        checked += 1;
        let step = p.min_filter_prune_step();
        if step >= ff {
            continue;
        }
        let q = p.with_pruned_filters(ff - step);
        assert!(
            q.is_some(),
            "step prune broke structure: ff={ff} step={step} {:?}/{:?}",
            p.ff_splits,
            p.ax3_splits
        );
        let q = q.unwrap();
        assert_eq!(q.ff_splits.len(), p.ff_splits.len());
        assert_eq!(q.ax3_splits.len(), p.ax3_splits.len());
    }
}

#[test]
fn prop_optimized_search_bit_identical_to_reference() {
    // The optimized tune_task (scoring cache, bounded elite pool,
    // double-buffered evolution — DESIGN.md §10) must return bit-identical
    // (best, latency, measured) to the straightforward reference search
    // across random seeds, workload shapes, devices, budgets, and seeded
    // vs unseeded starts.
    let devices = [DeviceSpec::kryo280(), DeviceSpec::kryo385(), DeviceSpec::kryo585()];
    for seed in 0..12u64 {
        let mut wrng = Rng::new(seed.wrapping_mul(0x9e37) ^ 0xC0FFEE);
        let ff = *wrng.choose(&[16usize, 32, 64, 96, 128, 179, 256]);
        let oh = 4 + wrng.below(28);
        let w = Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, oh, oh, ff],
            vec!["bn", "relu"],
        );
        let sim = Simulator::new(devices[seed as usize % devices.len()].clone());
        let opts = if seed % 2 == 0 {
            TuneOptions::quick()
        } else {
            TuneOptions { population: 32, rounds: 4, measure_top_k: 8, repeats: 2 }
        };
        let seed_prog = if seed % 3 == 0 {
            Some(Program::naive(&w))
        } else {
            None
        };
        let a = tune_task(&w, &sim, &opts, &mut Rng::new(seed), seed_prog.as_ref());
        let b = tune_task_reference(&w, &sim, &opts, &mut Rng::new(seed), seed_prog.as_ref());
        assert_eq!(a.best, b.best, "seed {seed}: best program diverged");
        assert_eq!(
            a.latency.to_bits(),
            b.latency.to_bits(),
            "seed {seed}: latency diverged ({} vs {})",
            a.latency,
            b.latency
        );
        assert_eq!(a.measured, b.measured, "seed {seed}: measured count diverged");
    }
}

#[test]
fn prop_tune_graph_identical_across_thread_budgets() {
    // Work-stealing claim order must never leak into results: 1 thread,
    // 8 threads and 0 (= all cores) produce identical task tables and
    // measured counts — each task's RNG stream derives from its own
    // workload hash, so who tunes it is irrelevant (DESIGN.md §10).
    for (kind, seed) in [
        (ModelKind::ResNet8Cifar, 3u64),
        (ModelKind::ResNet8Cifar, 11),
        (ModelKind::Vgg16Cifar, 5),
    ] {
        let m = Model::build(kind, seed);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut outcomes = Vec::new();
        for threads in [1usize, 8, 0] {
            let mut sess = TuningSession::new(&sim, TuneOptions::quick(), seed);
            sess.threads = threads;
            let table = sess.tune_graph(&m.graph, &HashMap::new());
            let mut lats: Vec<(usize, u64)> = table
                .tasks()
                .map(|t| (t.id, t.best_latency.unwrap().to_bits()))
                .collect();
            lats.sort_unstable();
            outcomes.push((lats, sess.measured_count()));
        }
        assert_eq!(outcomes[0], outcomes[1], "{kind:?} seed {seed}: 1 vs 8 threads");
        assert_eq!(outcomes[0], outcomes[2], "{kind:?} seed {seed}: 1 vs all-cores");
    }
}

#[test]
fn prop_measured_never_exceeds_budget() {
    // The honest measured counter is bounded by rounds × measure_top_k
    // and is strictly positive whenever any round measures.
    for seed in 0..10u64 {
        let mut wrng = Rng::new(seed + 77);
        let ff = *wrng.choose(&[24usize, 48, 64, 128]);
        let w = Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 16, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec![],
        );
        let sim = Simulator::new(DeviceSpec::mali_g72());
        let opts = TuneOptions::quick();
        let r = tune_task(&w, &sim, &opts, &mut Rng::new(seed), None);
        assert!(r.measured > 0);
        assert!(
            r.measured <= opts.rounds * opts.measure_top_k,
            "seed {seed}: counted {} > budget {}",
            r.measured,
            opts.rounds * opts.measure_top_k
        );
    }
}

#[test]
fn prop_simulator_sane_on_random_programs() {
    let mut rng = Rng::new(13);
    let devices = [DeviceSpec::kryo280(), DeviceSpec::kryo585(), DeviceSpec::mali_g72()];
    for _ in 0..300 {
        let ff = 8 + rng.below(512);
        let oh = 1 + rng.below(56);
        let w = Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 16, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, oh, oh, ff],
            vec![],
        );
        let p = Program::sample(&w, &mut rng);
        for spec in &devices {
            let sim = Simulator::new(spec.clone());
            let l = sim.latency(&w, &p);
            assert!(l.is_finite() && l > 0.0, "bad latency {l}");
            assert!(l >= sim.spec.dispatch_overhead_s);
        }
    }
}

#[test]
fn prop_proxy_oracle_monotone_in_pruning() {
    // Strictly more pruning on the same layer never increases accuracy.
    let model = Model::build(ModelKind::ResNet18ImageNet, 5);
    let mut oracle = ProxyOracle::new();
    for seed in 0..30u64 {
        let mut rng = Rng::new(seed);
        let conv = *rng.choose(&model.prunable);
        let mut light = PruneState::full(&model);
        let total = light.remaining(conv);
        let k1 = 1 + rng.below(total / 2);
        let k2 = k1 + 1 + rng.below(total / 4);
        light.shrink(conv, k1);
        let mut heavy = PruneState::full(&model);
        heavy.shrink(conv, k2);
        let a_light = oracle.top1(&summarize(&model, &light, Criterion::L1Norm), TrainPhase::Short);
        let a_heavy = oracle.top1(&summarize(&model, &heavy, Criterion::L1Norm), TrainPhase::Short);
        assert!(a_heavy <= a_light + 1e-12, "seed {seed}: heavier prune increased accuracy");
    }
}

#[test]
fn prop_shrink_never_below_floor() {
    let model = Model::build(ModelKind::Vgg16Cifar, 6);
    for seed in 0..20u64 {
        let mut rng = Rng::new(seed);
        let mut st = PruneState::full(&model);
        for _ in 0..50 {
            let conv = *rng.choose(&model.prunable);
            st.shrink(conv, 1 + rng.below(64));
        }
        for (_, &c) in &st.cout {
            assert!(c >= 2, "seed {seed}: channel below floor");
        }
    }
}
