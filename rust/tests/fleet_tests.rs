//! Integration: the fleet compilation layer — persistent TuneCache
//! round-trips through disk, and FleetSession results are independent of
//! the thread budget.

use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::tuner::{FleetOptions, FleetSession, TuneCache, TuneOptions, TuningSession};
use std::collections::HashMap;

fn specs3() -> Vec<DeviceSpec> {
    vec![DeviceSpec::kryo385(), DeviceSpec::kryo585(), DeviceSpec::mali_g72()]
}

#[test]
fn cache_roundtrip_warm_starts_a_fresh_session() {
    // tune → persist → a fresh session loads → zero new programs measured.
    let m = Model::build(ModelKind::ResNet8Cifar, 0);
    let sim = Simulator::new(DeviceSpec::kryo385());
    let cold = TuningSession::new(&sim, TuneOptions::quick(), 11);
    let t_cold = cold.tune_graph(&m.graph, &HashMap::new());
    assert!(cold.measured_count() > 0);

    let path = std::env::temp_dir().join("cprune_fleet_test_roundtrip.cache.json");
    cold.cache.save(&path, sim.spec.name).unwrap();

    // wrong-device loads are refused; the right device round-trips
    assert!(TuneCache::load(&path, "some other device").is_err());
    let loaded = TuneCache::load(&path, sim.spec.name).unwrap();
    assert_eq!(loaded.len(), cold.cache.len());
    let warm = TuningSession::with_cache(&sim, TuneOptions::quick(), 11, loaded);
    let t_warm = warm.tune_graph(&m.graph, &HashMap::new());
    assert_eq!(warm.measured_count(), 0, "persisted cache missed");
    assert_eq!(t_cold.model_latency(), t_warm.model_latency());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fleet_results_identical_at_1_and_n_threads() {
    let m = Model::build(ModelKind::ResNet8Cifar, 0);
    let run = |threads: usize| {
        let mut fleet = FleetSession::new(
            specs3(),
            FleetOptions { tune: TuneOptions::quick(), threads, cross_seed: true },
            4,
        );
        fleet.tune_graph(&m.graph)
    };
    let serial = run(1);
    let parallel = run(8);
    assert_eq!(serial.devices.len(), parallel.devices.len());
    for (a, b) in serial.devices.iter().zip(&parallel.devices) {
        assert_eq!(a.device, b.device);
        assert_eq!(a.latency, b.latency, "{}: thread budget changed results", a.device);
        assert_eq!(a.measured, b.measured, "{}: measured drifted", a.device);
        assert_eq!(a.table.model_latency(), b.table.model_latency());
    }
    assert_eq!(serial.total_measured(), parallel.total_measured());
}

#[test]
fn fleet_caches_roundtrip_through_directory() {
    let m = Model::build(ModelKind::ResNet8Cifar, 0);
    let dir = std::env::temp_dir().join("cprune_fleet_test_cachedir");
    let opts = || FleetOptions { tune: TuneOptions::quick(), ..Default::default() };

    let mut cold = FleetSession::new(specs3(), opts(), 9);
    let r_cold = cold.tune_graph(&m.graph);
    assert!(r_cold.total_measured() > 0);
    cold.save_caches(&dir).unwrap();

    let mut warm = FleetSession::new(specs3(), opts(), 9);
    assert_eq!(warm.load_caches(&dir).unwrap(), 3);
    let r_warm = warm.tune_graph(&m.graph);
    assert_eq!(r_warm.total_measured(), 0, "fleet warm start re-measured");
    for (c, w) in r_cold.devices.iter().zip(&r_warm.devices) {
        assert_eq!(c.latency, w.latency, "{} drifted through persistence", c.device);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_cache_files_are_rejected() {
    let path = std::env::temp_dir().join("cprune_fleet_test_corrupt.cache.json");
    std::fs::write(
        &path,
        "{\"format\":\"cprune-tune-cache\",\"version\":99,\"device\":\"d\",\"entries\":[]}",
    )
    .unwrap();
    assert!(TuneCache::load(&path, "d").is_err());
    std::fs::write(&path, "definitely not json").unwrap();
    assert!(TuneCache::load(&path, "d").is_err());
    let _ = std::fs::remove_file(&path);
}
