//! Integration across the compiler substrate: every zoo model goes
//! through partition → task extraction → tuning → compile → FPS on every
//! mobile device at smoke scale, and CPrune improves each model on at
//! least one device.

use cprune::accuracy::ProxyOracle;
use cprune::compiler;
use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::pruner::{cprune as run_cprune, CPruneConfig};
use cprune::tuner::{TuneOptions, TuningSession};
use std::collections::HashMap;

#[test]
fn every_model_compiles_on_every_device() {
    for kind in ModelKind::all() {
        let model = Model::build(kind, 0);
        for spec in DeviceSpec::mobile_targets() {
            let sim = Simulator::new(spec);
            let session = TuningSession::new(&sim, TuneOptions::quick(), 1);
            let tuned = compiler::compile_tuned(&model.graph, &session, &HashMap::new());
            let fallback = compiler::compile_fallback(&model.graph, &sim);
            assert!(tuned.fps().is_finite() && tuned.fps() > 0.0, "{kind:?}");
            assert!(
                tuned.fps() > fallback.fps() * 0.8,
                "{kind:?} on {}: tuned {} worse than fallback {}",
                sim.spec.name,
                tuned.fps(),
                fallback.fps()
            );
        }
    }
}

#[test]
fn mobile_fps_ordering_is_plausible() {
    // MobileNetV2 is faster than ResNet-18 on the same CPU (paper Table 1:
    // 28.2 vs 18.9 FPS); newer CPUs are faster.
    let sim385 = Simulator::new(DeviceSpec::kryo385());
    let sess = TuningSession::new(&sim385, TuneOptions::quick(), 2);
    let r18 = compiler::compile_tuned(
        &Model::build(ModelKind::ResNet18ImageNet, 0).graph, &sess, &HashMap::new());
    let mb2 = compiler::compile_tuned(
        &Model::build(ModelKind::MobileNetV2ImageNet, 0).graph, &sess, &HashMap::new());
    assert!(mb2.fps() > r18.fps(), "mb2 {} vs r18 {}", mb2.fps(), r18.fps());
}

#[test]
fn cprune_improves_resnet18_on_kryo585() {
    let model = Model::build(ModelKind::ResNet18Cifar, 0);
    let sim = Simulator::new(DeviceSpec::kryo585());
    let mut oracle = ProxyOracle::new();
    let cfg = CPruneConfig {
        max_iterations: 12,
        tune_opts: TuneOptions::quick(),
        ..Default::default()
    };
    let r = run_cprune(&model, &sim, &mut oracle, &cfg);
    assert!(r.fps_increase_rate > 1.2, "rate {}", r.fps_increase_rate);
    assert!(r.final_top1 > 0.90);
}
