//! CLI-level integration: commands run end-to-end and produce the
//! documented outputs (including the JSON report schema).

use cprune::cli;
use cprune::util::json;

fn run(args: &[&str]) -> i32 {
    cli::run(args.iter().map(|s| s.to_string()).collect())
}

#[test]
fn help_and_unknown_commands() {
    assert_eq!(run(&["help"]), 0);
    assert_eq!(run(&[]), 0);
    assert_eq!(run(&["frobnicate"]), 2);
    assert_eq!(run(&["report", "nosuchfig"]), 2);
}

#[test]
fn prune_writes_valid_json_report() {
    let path = std::env::temp_dir().join("cprune_cli_test_report.json");
    let p = path.to_str().unwrap();
    let code = run(&[
        "prune", "--model", "resnet8-cifar", "--device", "kryo385",
        "--iters", "3", "--out", p,
    ]);
    assert_eq!(code, 0);
    let text = std::fs::read_to_string(&path).unwrap();
    let j = json::parse(&text).expect("CLI report must be valid JSON");
    assert!(j.get("final_fps").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("iterations").unwrap().as_arr().is_some());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn dot_command_succeeds() {
    assert_eq!(run(&["dot", "--model", "resnet8-cifar"]), 0);
}

#[test]
fn run_command_selects_pruners_by_name_and_streams_events() {
    let path = std::env::temp_dir().join("cprune_cli_test_run_events.jsonl");
    let p = path.to_str().unwrap();
    let _ = std::fs::remove_file(&path);
    let code = run(&[
        "run", "--pruner", "magnitude", "--model", "resnet8-cifar",
        "--device", "kryo385", "--quiet", "--events", p,
    ]);
    assert_eq!(code, 0);
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    let header = json::parse(lines[0]).expect("header line must parse");
    assert_eq!(header.get("format").unwrap().as_str(), Some("cprune-run-events"));
    let last = json::parse(lines.last().unwrap()).expect("finished line must parse");
    assert_eq!(last.get("event").unwrap().as_str(), Some("finished"));
    assert_eq!(last.get("pruner").unwrap().as_str(), Some("magnitude"));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn run_command_rejects_unknown_pruners() {
    assert_eq!(run(&["run", "--pruner", "dropout", "--model", "resnet8-cifar"]), 2);
}

#[test]
fn run_command_accepts_key_equals_value_flags() {
    assert_eq!(
        run(&["run", "--pruner=pqf", "--model=resnet8-cifar", "--quiet"]),
        0
    );
}

#[test]
fn flag_lookalike_values_fail_loudly_instead_of_being_swallowed() {
    // Legacy parsing silently made `--events` a boolean here.
    assert_eq!(run(&["run", "--model", "resnet8-cifar", "--events", "--foo.jsonl"]), 2);
}

#[test]
fn serve_with_no_search_and_missing_frontier_fails_with_nonzero_exit() {
    // --no-search forbids the CPrune backfill, and no registry was
    // supplied: the requested device has no frontier to serve from.
    assert_eq!(
        run(&["serve", "--model", "resnet8-cifar", "--devices", "kryo385", "--no-search"]),
        1
    );
}

#[test]
fn report_fig6_smoke() {
    assert_eq!(run(&["report", "fig6", "--scale", "smoke"]), 0);
}

#[test]
fn bench_quick_writes_versioned_perf_jsons() {
    let dir = std::env::temp_dir().join("cprune_cli_test_bench");
    let d = dir.to_str().unwrap();
    let _ = std::fs::remove_file(dir.join("BENCH_tuner.json"));
    let _ = std::fs::remove_file(dir.join("BENCH_e2e.json"));
    assert_eq!(run(&["bench", "--tier", "quick", "--seed", "42", "--out-dir", d]), 0);
    for suite in ["tuner", "e2e"] {
        let path = dir.join(format!("BENCH_{suite}.json"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{} missing: {e}", path.display()));
        let j = json::parse(&text).expect("BENCH json must parse");
        assert_eq!(j.get("format").unwrap().as_str(), Some("cprune-bench"));
        assert_eq!(j.get("version").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("tier").unwrap().as_str(), Some("quick"));
        let records = j.get("records").unwrap().as_arr().unwrap();
        assert!(!records.is_empty(), "{suite}: no records");
        for r in records {
            assert!(r.get("name").unwrap().as_str().is_some());
            assert!(r.get("wall_s").unwrap().as_f64().unwrap() >= 0.0);
            assert!(r.get("programs_measured").unwrap().as_f64().is_some());
        }
        let _ = std::fs::remove_file(&path);
    }
    let _ = std::fs::remove_dir(&dir);
}

#[test]
fn bench_rejects_unknown_tier() {
    assert_eq!(run(&["bench", "--tier", "medium"]), 2);
}

#[test]
fn tune_warm_starts_from_cache_file() {
    let path = std::env::temp_dir().join("cprune_cli_test_tune.cache.json");
    let p = path.to_str().unwrap();
    let _ = std::fs::remove_file(&path);
    let args = ["tune", "--model", "resnet8-cifar", "--device", "kryo385", "--cache", p];
    assert_eq!(run(&args), 0);
    assert!(path.exists(), "cache file not written");
    // second run loads the cache (exit 0; the warm path is covered
    // quantitatively in tests/fleet_tests.rs and the tuner unit tests)
    assert_eq!(run(&args), 0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fleet_tunes_three_devices() {
    assert_eq!(
        run(&["fleet", "--model", "resnet8-cifar", "--devices", "kryo280,kryo385,kryo585",
              "--quick"]),
        0
    );
}

#[test]
fn serve_runs_end_to_end_and_persists_the_registry() {
    let path = std::env::temp_dir().join("cprune_cli_test_serve_registry.json");
    let p = path.to_str().unwrap();
    let _ = std::fs::remove_file(&path);
    let args = [
        "serve", "--model", "resnet8-cifar", "--devices", "kryo385",
        "--iters", "3", "--rps", "200", "--requests", "300",
        "--slo-ms", "25", "--accuracy-floor", "0.78", "--registry", p,
    ];
    assert_eq!(run(&args), 0);
    assert!(path.exists(), "registry file not written");
    // second run warm-starts from the persisted Pareto sets
    assert_eq!(run(&args), 0);
    // the file is the documented versioned format
    let text = std::fs::read_to_string(&path).unwrap();
    let j = json::parse(&text).unwrap();
    assert_eq!(j.get("format").unwrap().as_str(), Some("cprune-pareto-registry"));
    assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn serve_rejects_bad_flags() {
    assert_eq!(run(&["serve", "--devices", "nosuchdevice"]), 2);
    assert_eq!(run(&["serve", "--rps", "not-a-number"]), 2);
}

#[test]
fn fleet_cache_dir_roundtrip() {
    let dir = std::env::temp_dir().join("cprune_cli_test_fleet_caches");
    let _ = std::fs::remove_dir_all(&dir);
    let d = dir.to_str().unwrap();
    let args = ["fleet", "--model", "resnet8-cifar", "--devices", "kryo385,mali-g72",
                "--quick", "--cache-dir", d];
    assert_eq!(run(&args), 0);
    assert!(dir.read_dir().unwrap().count() >= 2, "per-device caches not written");
    assert_eq!(run(&args), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cache_for_another_device_is_refused() {
    let path = std::env::temp_dir().join("cprune_cli_test_xdev.cache.json");
    let p = path.to_str().unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(
        run(&["tune", "--model", "resnet8-cifar", "--device", "kryo385", "--cache", p]),
        0
    );
    // same cache file, different device: must fail loudly, not serve
    // kryo385 latencies as kryo585 results
    assert_eq!(
        run(&["tune", "--model", "resnet8-cifar", "--device", "kryo585", "--cache", p]),
        1
    );
    let _ = std::fs::remove_file(&path);
}

const DEVICE_FILE: &str = r#"{"format":"cprune-devices","version":1,"devices":[
  {"short":"testphone","name":"Test Phone (CLI)","kind":"cpu","cores":6,
   "peak_macs_per_core":8.0e9,"simd_lanes":4,"l1_bytes":65536,
   "l2_bytes":2097152,"mem_bytes_per_s":2.0e10,"dispatch_overhead_s":7e-6}]}"#;

#[test]
fn unknown_devices_exit_with_usage_errors() {
    // The diagnostic text (listing every registry name) is unit-tested in
    // device::registry; here the CLI paths must all reject cleanly.
    assert_eq!(run(&["prune", "--model", "resnet8-cifar", "--device", "galaxy-s10"]), 2);
    assert_eq!(run(&["run", "--model", "resnet8-cifar", "--target", "lut:galaxy-s10"]), 2);
    assert_eq!(run(&["fleet", "--devices", "kryo385,galaxy-s10"]), 2);
}

#[test]
fn provider_prefixes_are_validated_not_silently_dropped() {
    // lut: is only meaningful to run/prune — other commands must refuse
    // rather than silently downgrade to the analytic provider.
    assert_eq!(run(&["tune", "--model", "resnet8-cifar", "--target", "lut:kryo385"]), 2);
    // unknown providers are named in the diagnostic, not treated as devices
    assert_eq!(run(&["run", "--model", "resnet8-cifar", "--target", "replay:kryo385"]), 2);
    // --device never takes a provider prefix
    assert_eq!(run(&["prune", "--model", "resnet8-cifar", "--device", "lut:kryo385"]), 2);
}

#[test]
fn calibration_table_feeds_back_into_a_run() {
    let path = std::env::temp_dir().join("cprune_cli_test_calibration_run.json");
    let _ = std::fs::remove_file(&path);
    let p = path.to_str().unwrap();
    assert_eq!(run(&["calibrate", "--device", "kryo280", "--save", p]), 0);
    assert_eq!(
        run(&["run", "--pruner", "magnitude", "--model", "resnet8-cifar",
              "--device", "kryo280", "--calibration", p, "--quiet"]),
        0
    );
    // a corrupt table fails loudly instead of running uncalibrated
    std::fs::write(&path, "not json").unwrap();
    assert_eq!(
        run(&["run", "--pruner", "magnitude", "--model", "resnet8-cifar",
              "--device", "kryo280", "--calibration", p, "--quiet"]),
        1
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn devices_subcommand_lists_the_registry() {
    assert_eq!(run(&["devices"]), 0);
    let path = std::env::temp_dir().join("cprune_cli_test_devices_list.json");
    std::fs::write(&path, DEVICE_FILE).unwrap();
    assert_eq!(run(&["devices", "--device-file", path.to_str().unwrap()]), 0);
    assert_eq!(run(&["devices", "--device-file", "/nonexistent/devs.json"]), 1);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn custom_device_from_file_is_tunable_end_to_end() {
    let path = std::env::temp_dir().join("cprune_cli_test_devices_run.json");
    std::fs::write(&path, DEVICE_FILE).unwrap();
    let p = path.to_str().unwrap();
    // resolves and tunes end-to-end through `cprune run --target <name>`
    assert_eq!(
        run(&["run", "--pruner", "magnitude", "--model", "resnet8-cifar",
              "--device-file", p, "--target", "testphone", "--quiet"]),
        0
    );
    // without the device file the name is unknown
    assert_eq!(
        run(&["run", "--pruner", "magnitude", "--model", "resnet8-cifar",
              "--target", "testphone", "--quiet"]),
        2
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn record_then_replay_reproduces_the_event_stream_byte_for_byte() {
    let dir = std::env::temp_dir();
    let trace = dir.join("cprune_cli_test_replay.trace.json");
    let rec_events = dir.join("cprune_cli_test_replay_rec.jsonl");
    let rep_events = dir.join("cprune_cli_test_replay_rep.jsonl");
    for f in [&trace, &rec_events, &rep_events] {
        let _ = std::fs::remove_file(f);
    }
    let base = [
        "run", "--pruner", "cprune", "--model", "resnet8-cifar",
        "--device", "kryo385", "--iters", "2", "--seed", "7", "--quiet",
    ];
    let mut rec: Vec<&str> = base.to_vec();
    let (t, re, rp) = (
        trace.to_str().unwrap().to_string(),
        rec_events.to_str().unwrap().to_string(),
        rep_events.to_str().unwrap().to_string(),
    );
    rec.extend(["--events", &re, "--record-trace", &t]);
    assert_eq!(run(&rec), 0);
    assert!(trace.exists(), "trace not written");
    let mut rep: Vec<&str> = base.to_vec();
    rep.extend(["--events", &rp, "--replay-trace", &t]);
    assert_eq!(run(&rep), 0);
    let a = std::fs::read(&rec_events).unwrap();
    let b = std::fs::read(&rep_events).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "replayed RunEvent JSONL is not byte-identical");
    for f in [&trace, &rec_events, &rep_events] {
        let _ = std::fs::remove_file(f);
    }
}

#[test]
fn lut_target_runs_through_the_cli() {
    assert_eq!(
        run(&["run", "--pruner", "magnitude", "--model", "resnet8-cifar",
              "--target", "lut:kryo385", "--quiet"]),
        0
    );
}

#[test]
fn calibrate_saves_a_calibration_table() {
    let path = std::env::temp_dir().join("cprune_cli_test_calibration.json");
    let _ = std::fs::remove_file(&path);
    let p = path.to_str().unwrap();
    assert_eq!(run(&["calibrate", "--device", "kryo280", "--save", p]), 0);
    let text = std::fs::read_to_string(&path).unwrap();
    let j = json::parse(&text).unwrap();
    assert_eq!(j.get("format").unwrap().as_str(), Some("cprune-calibration"));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_cache_fails_loudly() {
    let path = std::env::temp_dir().join("cprune_cli_test_corrupt.cache.json");
    std::fs::write(&path, "not json at all").unwrap();
    let p = path.to_str().unwrap();
    assert_eq!(
        run(&["tune", "--model", "resnet8-cifar", "--device", "kryo385", "--cache", p]),
        1
    );
    let _ = std::fs::remove_file(&path);
}
