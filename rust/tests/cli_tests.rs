//! CLI-level integration: commands run end-to-end and produce the
//! documented outputs (including the JSON report schema).

use cprune::cli;
use cprune::util::json;

fn run(args: &[&str]) -> i32 {
    cli::run(args.iter().map(|s| s.to_string()).collect())
}

#[test]
fn help_and_unknown_commands() {
    assert_eq!(run(&["help"]), 0);
    assert_eq!(run(&[]), 0);
    assert_eq!(run(&["frobnicate"]), 2);
    assert_eq!(run(&["report", "nosuchfig"]), 2);
}

#[test]
fn prune_writes_valid_json_report() {
    let path = std::env::temp_dir().join("cprune_cli_test_report.json");
    let p = path.to_str().unwrap();
    let code = run(&[
        "prune", "--model", "resnet8-cifar", "--device", "kryo385",
        "--iters", "3", "--out", p,
    ]);
    assert_eq!(code, 0);
    let text = std::fs::read_to_string(&path).unwrap();
    let j = json::parse(&text).expect("CLI report must be valid JSON");
    assert!(j.get("final_fps").unwrap().as_f64().unwrap() > 0.0);
    assert!(j.get("iterations").unwrap().as_arr().is_some());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn dot_command_succeeds() {
    assert_eq!(run(&["dot", "--model", "resnet8-cifar"]), 0);
}

#[test]
fn report_fig6_smoke() {
    assert_eq!(run(&["report", "fig6", "--scale", "smoke"]), 0);
}
