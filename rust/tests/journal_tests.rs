//! Integration: the crash-safety plane (DESIGN.md §15).
//!
//! The acceptance pins:
//!
//! 1. **Kill-point property** — truncating a run journal at *every*
//!    record boundary (with and without a torn tail) and resuming
//!    reproduces the uninterrupted run's RunEvent JSONL byte-for-byte,
//!    and the resumed journal finishes cleanly under `cprune check`;
//! 2. **Torn-write fuzz** — an injected tear at write site `cache`
//!    leaves the old document in place, loadable and check-clean, for
//!    every seeded tear length;
//! 3. **Real abort** — a subprocess `cprune run --journal --faults
//!    abort@iter:1` dies with [`ABORT_EXIT_CODE`] at the barrier, and
//!    `cprune run --resume` completes the run with an event stream
//!    byte-identical to an uninterrupted reference (the same discipline
//!    the `crash-resume` CI job enforces).

use cprune::graph::model_zoo::ModelKind;
use cprune::run::{CPrune, JournalConfig, JsonlSink, RunBuilder};
use cprune::tuner::TuneCache;
use cprune::util::fault::{self, FaultPlan, ABORT_EXIT_CODE};
use cprune::verify::artifact::check_text;
use std::path::{Path, PathBuf};
use std::process::Command;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("cprune-journal-it-{}-{name}", std::process::id()))
}

fn cfg(iters: usize) -> JournalConfig {
    JournalConfig {
        seed: 7,
        pruner: "cprune".to_string(),
        model: "resnet8-cifar".to_string(),
        device: "kryo385".to_string(),
        iters,
        target_acc: None,
    }
}

/// Execute one seeded CPrune run writing its RunEvent JSONL to
/// `events`; `journal`/`resume` wire the crash-safety plane. Returns
/// the event stream's bytes.
fn run_once(events: &Path, journal: Option<&Path>, resume: Option<&Path>) -> Vec<u8> {
    let mut b = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo385")
        .seed(7)
        .max_iterations(3)
        .observer(Box::new(JsonlSink::create(events).unwrap()));
    if let Some(p) = journal {
        b = b.journal(p, cfg(3));
    }
    if let Some(p) = resume {
        b = b.resume(p);
    }
    let mut run = b.build().unwrap();
    run.execute(&CPrune::default()).unwrap();
    drop(run);
    std::fs::read(events).unwrap()
}

#[test]
fn golden_journal_pins_the_record_schema() {
    // `tests/golden/run_journal.jsonl` is the committed, check-artifacts
    // swept example of every `cprune-run-journal` record kind. Editing
    // the schema means bumping JOURNAL_VERSION and regenerating it.
    let golden = include_str!("golden/run_journal.jsonl");
    assert_eq!(check_text(golden), Some(vec![]));
    for kind in ["config", "baseline", "iteration", "resumed", "finished"] {
        assert!(
            golden.contains(&format!("\"record\":\"{kind}\"")),
            "golden journal must exercise record kind '{kind}'"
        );
    }
}

#[test]
fn resume_from_every_barrier_is_byte_identical() {
    let ref_events = tmp("ref-events.jsonl");
    let ref_journal = tmp("ref.journal");
    let reference = run_once(&ref_events, Some(&ref_journal), None);
    let journal_text = std::fs::read_to_string(&ref_journal).unwrap();
    let diags = check_text(&journal_text).expect("journals are a recognized artifact");
    assert!(diags.is_empty(), "reference journal failed verification: {diags:?}");
    // header, config, baseline, iteration(s), finished
    let lines: Vec<&str> = journal_text.lines().collect();
    assert!(lines.len() >= 4, "journal too short to exercise barriers:\n{journal_text}");
    assert!(lines.last().unwrap().contains("\"record\":\"finished\""), "{journal_text}");

    // Kill the run after every record boundary (keep = header+config up
    // to everything-but-finished), optionally with the torn final line a
    // mid-append crash leaves, and resume from the survivor.
    for keep in 2..lines.len() {
        for torn in [false, true] {
            let crash = tmp(&format!("crash-{keep}-{torn}.journal"));
            let mut text: String = lines[..keep].iter().map(|l| format!("{l}\n")).collect();
            if torn {
                text.push_str("{\"record\":\"iteration\",\"iter");
            }
            std::fs::write(&crash, text).unwrap();
            let events = tmp(&format!("resume-{keep}-{torn}.jsonl"));
            let resumed = run_once(&events, None, Some(&crash));
            assert_eq!(
                resumed, reference,
                "resume after {keep} journal records (torn tail: {torn}) must \
                 replay the event stream byte-identically"
            );
            let after = std::fs::read_to_string(&crash).unwrap();
            assert!(after.contains("\"record\":\"resumed\""), "{after}");
            assert!(after.contains("\"record\":\"finished\""), "{after}");
            let diags = check_text(&after).expect("resumed journal is a recognized artifact");
            assert!(diags.is_empty(), "resumed journal failed verification: {diags:?}\n{after}");
            let _ = std::fs::remove_file(&crash);
            let _ = std::fs::remove_file(&events);
        }
    }
    let _ = std::fs::remove_file(&ref_events);
    let _ = std::fs::remove_file(&ref_journal);
}

#[test]
fn torn_cache_saves_keep_the_old_document_loadable() {
    let path = tmp("fuzz-cache.json");
    let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo385")
        .seed(7)
        .max_iterations(2)
        .build()
        .unwrap();
    run.execute(&CPrune::default()).unwrap();
    let device = run.target().spec().name.to_string();
    run.cache().save(&path, &device).unwrap();
    let old = std::fs::read(&path).unwrap();

    for seed in 0..8u64 {
        let plan = FaultPlan::parse(&format!("seed:{seed},torn@cache")).unwrap();
        let guard = fault::install(Box::new(plan));
        let err = run.cache().save(&path, &device).unwrap_err();
        drop(guard);
        assert!(err.contains("torn"), "unexpected save error: {err}");
        assert_eq!(
            std::fs::read(&path).unwrap(),
            old,
            "a torn save (seed {seed}) must leave the old document in place"
        );
        // the survivor still loads and still passes `cprune check`
        TuneCache::load(&path, &device).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let diags = check_text(&text).expect("caches are a recognized artifact");
        assert!(diags.is_empty(), "survivor failed verification: {diags:?}");
    }

    // fail-before writes leave the document untouched too
    let guard = fault::install(Box::new(FaultPlan::parse("fail@cache").unwrap()));
    assert!(run.cache().save(&path, &device).is_err());
    drop(guard);
    assert_eq!(std::fs::read(&path).unwrap(), old);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn aborted_process_resumes_to_an_identical_event_stream() {
    // Real process death at a journal barrier — the transport-level twin
    // of the in-process kill-point test, and exactly what the
    // `crash-resume` CI job runs.
    let exe = env!("CARGO_BIN_EXE_cprune");
    let ref_events = tmp("abort-ref.jsonl");
    let journal = tmp("abort.journal");
    let resumed_events = tmp("abort-resumed.jsonl");
    let run_args = [
        "run", "--pruner", "cprune", "--model", "resnet8-cifar", "--device", "kryo385",
        "--iters", "3", "--seed", "7", "--quiet",
    ];

    let status = Command::new(exe)
        .args(run_args)
        .args(["--events", ref_events.to_str().unwrap()])
        .status()
        .unwrap();
    assert!(status.success(), "reference run failed: {status:?}");

    let status = Command::new(exe)
        .args(run_args)
        .args(["--journal", journal.to_str().unwrap(), "--faults", "abort@iter:1"])
        .status()
        .unwrap();
    assert_eq!(
        status.code(),
        Some(ABORT_EXIT_CODE),
        "the injected abort must kill the process at the iter:1 barrier"
    );

    let status = Command::new(exe)
        .args(["run", "--resume", journal.to_str().unwrap(), "--quiet"])
        .args(["--events", resumed_events.to_str().unwrap()])
        .status()
        .unwrap();
    assert!(status.success(), "resume failed: {status:?}");
    assert_eq!(
        std::fs::read(&resumed_events).unwrap(),
        std::fs::read(&ref_events).unwrap(),
        "resumed event stream must be byte-identical to the uninterrupted run's"
    );

    let status =
        Command::new(exe).args(["check", journal.to_str().unwrap()]).status().unwrap();
    assert!(status.success(), "finished journal must pass cprune check");

    for p in [&ref_events, &journal, &resumed_events] {
        let _ = std::fs::remove_file(p);
    }
}
