//! The measurement plane's contract (DESIGN.md §11):
//!
//! 1. **Equivalence** — tuning through `AnalyticTarget` (and through the
//!    registry) is bit-for-bit identical to the pre-redesign `Simulator`
//!    wiring: same `TuneResult`s, same `PruneOutcome`s, same `RunEvent`
//!    JSONL streams for fixed seeds;
//! 2. **Replay** — a recorded trace replayed through `ReplayTarget`
//!    reproduces an entire run's event stream byte-for-byte;
//! 3. **Registry** — a JSON-defined custom device round-trips through
//!    `TargetRegistry` and is tunable end-to-end;
//! 4. **Providers** — `LutTarget` drives a run with table-backed
//!    measurements and analytic fallback.

use cprune::device::{
    AnalyticTarget, DeviceSpec, LutTarget, ReplayTarget, Simulator, Target, TargetRegistry,
};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::graph::ops::OpKind;
use cprune::run::{CPrune, JsonlSink, RunBuilder};
use cprune::tir::Workload;
use cprune::tuner::{tune_task, TuneOptions, TuningSession};
use cprune::util::rng::Rng;
use std::collections::HashMap;
use std::path::PathBuf;

fn wl(ff: usize) -> Workload {
    Workload::from_conv(
        &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 },
        [1, 28, 28, ff],
        vec!["bn", "relu"],
    )
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

#[test]
fn analytic_target_tunes_bit_identically_to_the_simulator() {
    // The acceptance pin: for fixed seeds, the trait path reproduces the
    // legacy path exactly — best program, latency bits, measured count.
    for seed in [0u64, 3, 11] {
        let w = wl(96);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let legacy = tune_task(&w, &sim, &TuneOptions::quick(), &mut Rng::new(seed), None);
        let target = AnalyticTarget::new(DeviceSpec::kryo385());
        let plane = tune_task(&w, &target, &TuneOptions::quick(), &mut Rng::new(seed), None);
        assert_eq!(legacy.best, plane.best);
        assert_eq!(legacy.latency.to_bits(), plane.latency.to_bits());
        assert_eq!(legacy.measured, plane.measured);
        // and through the registry
        let resolved = TargetRegistry::builtin().resolve("kryo385").unwrap();
        let via_registry =
            tune_task(&w, resolved.as_ref(), &TuneOptions::quick(), &mut Rng::new(seed), None);
        assert_eq!(legacy.latency.to_bits(), via_registry.latency.to_bits());
        assert_eq!(legacy.measured, via_registry.measured);
    }
}

#[test]
fn whole_graph_tuning_matches_across_providers() {
    let m = Model::build(ModelKind::ResNet8Cifar, 0);
    let sim = Simulator::new(DeviceSpec::kryo585());
    let a = TuningSession::new(&sim, TuneOptions::quick(), 5)
        .tune_graph(&m.graph, &HashMap::new())
        .model_latency();
    let target = AnalyticTarget::new(DeviceSpec::kryo585());
    let b = TuningSession::new(&target, TuneOptions::quick(), 5)
        .tune_graph(&m.graph, &HashMap::new())
        .model_latency();
    assert_eq!(a.to_bits(), b.to_bits());
}

#[test]
fn run_builder_event_streams_are_identical_across_target_spellings() {
    // .device(name), .target_name(name) and .target(Box<AnalyticTarget>)
    // must produce byte-identical RunEvent JSONL for a fixed seed.
    let events = |tag: &str, wire: fn(RunBuilder) -> RunBuilder| -> Vec<u8> {
        let path = tmp(&format!("cprune_target_events_{tag}.jsonl"));
        let _ = std::fs::remove_file(&path);
        let builder = wire(
            RunBuilder::new(ModelKind::ResNet8Cifar)
                .seed(4)
                .max_iterations(3)
                .observer(Box::new(JsonlSink::create(&path).unwrap())),
        );
        let mut run = builder.build().unwrap();
        run.execute(&CPrune::default()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        bytes
    };
    let by_device = events("device", |b| b.device("kryo385"));
    let by_target_name = events("tname", |b| b.target_name("analytic:kryo385"));
    let by_explicit = events("explicit", |b| {
        b.target(Box::new(AnalyticTarget::new(DeviceSpec::kryo385())))
    });
    assert!(!by_device.is_empty());
    assert_eq!(by_device, by_target_name);
    assert_eq!(by_device, by_explicit);
}

#[test]
fn recorded_trace_replays_an_entire_run_byte_for_byte() {
    let trace = tmp("cprune_target_trace.json");
    let rec_events = tmp("cprune_target_rec.jsonl");
    let rep_events = tmp("cprune_target_rep.jsonl");
    for f in [&trace, &rec_events, &rep_events] {
        let _ = std::fs::remove_file(f);
    }

    let mut rec = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo385")
        .seed(9)
        .max_iterations(3)
        .record_trace(&trace)
        .observer(Box::new(JsonlSink::create(&rec_events).unwrap()))
        .build()
        .unwrap();
    let recorded = rec.execute(&CPrune::default()).unwrap();
    assert!(trace.exists());

    let mut rep = RunBuilder::new(ModelKind::ResNet8Cifar)
        .replay_trace(&trace)
        .seed(9)
        .max_iterations(3)
        .observer(Box::new(JsonlSink::create(&rep_events).unwrap()))
        .build()
        .unwrap();
    // the replay target carries the recorded device's spec
    assert_eq!(rep.target().spec().name, "Kryo 385 (Galaxy S9)");
    let replayed = rep.execute(&CPrune::default()).unwrap();

    assert_eq!(recorded.final_latency.to_bits(), replayed.final_latency.to_bits());
    assert_eq!(recorded.channels, replayed.channels);
    assert_eq!(recorded.programs_measured, replayed.programs_measured);
    assert_eq!(recorded.pareto, replayed.pareto);
    let a = std::fs::read(&rec_events).unwrap();
    let b = std::fs::read(&rep_events).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "replayed event stream diverged from the recording");
    // the trace file itself is byte-stable across serializations
    let trace_text = std::fs::read_to_string(&trace).unwrap();
    assert_eq!(
        ReplayTarget::parse(&trace_text).unwrap().to_json().to_string(),
        trace_text
    );
    for f in [&trace, &rec_events, &rep_events] {
        let _ = std::fs::remove_file(f);
    }
}

#[test]
fn json_defined_custom_device_is_tunable_end_to_end() {
    // Acceptance: a device that exists nowhere in the source resolves
    // through the registry and a full CPrune run tunes for it.
    let doc = r#"{"format":"cprune-devices","version":1,"devices":[
        {"short":"labphone","name":"Lab Phone (custom)","kind":"cpu","cores":6,
         "peak_macs_per_core":9.0e9,"simd_lanes":4,"l1_bytes":65536,
         "l2_bytes":3145728,"mem_bytes_per_s":2.8e10,"dispatch_overhead_s":6e-6}]}"#;
    let mut registry = TargetRegistry::builtin();
    registry.load_str(doc, "inline").unwrap();
    // round-trips: the registered spec serializes back identically
    let spec = registry.spec("labphone").unwrap().clone();
    assert_eq!(
        DeviceSpec::from_json(&spec.to_json()).unwrap().to_json().to_string(),
        spec.to_json().to_string()
    );

    let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
        .with_registry(registry)
        .target_name("labphone")
        .seed(1)
        .max_iterations(2)
        .build()
        .unwrap();
    let out = run.execute(&CPrune::default()).unwrap();
    assert_eq!(out.device, "Lab Phone (custom)");
    assert!(out.final_fps > 0.0 && out.final_fps.is_finite());
    assert!(out.programs_measured > 0);
}

#[test]
fn lut_target_drives_a_run_with_table_hits() {
    let m = Model::build(ModelKind::ResNet8Cifar, 2);
    let lut = LutTarget::for_model(DeviceSpec::kryo385(), &m, &TuneOptions::quick(), 2);
    assert!(lut.num_tables() > 0);
    let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
        .target(Box::new(lut))
        .seed(2)
        .max_iterations(2)
        .build()
        .unwrap();
    let out = run.execute(&CPrune::default()).unwrap();
    assert!(out.final_fps > 0.0 && out.final_fps.is_finite());
    assert!(out.programs_measured > 0);
}

#[test]
fn calibration_table_scales_the_built_target() {
    use cprune::device::calibration::{Calibration, CalibrationTable};
    let mut table = CalibrationTable::new();
    table.insert("Kryo 385 (Galaxy S9)", Calibration { scale: 0.5, residual: 0.0 });
    let calibrated = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo385")
        .calibration(table.clone())
        .build()
        .unwrap();
    let plain = RunBuilder::new(ModelKind::ResNet8Cifar).device("kryo385").build().unwrap();
    assert_eq!(
        calibrated.target().spec().peak_macs_per_core,
        plain.target().spec().peak_macs_per_core * 0.5
    );
    // devices absent from the table run uncalibrated
    let other = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo585")
        .calibration(table)
        .build()
        .unwrap();
    assert_eq!(
        other.target().spec().peak_macs_per_core,
        DeviceSpec::kryo585().peak_macs_per_core
    );
}

#[test]
fn mixed_provider_targets_share_one_session_api() {
    // One workload, three providers, one call shape.
    let w = wl(64);
    let providers: Vec<Box<dyn Target>> = vec![
        Box::new(AnalyticTarget::new(DeviceSpec::kryo385())),
        Box::new(LutTarget::new(DeviceSpec::kryo385())),
        TargetRegistry::builtin().resolve("mali").unwrap(),
    ];
    for t in &providers {
        let r = tune_task(&w, t.as_ref(), &TuneOptions::quick(), &mut Rng::new(3), None);
        assert!(r.latency > 0.0 && r.latency.is_finite(), "{}", t.spec().name);
        assert!(r.measured > 0);
    }
    // a table-less LutTarget is pure analytic fallback: identical bits
    let analytic = tune_task(
        &w,
        providers[0].as_ref(),
        &TuneOptions::quick(),
        &mut Rng::new(3),
        None,
    );
    let lut_fallback = tune_task(
        &w,
        providers[1].as_ref(),
        &TuneOptions::quick(),
        &mut Rng::new(3),
        None,
    );
    assert_eq!(analytic.latency.to_bits(), lut_fallback.latency.to_bits());
    assert_eq!(analytic.best, lut_fallback.best);
}
