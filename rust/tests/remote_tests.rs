//! Integration: the remote measurement plane (DESIGN.md §14).
//!
//! The acceptance pins:
//!
//! 1. **Bit-identity** — a `RemoteTarget` pool of loopback workers
//!    reproduces `AnalyticTarget` measurements (values *and* RNG stream)
//!    bit-for-bit for any worker count ≥ 1, and a whole seeded run's
//!    RunEvent JSONL is byte-identical across worker counts;
//! 2. **Fleet stress** — fleet work-stealing over remote pools is
//!    invariant across thread budgets 1/8/0 × worker counts 1/2/4;
//! 3. **Fault injection** — a worker dying or hanging mid-run is
//!    removed loudly and its chunk retried on the survivors with an
//!    identical final result; an exhausted pool panics;
//! 4. **Trace** — `--remote-trace` recordings pass `cprune check` and
//!    replay bit-identically through `load_trace_target`;
//! 5. **Subprocess** — real `cprune worker --stdio` children serve a
//!    pool bit-identically to the in-process provider.

use cprune::device::remote::{
    load_trace_target, Connection, RemoteOptions, RemoteTarget, WorkerFault,
};
use cprune::device::{AnalyticTarget, DeviceSpec, Target};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::graph::ops::OpKind;
use cprune::run::{CPrune, JsonlSink, RunBuilder};
use cprune::tir::{Program, Workload};
use cprune::tuner::{FleetOptions, FleetSession, TuneOptions};
use cprune::util::rng::Rng;
use cprune::verify::artifact::check_text;
use std::panic::AssertUnwindSafe;
use std::path::PathBuf;
use std::time::Duration;

fn wl(ff: usize) -> Workload {
    Workload::from_conv(
        &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 },
        [1, 28, 28, ff],
        vec!["bn", "relu"],
    )
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

/// A batch of distinct candidate programs for `w` (seeded sampling).
fn batch(w: &Workload, n: usize) -> Vec<Program> {
    let mut rng = Rng::new(42);
    (0..n).map(|_| Program::sample(w, &mut rng)).collect()
}

/// Fast-failing retry policy for fault-injection tests.
fn fast_opts() -> RemoteOptions {
    RemoteOptions {
        timeout: Duration::from_millis(500),
        retries: 2,
        backoff: Duration::from_millis(1),
    }
}

#[test]
fn pool_measurements_bit_identical_to_analytic_for_any_worker_count() {
    let w = wl(96);
    let programs = batch(&w, 7);
    let refs: Vec<&Program> = programs.iter().collect();
    let analytic = AnalyticTarget::new(DeviceSpec::kryo385());
    let mut base_rng = Rng::new(9);
    let want = analytic.measure_batch(&w, &refs, &mut base_rng, 3);
    let stream_marker = base_rng.next_u64();

    for workers in [1usize, 2, 3, 4] {
        let remote =
            RemoteTarget::loopback(DeviceSpec::kryo385(), workers, RemoteOptions::default())
                .unwrap();
        assert_eq!(remote.healthy_workers(), workers);
        assert_eq!(remote.spec().name, analytic.spec().name);
        assert_eq!(remote.noise_sigma().to_bits(), analytic.noise_sigma().to_bits());
        let mut rng = Rng::new(9);
        let got = remote.measure_batch(&w, &refs, &mut rng, 3);
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in want.iter().zip(&got).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "workers={workers} program={i}");
        }
        // the pool consumed exactly the contract's RNG draws
        assert_eq!(rng.next_u64(), stream_marker, "workers={workers} RNG stream drifted");
        // single latency queries match too
        let p = &programs[0];
        assert_eq!(remote.latency(&w, p).to_bits(), analytic.latency(&w, p).to_bits());
    }
}

#[test]
fn run_event_jsonl_byte_identical_across_worker_counts() {
    let events = |tag: &str, target: Option<Box<dyn Target>>| -> Vec<u8> {
        let path = tmp(&format!("cprune_remote_events_{tag}.jsonl"));
        let _ = std::fs::remove_file(&path);
        let builder = RunBuilder::new(ModelKind::ResNet8Cifar).seed(1).max_iterations(3);
        let builder = match target {
            Some(t) => builder.target(t),
            None => builder.device("kryo385"),
        };
        let mut run = builder
            .observer(Box::new(JsonlSink::create(&path).unwrap()))
            .build()
            .unwrap();
        run.execute(&CPrune::default()).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        bytes
    };

    let baseline = events("analytic", None);
    assert!(!baseline.is_empty());
    for workers in [1usize, 2, 4] {
        let remote =
            RemoteTarget::loopback(DeviceSpec::kryo385(), workers, RemoteOptions::default())
                .unwrap();
        let got = events(&format!("w{workers}"), Some(Box::new(remote)));
        assert_eq!(got, baseline, "worker count {workers} changed the event stream");
    }
}

#[test]
fn fleet_work_stealing_over_remote_pools_is_invariant() {
    // Satellite stress: thread budgets {1, 8, 0 (= all cores)} crossed
    // with worker counts {1, 2, 4} all reproduce the plain analytic
    // fleet bit-for-bit.
    let m = Model::build(ModelKind::ResNet8Cifar, 0);
    let specs = || vec![DeviceSpec::kryo385(), DeviceSpec::kryo585()];
    let opts = |threads: usize| FleetOptions {
        tune: TuneOptions::quick(),
        threads,
        cross_seed: true,
    };
    let baseline = FleetSession::new(specs(), opts(1), 4).tune_graph(&m.graph);

    for threads in [1usize, 8, 0] {
        for workers in [1usize, 2, 4] {
            let targets: Vec<Box<dyn Target>> = specs()
                .into_iter()
                .map(|s| {
                    let pool =
                        RemoteTarget::loopback(s, workers, RemoteOptions::default()).unwrap();
                    Box::new(pool) as Box<dyn Target>
                })
                .collect();
            let mut fleet = FleetSession::from_targets(targets, opts(threads), 4);
            let got = fleet.tune_graph(&m.graph);
            assert_eq!(got.devices.len(), baseline.devices.len());
            for (a, b) in baseline.devices.iter().zip(&got.devices) {
                let ctx = format!("threads={threads} workers={workers} device={}", a.device);
                assert_eq!(a.device, b.device, "{ctx}");
                assert_eq!(a.latency.to_bits(), b.latency.to_bits(), "{ctx}: latency drifted");
                assert_eq!(a.fps.to_bits(), b.fps.to_bits(), "{ctx}: fps drifted");
                assert_eq!(a.measured, b.measured, "{ctx}: measured drifted");
                assert_eq!(
                    a.table.model_latency().to_bits(),
                    b.table.model_latency().to_bits(),
                    "{ctx}: table drifted"
                );
            }
            assert_eq!(baseline.total_measured(), got.total_measured());
        }
    }
}

#[test]
fn dead_worker_mid_run_retries_on_survivors_with_identical_result() {
    let spec = DeviceSpec::kryo385();
    let w = wl(64);
    let programs = batch(&w, 5);
    let refs: Vec<&Program> = programs.iter().collect();

    // Expected stream: two batches against the in-process provider.
    let analytic = AnalyticTarget::new(spec.clone());
    let mut rng = Rng::new(7);
    let want1 = analytic.measure_batch(&w, &refs, &mut rng, 2);
    let want2 = analytic.measure_batch(&w, &refs, &mut rng, 2);

    // Worker 0 serves one request then drops the connection (EOF
    // mid-run); worker 1 stays healthy.
    let conns = vec![
        Connection::loopback_with(
            Box::new(AnalyticTarget::new(spec.clone())),
            WorkerFault::DieAfter(1),
            0,
        ),
        Connection::loopback(Box::new(AnalyticTarget::new(spec.clone())), 1),
    ];
    let remote = RemoteTarget::new(conns, fast_opts()).unwrap();
    assert_eq!(remote.healthy_workers(), 2);

    let mut rng = Rng::new(7);
    let got1 = remote.measure_batch(&w, &refs, &mut rng, 2);
    let got2 = remote.measure_batch(&w, &refs, &mut rng, 2);
    for (i, (a, b)) in want1.iter().zip(&got1).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "batch 1 program {i}");
    }
    for (i, (a, b)) in want2.iter().zip(&got2).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "batch 2 program {i} (after worker death)");
    }
    assert_eq!(remote.healthy_workers(), 1, "the dead worker must be removed");
}

#[test]
fn hung_worker_times_out_and_retries_on_survivors() {
    let spec = DeviceSpec::kryo385();
    let w = wl(64);
    let programs = batch(&w, 4);
    let refs: Vec<&Program> = programs.iter().collect();

    let analytic = AnalyticTarget::new(spec.clone());
    let mut rng = Rng::new(3);
    let want1 = analytic.measure_batch(&w, &refs, &mut rng, 2);
    let want2 = analytic.measure_batch(&w, &refs, &mut rng, 2);

    // Worker 0 swallows its second request without replying — the
    // client's deadline fires and the chunk re-runs on worker 1.
    let conns = vec![
        Connection::loopback_with(
            Box::new(AnalyticTarget::new(spec.clone())),
            WorkerFault::HangAfter(1),
            0,
        ),
        Connection::loopback(Box::new(AnalyticTarget::new(spec.clone())), 1),
    ];
    let remote = RemoteTarget::new(conns, fast_opts()).unwrap();

    let mut rng = Rng::new(3);
    let got1 = remote.measure_batch(&w, &refs, &mut rng, 2);
    let got2 = remote.measure_batch(&w, &refs, &mut rng, 2);
    for (a, b) in want1.iter().zip(&got1) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    for (a, b) in want2.iter().zip(&got2) {
        assert_eq!(a.to_bits(), b.to_bits(), "timeout retry changed a value");
    }
    assert_eq!(remote.healthy_workers(), 1, "the hung worker must be removed");
}

#[test]
fn exhausted_pool_panics_loudly() {
    let spec = DeviceSpec::kryo385();
    let w = wl(64);
    let programs = batch(&w, 3);
    let refs: Vec<&Program> = programs.iter().collect();
    // The handshake is not a request, so DieAfter(0) acks Hello and
    // then dies on the first real work.
    let conns = vec![Connection::loopback_with(
        Box::new(AnalyticTarget::new(spec)),
        WorkerFault::DieAfter(0),
        0,
    )];
    let remote = RemoteTarget::new(conns, fast_opts()).unwrap();
    let mut rng = Rng::new(1);
    let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
        remote.measure_batch(&w, &refs, &mut rng, 2)
    }));
    let payload = result.expect_err("an exhausted pool must panic, not return");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("unserved"), "unexpected panic message: {msg}");
}

#[test]
fn remote_trace_records_checks_and_replays_identically() {
    let spec = DeviceSpec::kryo385();
    let w = wl(96);
    let programs = batch(&w, 4);
    let refs: Vec<&Program> = programs.iter().collect();
    let remote = RemoteTarget::loopback(spec, 2, RemoteOptions::default()).unwrap();
    remote.start_trace();
    let lat = remote.latency(&w, &programs[0]);
    let mut rng = Rng::new(5);
    let means = remote.measure_batch(&w, &refs, &mut rng, 3);

    let path = tmp("cprune_remote_trace_integration_test.json");
    let _ = std::fs::remove_file(&path);
    remote.save_trace(&path).unwrap();

    // the recording is a clean `cprune check` artifact (CPV15x)
    let text = std::fs::read_to_string(&path).unwrap();
    let diags = check_text(&text).expect("remote traces are a recognized artifact");
    assert!(diags.is_empty(), "trace failed verification: {diags:?}");

    // and replays bit-identically through the shared dispatcher
    let rep = load_trace_target(&path).unwrap();
    assert_eq!(rep.latency(&w, &programs[0]).to_bits(), lat.to_bits());
    let mut rng = Rng::new(5);
    let replayed = rep.measure_batch(&w, &refs, &mut rng, 3);
    for (a, b) in means.iter().zip(&replayed) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn subprocess_stdio_workers_reproduce_the_in_process_pool() {
    // Real `cprune worker --stdio` children over stdin/stdout — the
    // transport the CLI's `--target remote:NAME` uses.
    let exe = std::path::Path::new(env!("CARGO_BIN_EXE_cprune"));
    let w = wl(64);
    let programs = batch(&w, 6);
    let refs: Vec<&Program> = programs.iter().collect();
    let analytic = AnalyticTarget::new(DeviceSpec::kryo385());
    let mut rng = Rng::new(13);
    let want = analytic.measure_batch(&w, &refs, &mut rng, 2);

    let remote =
        RemoteTarget::spawn_with_exe(exe, "kryo385", 2, RemoteOptions::default()).unwrap();
    assert_eq!(remote.spec().name, analytic.spec().name);
    let mut rng = Rng::new(13);
    let got = remote.measure_batch(&w, &refs, &mut rng, 2);
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "subprocess program {i}");
    }
}
