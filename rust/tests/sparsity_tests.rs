//! Integration: the sparsity subsystem end-to-end (DESIGN.md §16).
//!
//! Pins the PR's acceptance shape: at equal seed and iteration budget on
//! a model-zoo model, the scheme-select CPrune variant assigns a
//! non-channel scheme to at least one layer, meets the accuracy gate,
//! and lands strictly below every single-scheme run's measured latency
//! on the analytic target; the chosen schemes differ between CPU and
//! GPU device kinds; and every scheme-aware pruner is bit-deterministic
//! across runs and tuning thread budgets.

use cprune::accuracy::ProxyOracle;
use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::pruner::CPruneConfig;
use cprune::run::{CPrune, JsonlSink, PruneOutcome, Pruner, RunContext, RunObserver};
use cprune::sparsity::{BlockPruner, MaskSet, PatternPruner, Scheme, SchemeSelect};
use cprune::tuner::{TuneOptions, TuningSession};
use std::collections::BTreeSet;

const ITERS: usize = 12;
const SEED: u64 = 7;

fn cfg() -> CPruneConfig {
    CPruneConfig {
        max_iterations: ITERS,
        tune_opts: TuneOptions::quick(),
        seed: SEED,
        ..Default::default()
    }
}

fn select() -> SchemeSelect {
    SchemeSelect::with_cfg(cfg())
}

/// One pruner run on a fresh session at the given tuning thread budget,
/// optionally streaming events to a JSONL file.
fn run_pruner(
    pruner: &dyn Pruner,
    spec: DeviceSpec,
    threads: usize,
    events: Option<&std::path::Path>,
) -> PruneOutcome {
    let model = Model::build(ModelKind::ResNet8Cifar, 0);
    let sim = Simulator::new(spec);
    let mut session = TuningSession::new(&sim, TuneOptions::quick(), SEED);
    session.threads = threads;
    let mut oracle = ProxyOracle::new();
    let mut observers: Vec<Box<dyn RunObserver>> = match events {
        Some(path) => vec![Box::new(JsonlSink::create(path).unwrap())],
        None => Vec::new(),
    };
    let mut ctx = RunContext::new(&model, &session, &mut oracle, &mut observers);
    pruner.run(&mut ctx)
}

fn selected_schemes(out: &PruneOutcome) -> BTreeSet<Scheme> {
    out.pareto
        .fastest()
        .expect("non-empty frontier")
        .schemes
        .values()
        .map(|c| c.scheme)
        .collect()
}

#[test]
fn scheme_select_beats_every_single_scheme_run_at_equal_budget() {
    let spec = DeviceSpec::kryo385;
    let sel = run_pruner(&select(), spec(), 0, None);
    let channel = run_pruner(&CPrune::with_cfg(cfg()), spec(), 0, None);
    let pat = run_pruner(&PatternPruner, spec(), 0, None);
    let blk = run_pruner(&BlockPruner, spec(), 0, None);

    // at least one layer carries a non-channel scheme in the shipped model
    let schemes = selected_schemes(&sel);
    assert!(
        schemes.iter().any(|&s| s != Scheme::Channel),
        "scheme-select never left the channel scheme: {schemes:?}"
    );
    // the accuracy gate held all the way down
    assert!(sel.top1 > 0.5, "final top-1 {} collapsed", sel.top1);
    // and it beats each single-scheme run's measured latency
    for (name, single) in [("cprune", &channel), ("pattern", &pat), ("block", &blk)] {
        assert!(
            sel.final_latency < single.final_latency,
            "scheme-select ({:.6}s) lost to {name} ({:.6}s)",
            sel.final_latency,
            single.final_latency
        );
    }
}

#[test]
fn scheme_choice_depends_on_the_device_kind() {
    // The per-kind reorder overheads in device::sparse make pattern
    // compaction the cheap scheme on CPUs and block skipping the cheap
    // scheme on GPUs; the selection loop must follow the cost model.
    let cpu = run_pruner(&select(), DeviceSpec::kryo385(), 0, None);
    let gpu = run_pruner(&select(), DeviceSpec::mali_g72(), 0, None);
    assert!(
        selected_schemes(&cpu).contains(&Scheme::Pattern),
        "kryo385 (CPU) never picked pattern: {:?}",
        selected_schemes(&cpu)
    );
    assert!(
        selected_schemes(&gpu).contains(&Scheme::Block),
        "mali-g72 (GPU) never picked block: {:?}",
        selected_schemes(&gpu)
    );
}

#[test]
fn scheme_pruners_are_deterministic_across_runs_and_thread_budgets() {
    let sel = select();
    let pruners: [&dyn Pruner; 3] = [&sel, &PatternPruner, &BlockPruner];
    for pruner in pruners {
        let a = run_pruner(pruner, DeviceSpec::kryo385(), 1, None);
        let b = run_pruner(pruner, DeviceSpec::kryo385(), 8, None);
        assert_eq!(
            a.final_latency.to_bits(),
            b.final_latency.to_bits(),
            "{}: thread budget changed the final latency",
            pruner.name()
        );
        assert_eq!(a.channels, b.channels, "{}: masks/channels diverged", pruner.name());
        assert_eq!(a.pareto, b.pareto, "{}: frontier (schemes included) diverged", pruner.name());
    }
}

#[test]
fn scheme_select_event_stream_is_byte_identical_across_runs() {
    let dir = std::env::temp_dir();
    let p1 = dir.join(format!("cprune_sparsity_events_a_{}.jsonl", std::process::id()));
    let p2 = dir.join(format!("cprune_sparsity_events_b_{}.jsonl", std::process::id()));
    let _ = run_pruner(&select(), DeviceSpec::kryo385(), 1, Some(&p1));
    let _ = run_pruner(&select(), DeviceSpec::kryo385(), 8, Some(&p2));
    let a = std::fs::read(&p1).unwrap();
    let b = std::fs::read(&p2).unwrap();
    assert!(!a.is_empty(), "no events written");
    assert_eq!(a, b, "event streams diverged across thread budgets");
    let text = String::from_utf8(a).unwrap();
    assert!(text.contains("\"scheme\":"), "no scheme-stamped events in the stream");
    // the stream passes the semantic artifact checker
    assert_eq!(cprune::verify::artifact::check_text(&text), Some(vec![]));
    let _ = std::fs::remove_file(&p1);
    let _ = std::fs::remove_file(&p2);
}

#[test]
fn golden_mask_fixture_round_trips_byte_stably() {
    let golden = include_str!("golden/sparsity_masks.json");
    let set = MaskSet::parse(golden).unwrap();
    assert_eq!(set.masks.len(), 2);
    assert_eq!(set.to_json().to_string(), golden.trim_end());
    let schemes = set.to_schemes();
    assert_eq!(schemes.len(), 2);
    assert!(schemes.values().any(|c| c.scheme == Scheme::Pattern));
    assert!(schemes.values().any(|c| c.scheme == Scheme::Block));
}
