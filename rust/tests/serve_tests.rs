//! Integration: the serving layer end-to-end — CPrune runs publish
//! Pareto frontiers into a registry, the registry round-trips through
//! disk, and the serving simulator's statistics are identical across
//! runs and across tuning thread budgets (mirroring the tuner's
//! `thread_budget_does_not_change_results` contract at the next layer
//! up).

use cprune::accuracy::ProxyOracle;
use cprune::device::{DeviceSpec, Simulator};
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::pruner::{cprune_with_session, CPruneConfig};
use cprune::serve::{Registry, ServeOptions, ServeReport, Simulator as ServeSimulator};
use cprune::tuner::{TuneOptions, TuningSession};

fn specs2() -> Vec<DeviceSpec> {
    vec![DeviceSpec::kryo385(), DeviceSpec::kryo585()]
}

/// One CPrune run per device at the given tuning thread budget, frontiers
/// published into a fresh registry.
fn registry_with_threads(threads: usize) -> (Registry, &'static str) {
    let kind = ModelKind::ResNet8Cifar;
    let model = Model::build(kind, 0);
    let mut registry = Registry::new();
    for spec in specs2() {
        let sim = Simulator::new(spec);
        let cfg = CPruneConfig {
            max_iterations: 6,
            tune_opts: TuneOptions::quick(),
            seed: 0,
            ..Default::default()
        };
        let mut session = TuningSession::new(&sim, cfg.tune_opts, 0);
        session.threads = threads;
        let mut oracle = ProxyOracle::new();
        let r = cprune_with_session(&model, &mut oracle, &cfg, &session);
        assert!(!r.pareto.is_empty(), "{}: empty frontier", sim.spec.name);
        registry.publish(kind.name(), sim.spec.name, &r.pareto);
    }
    (registry, kind.name())
}

fn simulate(registry: &Registry, model: &str) -> ServeReport {
    let mut sim = ServeSimulator::new(ServeOptions {
        rps: 150.0,
        requests: 1000,
        slo_ms: 40.0,
        accuracy_floor: 0.78,
        trace_seed: 3,
        max_batch: 8,
    });
    for spec in specs2() {
        sim.add_device(spec.name, registry.get(model, spec.name).unwrap()).unwrap();
    }
    sim.run().unwrap()
}

#[test]
fn serving_stats_identical_across_runs_and_thread_budgets() {
    let (reg_serial, model) = registry_with_threads(1);
    let (reg_parallel, _) = registry_with_threads(8);
    assert_eq!(reg_serial, reg_parallel, "thread budget changed the frontiers");

    let a = simulate(&reg_serial, model);
    let b = simulate(&reg_serial, model); // same registry, fresh trace replay
    let c = simulate(&reg_parallel, model); // frontiers tuned at 8 threads
    assert_eq!(a.p50_ms, b.p50_ms);
    assert_eq!(a.p95_ms, b.p95_ms);
    assert_eq!(a.p99_ms, b.p99_ms);
    assert_eq!(a.slo_violations, b.slo_violations);
    assert_eq!(a, b);
    assert_eq!(a, c, "tuning thread budget leaked into serving stats");
    // the printed report is byte-identical too (the CLI's contract)
    assert_eq!(a.render(), c.render());
}

#[test]
fn across_fleet_matches_manually_wired_lanes() {
    use cprune::tuner::{FleetOptions, FleetSession};
    let (registry, model) = registry_with_threads(1);
    let fleet = FleetSession::new(specs2(), FleetOptions::default(), 0);
    let opts = ServeOptions {
        rps: 150.0,
        requests: 1000,
        slo_ms: 40.0,
        accuracy_floor: 0.78,
        trace_seed: 3,
        max_batch: 8,
    };
    let from_fleet = ServeSimulator::across_fleet(&fleet, &registry, model, opts)
        .unwrap()
        .run()
        .unwrap();
    assert_eq!(from_fleet, simulate(&registry, model), "fleet wiring changed the lanes");
    // a model the registry has never seen is refused loudly
    assert!(ServeSimulator::across_fleet(&fleet, &registry, "no-such-model", opts).is_err());
}

#[test]
fn registry_roundtrips_cprune_frontiers_through_disk() {
    let (registry, model) = registry_with_threads(1);
    let path = std::env::temp_dir().join("cprune_serve_test_registry.json");
    registry.save(&path).unwrap();
    let loaded = Registry::load(&path).unwrap();
    assert_eq!(loaded, registry);
    // serving from the loaded registry reproduces the in-memory stats
    assert_eq!(simulate(&loaded, model).render(), simulate(&registry, model).render());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tighter_slo_never_raises_served_accuracy() {
    // The SLO-aware policy degrades down the frontier under pressure: a
    // tighter SLO can only push more requests onto faster, less accurate
    // checkpoints. Hand-built identical frontiers on both lanes keep the
    // comparison independent of how traffic splits across lanes.
    use cprune::serve::{Checkpoint, ParetoSet};
    use std::collections::BTreeMap;
    let mut frontier = ParetoSet::new();
    for (it, lat, acc) in [(2, 0.002, 0.80), (1, 0.005, 0.85), (0, 0.020, 0.92)] {
        frontier.insert(Checkpoint {
            iteration: it,
            latency: lat,
            accuracy: acc,
            channels: BTreeMap::new(),
            schemes: BTreeMap::new(),
        });
    }
    let run_with_slo = |slo_ms: f64| {
        let mut sim = ServeSimulator::new(ServeOptions {
            rps: 300.0,
            requests: 1000,
            slo_ms,
            accuracy_floor: 0.90,
            trace_seed: 3,
            max_batch: 8,
        });
        sim.add_device("laneA", &frontier).unwrap();
        sim.add_device("laneB", &frontier).unwrap();
        sim.run().unwrap()
    };
    let tight = run_with_slo(5.0);
    let loose = run_with_slo(500.0);
    assert!(tight.mean_served_accuracy < loose.mean_served_accuracy);
    assert!(tight.degraded_requests > loose.degraded_requests);
    assert!(tight.p99_ms < loose.p99_ms, "degrading did not buy latency");
}
