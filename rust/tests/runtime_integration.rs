//! Integration: the full AOT path — HLO-text artifacts produced by
//! python/compile/aot.py, loaded and executed from Rust via PJRT.
//! Tests no-op gracefully when `make artifacts` has not run.
//!
//! The whole file is gated on the `pjrt` feature (and needs the *real*
//! xla crate linked in place of the rust/shims/xla stub to do anything).
#![cfg(feature = "pjrt")]

use cprune::runtime::{literal_f32, Runtime};
use cprune::train::{Dataset, TrainConfig, Trainer};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

#[test]
fn gemm_kernel_artifact_matches_cpu_reference() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).unwrap();
    let exe = rt.load("kernel_gemm").unwrap();
    // x: (128,64) ones*0.01, w: (64,32) ones*0.02, scale=1, shift=0, relu
    let x = vec![0.01f32; 128 * 64];
    let w = vec![0.02f32; 64 * 32];
    let scale = vec![1.0f32; 32];
    let shift = vec![0.0f32; 32];
    let out = exe
        .run(&[
            literal_f32(&x, &[128, 64]).unwrap(),
            literal_f32(&w, &[64, 32]).unwrap(),
            literal_f32(&scale, &[32]).unwrap(),
            literal_f32(&shift, &[32]).unwrap(),
        ])
        .unwrap();
    let vals = out[0].to_vec::<f32>().unwrap();
    assert_eq!(vals.len(), 128 * 32);
    // every element = 64 * 0.01 * 0.02 = 0.0128
    for v in &vals {
        assert!((v - 0.0128).abs() < 1e-5, "got {v}");
    }
}

#[test]
fn train_step_reduces_loss_from_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).unwrap();
    let mut trainer = Trainer::new(&rt, TrainConfig::default()).unwrap();
    let data = Dataset::synthetic(256, 32, 10, 0);
    let losses = trainer.train(&data, 6, 0.05).unwrap();
    assert!(losses.iter().all(|l| l.is_finite()));
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn eval_and_masking_from_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu(&dir).unwrap();
    let mut trainer = Trainer::new(&rt, TrainConfig::default()).unwrap();
    let data = Dataset::synthetic(400, 32, 10, 1);
    let acc0 = trainer.evaluate(&data, 2).unwrap();
    assert!((0.0..=1.0).contains(&acc0));
    // mask half of b3c1's channels; accuracy must still be a valid number
    let mut remaining = std::collections::BTreeMap::new();
    remaining.insert("b3c1".to_string(), 32usize);
    trainer.set_masks(&remaining).unwrap();
    let masked = trainer.mask_vectors();
    let b3c1_mask: &Vec<f32> = &masked[6]; // CONV_SPECS order: b3c1 is 7th
    assert_eq!(b3c1_mask.iter().filter(|&&m| m == 1.0).count(), 32);
    let acc1 = trainer.evaluate(&data, 2).unwrap();
    assert!((0.0..=1.0).contains(&acc1));
}
