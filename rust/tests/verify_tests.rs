//! Mutation-fuzz integration tests for the semantic verifier
//! (DESIGN.md §13): start from known-valid graphs, schedules and
//! persisted artifacts, apply one seeded single-field corruption per
//! case, and pin every corruption class to its stable `CPVnnn` ID.

use cprune::device::remote::RemoteTrace;
use cprune::device::DeviceSpec;
use cprune::graph::model_zoo::{Model, ModelKind};
use cprune::graph::ops::OpKind;
use cprune::graph::prune::{self, PruneState};
use cprune::serve::Registry;
use cprune::tir::jsonio::{program_to_json, workload_to_json};
use cprune::tir::{Program, Workload};
use cprune::tuner::TuneCache;
use cprune::util::json::Json;
use cprune::verify::{artifact, graph as vgraph, program as vprogram, Diagnostic};

fn wl(ff: usize) -> Workload {
    let op = OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 };
    Workload::from_conv(&op, [1, 14, 14, 64], vec!["bn", "relu"])
}

fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.code.id()).collect()
}

// ---------------------------------------------------------------- graphs

#[test]
fn model_zoo_graphs_are_clean() {
    for kind in [
        ModelKind::ResNet8Cifar,
        ModelKind::Vgg16Cifar,
        ModelKind::ResNet18ImageNet,
        ModelKind::MobileNetV2ImageNet,
        ModelKind::MnasNet10ImageNet,
    ] {
        let m = Model::build(kind, 0);
        let diags = vgraph::check_graph(&m.graph);
        assert!(diags.is_empty(), "{}: {:?}", m.kind.name(), diags);
    }
}

#[test]
fn pruned_graphs_stay_clean() {
    let m = Model::build(ModelKind::Vgg16Cifar, 0);
    let mut st = PruneState::full(&m);
    st.shrink(m.prunable[0], 32);
    let g = prune::apply(&m.graph, &st.cout).unwrap();
    assert!(vgraph::check_graph(&g).is_empty());

    let m = Model::build(ModelKind::ResNet18ImageNet, 0);
    let mut st = PruneState::full(&m);
    st.shrink(m.prunable[2], 16);
    let g = prune::apply(&m.graph, &st.cout).unwrap();
    assert!(vgraph::check_graph(&g).is_empty());
}

#[test]
fn conv_cin_corruption_is_cpv101() {
    let mut g = Model::build(ModelKind::Vgg16Cifar, 0).graph;
    let conv = g.conv_ids()[0];
    if let OpKind::Conv2d { cin, .. } = &mut g.nodes[conv].op {
        *cin += 1;
    }
    assert_eq!(ids(&vgraph::check_graph(&g)), ["CPV101"]);
}

#[test]
fn residual_rewire_is_cpv102() {
    let mut g = Model::build(ModelKind::ResNet8Cifar, 0).graph;
    let add = g
        .nodes
        .iter()
        .find(|n| matches!(n.op, OpKind::Add))
        .map(|n| n.id)
        .expect("resnet-8 has residual adds");
    // Point one operand at the network input (different shape entirely).
    g.nodes[add].inputs[1] = 0;
    let diags = vgraph::check_graph(&g);
    assert!(ids(&diags).contains(&"CPV102"), "{diags:?}");
}

#[test]
fn group_divisibility_corruption_is_cpv103() {
    let mut g = Model::build(ModelKind::MobileNetV2ImageNet, 0).graph;
    let dw = g
        .nodes
        .iter()
        .find(|n| n.op.mnemonic() == "dwconv2d")
        .map(|n| n.id)
        .expect("mobilenet-v2 has depthwise convs");
    if let OpKind::Conv2d { groups, .. } = &mut g.nodes[dw].op {
        *groups -= 1; // no longer divides cin/cout
    }
    let diags = vgraph::check_graph(&g);
    assert!(ids(&diags).contains(&"CPV103"), "{diags:?}");
}

#[test]
fn channel_floor_corruption_is_cpv104() {
    let mut g = Model::build(ModelKind::Vgg16Cifar, 0).graph;
    let conv = g.conv_ids()[0];
    if let OpKind::Conv2d { cout, .. } = &mut g.nodes[conv].op {
        *cout = 1;
    }
    let diags = vgraph::check_graph(&g);
    assert!(ids(&diags).contains(&"CPV104"), "{diags:?}");
}

#[test]
fn arity_corruption_is_cpv100_and_fails_validate() {
    let mut g = Model::build(ModelKind::Vgg16Cifar, 0).graph;
    let conv = g.conv_ids()[0];
    let input = g.nodes[conv].inputs[0];
    g.nodes[conv].inputs.push(input);
    assert_eq!(ids(&vgraph::check_graph(&g)), ["CPV100"]);
    // Graph::validate delegates to the same pass.
    let err = g.validate().unwrap_err();
    assert!(err.contains("CPV100"), "{err}");
}

// -------------------------------------------------------------- programs

#[test]
fn tile_factor_corruptions_have_stable_ids() {
    let w = wl(64);
    let base = Program::naive(&w);
    assert!(vprogram::check_program(&base, &w).is_empty());

    let mut p = base.clone();
    p.ff_splits = vec![7]; // product 7 < 64: illegal tile factor
    assert_eq!(ids(&vprogram::check_program(&p, &w)), ["CPV111"]);

    let mut p = base.clone();
    p.ic_splits = vec![64, 0];
    assert_eq!(ids(&vprogram::check_program(&p, &w)), ["CPV110"]);

    let mut p = base.clone();
    p.spatial_splits = Vec::new();
    assert_eq!(ids(&vprogram::check_program(&p, &w)), ["CPV110"]);

    let mut p = base.clone();
    p.vectorize = 3;
    assert_eq!(ids(&vprogram::check_program(&p, &w)), ["CPV112"]);

    // Program::validate surfaces the same diagnostic.
    let err = p.validate(&w).unwrap_err();
    assert!(err.contains("CPV112"), "{err}");
}

// ------------------------------------------------------------- artifacts

#[test]
fn cache_corruptions_have_stable_ids() {
    let cache = TuneCache::new();
    cache.put(wl(64), Program::naive(&wl(64)), 0.001, 5);
    let text = cache.to_json("devA").to_string();
    assert_eq!(artifact::check_text(&text), Some(vec![]));

    // negative latency
    let broken = text.replace("\"latency\":0.001", "\"latency\":-1");
    assert_ne!(broken, text);
    assert!(ids(&artifact::check_text(&broken).unwrap()).contains(&"CPV123"));

    // non-canonical workload key (64.5 truncates back to 64 on parse)
    let broken = text.replace("\"ff\":64", "\"ff\":64.5");
    assert_ne!(broken, text);
    assert!(ids(&artifact::check_text(&broken).unwrap()).contains(&"CPV122"));

    // cached program no longer legal for its workload
    let broken = text.replace("\"ff_splits\":[64]", "\"ff_splits\":[7]");
    assert_ne!(broken, text);
    assert!(ids(&artifact::check_text(&broken).unwrap()).contains(&"CPV111"));
}

#[test]
fn trace_key_corruption_is_cpv122() {
    let w = wl(64);
    let p = Program::naive(&w);
    let entry = Json::obj(vec![
        ("workload", workload_to_json(&w)),
        ("program", program_to_json(&p)),
        ("seconds", Json::Num(0.001)),
    ]);
    let text = Json::obj(vec![
        ("format", Json::Str("cprune-measure-trace".into())),
        ("version", Json::Num(1.0)),
        ("device", DeviceSpec::kryo385().to_json()),
        ("noise_sigma", Json::Num(0.0)),
        ("latencies", Json::Arr(vec![entry])),
        ("measurements", Json::Arr(Vec::new())),
    ])
    .to_string();
    assert_eq!(artifact::check_text(&text), Some(vec![]));

    let broken = text.replace("\"ff\":64", "\"ff\":64.5");
    assert_ne!(broken, text);
    assert!(ids(&artifact::check_text(&broken).unwrap()).contains(&"CPV122"));
}

#[test]
fn registry_frontier_corruptions_have_stable_ids() {
    let point = |lat: f64, acc: f64| {
        format!("{{\"iteration\":0,\"latency\":{lat},\"accuracy\":{acc},\"channels\":{{}}}}")
    };
    let doc = |points: &[String]| {
        format!(
            "{{\"format\":\"cprune-pareto-registry\",\"version\":1,\"entries\":[{{\
             \"model\":\"m\",\"device\":\"d\",\"pareto\":{{\"points\":[{}]}}}}]}}",
            points.join(",")
        )
    };

    let clean = doc(&[point(0.004, 0.91), point(0.010, 0.93)]);
    assert_eq!(artifact::check_text(&clean), Some(vec![]));
    assert!(Registry::parse(&clean).is_ok());

    // dominated point: same accuracy, strictly slower
    let dominated = doc(&[point(0.004, 0.91), point(0.010, 0.91)]);
    assert!(ids(&artifact::check_text(&dominated).unwrap()).contains(&"CPV130"));

    // order break: mutually non-dominated but sorted descending
    let unsorted = doc(&[point(0.010, 0.93), point(0.004, 0.91)]);
    assert!(ids(&artifact::check_text(&unsorted).unwrap()).contains(&"CPV131"));

    // strict load: no silent repair of a corrupt persisted frontier
    for broken in [&dominated, &unsorted] {
        let err = Registry::parse(broken).unwrap_err();
        assert!(err.contains("refusing to repair"), "{err}");
    }
}

#[test]
fn events_log_corruptions_are_cpv140() {
    let golden = include_str!("golden/run_events.jsonl");
    assert_eq!(artifact::check_text(golden), Some(vec![]));

    let truncated = format!("{golden}{{\"event\":\"baseline_tuned\",\"fps\":4}}\n");
    assert!(ids(&artifact::check_text(&truncated).unwrap()).contains(&"CPV140"));

    let unknown = format!("{golden}{{\"event\":\"mystery\"}}\n");
    assert!(ids(&artifact::check_text(&unknown).unwrap()).contains(&"CPV140"));
}

#[test]
fn remote_trace_corruptions_have_stable_ids() {
    let w = wl(64);
    let p = Program::naive(&w);
    let mut trace = RemoteTrace::new(DeviceSpec::kryo385(), 0.0, 1);
    trace.record_latency(&w, &p, 0.001);
    trace.record_measurement(&w, &p, 2, vec![1.0, 1.0], 0.001);
    let text = trace.to_json().to_string();
    assert_eq!(artifact::check_text(&text), Some(vec![]));

    // a sample missing its mean
    let broken = text.replace("\"mean\":0.001", "\"meen\":0.001");
    assert_ne!(broken, text);
    assert!(ids(&artifact::check_text(&broken).unwrap()).contains(&"CPV150"));

    // jitter arity no longer matches the entry's repeats
    let broken = text.replace("\"repeats\":2", "\"repeats\":3");
    assert_ne!(broken, text);
    assert!(ids(&artifact::check_text(&broken).unwrap()).contains(&"CPV151"));

    // a non-positive jitter multiplier
    let broken = text.replace("\"jitter\":[1,1]", "\"jitter\":[1,-1]");
    assert_ne!(broken, text);
    assert!(ids(&artifact::check_text(&broken).unwrap()).contains(&"CPV152"));

    // sigma 0 demands unit jitter
    let broken = text.replace("\"jitter\":[1,1]", "\"jitter\":[1,1.5]");
    assert_ne!(broken, text);
    assert!(ids(&artifact::check_text(&broken).unwrap()).contains(&"CPV152"));
}

#[test]
fn sparsity_mask_corruptions_have_stable_ids() {
    let golden = include_str!("golden/sparsity_masks.json");
    assert_eq!(artifact::check_text(golden), Some(vec![]));

    // conv ids out of strictly ascending order
    let broken = golden.replace("\"conv\":7", "\"conv\":1");
    assert_ne!(broken, golden);
    assert_eq!(ids(&artifact::check_text(&broken).unwrap()), ["CPV170"]);

    // density outside (0, 1]
    let broken = golden.replace("\"density\":0.5", "\"density\":1.5");
    assert_eq!(ids(&artifact::check_text(&broken).unwrap()), ["CPV171"]);
    let broken = golden.replace("\"density\":0.5", "\"density\":0");
    assert_eq!(ids(&artifact::check_text(&broken).unwrap()), ["CPV171"]);

    // unknown scheme name
    let broken = golden.replace("\"scheme\":\"block\"", "\"scheme\":\"vibes\"");
    assert_eq!(ids(&artifact::check_text(&broken).unwrap()), ["CPV172"]);

    // pattern params out of the library's range, then unsorted
    let broken = golden.replace("\"params\":[0,2]", "\"params\":[0,99]");
    assert_eq!(ids(&artifact::check_text(&broken).unwrap()), ["CPV172"]);
    let broken = golden.replace("\"params\":[0,2]", "\"params\":[2,0]");
    assert_eq!(ids(&artifact::check_text(&broken).unwrap()), ["CPV172"]);

    // block params must be [keep, group] with 0 < keep < group
    let broken = golden.replace("\"params\":[2,4]", "\"params\":[4,2]");
    assert_eq!(ids(&artifact::check_text(&broken).unwrap()), ["CPV172"]);
}

#[test]
fn event_scheme_extension_is_checked() {
    // scheme-aware pruners stamp measurement events with a scheme name;
    // channel-only logs (the v1 golden) omit the field entirely.
    let with_scheme = "{\"format\":\"cprune-run-events\",\"version\":1}\n\
        {\"event\":\"iteration_accepted\",\"accuracy_gate\":0.8,\"filters_removed\":0,\
         \"iteration\":1,\"latency\":0.2,\"latency_target\":0.25,\"scheme\":\"block\",\
         \"short_accuracy\":0.9}\n";
    assert_eq!(artifact::check_text(with_scheme), Some(vec![]));
    let bad = with_scheme.replace("\"scheme\":\"block\"", "\"scheme\":\"vibes\"");
    assert_eq!(ids(&artifact::check_text(&bad).unwrap()), ["CPV140"]);
}

// ------------------------------------------------------------------- CLI

#[test]
fn cli_check_sweeps_and_sets_exit_codes() {
    let dir = std::env::temp_dir().join(format!("cprune_check_cli_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let run = |args: &[&str]| cprune::cli::run(args.iter().map(|s| s.to_string()).collect());

    let cache = TuneCache::new();
    cache.put(wl(64), Program::naive(&wl(64)), 0.001, 5);
    let text = cache.to_json("devA").to_string();
    std::fs::write(dir.join("cache.json"), &text).unwrap();
    std::fs::write(dir.join("foreign.json"), "{\"hello\":\"world\"}").unwrap();
    let dir_arg = dir.to_str().unwrap();
    assert_eq!(run(&["check", dir_arg]), 0);
    assert_eq!(run(&["check", dir.join("cache.json").to_str().unwrap()]), 0);

    std::fs::write(dir.join("bad.json"), text.replace("\"latency\":0.001", "\"latency\":-1"))
        .unwrap();
    assert_eq!(run(&["check", dir_arg]), 1);
    assert_eq!(run(&["check", dir.join("bad.json").to_str().unwrap()]), 1);

    assert_eq!(run(&["check", "--codes"]), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

// The committed tree itself must be clean — the same contract the CI
// `check-artifacts` job enforces with `cprune check .`.
#[test]
fn committed_artifacts_are_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let results = cprune::verify::sweep(&root).expect("sweep failed");
    assert!(!results.is_empty(), "sweep found no artifacts — walker broken?");
    for (file, diags) in &results {
        assert!(diags.is_empty(), "{file}: {:?}", diags);
    }
}
