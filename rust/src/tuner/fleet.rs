//! Fleet compilation: tune one graph for N devices in one session.
//!
//! The north-star deployment tunes a model for a whole *fleet* of device
//! types, not one phone. Two structural savings make that affordable:
//!
//! 1. **Persistent caches** — each device keeps its own [`TuneCache`]
//!    across `tune_graph` calls and (via `save_caches`/`load_caches`)
//!    across process runs, so repeated fleet compilations warm-start.
//! 2. **Cross-device seeding** — the first device in the fleet (the
//!    *pilot*) tunes natively; its best program per workload then seeds
//!    every other device's search, generalizing the paper's §3.5
//!    structure-preserving seed and the Fig. 8 observation that a tuned
//!    program is a strong (if not optimal) starting point elsewhere.
//!
//! Determinism: per-device sessions derive per-workload RNG streams, the
//! pilot runs before every follower, and followers only read the pilot's
//! (fixed) results — so the outcome is identical at any thread budget.
//! That also holds when targets are [`crate::device::RemoteTarget`]
//! pools of out-of-process workers (DESIGN.md §14): the remote plane is
//! bit-identical to in-process measurement for any worker count, so
//! fleet results stay independent of both knobs.

use super::cache::TuneCache;
use super::search::TuneOptions;
use super::session::{resolve_thread_budget, TuningSession};
use crate::compiler::{self, CompiledModel};
use crate::device::{AnalyticTarget, DeviceSpec, Target};
use crate::graph::ops::Graph;
use crate::relay::TaskTable;
use crate::tir::{Program, Workload};
use crate::util::rng::stable_hash;
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Fleet-wide knobs.
#[derive(Clone, Copy, Debug)]
pub struct FleetOptions {
    /// Per-task tuning budget (shared by every device).
    pub tune: TuneOptions,
    /// Total worker-thread budget shared across the fleet (0 = all cores).
    pub threads: usize,
    /// Seed follower devices with the pilot's best programs.
    pub cross_seed: bool,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions { tune: TuneOptions::default(), threads: 0, cross_seed: true }
    }
}

/// Outcome of one device's tune within a fleet run.
#[derive(Clone, Debug)]
pub struct FleetDeviceResult {
    pub device: &'static str,
    pub table: TaskTable,
    /// End-to-end model latency (seconds) and FPS on this device.
    pub latency: f64,
    pub fps: f64,
    pub tasks: usize,
    /// Programs actually measured for this device in this run.
    pub measured: usize,
    /// Task lookups served by this device's persistent cache this run.
    pub cache_hits: usize,
    pub cache_misses: usize,
    /// Measurements those hits avoided (Fig. 11's cost metric).
    pub measured_saved: usize,
    /// Workloads whose search *this run* was seeded by the pilot device
    /// (0 on warm runs where everything came from the cache).
    pub seeded: usize,
}

impl FleetDeviceResult {
    /// Column headers matching [`FleetDeviceResult::table_row`] (shared by
    /// the CLI `fleet` table and the `fleet_tuning` bench).
    pub const TABLE_HEADERS: [&'static str; 7] =
        ["device", "FPS", "latency ms", "tasks", "measured", "cache hits", "seeded"];

    /// Render this device's result as one `print_table` row.
    pub fn table_row(&self) -> Vec<String> {
        vec![
            self.device.to_string(),
            format!("{:.2}", self.fps),
            format!("{:.2}", self.latency * 1e3),
            self.tasks.to_string(),
            self.measured.to_string(),
            self.cache_hits.to_string(),
            self.seeded.to_string(),
        ]
    }
}

/// One fleet compilation's per-device results.
#[derive(Debug)]
pub struct FleetResult {
    pub devices: Vec<FleetDeviceResult>,
}

impl FleetResult {
    pub fn total_measured(&self) -> usize {
        self.devices.iter().map(|d| d.measured).sum()
    }

    pub fn total_cache_hits(&self) -> usize {
        self.devices.iter().map(|d| d.cache_hits).sum()
    }

    pub fn total_measured_saved(&self) -> usize {
        self.devices.iter().map(|d| d.measured_saved).sum()
    }

    /// Fraction of task lookups served from persistent caches.
    pub fn hit_rate(&self) -> f64 {
        let hits = self.total_cache_hits();
        let total: usize = hits + self.devices.iter().map(|d| d.cache_misses).sum::<usize>();
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

/// One cell of the cross-device execution grid (Fig. 8): programs tuned
/// for `tuned_for`, executed on `run_on`.
#[derive(Clone, Debug)]
pub struct TransferCell {
    pub tuned_for: &'static str,
    pub run_on: &'static str,
    pub latency: f64,
}

/// A persistent multi-device tuning service: N measurement providers, N
/// caches, one shared thread budget and seed policy.
///
/// Providers may be heterogeneous (DESIGN.md §11): an analytic pilot
/// seeding a LUT-backed follower, or a replayed device riding along with
/// live ones — the fleet only talks to [`Target`].
pub struct FleetSession {
    targets: Vec<Box<dyn Target>>,
    /// Per-device persistent caches (index-aligned with the targets).
    pub caches: Vec<TuneCache>,
    pub opts: FleetOptions,
    pub seed: u64,
}

impl FleetSession {
    /// An all-analytic fleet over `specs` (the historical constructor —
    /// bit-identical to the pre-[`Target`] simulator wiring).
    pub fn new(specs: Vec<DeviceSpec>, opts: FleetOptions, seed: u64) -> FleetSession {
        Self::from_targets(
            specs
                .into_iter()
                .map(|s| Box::new(AnalyticTarget::new(s)) as Box<dyn Target>)
                .collect(),
            opts,
            seed,
        )
    }

    /// A fleet over arbitrary (possibly mixed-provider) targets.
    pub fn from_targets(
        targets: Vec<Box<dyn Target>>,
        opts: FleetOptions,
        seed: u64,
    ) -> FleetSession {
        assert!(!targets.is_empty(), "fleet needs at least one device");
        let caches = targets.iter().map(|_| TuneCache::new()).collect();
        FleetSession { targets, caches, opts, seed }
    }

    pub fn num_devices(&self) -> usize {
        self.targets.len()
    }

    /// The measurement provider for device `i` (pilot = 0).
    pub fn target(&self, i: usize) -> &dyn Target {
        self.targets[i].as_ref()
    }

    /// Tune `graph` for every device. The pilot (device 0) tunes first
    /// with the whole thread budget; followers then tune concurrently,
    /// splitting the budget, each seeded with the pilot's best programs.
    pub fn tune_graph(&mut self, graph: &Graph) -> FleetResult {
        let n = self.targets.len();
        let budget = resolve_thread_budget(self.opts.threads);

        let caches = std::mem::take(&mut self.caches);
        let mut sessions: Vec<TuningSession<'_>> = Vec::with_capacity(n);
        for (i, (target, cache)) in self.targets.iter().zip(caches).enumerate() {
            let mut s = TuningSession::with_cache(
                target.as_ref(),
                self.opts.tune,
                device_seed(self.seed, i),
                cache,
            );
            s.threads = budget;
            sessions.push(s);
        }
        let before: Vec<(usize, usize, usize)> = sessions
            .iter()
            .map(|s| (s.cache.hits(), s.cache.misses(), s.cache.saved()))
            .collect();

        // Phase 1 — pilot tunes natively.
        let pilot = compiler::compile_tuned(graph, &sessions[0], &HashMap::new());
        let mut seeds: HashMap<Workload, Program> = HashMap::new();
        if self.opts.cross_seed {
            for t in pilot.table.tasks() {
                if let Some(p) = &t.best_program {
                    seeds.insert(t.workload.clone(), p.clone());
                }
            }
        }

        // How many of each follower's *upcoming* searches the pilot seeds:
        // seed programs for workloads the follower does not already have
        // cached. Computed before phase 2 fills the caches (and via
        // `contains`, so the hit/miss counters stay honest).
        let seeded_counts: Vec<usize> = sessions
            .iter()
            .enumerate()
            .map(|(i, s)| {
                if i == 0 {
                    0
                } else {
                    seeds.keys().filter(|w| !s.cache.contains(w)).count() // cprune-lint: allow(CPL002, reason="order-insensitive count")
                }
            })
            .collect();

        // Phase 2 — followers share the budget, pilot-seeded.
        let mut compiled: Vec<Option<CompiledModel>> = (0..n).map(|_| None).collect();
        compiled[0] = Some(pilot);
        if n > 1 {
            let workers = budget.min(n - 1).max(1);
            let per_session = (budget / workers).max(1);
            for s in sessions[1..].iter_mut() {
                s.threads = per_session;
            }
            if workers <= 1 {
                for (i, slot) in compiled.iter_mut().enumerate().skip(1) {
                    *slot = Some(compiler::compile_tuned(graph, &sessions[i], &seeds));
                }
            } else {
                // Work-stealing over follower devices: workers claim the
                // next untuned device off a shared atomic index instead of
                // a static stride, so one slow device (e.g. the GPU spec's
                // larger search space) cannot serialize its stride-mates.
                // Device results depend only on per-device seeds and the
                // pilot's (already fixed) programs, so claim order cannot
                // change any output (DESIGN.md §10).
                let sessions_ref = &sessions;
                let seeds_ref = &seeds;
                let next = AtomicUsize::new(1); // 0 = pilot, already tuned
                let next_ref = &next;
                let results: Vec<(usize, CompiledModel)> = std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..workers)
                        .map(|_| {
                            scope.spawn(move || {
                                let mut out = Vec::new();
                                loop {
                                    let i = next_ref.fetch_add(1, Ordering::Relaxed);
                                    if i >= n {
                                        break;
                                    }
                                    out.push((
                                        i,
                                        compiler::compile_tuned(
                                            graph,
                                            &sessions_ref[i],
                                            seeds_ref,
                                        ),
                                    ));
                                }
                                out
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        // Re-raise worker panics with their payload intact,
                        // so a structured replay Divergence (CPV124) survives
                        // to the catcher in `run::Run::execute`.
                        .flat_map(|h| {
                            h.join().unwrap_or_else(|e| std::panic::resume_unwind(e))
                        })
                        .collect()
                });
                for (i, c) in results {
                    compiled[i] = Some(c);
                }
            }
        }

        let mut devices = Vec::with_capacity(n);
        for (i, (sess, c)) in sessions.iter().zip(compiled).enumerate() {
            let c = c.expect("every device compiled"); // cprune-lint: allow(CPL005, reason="loop above fills every slot")
            devices.push(FleetDeviceResult {
                device: self.targets[i].spec().name,
                latency: c.latency(),
                fps: c.fps(),
                tasks: c.table.len(),
                measured: sess.measured_count(),
                cache_hits: sess.cache.hits() - before[i].0,
                cache_misses: sess.cache.misses() - before[i].1,
                measured_saved: sess.cache.saved() - before[i].2,
                seeded: seeded_counts[i],
                table: c.table,
            });
        }
        self.caches = sessions.into_iter().map(|s| s.cache).collect();
        FleetResult { devices }
    }

    /// The Fig. 8 grid: for each tuned model i (graph + task table, tuned
    /// natively for device i) evaluate it on every device j with i's
    /// programs. `models` must be index-aligned with the fleet's devices.
    pub fn transfer_matrix(&self, models: &[(&Graph, &TaskTable)]) -> Vec<TransferCell> {
        assert_eq!(models.len(), self.targets.len(), "one model per fleet device");
        let mut cells = Vec::with_capacity(models.len() * self.targets.len());
        for (i, (graph, table)) in models.iter().enumerate() {
            for target in &self.targets {
                cells.push(TransferCell {
                    tuned_for: self.targets[i].spec().name,
                    run_on: target.spec().name,
                    latency: compiler::latency_with_programs(graph, table, target.as_ref()),
                });
            }
        }
        cells
    }

    /// Load per-device caches from `dir` (files named by [`cache_file_name`]).
    /// Missing files are fine (cold devices); returns how many loaded.
    pub fn load_caches(&mut self, dir: impl AsRef<Path>) -> Result<usize, String> {
        let dir = dir.as_ref();
        let mut loaded = 0;
        for (i, target) in self.targets.iter().enumerate() {
            let name = target.spec().name;
            let path = dir.join(cache_file_name(name));
            if path.exists() {
                self.caches[i] = TuneCache::load(&path, name)?;
                loaded += 1;
            }
        }
        Ok(loaded)
    }

    /// Persist every device's cache into `dir` (created if absent).
    pub fn save_caches(&self, dir: impl AsRef<Path>) -> Result<(), String> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
        for (i, target) in self.targets.iter().enumerate() {
            let name = target.spec().name;
            self.caches[i].save(dir.join(cache_file_name(name)), name)?;
        }
        Ok(())
    }
}

/// Per-device session seed: the pilot keeps the fleet seed (so a
/// single-device fleet reproduces a plain [`TuningSession`] run), followers
/// get stable derived streams.
fn device_seed(seed: u64, index: usize) -> u64 {
    if index == 0 {
        seed
    } else {
        stable_hash(&(seed, index as u64))
    }
}

/// Filesystem-safe cache file name for a device ("Kryo 385 (Galaxy S9)" →
/// "kryo-385-galaxy-s9.cache.json").
pub fn cache_file_name(device_name: &str) -> String {
    let mut slug = String::with_capacity(device_name.len());
    for c in device_name.chars() {
        if c.is_ascii_alphanumeric() {
            slug.push(c.to_ascii_lowercase());
        } else if !slug.ends_with('-') {
            slug.push('-');
        }
    }
    format!("{}.cache.json", slug.trim_matches('-'))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::{Model, ModelKind};

    fn specs3() -> Vec<DeviceSpec> {
        vec![DeviceSpec::kryo385(), DeviceSpec::kryo585(), DeviceSpec::mali_g72()]
    }

    #[test]
    fn fleet_tunes_every_device() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut fleet = FleetSession::new(
            specs3(),
            FleetOptions { tune: TuneOptions::quick(), ..Default::default() },
            1,
        );
        let r = fleet.tune_graph(&m.graph);
        assert_eq!(r.devices.len(), 3);
        for d in &r.devices {
            assert!(d.fps > 0.0 && d.fps.is_finite(), "{}: bad fps", d.device);
            assert!(d.tasks >= 5);
            assert!(d.measured > 0, "{}: cold run measured nothing", d.device);
        }
        // followers were seeded with the pilot's programs
        assert!(r.devices[1].seeded > 0);
        assert_eq!(r.devices[0].seeded, 0);
    }

    #[test]
    fn single_device_fleet_matches_plain_session() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut fleet = FleetSession::new(
            vec![DeviceSpec::kryo385()],
            FleetOptions { tune: TuneOptions::quick(), ..Default::default() },
            7,
        );
        let r = fleet.tune_graph(&m.graph);
        let sim = crate::device::Simulator::new(DeviceSpec::kryo385());
        let sess = TuningSession::new(&sim, TuneOptions::quick(), 7);
        let table = sess.tune_graph(&m.graph, &HashMap::new());
        assert_eq!(r.devices[0].table.model_latency(), table.model_latency());
    }

    #[test]
    fn mixed_provider_fleet_tunes_every_device() {
        // Heterogeneous providers behind one fleet: an analytic device
        // plus a LUT-backed one (DESIGN.md §11).
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let targets: Vec<Box<dyn Target>> = vec![
            Box::new(AnalyticTarget::new(DeviceSpec::kryo385())),
            Box::new(crate::device::LutTarget::for_model(
                DeviceSpec::kryo585(),
                &m,
                &TuneOptions::quick(),
                0,
            )),
        ];
        let mut fleet = FleetSession::from_targets(
            targets,
            FleetOptions { tune: TuneOptions::quick(), ..Default::default() },
            3,
        );
        let r = fleet.tune_graph(&m.graph);
        assert_eq!(r.devices.len(), 2);
        for d in &r.devices {
            assert!(d.fps > 0.0 && d.fps.is_finite(), "{}: bad fps", d.device);
            assert!(d.measured > 0, "{}: measured nothing", d.device);
        }
        assert_eq!(r.devices[0].device, "Kryo 385 (Galaxy S9)");
        assert_eq!(r.devices[1].device, "Kryo 585 (Galaxy S20+)");
    }

    #[test]
    fn second_fleet_run_is_all_hits() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut fleet = FleetSession::new(
            specs3(),
            FleetOptions { tune: TuneOptions::quick(), ..Default::default() },
            2,
        );
        let cold = fleet.tune_graph(&m.graph);
        assert!(cold.total_measured() > 0);
        let warm = fleet.tune_graph(&m.graph);
        assert_eq!(warm.total_measured(), 0, "warm fleet run re-measured");
        assert!(warm.hit_rate() > 0.999, "hit rate {}", warm.hit_rate());
        assert!(warm.total_measured_saved() >= cold.total_measured());
        for (c, w) in cold.devices.iter().zip(&warm.devices) {
            assert_eq!(c.latency, w.latency, "{} drifted across runs", c.device);
            assert_eq!(w.seeded, 0, "{}: warm run claims seeding happened", w.device);
        }
    }

    #[test]
    fn fleet_results_identical_across_thread_budgets() {
        // Work-stealing claim order must not leak into any result.
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut one = FleetSession::new(
            specs3(),
            FleetOptions { tune: TuneOptions::quick(), threads: 1, cross_seed: true },
            9,
        );
        let mut many = FleetSession::new(
            specs3(),
            FleetOptions { tune: TuneOptions::quick(), threads: 8, cross_seed: true },
            9,
        );
        let a = one.tune_graph(&m.graph);
        let b = many.tune_graph(&m.graph);
        for (x, y) in a.devices.iter().zip(&b.devices) {
            assert_eq!(
                x.latency.to_bits(),
                y.latency.to_bits(),
                "{} drifted across thread budgets",
                x.device
            );
            assert_eq!(x.measured, y.measured, "{} measured-count drifted", x.device);
        }
    }

    #[test]
    fn transfer_matrix_shape_and_diagonal() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut fleet = FleetSession::new(
            specs3(),
            FleetOptions { tune: TuneOptions::quick(), ..Default::default() },
            3,
        );
        let r = fleet.tune_graph(&m.graph);
        let models: Vec<(&Graph, &TaskTable)> =
            r.devices.iter().map(|d| (&m.graph, &d.table)).collect();
        let cells = fleet.transfer_matrix(&models);
        assert_eq!(cells.len(), 9);
        for (idx, c) in cells.iter().enumerate() {
            assert!(c.latency > 0.0);
            assert_eq!(c.tuned_for, fleet.target(idx / 3).spec().name);
            assert_eq!(c.run_on, fleet.target(idx % 3).spec().name);
        }
    }

    #[test]
    fn cache_file_names_are_sane() {
        assert_eq!(cache_file_name("Kryo 385 (Galaxy S9)"), "kryo-385-galaxy-s9.cache.json");
        assert_eq!(
            cache_file_name("Mali-G72 (Galaxy S9 GPU)"),
            "mali-g72-galaxy-s9-gpu.cache.json"
        );
    }
}
