//! Learned cost model: online ridge regression over schedule features.
//!
//! Ansor trains a gradient-boosted model on measured programs and uses it
//! to rank candidates cheaply between measurement batches. We reproduce
//! the same loop with a ridge regressor on hand-crafted features
//! (log-latency target). It is intentionally *imperfect* — rankings are
//! good, absolute values rough — so the search still needs real
//! measurements, like the paper's pipeline.

use crate::tir::{Program, Workload};

/// Number of features extracted per (workload, program).
pub const NFEAT: usize = 12;

/// Schedule features. All scale-free or log-scaled so one model serves
/// every task of a model.
pub fn features(w: &Workload, p: &Program) -> [f64; NFEAT] {
    let macs = w.macs() as f64;
    let (sp_tile, ff_tile) = p.inner_tile();
    let ic_tile = *p.ic_splits.last().unwrap_or(&1);
    let outer = (p.spatial_splits.first().copied().unwrap_or(1)
        * p.ff_splits.first().copied().unwrap_or(1)) as f64;
    let footprint =
        4.0 * (sp_tile * ic_tile * w.kh * w.kw + ff_tile * ic_tile * w.kh * w.kw + sp_tile * ff_tile) as f64;
    let ax3_inner = *p.ax3_splits.last().unwrap_or(&1) as f64;
    [
        1.0,                                     // bias
        macs.ln(),                               // problem size
        (p.parallel as f64).ln_1p(),             // thread request
        (p.vectorize as f64).ln_1p(),            // vector width
        (sp_tile as f64).ln_1p(),                // inner spatial tile
        (ff_tile as f64).ln_1p(),                // inner filter tile
        footprint.ln_1p(),                       // cache footprint
        outer.ln_1p(),                           // parallel grain count
        ax3_inner.ln_1p(),                       // layout-stage inner extent
        if ff_tile % p.vectorize.max(1) == 0 { 1.0 } else { 0.0 }, // vec divisibility
        (p.unroll as f64).ln_1p(),               // unroll
        (w.working_set_bytes() as f64).ln(),     // memory pressure
    ]
}

/// Trait so the search can swap models (learned vs. oracle in tests).
pub trait CostModel {
    /// Predicted log-latency (lower = better). Only the *ranking* matters.
    fn score(&self, w: &Workload, p: &Program) -> f64;
    /// Feed one measured sample (latency in seconds).
    fn observe(&mut self, w: &Workload, p: &Program, latency: f64);
    /// Re-fit after a batch of observations.
    fn refit(&mut self);
    /// True once the model has enough data to rank candidates.
    fn trained(&self) -> bool;
}

/// Ridge regression on [`features`] → log-latency.
///
/// The normal-equation sufficient statistics (XᵀX, Xᵀy) are accumulated
/// *incrementally* in [`CostModel::observe`], so [`CostModel::refit`]
/// solves the NFEAT×NFEAT system directly instead of rebuilding the Gram
/// matrix from the whole sample history each round — refit cost is
/// independent of how many programs were ever measured, and memory stays
/// O(NFEAT²) across an arbitrarily long CPrune run (DESIGN.md §10).
/// Because each sample's contribution is added in observation order, the
/// accumulated sums are bit-identical to a batch rebuild over the full
/// history (floating-point addition happens in the same sequence).
pub struct LearnedCost {
    /// Running XᵀX over every observed sample.
    xtx: [[f64; NFEAT]; NFEAT],
    /// Running Xᵀy (y = log-latency).
    xty: [f64; NFEAT],
    /// Observation count (the old `xs.len()`).
    n: usize,
    weights: Option<[f64; NFEAT]>,
    /// L2 regularization strength.
    lambda: f64,
}

impl LearnedCost {
    pub fn new() -> LearnedCost {
        LearnedCost {
            xtx: [[0.0; NFEAT]; NFEAT],
            xty: [0.0; NFEAT],
            n: 0,
            weights: None,
            lambda: 1e-3,
        }
    }

    pub fn n_samples(&self) -> usize {
        self.n
    }
}

impl Default for LearnedCost {
    fn default() -> Self {
        Self::new()
    }
}

impl CostModel for LearnedCost {
    fn score(&self, w: &Workload, p: &Program) -> f64 {
        match &self.weights {
            Some(ws) => {
                let f = features(w, p);
                f.iter().zip(ws).map(|(a, b)| a * b).sum()
            }
            None => 0.0,
        }
    }

    fn observe(&mut self, w: &Workload, p: &Program, latency: f64) {
        let x = features(w, p);
        let y = latency.max(1e-12).ln();
        // Accumulate this sample's rank-1 update in the same element order
        // the old batch rebuild used, so the sums stay bit-identical.
        for (row, &xi) in self.xtx.iter_mut().zip(&x) {
            for (cell, &xj) in row.iter_mut().zip(&x) {
                *cell += xi * xj;
            }
        }
        for (acc, &xi) in self.xty.iter_mut().zip(&x) {
            *acc += xi * y;
        }
        self.n += 1;
    }

    fn refit(&mut self) {
        if self.n < NFEAT {
            return; // underdetermined; stay untrained
        }
        // Normal equations: (XᵀX + λI) w = Xᵀy over the pre-accumulated
        // sufficient statistics, solved by Gaussian elimination with
        // partial pivoting (NFEAT is tiny).
        let n = NFEAT;
        let mut a = vec![vec![0.0f64; n + 1]; n];
        for (i, row) in a.iter_mut().enumerate() {
            row[..n].copy_from_slice(&self.xtx[i]);
            row[n] = self.xty[i];
            row[i] += self.lambda;
        }
        if let Some(w) = solve(&mut a) {
            let mut ws = [0.0; NFEAT];
            ws.copy_from_slice(&w);
            self.weights = Some(ws);
        }
    }

    fn trained(&self) -> bool {
        self.weights.is_some()
    }
}

/// Solve the augmented system in place; returns x or None if singular.
fn solve(a: &mut [Vec<f64>]) -> Option<Vec<f64>> {
    let n = a.len();
    for col in 0..n {
        // partial pivot
        let pivot = (col..n).max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))?;
        if a[pivot][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, pivot);
        for row in 0..n {
            if row != col {
                let f = a[row][col] / a[col][col];
                for k in col..=n {
                    a[row][k] -= f * a[col][k];
                }
            }
        }
    }
    Some((0..n).map(|i| a[i][n] / a[i][i]).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::ops::OpKind;
    use crate::util::rng::Rng;
    use crate::util::stats::spearman;

    fn wl() -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: 128, stride: 1, padding: 1, groups: 1 },
            [1, 28, 28, 128],
            vec!["bn", "relu"],
        )
    }

    #[test]
    fn solve_identity() {
        let mut a = vec![
            vec![2.0, 0.0, 4.0],
            vec![0.0, 3.0, 9.0],
        ];
        let x = solve(&mut a).unwrap();
        assert!((x[0] - 2.0).abs() < 1e-12 && (x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn learned_model_ranks_programs_usefully() {
        // Train on 200 measured programs, check Spearman correlation of
        // predictions vs. true latencies on 100 held-out programs.
        let w = wl();
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut rng = Rng::new(5);
        let mut model = LearnedCost::new();
        for _ in 0..200 {
            let p = Program::sample(&w, &mut rng);
            model.observe(&w, &p, sim.measure(&w, &p, &mut rng));
        }
        model.refit();
        assert!(model.trained());
        let mut preds = Vec::new();
        let mut truth = Vec::new();
        for _ in 0..100 {
            let p = Program::sample(&w, &mut rng);
            preds.push(model.score(&w, &p));
            truth.push(sim.latency(&w, &p).ln());
        }
        let rho = spearman(&preds, &truth);
        assert!(rho > 0.5, "cost model useless: spearman={rho}");
    }

    #[test]
    fn untrained_model_scores_zero() {
        let w = wl();
        let model = LearnedCost::new();
        let mut rng = Rng::new(0);
        assert_eq!(model.score(&w, &Program::sample(&w, &mut rng)), 0.0);
        assert!(!model.trained());
    }

    #[test]
    fn refit_cadence_does_not_change_weights() {
        // Incremental sufficient statistics make refit a pure function of
        // the observation sequence: interleaving extra refits must produce
        // bit-identical predictions to one final refit (the old
        // full-history rebuild had this property; pin it).
        let w = wl();
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut rng = Rng::new(8);
        let samples: Vec<(Program, f64)> = (0..60)
            .map(|_| {
                let p = Program::sample(&w, &mut rng);
                let l = sim.measure(&w, &p, &mut rng);
                (p, l)
            })
            .collect();
        let mut eager = LearnedCost::new();
        let mut lazy = LearnedCost::new();
        for (i, (p, l)) in samples.iter().enumerate() {
            eager.observe(&w, p, *l);
            lazy.observe(&w, p, *l);
            if i % 7 == 0 {
                eager.refit();
            }
        }
        eager.refit();
        lazy.refit();
        assert_eq!(eager.n_samples(), lazy.n_samples());
        for _ in 0..50 {
            let p = Program::sample(&w, &mut rng);
            assert_eq!(
                eager.score(&w, &p).to_bits(),
                lazy.score(&w, &p).to_bits(),
                "refit cadence changed the fitted weights"
            );
        }
    }

    #[test]
    fn incremental_gram_matches_batch_rebuild() {
        // Independent naive batch implementation of the same ridge solve;
        // the incremental accumulation must reproduce its weights exactly.
        let w = wl();
        let sim = Simulator::new(DeviceSpec::kryo280());
        let mut rng = Rng::new(17);
        let samples: Vec<(Program, f64)> = (0..40)
            .map(|_| {
                let p = Program::sample(&w, &mut rng);
                let l = sim.measure(&w, &p, &mut rng);
                (p, l)
            })
            .collect();
        let mut model = LearnedCost::new();
        for (p, l) in &samples {
            model.observe(&w, p, *l);
        }
        model.refit();
        // batch rebuild, exactly as the pre-incremental refit did it
        let n = NFEAT;
        let mut a = vec![vec![0.0f64; n + 1]; n];
        for (p, l) in &samples {
            let x = features(&w, p);
            let y = l.max(1e-12).ln();
            for i in 0..n {
                for j in 0..n {
                    a[i][j] += x[i] * x[j];
                }
                a[i][n] += x[i] * y;
            }
        }
        for (i, row) in a.iter_mut().enumerate() {
            row[i] += 1e-3;
        }
        let batch_w = solve(&mut a).expect("batch system solvable");
        for _ in 0..30 {
            let p = Program::sample(&w, &mut rng);
            let x = features(&w, &p);
            let batch_score: f64 = x.iter().zip(&batch_w).map(|(a, b)| a * b).sum();
            assert_eq!(
                model.score(&w, &p).to_bits(),
                batch_score.to_bits(),
                "incremental Gram diverged from batch rebuild"
            );
        }
    }

    #[test]
    fn refit_needs_enough_samples() {
        let w = wl();
        let mut rng = Rng::new(1);
        let mut model = LearnedCost::new();
        for _ in 0..3 {
            let p = Program::sample(&w, &mut rng);
            model.observe(&w, &p, 1e-3);
        }
        model.refit();
        assert!(!model.trained());
    }
}
