//! Whole-model tuning session: tune every task of a partitioned graph,
//! with a cross-iteration (and, via [`TuneCache::save`], cross-run) cache.
//!
//! CPrune re-tunes the model after every pruning step (Alg. 1 line 8).
//! Tasks whose workload did not change hit the cache — the big practical
//! saving CPrune's selective search enables (Fig. 11's comparison point).
//! `retune_everything` disables the cache to emulate exhaustive behaviour.

use super::cache::TuneCache;
use super::search::{tune_task, TuneOptions, TuneResult};
use crate::device::{DeviceSpec, Target};
use crate::graph::ops::Graph;
use crate::relay::partition::extract_tasks;
use crate::relay::TaskTable;
use crate::tir::{Program, Workload};
use crate::util::rng::{stable_hash, Rng};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Tunes models for one device; owns the cache and the RNG seed policy.
///
/// The device is any [`Target`] measurement provider (DESIGN.md §11):
/// the analytic roofline, a calibrated LUT target, a record/replay
/// target, or a [`crate::device::RemoteTarget`] pool of out-of-process
/// workers (DESIGN.md §14) — the session neither knows nor cares which.
pub struct TuningSession<'a> {
    pub target: &'a dyn Target,
    pub opts: TuneOptions,
    pub cache: TuneCache,
    pub seed: u64,
    /// When false (default) identical workloads reuse cached results
    /// across pruning iterations.
    pub retune_everything: bool,
    /// Worker-thread budget for `tune_graph` (0 = all available cores).
    /// Thread count never changes results: each task derives its RNG
    /// stream from its own workload hash.
    pub threads: usize,
    /// Cumulative count of programs actually measured (search cost).
    pub total_measured: AtomicUsize,
}

impl<'a> TuningSession<'a> {
    pub fn new(target: &'a dyn Target, opts: TuneOptions, seed: u64) -> TuningSession<'a> {
        Self::with_cache(target, opts, seed, TuneCache::new())
    }

    /// Warm-start from an existing (e.g. [`TuneCache::load`]ed) cache.
    pub fn with_cache(
        target: &'a dyn Target,
        opts: TuneOptions,
        seed: u64,
        cache: TuneCache,
    ) -> TuningSession<'a> {
        TuningSession {
            target,
            opts,
            cache,
            seed,
            retune_everything: false,
            threads: 0,
            total_measured: AtomicUsize::new(0),
        }
    }

    /// Partition + tune all tasks of `graph`. `seed_programs` optionally
    /// maps a task's workload to a structure-preserving starting program
    /// (the §3.5 mechanism). Returns the filled task table.
    ///
    /// Uncached tasks are tuned in parallel across OS threads (tuning is
    /// embarrassingly parallel per task and fully deterministic: each task
    /// derives its RNG stream from its own workload hash, so the schedule
    /// of threads cannot change any result).
    pub fn tune_graph(
        &self,
        graph: &Graph,
        seed_programs: &HashMap<Workload, Program>,
    ) -> TaskTable {
        let (_, mut table) = extract_tasks(graph);
        let task_ids: Vec<usize> = table.tasks().map(|t| t.id).collect();

        // Split into cached (serve immediately) and to-tune (parallel).
        let mut pending: Vec<(usize, Workload)> = Vec::new();
        for &tid in &task_ids {
            let w = table.get(tid).workload.clone();
            if !self.retune_everything {
                if let Some((p, lat, _)) = self.cache.get(&w) {
                    table.record_tuned(tid, p, lat);
                    continue;
                }
            }
            pending.push((tid, w));
        }
        if pending.is_empty() {
            return table;
        }

        let budget = resolve_thread_budget(self.threads);
        let threads = budget.min(pending.len()).max(1);
        // `pending` workloads already missed the cache above (and tasks are
        // deduplicated), so tune them directly — probing again through
        // `tune_workload` would double-count every miss in the hit-rate
        // accounting.
        //
        // Work-stealing: workers claim tasks one at a time off a shared
        // atomic next-index instead of a static `chunks()` split, so a
        // thread stuck on the largest conv task no longer serializes the
        // call while its chunk-mates idle. Safe for determinism: each
        // task's result depends only on its own workload-hash-derived RNG
        // stream (DESIGN.md §10), so which worker tunes it — and in what
        // order — cannot change any output.
        let results: Vec<(usize, Program, f64)> = if threads <= 1 || pending.len() == 1 {
            pending
                .iter()
                .map(|(tid, w)| {
                    let (p, lat) = self.tune_uncached(w, seed_programs.get(w));
                    (*tid, p, lat)
                })
                .collect()
        } else {
            let next = AtomicUsize::new(0);
            let next_ref = &next;
            let pending_ref = &pending;
            std::thread::scope(|scope| {
                let handles: Vec<_> = (0..threads)
                    .map(|_| {
                        scope.spawn(move || {
                            let mut out = Vec::new();
                            loop {
                                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                                let Some((tid, w)) = pending_ref.get(i) else { break };
                                let (p, lat) = self.tune_uncached(w, seed_programs.get(w));
                                out.push((*tid, p, lat));
                            }
                            out
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    // Re-raise worker panics with their payload intact, so a
                    // structured replay Divergence (CPV124) survives to the
                    // catcher in `run::Run::execute`.
                    .flat_map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
                    .collect()
            })
        };
        for (tid, prog, lat) in results {
            table.record_tuned(tid, prog, lat);
        }
        table
    }

    /// Tune a single workload (cache-aware).
    pub fn tune_workload(&self, w: &Workload, seed_prog: Option<&Program>) -> (Program, f64) {
        if !self.retune_everything {
            if let Some((p, lat, _)) = self.cache.get(w) {
                return (p, lat);
            }
        }
        self.tune_uncached(w, seed_prog)
    }

    /// Tune without consulting the cache (the caller already established a
    /// miss); still records the result.
    fn tune_uncached(&self, w: &Workload, seed_prog: Option<&Program>) -> (Program, f64) {
        let mut rng = Rng::with_stream(self.seed, hash_workload(w));
        let TuneResult { best, latency, measured } =
            tune_task(w, self.target, &self.opts, &mut rng, seed_prog);
        self.total_measured.fetch_add(measured, Ordering::Relaxed);
        self.cache.put(w.clone(), best.clone(), latency, measured);
        (best, latency)
    }

    pub fn measured_count(&self) -> usize {
        self.total_measured.load(Ordering::Relaxed)
    }

    /// Architectural parameters of the session's device.
    pub fn spec(&self) -> &DeviceSpec {
        self.target.spec()
    }

    /// Display name of the session's device.
    pub fn device_name(&self) -> &'static str {
        self.target.spec().name
    }
}

/// Resolve a worker-thread knob: 0 means "all available cores" (shared by
/// [`TuningSession`] and the fleet layer so the fallback policy cannot
/// diverge between them).
pub(crate) fn resolve_thread_budget(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
    } else {
        threads
    }
}

/// Stable hash of a workload for RNG stream derivation (not dedup — dedup
/// uses full equality via the `HashMap`). Uses the repo's FNV-1a
/// [`stable_hash`], NOT `DefaultHasher`: the latter's algorithm is
/// unspecified across Rust releases, which would silently re-seed every
/// search (breaking replays and persisted-cache golden latencies) on a
/// toolchain upgrade.
fn hash_workload(w: &Workload) -> u64 {
    stable_hash(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::{Model, ModelKind};

    #[test]
    fn tune_graph_fills_every_task() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo280());
        let sess = TuningSession::new(&sim, TuneOptions::quick(), 1);
        let table = sess.tune_graph(&m.graph, &HashMap::new());
        assert!(table.len() >= 5);
        for t in table.tasks() {
            assert!(t.best_program.is_some(), "task {} untuned", t.id);
            assert!(t.best_latency.unwrap() > 0.0);
        }
        assert!(table.model_latency() > 0.0);
    }

    #[test]
    fn cache_hits_across_repeat_tuning() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo280());
        let sess = TuningSession::new(&sim, TuneOptions::quick(), 1);
        let t1 = sess.tune_graph(&m.graph, &HashMap::new());
        let measured_after_first = sess.measured_count();
        let t2 = sess.tune_graph(&m.graph, &HashMap::new());
        assert_eq!(sess.measured_count(), measured_after_first, "cache missed");
        assert_eq!(t1.model_latency(), t2.model_latency());
        assert!(sess.cache.hits() >= t2.len(), "hits not accounted");
    }

    #[test]
    fn retune_everything_bypasses_cache() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo280());
        let mut sess = TuningSession::new(&sim, TuneOptions::quick(), 1);
        sess.retune_everything = true;
        sess.tune_graph(&m.graph, &HashMap::new());
        let after_first = sess.measured_count();
        sess.tune_graph(&m.graph, &HashMap::new());
        assert!(sess.measured_count() > after_first);
    }

    #[test]
    fn deterministic_across_sessions() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let a = TuningSession::new(&sim, TuneOptions::quick(), 7)
            .tune_graph(&m.graph, &HashMap::new())
            .model_latency();
        let b = TuningSession::new(&sim, TuneOptions::quick(), 7)
            .tune_graph(&m.graph, &HashMap::new())
            .model_latency();
        assert_eq!(a, b);
    }

    #[test]
    fn thread_budget_does_not_change_results() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut one = TuningSession::new(&sim, TuneOptions::quick(), 3);
        one.threads = 1;
        let mut many = TuningSession::new(&sim, TuneOptions::quick(), 3);
        many.threads = 8;
        let a = one.tune_graph(&m.graph, &HashMap::new());
        let b = many.tune_graph(&m.graph, &HashMap::new());
        assert_eq!(a.model_latency(), b.model_latency());
        assert_eq!(one.measured_count(), many.measured_count());
    }

    #[test]
    fn warm_start_from_preloaded_cache_measures_nothing() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let cold = TuningSession::new(&sim, TuneOptions::quick(), 5);
        let t_cold = cold.tune_graph(&m.graph, &HashMap::new());
        assert!(cold.measured_count() > 0);
        let warm = TuningSession::with_cache(&sim, TuneOptions::quick(), 5, cold.cache);
        let t_warm = warm.tune_graph(&m.graph, &HashMap::new());
        assert_eq!(warm.measured_count(), 0, "warm start re-measured");
        assert_eq!(t_cold.model_latency(), t_warm.model_latency());
    }
}
