//! Ansor-style auto-tuner (§2.2's substrate, used by Fig. 3's ②).
//!
//! Per task: evolutionary search over the schedule space, guided by a
//! *learned cost model* (online ridge regression over schedule features,
//! mirroring Ansor's XGBoost-on-measurements loop) and validated by noisy
//! simulated measurements. Returns the fastest program + its latency —
//! exactly the pair CPrune's table stores per task.

pub mod cost_model;
pub mod search;
pub mod session;

pub use cost_model::{features, CostModel, LearnedCost};
pub use search::{tune_task, TuneOptions};
pub use session::{TuneCache, TuningSession};
