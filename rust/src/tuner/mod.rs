//! Ansor-style auto-tuner (§2.2's substrate, used by Fig. 3's ②).
//!
//! Per task: evolutionary search over the schedule space, guided by a
//! *learned cost model* (online ridge regression over schedule features,
//! mirroring Ansor's XGBoost-on-measurements loop) and validated by noisy
//! simulated measurements. Returns the fastest program + its latency —
//! exactly the pair CPrune's table stores per task.
//!
//! On top of the per-device [`TuningSession`] sit the persistence and
//! fleet layers (DESIGN.md §5): [`TuneCache`] serializes results across
//! runs, and [`FleetSession`] tunes one graph for many devices with
//! cross-device seeding.
//!
//! Devices are [`crate::device::Target`] measurement providers
//! (DESIGN.md §11): every measurement flows through
//! `Target::measure_batch`, so the tuner runs unchanged against the
//! analytic roofline, calibrated LUT tables or a recorded replay trace,
//! and [`FleetSession::from_targets`] mixes providers in one fleet.
//!
//! Performance architecture (DESIGN.md §10): the per-task search caches
//! cost-model scores per round, keeps a bounded seen-set-keyed elite pool
//! instead of re-sorting the measurement history, and double-buffers the
//! population ([`search`]); the cost model accumulates its normal
//! equations incrementally ([`cost_model`]); graph- and fleet-level
//! parallelism uses work-stealing over a shared atomic index, which is
//! result-invariant because every task's RNG stream derives from its own
//! workload hash ([`session`], [`fleet`]). The `crate::perf` harness
//! (`cprune bench`) records this module's hot-path wall clock and
//! programs-measured counts into versioned `BENCH_*.json` files so every
//! PR has a perf trajectory.
//!
//! Determinism here is machine-enforced: `cprune-lint` (DESIGN.md §12)
//! denies wall-clock/env reads, f32 latency math and hash-ordered
//! iteration throughout `tuner/`. Persisted tune caches are
//! machine-checked as well: [`TuneCache::save`]/`load` sweep the
//! document through [`crate::verify::artifact`] (DESIGN.md §13) in
//! debug builds, and the CI `check-artifacts` job does the same for
//! every committed artifact via `cprune check .`.

pub mod cache;
pub mod cost_model;
pub mod fleet;
pub mod search;
pub mod session;

pub use cache::TuneCache;
pub use cost_model::{features, CostModel, LearnedCost};
pub use fleet::{FleetDeviceResult, FleetOptions, FleetResult, FleetSession};
pub use search::{tune_task, TuneOptions};
pub use session::TuningSession;
