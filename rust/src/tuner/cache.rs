//! Persistent tuning cache: `Workload → (Program, latency, measured)`.
//!
//! CPrune's practical win is amortizing search cost — across pruning
//! iterations (Fig. 11), across runs, and across devices (Fig. 8). The
//! in-memory side serves [`super::session::TuningSession`]; the
//! `save`/`load` side turns a run's results into a versioned JSON file
//! (via `util::json`; serde is unavailable offline) so repeated `cprune`
//! invocations and fleet sessions warm-start instead of re-measuring.
//!
//! Determinism note: a cache hit returns the exact latency that was
//! measured when the entry was created, and `Json::Num` round-trips f64
//! through Rust's shortest-representation formatter, so a warm-started
//! run reproduces the cold run's numbers bit-for-bit.

// Canonical workload/program JSON lives in `tir::jsonio` — shared with
// the measurement traces of `device::ReplayTarget`, so both persistence
// surfaces parse each other's keys.
use crate::tir::jsonio::{program_from_json, program_to_json, workload_from_json, workload_to_json};
use crate::tir::{Program, Workload};
use crate::util::json::{self, Json};
use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Format tag of the on-disk header (guards against foreign JSON files).
pub const CACHE_FORMAT: &str = "cprune-tune-cache";
/// Bump when the entry schema changes; `load` rejects other versions.
pub const CACHE_VERSION: u64 = 1;

/// Thread-safe cache of tuning results keyed by workload structure, with
/// hit/miss accounting for warm-start reporting.
#[derive(Default)]
pub struct TuneCache {
    map: Mutex<HashMap<Workload, (Program, f64, usize)>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    /// Programs-measured the hits avoided re-measuring (Σ `measured` of
    /// every hit entry) — the Fig. 11 cost metric a warm start saves.
    saved: AtomicUsize,
}

impl TuneCache {
    pub fn new() -> TuneCache {
        TuneCache::default()
    }

    pub fn get(&self, w: &Workload) -> Option<(Program, f64, usize)> {
        let found = self.map.lock().unwrap().get(w).cloned(); // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
        match &found {
            Some((_, _, measured)) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                self.saved.fetch_add(*measured, Ordering::Relaxed);
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
            }
        }
        found
    }

    /// Membership probe that does NOT touch the hit/miss counters (for
    /// bookkeeping questions, not lookups on the tuning path).
    pub fn contains(&self, w: &Workload) -> bool {
        self.map.lock().unwrap().contains_key(w) // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
    }

    pub fn put(&self, w: Workload, p: Program, lat: f64, measured: usize) {
        self.map.lock().unwrap().insert(w, (p, lat, measured)); // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len() // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lookups served from the cache since construction/load.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that fell through to the tuner.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Program measurements avoided by hits (search-cost savings).
    pub fn saved(&self) -> usize {
        self.saved.load(Ordering::Relaxed)
    }

    /// Fraction of lookups served from the cache (0 when none happened).
    pub fn hit_rate(&self) -> f64 {
        let h = self.hits();
        let total = h + self.misses();
        if total == 0 {
            0.0
        } else {
            h as f64 / total as f64
        }
    }

    /// Serialize to the versioned JSON document. `device` names the target
    /// the latencies were measured for — entries are device-specific, and
    /// `load` refuses a file recorded for a different device. Entries are
    /// sorted by their serialized workload so output is byte-stable.
    pub fn to_json(&self, device: &str) -> Json {
        let mut entries: Vec<(String, Json)> = self
            .map
            .lock()
            .unwrap() // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
            .iter()
            .map(|(w, (p, lat, measured))| {
                let wj = workload_to_json(w);
                let key = wj.to_string();
                let entry = Json::obj(vec![
                    ("workload", wj),
                    ("program", program_to_json(p)),
                    ("latency", Json::Num(*lat)),
                    ("measured", Json::Num(*measured as f64)),
                ]);
                (key, entry)
            })
            .collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        Json::obj(vec![
            ("format", Json::Str(CACHE_FORMAT.to_string())),
            ("version", Json::Num(CACHE_VERSION as f64)),
            ("device", Json::Str(device.to_string())),
            ("entries", Json::Arr(entries.into_iter().map(|(_, e)| e).collect())),
        ])
    }

    /// Serialized entries whose workload key is NOT in `known` — the run
    /// journal's per-barrier cache delta (DESIGN.md §15). Keys are the
    /// canonical workload JSON, the same string [`TuneCache::to_json`]
    /// sorts by, and entries come back sorted by that key so journals
    /// are byte-stable.
    pub fn entries_not_in(&self, known: &HashSet<String>) -> Vec<(String, Json)> {
        let mut entries: Vec<(String, Json)> = self
            .map
            .lock()
            .unwrap() // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
            .iter()
            .filter_map(|(w, (p, lat, measured))| {
                let wj = workload_to_json(w);
                let key = wj.to_string();
                if known.contains(&key) {
                    return None;
                }
                let entry = Json::obj(vec![
                    ("workload", wj),
                    ("program", program_to_json(p)),
                    ("latency", Json::Num(*lat)),
                    ("measured", Json::Num(*measured as f64)),
                ]);
                Some((key, entry))
            })
            .collect();
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        entries
    }

    /// Merge one serialized entry (the shape [`TuneCache::to_json`] emits
    /// and the run journal stores) into the cache, replacing any existing
    /// entry for the same workload.
    pub fn merge_entry_json(&self, e: &Json) -> Result<(), String> {
        let w = workload_from_json(e.get("workload").ok_or("entry missing workload")?)?;
        let p = program_from_json(e.get("program").ok_or("entry missing program")?)?;
        let lat = e.get("latency").and_then(Json::as_f64).ok_or("entry missing latency")?;
        let measured =
            e.get("measured").and_then(Json::as_usize).ok_or("entry missing measured")?;
        self.put(w, p, lat, measured);
        Ok(())
    }

    /// Parse a document produced by [`TuneCache::to_json`]. When
    /// `expected_device` is given, a file recorded for a different device
    /// is rejected — latencies are device-specific, so silently serving
    /// them to another target would produce wrong-but-plausible results.
    /// Counters start at zero (they describe the current run).
    pub fn parse(text: &str, expected_device: Option<&str>) -> Result<TuneCache, String> {
        let j = json::parse(text)?;
        match j.get("format").and_then(Json::as_str) {
            Some(CACHE_FORMAT) => {}
            other => return Err(format!("not a tune cache (format {other:?})")),
        }
        match j.get("version").and_then(Json::as_usize) {
            Some(v) if v as u64 == CACHE_VERSION => {}
            other => {
                return Err(format!(
                    "unsupported cache version {other:?} (want {CACHE_VERSION})"
                ))
            }
        }
        let recorded = j
            .get("device")
            .and_then(Json::as_str)
            .ok_or("cache missing device")?;
        if let Some(expected) = expected_device {
            if recorded != expected {
                return Err(format!(
                    "cache was tuned for '{recorded}', not '{expected}' — \
                     latencies do not transfer across devices"
                ));
            }
        }
        let cache = TuneCache::new();
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("cache missing entries")?;
        for e in entries {
            let w = workload_from_json(e.get("workload").ok_or("entry missing workload")?)?;
            let p = program_from_json(e.get("program").ok_or("entry missing program")?)?;
            let lat = e
                .get("latency")
                .and_then(Json::as_f64)
                .ok_or("entry missing latency")?;
            let measured = e
                .get("measured")
                .and_then(Json::as_usize)
                .ok_or("entry missing measured")?;
            cache.map.lock().unwrap().insert(w, (p, lat, measured)); // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
        }
        Ok(cache)
    }

    /// Write the cache to `path` (versioned JSON), recording the device
    /// the latencies belong to. Persisted via
    /// [`crate::util::io::atomic_write`] (temp + fsync + rename,
    /// DESIGN.md §15), so an interrupted save never leaves a truncated
    /// cache that would brick later warm starts.
    pub fn save(&self, path: impl AsRef<Path>, device: &str) -> Result<(), String> {
        let text = self.to_json(device).to_string();
        // Debug builds sweep the serialized document through the artifact
        // checker (DESIGN.md §13) before it can reach disk.
        #[cfg(debug_assertions)]
        if let Some(d) =
            crate::verify::artifact::check_text(&text).and_then(|ds| ds.into_iter().next())
        {
            panic!("TuneCache::save produced a non-canonical document: {d}");
        }
        crate::util::io::atomic_write(path, &text, "cache")
    }

    /// Load a cache previously written by [`TuneCache::save`], verifying
    /// it was recorded for `expected_device`.
    pub fn load(path: impl AsRef<Path>, expected_device: &str) -> Result<TuneCache, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let cache = Self::parse(&text, Some(expected_device))
            .map_err(|e| format!("{}: {e}", path.display()))?;
        // Debug builds re-check the accepted document semantically — cached
        // programs must be legal for their workloads, keys canonical and
        // sorted (DESIGN.md §13).
        #[cfg(debug_assertions)]
        if let Some(d) =
            crate::verify::artifact::check_text(&text).and_then(|ds| ds.into_iter().next())
        {
            panic!("TuneCache::load accepted a non-canonical document {}: {d}", path.display());
        }
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::OpKind;

    fn wl(ff: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec!["bn", "relu"],
        )
    }

    fn prog() -> Program {
        Program {
            spatial_splits: vec![49, 4],
            ff_splits: vec![4, 8, 4],
            ax3_splits: vec![16, 8],
            ic_splits: vec![64],
            parallel: 4,
            vectorize: 8,
            unroll: 2,
        }
    }

    #[test]
    fn json_roundtrip_preserves_entries_exactly() {
        let cache = TuneCache::new();
        cache.put(wl(128), prog(), 0.001234567890123, 42);
        cache.put(wl(96), Program::naive(&wl(96)), 3.5e-5, 7);
        let text = cache.to_json("devA").to_string();
        let back = TuneCache::parse(&text, Some("devA")).unwrap();
        assert_eq!(back.len(), 2);
        let (p, lat, measured) = back.get(&wl(128)).unwrap();
        assert_eq!(p, prog());
        assert_eq!(lat, 0.001234567890123);
        assert_eq!(measured, 42);
        // epilogue interning must keep task identity intact
        let (_, lat2, _) = back.get(&wl(96)).unwrap();
        assert_eq!(lat2, 3.5e-5);
    }

    #[test]
    fn serialized_form_is_stable() {
        let a = TuneCache::new();
        let b = TuneCache::new();
        for &ff in &[64, 128, 256, 96] {
            a.put(wl(ff), prog(), ff as f64, ff);
            b.put(wl(ff), prog(), ff as f64, ff);
        }
        assert_eq!(a.to_json("d").to_string(), b.to_json("d").to_string());
    }

    #[test]
    fn rejects_foreign_and_versioned_documents() {
        let ok = r#"{"format":"cprune-tune-cache","version":1,"device":"d","entries":[]}"#;
        assert!(TuneCache::parse("{}", None).is_err());
        assert!(
            TuneCache::parse(r#"{"format":"other","version":1,"device":"d","entries":[]}"#, None)
                .is_err()
        );
        assert!(TuneCache::parse(
            r#"{"format":"cprune-tune-cache","version":999,"device":"d","entries":[]}"#,
            None
        )
        .is_err());
        assert!(TuneCache::parse(ok, None).is_ok());
        assert!(TuneCache::parse(ok, Some("d")).is_ok());
        // device mismatch: latencies must not silently transfer
        assert!(TuneCache::parse(ok, Some("other-device")).is_err());
        assert!(TuneCache::parse("not json", None).is_err());
    }

    #[test]
    fn hit_miss_and_savings_accounting() {
        let cache = TuneCache::new();
        cache.put(wl(128), prog(), 1.0, 30);
        assert!(cache.get(&wl(128)).is_some());
        assert!(cache.get(&wl(128)).is_some());
        assert!(cache.get(&wl(64)).is_none());
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.saved(), 60);
        assert!((cache.hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn save_load_via_disk() {
        let cache = TuneCache::new();
        cache.put(wl(128), prog(), 0.25, 12);
        let path = std::env::temp_dir().join("cprune_cache_unit_test.json");
        cache.save(&path, "devA").unwrap();
        let back = TuneCache::load(&path, "devA").unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back.get(&wl(128)).unwrap().1, 0.25);
        assert!(TuneCache::load(&path, "devB").is_err(), "wrong-device load accepted");
        let _ = std::fs::remove_file(&path);
    }
}
