//! Evolutionary schedule search for one task (Ansor's program tuner).
//!
//! Loop per round: rank the population with the learned cost model,
//! *measure* the best few on the (simulated) device, feed measurements
//! back into the model, then evolve the population by mutating the
//! measured elites. Returns the best measured program.

use super::cost_model::{CostModel, LearnedCost};
use crate::device::Simulator;
use crate::tir::{Program, Workload};
use crate::util::rng::Rng;

/// Tuning budget knobs.
#[derive(Clone, Copy, Debug)]
pub struct TuneOptions {
    /// Population per round.
    pub population: usize,
    /// Evolution rounds.
    pub rounds: usize,
    /// Programs measured on the device per round.
    pub measure_top_k: usize,
    /// Repeated measurements averaged per program.
    pub repeats: usize,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions { population: 64, rounds: 4, measure_top_k: 8, repeats: 3 }
    }
}

impl TuneOptions {
    /// A cheaper budget for inner loops (pruning candidate evaluation).
    pub fn quick() -> TuneOptions {
        TuneOptions { population: 48, rounds: 3, measure_top_k: 6, repeats: 2 }
    }
}

/// Result of tuning one task.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: Program,
    /// Mean measured latency of `best` (seconds).
    pub latency: f64,
    /// Total programs measured (the paper's search-cost metric, Fig. 11).
    pub measured: usize,
}

/// Tune one workload on one device. Deterministic given `rng`'s seed.
///
/// `seed_program`: optionally start from a known-good structure — CPrune
/// seeds the pruned task's search with the pre-pruning fastest program
/// (structure preservation, §3.5).
pub fn tune_task(
    w: &Workload,
    sim: &Simulator,
    opts: &TuneOptions,
    rng: &mut Rng,
    seed_program: Option<&Program>,
) -> TuneResult {
    let mut model = LearnedCost::new();
    let mut measured: Vec<(Program, f64)> = Vec::new();

    // Initial population: random samples (+ the seed program, if any valid).
    let mut population: Vec<Program> = Vec::with_capacity(opts.population);
    if let Some(p) = seed_program {
        if p.validate(w).is_ok() {
            population.push(p.clone());
        }
    }
    while population.len() < opts.population {
        population.push(Program::sample(w, rng));
    }

    for round in 0..opts.rounds {
        // Rank candidates: by cost model once trained, else randomly.
        let mut order: Vec<usize> = (0..population.len()).collect();
        if model.trained() {
            order.sort_by(|&a, &b| {
                model
                    .score(w, &population[a])
                    .total_cmp(&model.score(w, &population[b]))
            });
        } else {
            rng.shuffle(&mut order);
            // always measure the seed program first if present
            if seed_program.is_some() && round == 0 {
                if let Some(pos) = order.iter().position(|&i| i == 0) {
                    order.swap(0, pos);
                }
            }
        }

        // Measure the predicted-best candidates, keeping ~25% of the batch
        // for exploration (random picks) so a misled cost model cannot
        // starve good programs of measurements (Ansor's eps-greedy).
        let explore = (opts.measure_top_k / 4).max(1);
        let exploit = opts.measure_top_k.saturating_sub(explore);
        let mut batch: Vec<usize> = order.iter().take(exploit).copied().collect();
        for _ in 0..explore {
            batch.push(order[rng.below(order.len())]);
        }
        batch.dedup();
        for &i in &batch {
            let p = &population[i];
            let lat = sim.measure_avg(w, p, rng, opts.repeats);
            model.observe(w, p, lat);
            measured.push((p.clone(), lat));
        }
        model.refit();

        // Evolve: keep elites (by measured latency), refill with mutants
        // of elites + fresh randoms.
        measured.sort_by(|a, b| a.1.total_cmp(&b.1));
        measured.dedup_by(|a, b| a.0 == b.0);
        let elites: Vec<Program> = measured.iter().take(8).map(|(p, _)| p.clone()).collect();
        population.clear();
        population.extend(elites.iter().cloned());
        while population.len() < opts.population {
            if !elites.is_empty() && rng.f32() < 0.7 {
                let parent = rng.choose(&elites).clone();
                population.push(parent.mutate(w, rng));
            } else {
                population.push(Program::sample(w, rng));
            }
        }
    }

    let (best, latency) = measured
        .first()
        .cloned()
        .expect("at least one program measured");
    TuneResult { best, latency, measured: measured.len().max(opts.rounds * opts.measure_top_k) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSpec;
    use crate::graph::ops::OpKind;

    fn wl(ff: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 28, 28, ff],
            vec!["bn", "relu"],
        )
    }

    #[test]
    fn tuning_beats_naive_schedule() {
        let w = wl(128);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut rng = Rng::new(0);
        let res = tune_task(&w, &sim, &TuneOptions::default(), &mut rng, None);
        let naive = sim.latency(&w, &Program::naive(&w));
        assert!(
            naive / res.latency > 3.0,
            "tuner too weak: naive={naive}, tuned={}",
            res.latency
        );
        assert!(res.best.validate(&w).is_ok());
    }

    #[test]
    fn tuning_is_deterministic_given_seed() {
        let w = wl(64);
        let sim = Simulator::new(DeviceSpec::kryo280());
        let a = tune_task(&w, &sim, &TuneOptions::quick(), &mut Rng::new(9), None);
        let b = tune_task(&w, &sim, &TuneOptions::quick(), &mut Rng::new(9), None);
        assert_eq!(a.best, b.best);
        assert_eq!(a.latency, b.latency);
    }

    #[test]
    fn seed_program_is_honored() {
        // Seeding with a known-good structure should never end worse than
        // the seed itself (the search measures it first).
        let w = wl(96);
        let sim = Simulator::new(DeviceSpec::kryo585());
        let mut rng = Rng::new(4);
        let strong = tune_task(&w, &sim, &TuneOptions::default(), &mut rng, None);
        let mut rng2 = Rng::new(5);
        let seeded = tune_task(&w, &sim, &TuneOptions::quick(), &mut rng2, Some(&strong.best));
        let seed_lat = sim.latency(&w, &strong.best);
        assert!(seeded.latency <= seed_lat * 1.15, "{} vs {seed_lat}", seeded.latency);
    }

    #[test]
    fn more_budget_does_not_hurt() {
        let w = wl(256);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let quick = tune_task(&w, &sim, &TuneOptions::quick(), &mut Rng::new(2), None);
        let full = tune_task(
            &w,
            &sim,
            &TuneOptions { population: 128, rounds: 6, measure_top_k: 12, repeats: 3 },
            &mut Rng::new(2),
            None,
        );
        // compare noise-free true latencies of the chosen programs
        let lq = sim.latency(&w, &quick.best);
        let lf = sim.latency(&w, &full.best);
        assert!(lf <= lq * 1.05, "full {lf} worse than quick {lq}");
    }
}
