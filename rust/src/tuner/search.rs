//! Evolutionary schedule search for one task (Ansor's program tuner).
//!
//! Loop per round: rank the population with the learned cost model,
//! *measure* the best few on the (simulated) device, feed measurements
//! back into the model, then evolve the population by mutating the
//! measured elites. Returns the best measured program.
//!
//! This is the hot loop of the whole system — every pruning iteration
//! re-tunes candidate models, so constant factors here multiply into
//! end-to-end wall clock (DESIGN.md §10). The optimized path therefore:
//!
//! * scores each candidate **once per round** into a scratch buffer and
//!   sorts indices by the cached score, instead of re-extracting all
//!   [`super::cost_model::NFEAT`] features inside the sort comparator
//!   (O(n log n) → O(n) feature extractions per round);
//! * keeps a **bounded elite pool** keyed by a per-program seen-set
//!   instead of re-sorting and `dedup`-ing the full measurement history
//!   every round;
//! * **double-buffers the population**, overwriting slots in place via
//!   `Program::clone_from` / [`Program::mutate_into`] /
//!   [`Program::sample_into`] so evolution reuses allocations.
//!
//! `tune_task_reference` preserves the straightforward implementation;
//! `tests/property_tests.rs` pins the optimized search to it bit-for-bit
//! across random seeds and workloads, and `benches/tuner_micro.rs`
//! reports the speedup between the two.

use super::cost_model::{CostModel, LearnedCost};
use crate::device::Target;
use crate::tir::{Program, Workload};
use crate::util::rng::Rng;
use std::cmp::Ordering;
use std::collections::{HashMap, HashSet};

/// Tuning budget knobs.
#[derive(Clone, Copy, Debug)]
pub struct TuneOptions {
    /// Population per round.
    pub population: usize,
    /// Evolution rounds.
    pub rounds: usize,
    /// Programs measured on the device per round.
    pub measure_top_k: usize,
    /// Repeated measurements averaged per program.
    pub repeats: usize,
}

impl Default for TuneOptions {
    fn default() -> Self {
        TuneOptions { population: 64, rounds: 4, measure_top_k: 8, repeats: 3 }
    }
}

impl TuneOptions {
    /// A cheaper budget for inner loops (pruning candidate evaluation).
    pub fn quick() -> TuneOptions {
        TuneOptions { population: 48, rounds: 3, measure_top_k: 6, repeats: 2 }
    }
}

/// Result of tuning one task.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: Program,
    /// Mean measured latency of `best` (seconds).
    pub latency: f64,
    /// Programs actually measured on the device — one count per
    /// `measure_avg` call (the paper's search-cost metric, Fig. 11).
    /// This is an honest counter: it used to be inferred from the deduped
    /// measurement history and papered over with
    /// `len().max(rounds * measure_top_k)`, which both under- and
    /// over-reported whenever a measurement batch contained duplicates.
    pub measured: usize,
}

/// Elite-pool capacity: the evolution step mutates at most this many of
/// the best measured programs (matches Ansor's small elite set).
const ELITE_POOL: usize = 8;

/// Bounded pool of the best measured programs, deduplicated by value.
///
/// Semantics (shared by the optimized and reference searches): each
/// program's key is its best measured latency — only a *strict*
/// improvement re-ranks it, so ties keep first-measured order — and the
/// pool holds the `ELITE_POOL` lowest-keyed unique programs in ascending
/// order. Equivalent to stably sorting the full measurement history by
/// latency, deduplicating by program (first occurrence wins) and taking
/// the prefix — without storing or re-sorting that history each round.
struct ElitePool {
    /// Ascending by latency; unique programs; len ≤ `ELITE_POOL`.
    pool: Vec<(Program, f64)>,
    /// Best latency ever measured per unique program (the seen-set).
    /// Needed beyond the pool itself so a program that once fell out of
    /// the top-`ELITE_POOL` re-enters with its true historical best if a
    /// later (worse) re-measurement would otherwise mask it.
    best_lat: HashMap<Program, f64>,
}

impl ElitePool {
    fn new() -> ElitePool {
        ElitePool { pool: Vec::with_capacity(ELITE_POOL + 1), best_lat: HashMap::new() }
    }

    fn record(&mut self, p: &Program, lat: f64) {
        // All comparisons go through total_cmp (the repo's measurement-path
        // convention): a NaN latency gets the same well-defined rank the
        // reference search's total_cmp sort gives it (positive NaN last)
        // instead of poisoning the pool via always-false `<` comparisons.
        let improved = match self.best_lat.get_mut(p) {
            Some(cur) => {
                if lat.total_cmp(cur) == Ordering::Less {
                    *cur = lat;
                    true
                } else {
                    false
                }
            }
            None => {
                self.best_lat.insert(p.clone(), lat);
                true
            }
        };
        if !improved {
            return;
        }
        if let Some(pos) = self.pool.iter().position(|(q, _)| q == p) {
            self.pool.remove(pos);
        }
        // Insert after any equal latency (stable w.r.t. measurement order).
        let idx = self.pool.partition_point(|(_, l)| l.total_cmp(&lat) != Ordering::Greater);
        if idx < ELITE_POOL {
            self.pool.insert(idx, (p.clone(), lat));
            self.pool.truncate(ELITE_POOL);
        }
    }

    fn elites(&self) -> &[(Program, f64)] {
        &self.pool
    }

    /// Best measured (program, latency) overall — the pool minimum is the
    /// global minimum: a new global best always inserts at index 0 and is
    /// never truncated away.
    fn best(&self) -> Option<&(Program, f64)> {
        self.pool.first()
    }
}

/// Tune one workload on one device (any [`Target`] provider — analytic,
/// LUT-backed or replayed). Deterministic given `rng`'s seed.
///
/// `seed_program`: optionally start from a known-good structure — CPrune
/// seeds the pruned task's search with the pre-pruning fastest program
/// (structure preservation, §3.5).
pub fn tune_task(
    w: &Workload,
    target: &dyn Target,
    opts: &TuneOptions,
    rng: &mut Rng,
    seed_program: Option<&Program>,
) -> TuneResult {
    let mut model = LearnedCost::new();
    let mut pool = ElitePool::new();
    let mut n_measured = 0usize;

    // Initial population: random samples (+ the seed program, if any valid).
    let mut population: Vec<Program> = Vec::with_capacity(opts.population);
    if let Some(p) = seed_program {
        if p.validate(w).is_ok() {
            population.push(p.clone());
        }
    }
    while population.len() < opts.population {
        population.push(Program::sample(w, rng));
    }
    // Double buffer for evolution; grown lazily, slots overwritten in place.
    let mut next_gen: Vec<Program> = Vec::with_capacity(opts.population);

    // Per-round scratch (allocated once, reused every round).
    let mut scores: Vec<f64> = Vec::with_capacity(opts.population);
    let mut order: Vec<usize> = Vec::with_capacity(opts.population);
    let mut batch: Vec<usize> = Vec::with_capacity(opts.measure_top_k);
    let mut batch_seen: HashSet<usize> = HashSet::with_capacity(opts.measure_top_k);

    for round in 0..opts.rounds {
        // Rank candidates: by cost model once trained, else randomly.
        // Scores are computed once per candidate into a scratch buffer so
        // the comparator is a pure f64 lookup (the model re-extracts all
        // features per `score` call, which used to run O(n log n) times).
        order.clear();
        order.extend(0..population.len());
        if model.trained() {
            scores.clear();
            scores.extend(population.iter().map(|p| model.score(w, p)));
            order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
        } else {
            rng.shuffle(&mut order);
            // always measure the seed program first if present
            if seed_program.is_some() && round == 0 {
                if let Some(pos) = order.iter().position(|&i| i == 0) {
                    order.swap(0, pos);
                }
            }
        }

        // Measure the predicted-best candidates, keeping ~25% of the batch
        // for exploration (random picks) so a misled cost model cannot
        // starve good programs of measurements (Ansor's eps-greedy).
        let explore = (opts.measure_top_k / 4).max(1);
        let exploit = opts.measure_top_k.saturating_sub(explore);
        batch.clear();
        batch.extend(order.iter().take(exploit));
        for _ in 0..explore {
            batch.push(order[rng.below(order.len())]);
        }
        // Dedup with a seen-set: an exploration pick may duplicate a
        // *non-adjacent* exploit pick, which adjacent-only `Vec::dedup`
        // missed — double-measuring the same program skewed the cost
        // model's sample weights and the measured count.
        batch_seen.clear();
        batch.retain(|&i| batch_seen.insert(i));
        // One measurement-plane call for the whole deduped batch:
        // repeats and seeded jitter live in `Target::measure_batch`
        // (draw-for-draw identical to the historical per-program
        // `measure_avg` loop), and the honest `measured` counter is one
        // count per batch slot.
        let lats = {
            let programs: Vec<&Program> = batch.iter().map(|&i| &population[i]).collect();
            target.measure_batch(w, &programs, rng, opts.repeats)
        };
        for (&i, lat) in batch.iter().zip(lats) {
            let p = &population[i];
            model.observe(w, p, lat);
            n_measured += 1;
            pool.record(p, lat);
        }
        model.refit();

        // Evolve into the spare buffer: keep elites (by measured latency),
        // refill with mutants of elites + fresh randoms. Slots are
        // overwritten in place, reusing their split-tree allocations.
        let elites = pool.elites();
        let mut len = 0usize;
        for (e, _) in elites {
            grow_slot(&mut next_gen, len).clone_from(e);
            len += 1;
        }
        while len < opts.population {
            if !elites.is_empty() && rng.f32() < 0.7 {
                let parent = &elites[rng.below(elites.len())].0;
                parent.mutate_into(w, rng, grow_slot(&mut next_gen, len));
            } else {
                Program::sample_into(w, rng, grow_slot(&mut next_gen, len));
            }
            len += 1;
        }
        next_gen.truncate(len);
        std::mem::swap(&mut population, &mut next_gen);
    }

    let (best, latency) = pool.best().cloned().expect("at least one program measured"); // cprune-lint: allow(CPL005, reason="pool always measures at least one program")
    TuneResult { best, latency, measured: n_measured }
}

/// Slot `i` of `buf`, growing the buffer by one placeholder when writing
/// one past the end (the caller always overwrites the returned program).
fn grow_slot(buf: &mut Vec<Program>, i: usize) -> &mut Program {
    if i == buf.len() {
        buf.push(Program::empty());
    }
    &mut buf[i]
}

/// The straightforward (pre-optimization) search: identical semantics to
/// [`tune_task`], implemented with per-round full-history re-sorting,
/// comparator-time scoring and allocation-per-program evolution.
///
/// Kept as the executable specification: property tests assert the
/// optimized search returns bit-identical `(best, latency, measured)`
/// across random seeds/workloads, and the perf harness reports the
/// speedup between the two. Not used on any production path.
#[doc(hidden)]
pub fn tune_task_reference(
    w: &Workload,
    target: &dyn Target,
    opts: &TuneOptions,
    rng: &mut Rng,
    seed_program: Option<&Program>,
) -> TuneResult {
    let mut model = LearnedCost::new();
    let mut history: Vec<(Program, f64)> = Vec::new();
    let mut n_measured = 0usize;

    let mut population: Vec<Program> = Vec::with_capacity(opts.population);
    if let Some(p) = seed_program {
        if p.validate(w).is_ok() {
            population.push(p.clone());
        }
    }
    while population.len() < opts.population {
        population.push(Program::sample(w, rng));
    }

    for round in 0..opts.rounds {
        let mut order: Vec<usize> = (0..population.len()).collect();
        if model.trained() {
            order.sort_by(|&a, &b| {
                model
                    .score(w, &population[a])
                    .total_cmp(&model.score(w, &population[b]))
            });
        } else {
            rng.shuffle(&mut order);
            if seed_program.is_some() && round == 0 {
                if let Some(pos) = order.iter().position(|&i| i == 0) {
                    order.swap(0, pos);
                }
            }
        }

        let explore = (opts.measure_top_k / 4).max(1);
        let exploit = opts.measure_top_k.saturating_sub(explore);
        let mut batch: Vec<usize> = order.iter().take(exploit).copied().collect();
        for _ in 0..explore {
            batch.push(order[rng.below(order.len())]);
        }
        let mut seen_idx = HashSet::new();
        batch.retain(|&i| seen_idx.insert(i));
        let lats = {
            let programs: Vec<&Program> = batch.iter().map(|&i| &population[i]).collect();
            target.measure_batch(w, &programs, rng, opts.repeats)
        };
        for (&i, lat) in batch.iter().zip(lats) {
            let p = &population[i];
            model.observe(w, p, lat);
            n_measured += 1;
            history.push((p.clone(), lat));
        }
        model.refit();

        // Elites: stable sort of the full history by latency, per-program
        // dedup keeping the first (= best, earliest-measured) occurrence.
        let mut sorted = history.clone();
        sorted.sort_by(|a, b| a.1.total_cmp(&b.1));
        let mut seen_prog = HashSet::new();
        let elites: Vec<(Program, f64)> = sorted
            .into_iter()
            .filter(|(p, _)| seen_prog.insert(p.clone()))
            .take(ELITE_POOL)
            .collect();
        population.clear();
        population.extend(elites.iter().map(|(p, _)| p.clone()));
        while population.len() < opts.population {
            if !elites.is_empty() && rng.f32() < 0.7 {
                let parent = &elites[rng.below(elites.len())].0;
                population.push(parent.mutate(w, rng));
            } else {
                population.push(Program::sample(w, rng));
            }
        }
    }

    let (best, latency) = history
        .iter()
        .min_by(|a, b| a.1.total_cmp(&b.1))
        .cloned()
        .expect("at least one program measured"); // cprune-lint: allow(CPL005, reason="pool always measures at least one program")
    TuneResult { best, latency, measured: n_measured }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::ops::OpKind;

    fn wl(ff: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 28, 28, ff],
            vec!["bn", "relu"],
        )
    }

    #[test]
    fn tuning_beats_naive_schedule() {
        let w = wl(128);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut rng = Rng::new(0);
        let res = tune_task(&w, &sim, &TuneOptions::default(), &mut rng, None);
        let naive = sim.latency(&w, &Program::naive(&w));
        assert!(
            naive / res.latency > 3.0,
            "tuner too weak: naive={naive}, tuned={}",
            res.latency
        );
        assert!(res.best.validate(&w).is_ok());
    }

    #[test]
    fn tuning_is_deterministic_given_seed() {
        let w = wl(64);
        let sim = Simulator::new(DeviceSpec::kryo280());
        let a = tune_task(&w, &sim, &TuneOptions::quick(), &mut Rng::new(9), None);
        let b = tune_task(&w, &sim, &TuneOptions::quick(), &mut Rng::new(9), None);
        assert_eq!(a.best, b.best);
        assert_eq!(a.latency, b.latency);
        assert_eq!(a.measured, b.measured);
    }

    #[test]
    fn optimized_matches_reference_search() {
        // The full cross-seed/workload sweep lives in
        // tests/property_tests.rs; this is the fast smoke version.
        let w = wl(96);
        let sim = Simulator::new(DeviceSpec::kryo585());
        let a = tune_task(&w, &sim, &TuneOptions::quick(), &mut Rng::new(3), None);
        let b = tune_task_reference(&w, &sim, &TuneOptions::quick(), &mut Rng::new(3), None);
        assert_eq!(a.best, b.best);
        assert_eq!(a.latency.to_bits(), b.latency.to_bits());
        assert_eq!(a.measured, b.measured);
    }

    #[test]
    fn measured_counts_actual_device_measurements() {
        // The measured count is the number of measure_avg calls — never
        // more than the nominal budget, and strictly less when a batch
        // contains duplicate picks (tiny population forces collisions).
        let w = wl(64);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let opts = TuneOptions { population: 2, rounds: 4, measure_top_k: 8, repeats: 1 };
        let dflt = TuneOptions::default();
        let res = tune_task(&w, &sim, &dflt, &mut Rng::new(1), None);
        assert!(res.measured <= dflt.rounds * dflt.measure_top_k);
        assert!(res.measured > 0);
        let tiny = tune_task(&w, &sim, &opts, &mut Rng::new(1), None);
        // population of 2 can never yield 8 unique picks per round
        assert!(
            tiny.measured <= opts.rounds * 2,
            "dedup failed: {} measurements from a 2-program population",
            tiny.measured
        );
        // the old fudge would have reported exactly rounds * measure_top_k
        assert!(tiny.measured < opts.rounds * opts.measure_top_k);
    }

    #[test]
    fn elite_pool_matches_sort_dedup_semantics() {
        // Feed a measurement stream with duplicates and ties; the pool
        // must equal "stable sort by latency, dedup by program keeping
        // the first occurrence, take ELITE_POOL".
        let w = wl(32);
        let progs: Vec<Program> = (0..6)
            .map(|i| {
                let mut p = Program::naive(&w);
                p.unroll = i + 1; // distinct by value, guaranteed
                p
            })
            .collect();
        let stream: Vec<(usize, f64)> = vec![
            (0, 3.0),
            (1, 2.0),
            (0, 1.5), // improvement: re-ranks program 0
            (2, 2.0), // tie with program 1: must stay after it
            (3, 9.0),
            (1, 2.5), // worse re-measurement: ignored
            (4, 0.5),
            (5, 9.0),
        ];
        let mut pool = ElitePool::new();
        let mut history: Vec<(Program, f64)> = Vec::new();
        for &(i, lat) in &stream {
            pool.record(&progs[i], lat);
            history.push((progs[i].clone(), lat));
        }
        let mut sorted = history.clone();
        sorted.sort_by(|a, b| a.1.total_cmp(&b.1));
        let mut seen = HashSet::new();
        let expect: Vec<(Program, f64)> = sorted
            .into_iter()
            .filter(|(p, _)| seen.insert(p.clone()))
            .take(ELITE_POOL)
            .collect();
        assert_eq!(pool.elites(), &expect[..]);
        assert_eq!(pool.best().unwrap().1, 0.5);
    }

    #[test]
    fn elite_pool_is_nan_safe() {
        // A NaN measurement must rank last (total_cmp, the repo-wide
        // measurement-path convention) — never claim best() or poison the
        // program's seen-set entry against later finite measurements.
        let w = wl(32);
        let good = Program::naive(&w);
        let mut bad = Program::naive(&w);
        bad.unroll = 7;
        let mut pool = ElitePool::new();
        pool.record(&bad, f64::NAN);
        pool.record(&good, 1.0);
        assert_eq!(pool.best().unwrap().0, good);
        assert_eq!(pool.best().unwrap().1, 1.0);
        // a later finite re-measurement of the NaN program recovers it
        pool.record(&bad, 0.5);
        assert_eq!(pool.best().unwrap().1, 0.5);
        assert_eq!(pool.best().unwrap().0, bad);
    }

    #[test]
    fn seed_program_is_honored() {
        // Seeding with a known-good structure should never end worse than
        // the seed itself (the search measures it first).
        let w = wl(96);
        let sim = Simulator::new(DeviceSpec::kryo585());
        let mut rng = Rng::new(4);
        let strong = tune_task(&w, &sim, &TuneOptions::default(), &mut rng, None);
        let mut rng2 = Rng::new(5);
        let seeded = tune_task(&w, &sim, &TuneOptions::quick(), &mut rng2, Some(&strong.best));
        let seed_lat = sim.latency(&w, &strong.best);
        assert!(seeded.latency <= seed_lat * 1.15, "{} vs {seed_lat}", seeded.latency);
    }

    #[test]
    fn more_budget_does_not_hurt() {
        let w = wl(256);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let quick = tune_task(&w, &sim, &TuneOptions::quick(), &mut Rng::new(2), None);
        let full = tune_task(
            &w,
            &sim,
            &TuneOptions { population: 128, rounds: 6, measure_top_k: 12, repeats: 3 },
            &mut Rng::new(2),
            None,
        );
        // compare noise-free true latencies of the chosen programs
        let lq = sim.latency(&w, &quick.best);
        let lf = sim.latency(&w, &full.best);
        assert!(lf <= lq * 1.05, "full {lf} worse than quick {lq}");
    }
}
