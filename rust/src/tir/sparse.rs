//! Sparse lowering classes (DESIGN.md §16).
//!
//! How a [`crate::sparsity::Scheme`] reaches the generated loop nest.
//! The class determines two things the cost model needs: the *compute
//! scale* (fraction of the dense inner-loop trips that survive) and
//! whether the lowering must *reorder* filters to keep the inner loop
//! dense — PatDNN's kernel compaction groups filters by pattern, which
//! is a gather the device pays for; N:M block skipping runs in place at
//! fixed stride; a dense channel shrink is just a smaller dense kernel.
//! Per-device pricing of these classes lives in
//! [`crate::device::sparse::scheme_factor`].

use crate::sparsity::{Scheme, SchemeChoice};

/// How a scheme lowers to TIR.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseLowering {
    /// Channel pruning: the kernel shrinks densely; nothing sparse to
    /// lower.
    DenseShrink,
    /// Pattern sparsity: kernels compact to `taps` of `total` taps;
    /// filters sharing a pattern are grouped so the inner loop is dense
    /// over the kept taps (requires a filter reorder).
    PatternCompact { taps: usize, total: usize },
    /// N:M block sparsity: of every `group` consecutive fan-in weights,
    /// `keep` survive; the loop skips at fixed stride, no reorder.
    BlockSkip { keep: usize, group: usize },
}

impl SparseLowering {
    /// The canonical lowering of a scheme choice.
    pub fn for_choice(choice: &SchemeChoice) -> SparseLowering {
        match choice.scheme {
            Scheme::Channel => SparseLowering::DenseShrink,
            Scheme::Pattern => SparseLowering::PatternCompact {
                taps: crate::sparsity::pattern::KEPT_TAPS,
                total: crate::sparsity::pattern::TOTAL_TAPS,
            },
            Scheme::Block => SparseLowering::BlockSkip {
                keep: crate::sparsity::block::KEEP,
                group: crate::sparsity::block::GROUP,
            },
        }
    }

    /// Fraction of the dense inner-loop trips that survive.
    pub fn compute_scale(&self) -> f64 {
        match *self {
            SparseLowering::DenseShrink => 1.0,
            SparseLowering::PatternCompact { taps, total } => taps as f64 / total as f64,
            SparseLowering::BlockSkip { keep, group } => keep as f64 / group as f64,
        }
    }

    /// Whether the lowering must gather/reorder filters before the dense
    /// inner loop can run.
    pub fn needs_reorder(&self) -> bool {
        matches!(self, SparseLowering::PatternCompact { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lowering_matches_scheme_density() {
        for s in Scheme::ALL {
            let c = SchemeChoice::for_scheme(s);
            let l = SparseLowering::for_choice(&c);
            assert_eq!(l.compute_scale(), c.density, "{s:?}");
        }
    }

    #[test]
    fn only_pattern_compaction_reorders() {
        assert!(!SparseLowering::DenseShrink.needs_reorder());
        assert!(SparseLowering::PatternCompact { taps: 4, total: 9 }.needs_reorder());
        assert!(!SparseLowering::BlockSkip { keep: 2, group: 4 }.needs_reorder());
    }
}
