//! Schedules ("programs") over a conv workload, and the paper's §3.5
//! minimum-filter-prune-step rule.
//!
//! A [`Program`] captures what TVM's generated code looks like for one
//! task: split trees over the spatial axes, the *two* filter-related
//! iterators (`ff` in the compute nest, `ax3` in the cache-write/layout
//! stage — Fig. 5 (b)/(c)), a reduce-axis split, and parallel /
//! vectorize / unroll annotations.

use super::loopnest::Workload;
use crate::util::rng::Rng;
use crate::util::{divisors, lcm};

/// One concrete schedule for a workload.
///
/// `Eq`/`Hash` let the tuner key its measured-program seen-set by value
/// (all fields are integers, so both derive exactly). `Clone` is written
/// by hand so `clone_from` reuses the destination's split-tree
/// allocations — the tuner's evolution loop (DESIGN.md §10) overwrites
/// population slots in place instead of re-allocating every generation.
#[derive(Debug, PartialEq, Eq, Hash)]
pub struct Program {
    /// Split tree of the fused spatial axis (oh*ow): outer→inner factors.
    pub spatial_splits: Vec<usize>,
    /// Split tree of the compute-nest filter iterator `ff` (Fig. 5 (b): 512→[4,8,16]).
    pub ff_splits: Vec<usize>,
    /// Split tree of the layout-stage filter iterator `ax3`.
    pub ax3_splits: Vec<usize>,
    /// Split tree of the reduce axis ic (kh/kw stay unsplit).
    pub ic_splits: Vec<usize>,
    /// Number of outer iterations bound to worker threads / cores.
    pub parallel: usize,
    /// Vector width applied to the innermost axis (1 = scalar).
    pub vectorize: usize,
    /// Innermost unroll factor.
    pub unroll: usize,
}

impl Clone for Program {
    fn clone(&self) -> Program {
        Program {
            spatial_splits: self.spatial_splits.clone(),
            ff_splits: self.ff_splits.clone(),
            ax3_splits: self.ax3_splits.clone(),
            ic_splits: self.ic_splits.clone(),
            parallel: self.parallel,
            vectorize: self.vectorize,
            unroll: self.unroll,
        }
    }

    fn clone_from(&mut self, src: &Program) {
        self.spatial_splits.clone_from(&src.spatial_splits);
        self.ff_splits.clone_from(&src.ff_splits);
        self.ax3_splits.clone_from(&src.ax3_splits);
        self.ic_splits.clone_from(&src.ic_splits);
        self.parallel = src.parallel;
        self.vectorize = src.vectorize;
        self.unroll = src.unroll;
    }
}

impl Program {
    /// The naive untuned schedule (what a "default" / TFLite-like library
    /// path runs): no tiling beyond the trivial, scalar inner loop.
    pub fn naive(w: &Workload) -> Program {
        Program {
            spatial_splits: vec![w.oh * w.ow],
            ff_splits: vec![w.ff],
            ax3_splits: vec![w.ff, 1],
            ic_splits: vec![w.ic],
            parallel: 1,
            vectorize: 1,
            unroll: 1,
        }
    }

    /// An all-empty placeholder, only for buffers that are immediately
    /// overwritten via `Program::clone_from` / [`Program::sample_into`]
    /// (it does not validate against any workload).
    pub(crate) fn empty() -> Program {
        Program {
            spatial_splits: Vec::new(),
            ff_splits: Vec::new(),
            ax3_splits: Vec::new(),
            ic_splits: Vec::new(),
            parallel: 1,
            vectorize: 1,
            unroll: 1,
        }
    }

    /// Sample a random valid schedule (Ansor-style sketch sampling).
    pub fn sample(w: &Workload, rng: &mut Rng) -> Program {
        let mut prog = Program::empty();
        Program::sample_into(w, rng, &mut prog);
        prog
    }

    /// [`Program::sample`] into an existing buffer, reusing its split-tree
    /// allocations. Draws exactly the same RNG sequence as `sample`.
    pub fn sample_into(w: &Workload, rng: &mut Rng, out: &mut Program) {
        let spatial = w.oh * w.ow;
        sample_splits_into(spatial, 3, rng, &mut out.spatial_splits);
        sample_splits_into(w.ff, 3, rng, &mut out.ff_splits);
        sample_splits_into(w.ff, 3, rng, &mut out.ax3_splits);
        sample_splits_into(w.ic, 2, rng, &mut out.ic_splits);
        out.parallel = *rng.choose(&[1, 2, 4, 8]);
        out.vectorize = *rng.choose(&[1, 4, 8, 16]);
        out.unroll = *rng.choose(&[1, 2, 4, 16]);
        debug_assert!(out.validate(w).is_ok());
    }

    /// Mutate one schedule decision (evolutionary-search step).
    pub fn mutate(&self, w: &Workload, rng: &mut Rng) -> Program {
        let mut p = Program::empty();
        self.mutate_into(w, rng, &mut p);
        p
    }

    /// [`Program::mutate`] into an existing buffer, reusing its split-tree
    /// allocations. Draws exactly the same RNG sequence as `mutate`.
    pub fn mutate_into(&self, w: &Workload, rng: &mut Rng, out: &mut Program) {
        out.clone_from(self);
        match rng.below(6) {
            0 => sample_splits_into(w.oh * w.ow, 3, rng, &mut out.spatial_splits),
            1 => sample_splits_into(w.ff, 3, rng, &mut out.ff_splits),
            2 => sample_splits_into(w.ff, 3, rng, &mut out.ax3_splits),
            3 => sample_splits_into(w.ic, 2, rng, &mut out.ic_splits),
            4 => out.parallel = *rng.choose(&[1, 2, 4, 8]),
            _ => {
                out.vectorize = *rng.choose(&[1, 4, 8, 16]);
                out.unroll = *rng.choose(&[1, 2, 4, 16]);
            }
        }
    }

    /// Check split products against the workload extents.
    ///
    /// Split products may *pad*: `extent ≤ Π factors < 2·extent` (TVM
    /// handles non-dividing tile sizes with tail iterations; the padded
    /// fraction is wasted work the simulator charges for). Exact products
    /// are the zero-waste special case.
    /// Delegates to [`crate::verify::program::check_program`] (DESIGN.md
    /// §13) — the same legality pass the `cprune check` artifact sweep
    /// applies to cached programs — and reports the first finding. The
    /// passing path allocates nothing, so the `debug_assert!` in
    /// [`Program::sample_into`] stays cheap.
    pub fn validate(&self, w: &Workload) -> Result<(), String> {
        match crate::verify::program::check_program(self, w).into_iter().next() {
            None => Ok(()),
            Some(d) => Err(d.to_string()),
        }
    }

    /// Wasted-work ratios (≥ 1) from padded tiling: (spatial, ff).
    pub fn waste(&self, w: &Workload) -> (f64, f64) {
        let ratio = |splits: &[usize], extent: usize| {
            let prod: usize = splits.iter().product();
            prod as f64 / extent.max(1) as f64
        };
        (
            ratio(&self.spatial_splits, w.oh * w.ow).max(1.0),
            ratio(&self.ff_splits, w.ff).max(1.0),
        )
    }

    /// §3.5: the minimum number of filters that can be pruned while
    /// preserving this program's structure.
    ///
    /// For each filter iterator, the cheapest structure-preserving
    /// reduction shrinks the *largest* factor by one unit, removing
    /// `Π factors / max_factor` filters; the step must satisfy both
    /// iterators at once, hence the LCM:
    /// `LCM(Πa/max(a), Πb/max(b))` — Fig. 5 (b) gives LCM(32,32)=32,
    /// Fig. 5 (c) gives LCM(4,1)=4.
    pub fn min_filter_prune_step(&self) -> usize {
        let step = |splits: &[usize]| -> u64 {
            let prod: u64 = splits.iter().map(|&f| f as u64).product();
            let max = splits.iter().copied().max().unwrap_or(1) as u64;
            prod / max
        };
        lcm(step(&self.ff_splits), step(&self.ax3_splits)) as usize
    }

    /// Rewrite the filter split trees for a reduced channel count, keeping
    /// the tree *shape* (the preserved structure CPrune relies on): the
    /// largest factor of each tree absorbs the reduction.
    ///
    /// Returns `None` if `new_ff` is incompatible with the structure
    /// (i.e. not reachable by shrinking the max factors).
    pub fn with_pruned_filters(&self, new_ff: usize) -> Option<Program> {
        let shrink = |splits: &[usize]| -> Option<Vec<usize>> {
            let prod: usize = splits.iter().product();
            if prod == new_ff {
                return Some(splits.to_vec());
            }
            let (max_i, &max_f) = splits
                .iter()
                .enumerate()
                .max_by_key(|(_, &f)| f)?;
            let rest: usize = prod / max_f;
            if rest == 0 || new_ff % rest != 0 {
                return None;
            }
            let new_max = new_ff / rest;
            if new_max == 0 {
                return None;
            }
            let mut out = splits.to_vec();
            out[max_i] = new_max;
            Some(out)
        };
        Some(Program {
            ff_splits: shrink(&self.ff_splits)?,
            ax3_splits: shrink(&self.ax3_splits)?,
            ..self.clone()
        })
    }

    /// Inner tile extents (spatial_tile, ff_tile): the innermost factors,
    /// which determine the register/cache footprint the simulator models.
    pub fn inner_tile(&self) -> (usize, usize) {
        (
            *self.spatial_splits.last().unwrap_or(&1),
            *self.ff_splits.last().unwrap_or(&1),
        )
    }
}

/// Sample a split of `extent` into exactly `nparts` factors (outer→inner).
///
/// Two families, mirroring TVM's split primitive:
/// * exact divisor chains (zero waste), and
/// * padded tilings — a power-of-two inner tile with `ceil(extent/tile)`
///   outer iterations (waste < 2×), which keeps awkward extents (primes,
///   e.g. a 179-channel pruned conv) tileable.
pub fn sample_splits(extent: usize, nparts: usize, rng: &mut Rng) -> Vec<usize> {
    let mut out = Vec::with_capacity(nparts);
    sample_splits_into(extent, nparts, rng, &mut out);
    out
}

/// [`sample_splits`] into an existing buffer (cleared first), reusing its
/// allocation. Draws exactly the same RNG sequence as `sample_splits`.
pub fn sample_splits_into(extent: usize, nparts: usize, rng: &mut Rng, out: &mut Vec<usize>) {
    assert!(extent >= 1 && nparts >= 1);
    out.clear();
    if nparts == 1 {
        out.push(extent);
        return;
    }
    if rng.f32() < 0.5 {
        // exact divisor chain
        let mut rem = extent;
        for _ in 0..nparts - 1 {
            let divs = divisors(rem);
            let f = *rng.choose(&divs);
            out.push(f);
            rem /= f;
        }
        out.push(rem);
    } else {
        // padded: choose an inner power-of-two tile ≤ extent, cover the
        // rest with ceil-division, then split the outer part exactly.
        let max_pow = (usize::BITS - 1 - extent.leading_zeros()) as usize; // floor(log2)
        let tile = 1usize << rng.below(max_pow + 1).min(8);
        let outer = extent.div_ceil(tile);
        sample_splits_exact_into(outer, nparts - 1, rng, out);
        out.push(tile);
    }
}

/// Exact divisor-chain split (helper for the padded family's outer part).
fn sample_splits_exact_into(extent: usize, nparts: usize, rng: &mut Rng, out: &mut Vec<usize>) {
    let mut rem = extent;
    for _ in 0..nparts.saturating_sub(1) {
        let divs = divisors(rem);
        let f = *rng.choose(&divs);
        out.push(f);
        rem /= f;
    }
    out.push(rem);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::OpKind;

    fn wl(ff: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec!["bn", "relu"],
        )
    }

    #[test]
    fn paper_fig5b_fast_program_step_is_32() {
        // ff = ax3 = 4x8x16 over 512 filters → LCM(512/16, 512/16) = 32.
        let p = Program {
            spatial_splits: vec![49, 4],
            ff_splits: vec![4, 8, 16],
            ax3_splits: vec![4, 8, 16],
            ic_splits: vec![64],
            parallel: 8,
            vectorize: 16,
            unroll: 2,
        };
        assert_eq!(p.min_filter_prune_step(), 32);
    }

    #[test]
    fn paper_fig5c_slow_program_step_is_4() {
        // ff = 4x128, ax3 = 512x1 → LCM(512/128, 512/512) = LCM(4,1) = 4.
        let p = Program {
            spatial_splits: vec![196],
            ff_splits: vec![4, 128],
            ax3_splits: vec![512, 1],
            ic_splits: vec![64],
            parallel: 1,
            vectorize: 1,
            unroll: 1,
        };
        assert_eq!(p.min_filter_prune_step(), 4);
    }

    #[test]
    fn sampled_programs_validate() {
        let w = wl(128);
        let mut rng = Rng::new(0);
        for _ in 0..200 {
            let p = Program::sample(&w, &mut rng);
            assert!(p.validate(&w).is_ok());
            assert!(p.min_filter_prune_step() >= 1);
        }
    }

    #[test]
    fn mutation_stays_valid() {
        let w = wl(96);
        let mut rng = Rng::new(1);
        let mut p = Program::sample(&w, &mut rng);
        for _ in 0..100 {
            p = p.mutate(&w, &mut rng);
            assert!(p.validate(&w).is_ok());
        }
    }

    #[test]
    fn with_pruned_filters_preserves_tree_shape() {
        let p = Program {
            spatial_splits: vec![196],
            ff_splits: vec![4, 8, 16],
            ax3_splits: vec![4, 8, 16],
            ic_splits: vec![64],
            parallel: 4,
            vectorize: 8,
            unroll: 1,
        };
        // prune one step (32 filters): 512 → 480 = 4x8x15
        let q = p.with_pruned_filters(480).unwrap();
        assert_eq!(q.ff_splits, vec![4, 8, 15]);
        assert_eq!(q.ax3_splits, vec![4, 8, 15]);
        // incompatible target (not a multiple of 4*8)
        assert!(p.with_pruned_filters(481).is_none());
    }

    #[test]
    fn naive_program_step_is_small() {
        // Untuned: ff unsplit → step 1; ax3=[ff,1] → step 1 → LCM = 1.
        let w = wl(512);
        let p = Program::naive(&w);
        assert_eq!(p.min_filter_prune_step(), 1);
    }

    #[test]
    fn sample_splits_cover_extent_with_bounded_waste() {
        let mut rng = Rng::new(2);
        for extent in [1usize, 7, 12, 96, 512, 196, 179] {
            for nparts in 1..=4 {
                for _ in 0..50 {
                    let s = sample_splits(extent, nparts, &mut rng);
                    let prod = s.iter().product::<usize>();
                    assert_eq!(s.len(), nparts);
                    assert!(prod >= extent, "{s:?} does not cover {extent}");
                    assert!(prod < 2 * extent.max(1), "{s:?} wastes ≥2x over {extent}");
                }
            }
        }
    }

    #[test]
    fn prime_extents_remain_tileable() {
        // A pruned conv can end up with a prime channel count (e.g. 179);
        // padded tiling must still offer real inner tiles.
        let mut rng = Rng::new(3);
        let some_tiled = (0..100).any(|_| {
            let s = sample_splits(179, 3, &mut rng);
            *s.last().unwrap() >= 8
        });
        assert!(some_tiled, "no padded tiling sampled for prime extent");
    }

    #[test]
    fn sample_into_matches_sample_exactly() {
        // The buffer-reusing variants must draw the same RNG sequence and
        // produce the same program as the allocating ones — the tuner's
        // determinism contract (DESIGN.md §10) depends on it.
        let w = wl(128);
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        let mut buf = Program::naive(&w); // non-empty: reuse must overwrite fully
        for _ in 0..100 {
            let fresh = Program::sample(&w, &mut a);
            Program::sample_into(&w, &mut b, &mut buf);
            assert_eq!(fresh, buf);
        }
        assert_eq!(a.next_u64(), b.next_u64(), "RNG streams diverged");
    }

    #[test]
    fn mutate_into_matches_mutate_exactly() {
        let w = wl(96);
        let mut a = Rng::new(22);
        let mut b = Rng::new(22);
        let parent = Program::sample(&w, &mut Rng::new(0));
        let mut buf = Program::empty();
        for _ in 0..100 {
            let fresh = parent.mutate(&w, &mut a);
            parent.mutate_into(&w, &mut b, &mut buf);
            assert_eq!(fresh, buf);
        }
        assert_eq!(a.next_u64(), b.next_u64(), "RNG streams diverged");
    }

    #[test]
    fn clone_from_reuses_and_matches() {
        let w = wl(64);
        let mut rng = Rng::new(23);
        let src = Program::sample(&w, &mut rng);
        let mut dst = Program::sample(&w, &mut rng);
        dst.clone_from(&src);
        assert_eq!(dst, src);
    }

    #[test]
    fn waste_ratios() {
        let w = wl(100);
        let exact = Program::naive(&w);
        assert_eq!(exact.waste(&w), (1.0, 1.0));
        let padded = Program {
            spatial_splits: vec![w.oh * w.ow],
            ff_splits: vec![13, 8], // 104 covers 100 → 4% waste
            ax3_splits: vec![100],
            ic_splits: vec![w.ic],
            parallel: 1,
            vectorize: 1,
            unroll: 1,
        };
        assert!(padded.validate(&w).is_ok());
        let (ws, wf) = padded.waste(&w);
        assert_eq!(ws, 1.0);
        assert!((wf - 1.04).abs() < 1e-9);
    }
}
