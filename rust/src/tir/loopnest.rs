//! Conv workload description: the iteration domain a task's programs tile.

use crate::graph::ops::OpKind;
use crate::graph::shape_infer::Shape;

/// The iteration extents of one conv-like task (a fused
/// conv(+bn+act[+add]) subgraph's anchor computation).
///
/// A dense conv iterates `n × oh × ow × ff × (ic/groups) × kh × kw`; the
/// tuner splits the parallel axes (`oh`, `ow`, `ff`) and reduce axes.
/// Dense layers are modeled as 1×1 convs over a 1×1 spatial domain.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Workload {
    pub n: usize,
    pub oh: usize,
    pub ow: usize,
    /// Output channels — the filter dimension CPrune prunes.
    pub ff: usize,
    /// Input channels per group (reduce axis).
    pub ic: usize,
    pub kh: usize,
    pub kw: usize,
    pub groups: usize,
    pub stride: usize,
    /// Fused epilogue ops (bn/relu/add) — cheap, but they shape the
    /// structural hash: tasks only merge when epilogues match (§3.4).
    pub epilogue: Vec<&'static str>,
}

impl Workload {
    /// Build from a conv node's op + inferred output shape.
    pub fn from_conv(op: &OpKind, out_shape: Shape, epilogue: Vec<&'static str>) -> Workload {
        match *op {
            OpKind::Conv2d { kh, kw, cin, cout, stride, groups, .. } => Workload {
                n: out_shape[0],
                oh: out_shape[1],
                ow: out_shape[2],
                ff: cout,
                ic: cin / groups,
                kh,
                kw,
                groups,
                stride,
                epilogue,
            },
            OpKind::Dense { cin, cout } => Workload {
                n: out_shape[0],
                oh: 1,
                ow: 1,
                ff: cout,
                ic: cin,
                kh: 1,
                kw: 1,
                groups: 1,
                stride: 1,
                epilogue,
            },
            ref other => panic!("Workload::from_conv on non-conv op {other:?}"),
        }
    }

    /// Multiply-accumulates of one execution of the task.
    pub fn macs(&self) -> u64 {
        (self.n * self.oh * self.ow * self.ff) as u64 * (self.ic * self.kh * self.kw) as u64
    }

    /// Bytes of unique data touched (f32): input patch + filters + output.
    pub fn working_set_bytes(&self) -> u64 {
        let input = self.n
            * (self.oh * self.stride + self.kh)
            * (self.ow * self.stride + self.kw)
            * self.ic
            * self.groups;
        let filters = self.kh * self.kw * self.ic * self.ff;
        let output = self.n * self.oh * self.ow * self.ff;
        ((input + filters + output) * 4) as u64
    }

    /// True when this is a depthwise conv (one filter per input channel).
    pub fn is_depthwise(&self) -> bool {
        self.groups > 1 && self.ic == 1
    }

    /// Structural identity used for task deduplication (§3.4): two
    /// subgraphs map to the same task iff every extent, stride and
    /// epilogue op matches. Derives from `PartialEq + Hash` on the struct.
    pub fn same_task(&self, other: &Workload) -> bool {
        self == other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv_op() -> OpKind {
        OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: 128, stride: 2, padding: 1, groups: 1 }
    }

    #[test]
    fn from_conv_extents() {
        let w = Workload::from_conv(&conv_op(), [1, 28, 28, 128], vec!["bn", "relu"]);
        assert_eq!((w.oh, w.ow, w.ff, w.ic, w.kh), (28, 28, 128, 64, 3));
        assert_eq!(w.macs(), (28 * 28 * 128) as u64 * (64 * 9) as u64);
    }

    #[test]
    fn dense_as_1x1() {
        let w = Workload::from_conv(&OpKind::Dense { cin: 512, cout: 10 }, [1, 1, 1, 10], vec![]);
        assert_eq!((w.ff, w.ic, w.oh), (10, 512, 1));
    }

    #[test]
    fn depthwise_detection() {
        let op = OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: 32, stride: 1, padding: 1, groups: 32 };
        let w = Workload::from_conv(&op, [1, 14, 14, 32], vec![]);
        assert!(w.is_depthwise());
        assert_eq!(w.ic, 1);
    }

    #[test]
    fn task_identity_includes_epilogue() {
        let a = Workload::from_conv(&conv_op(), [1, 28, 28, 128], vec!["bn", "relu"]);
        let b = Workload::from_conv(&conv_op(), [1, 28, 28, 128], vec!["bn"]);
        let c = Workload::from_conv(&conv_op(), [1, 28, 28, 128], vec!["bn", "relu"]);
        assert!(!a.same_task(&b));
        assert!(a.same_task(&c));
    }

    #[test]
    fn working_set_positive() {
        let w = Workload::from_conv(&conv_op(), [1, 28, 28, 128], vec![]);
        assert!(w.working_set_bytes() > 0);
    }
}
