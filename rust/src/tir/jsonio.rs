//! JSON (de)serialization of [`Workload`]s and [`Program`]s.
//!
//! One canonical encoding shared by every persistence surface that keys
//! on TIR values — the tuning cache (`cprune-tune-cache`), the
//! measurement traces of [`crate::device::ReplayTarget`]
//! (`cprune-measure-trace`) — so a workload/program serialized by one
//! layer parses identically in another, and byte-stable document output
//! (sorted object keys via `util::json`, shortest-f64 numbers) holds
//! everywhere.

use super::{Program, Workload};
use crate::util::json::Json;

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

fn nums(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| num(x)).collect())
}

fn usize_field(j: &Json, key: &str) -> Result<usize, String> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| format!("missing field {key}"))
}

fn usize_list(j: &Json, key: &str) -> Result<Vec<usize>, String> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("missing list {key}"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| format!("non-integer in {key}")))
        .collect()
}

/// Epilogue tags come from the fixed fusion vocabulary in
/// `relay::partition`; map parsed strings back onto the `'static` strs the
/// `Workload` type carries (unknown tags — future fusions — are leaked,
/// which costs bytes once per distinct tag per process).
fn intern_epilogue(tag: &str) -> &'static str {
    match tag {
        "bn" => "bn",
        "relu" => "relu",
        "relu6" => "relu6",
        "softmax" => "softmax",
        "add" => "add",
        other => Box::leak(other.to_string().into_boxed_str()),
    }
}

/// Canonical JSON encoding of a workload.
pub fn workload_to_json(w: &Workload) -> Json {
    Json::obj(vec![
        ("n", num(w.n)),
        ("oh", num(w.oh)),
        ("ow", num(w.ow)),
        ("ff", num(w.ff)),
        ("ic", num(w.ic)),
        ("kh", num(w.kh)),
        ("kw", num(w.kw)),
        ("groups", num(w.groups)),
        ("stride", num(w.stride)),
        (
            "epilogue",
            Json::Arr(w.epilogue.iter().map(|t| Json::Str(t.to_string())).collect()),
        ),
    ])
}

/// Parse a workload from [`workload_to_json`] output.
pub fn workload_from_json(j: &Json) -> Result<Workload, String> {
    let epilogue = j
        .get("epilogue")
        .and_then(Json::as_arr)
        .ok_or("workload missing epilogue")?
        .iter()
        .map(|v| {
            v.as_str()
                .map(intern_epilogue)
                .ok_or_else(|| "non-string epilogue tag".to_string())
        })
        .collect::<Result<Vec<_>, _>>()?;
    Ok(Workload {
        n: usize_field(j, "n")?,
        oh: usize_field(j, "oh")?,
        ow: usize_field(j, "ow")?,
        ff: usize_field(j, "ff")?,
        ic: usize_field(j, "ic")?,
        kh: usize_field(j, "kh")?,
        kw: usize_field(j, "kw")?,
        groups: usize_field(j, "groups")?,
        stride: usize_field(j, "stride")?,
        epilogue,
    })
}

/// Canonical JSON encoding of a program.
pub fn program_to_json(p: &Program) -> Json {
    Json::obj(vec![
        ("spatial_splits", nums(&p.spatial_splits)),
        ("ff_splits", nums(&p.ff_splits)),
        ("ax3_splits", nums(&p.ax3_splits)),
        ("ic_splits", nums(&p.ic_splits)),
        ("parallel", num(p.parallel)),
        ("vectorize", num(p.vectorize)),
        ("unroll", num(p.unroll)),
    ])
}

/// Parse a program from [`program_to_json`] output.
pub fn program_from_json(j: &Json) -> Result<Program, String> {
    Ok(Program {
        spatial_splits: usize_list(j, "spatial_splits")?,
        ff_splits: usize_list(j, "ff_splits")?,
        ax3_splits: usize_list(j, "ax3_splits")?,
        ic_splits: usize_list(j, "ic_splits")?,
        parallel: usize_field(j, "parallel")?,
        vectorize: usize_field(j, "vectorize")?,
        unroll: usize_field(j, "unroll")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::OpKind;

    #[test]
    fn workload_and_program_roundtrip() {
        let w = Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: 96, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, 96],
            vec!["bn", "relu"],
        );
        let p = Program::naive(&w);
        let w2 = workload_from_json(&workload_to_json(&w)).unwrap();
        let p2 = program_from_json(&program_to_json(&p)).unwrap();
        assert_eq!(w, w2);
        assert_eq!(p, p2);
        // canonical: serialize → parse → serialize is the identity
        assert_eq!(
            workload_to_json(&w).to_string(),
            workload_to_json(&w2).to_string()
        );
    }
}
