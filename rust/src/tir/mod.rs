//! Loop-nest tensor IR: the compiler substrate the paper's §3.5 reads.
//!
//! A TVM/Ansor schedule for a conv task is, at its core, a set of *split
//! trees* over the loop iterators plus parallel/vectorize/unroll
//! annotations. CPrune consumes exactly two pieces of this structure:
//!
//! 1. the split trees of the two filter-related iterators (`ff` in the
//!    compute loop and `ax3` in the layout/cache-write stage — Fig. 5),
//!    from which it derives the minimum prunable filter step, and
//! 2. the program's overall arrangement, which must be *preserved* across
//!    pruning so the compiler regenerates equally-efficient code.
//!
//! [`Workload`] describes a conv task's extents; [`Program`] is one
//! concrete schedule; [`Program::min_filter_prune_step`] is the paper's
//! LCM rule.
//!
//! Schedule legality is machine-checked: [`Program::validate`] delegates
//! to [`crate::verify::program`] (DESIGN.md §13), which also runs inside
//! the artifact checker so a persisted program must stay legal for the
//! workload key it is cached under.
//!
//! `sparse.rs` describes how a pattern- or block-sparse layer lowers
//! onto the dense loop nest ([`sparse::SparseLowering`], DESIGN.md §16):
//! the compute scale a scheme buys and whether it needs a data-reorder
//! stage, which the per-device cost model in [`crate::device::sparse`]
//! prices.

pub mod jsonio;
pub mod loopnest;
pub mod lower;
pub mod program;
pub mod sparse;

pub use loopnest::Workload;
pub use program::Program;
