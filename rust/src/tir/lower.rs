//! Program rendering: lower a schedule to TVM-style pseudo-code text.
//!
//! The paper's Fig. 5 shows generated programs as nested `for` loops with
//! split iterators (`ff.3`, `ax3`, vectorize/parallel annotations); CPrune
//! *reads* that structure. This module renders our [`Program`]s the same
//! way — used by the `program_structure` example, debug logging, and the
//! docs — and is the ground truth for how split trees map to loops.

use super::loopnest::Workload;
use super::program::Program;
use std::fmt::Write as _;

/// Render a program over a workload as nested-loop pseudo-code.
pub fn render(w: &Workload, p: &Program) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "// task: conv {}x{} cin={} ff={} oh={} ow={} stride={} epilogue={:?}",
        w.kh, w.kw, w.ic, w.ff, w.oh, w.ow, w.stride, w.epilogue
    );
    let _ = writeln!(
        out,
        "// schedule: parallel={} vectorize={} unroll={}",
        p.parallel, p.vectorize, p.unroll
    );

    let mut depth = 0;
    let indent = |d: usize| "  ".repeat(d);

    // parallel outer spatial/ff loops
    let sp = &p.spatial_splits;
    let ff = &p.ff_splits;
    let _ = writeln!(
        out,
        "{}parallel for sp.0 in 0..{} {{  // spatial outer",
        indent(depth),
        sp.first().copied().unwrap_or(1)
    );
    depth += 1;
    let _ = writeln!(
        out,
        "{}for ff.0 in 0..{} {{  // filter outer",
        indent(depth),
        ff.first().copied().unwrap_or(1)
    );
    depth += 1;
    for (i, f) in sp.iter().enumerate().skip(1) {
        let _ = writeln!(out, "{}for sp.{} in 0..{} {{", indent(depth), i, f);
        depth += 1;
    }
    for (i, f) in ff.iter().enumerate().skip(1) {
        let last = i + 1 == ff.len();
        let ann = if last && p.vectorize > 1 {
            format!("  // vectorize x{}", p.vectorize)
        } else {
            String::new()
        };
        let _ = writeln!(out, "{}for ff.{} in 0..{} {{{}", indent(depth), i, f, ann);
        depth += 1;
    }
    for (i, f) in p.ic_splits.iter().enumerate() {
        let _ = writeln!(out, "{}for ic.{} in 0..{} {{  // reduce", indent(depth), i, f);
        depth += 1;
    }
    let _ = writeln!(
        out,
        "{}for kh in 0..{} {{ for kw in 0..{} {{  // unroll x{}",
        indent(depth),
        w.kh,
        w.kw,
        p.unroll
    );
    depth += 1;
    let _ = writeln!(
        out,
        "{}acc[ff] += input[sp, ic, kh, kw] * filter[ff, ic, kh, kw];",
        indent(depth)
    );
    depth -= 1;
    let _ = writeln!(out, "{}}} }}", indent(depth));
    for _ in 0..p.ic_splits.len() + ff.len().saturating_sub(1) + sp.len().saturating_sub(1) {
        depth = depth.saturating_sub(1);
        let _ = writeln!(out, "{}}}", indent(depth));
    }
    // cache-write / layout stage (the ax3 iterator of Fig. 5)
    let _ = writeln!(out, "{}// cache write (layout stage)", indent(depth));
    for (i, f) in p.ax3_splits.iter().enumerate() {
        let _ = writeln!(out, "{}for ax3.{} in 0..{} {{", indent(depth), i, f);
        depth += 1;
    }
    let _ = writeln!(out, "{}output[sp, ax3] = epilogue(acc[ax3]);", indent(depth));
    for _ in 0..p.ax3_splits.len() {
        depth = depth.saturating_sub(1);
        let _ = writeln!(out, "{}}}", indent(depth));
    }
    depth = depth.saturating_sub(1);
    let _ = writeln!(out, "{}}}", indent(depth));
    depth = depth.saturating_sub(1);
    let _ = writeln!(out, "{}}}", indent(depth));
    let _ = writeln!(
        out,
        "// min structure-preserving prune step (LCM rule): {}",
        p.min_filter_prune_step()
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::OpKind;

    fn wl() -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 7, kw: 7, cin: 512, cout: 512, stride: 1, padding: 3, groups: 1 },
            [1, 7, 7, 512],
            vec!["bn", "relu"],
        )
    }

    #[test]
    fn renders_fig5b_like_program() {
        let p = Program {
            spatial_splits: vec![49],
            ff_splits: vec![4, 8, 16],
            ax3_splits: vec![4, 8, 16],
            ic_splits: vec![512],
            parallel: 8,
            vectorize: 16,
            unroll: 2,
        };
        let text = render(&wl(), &p);
        assert!(text.contains("for ff.1 in 0..8"));
        assert!(text.contains("for ff.2 in 0..16 {  // vectorize x16"));
        assert!(text.contains("for ax3.2 in 0..16"));
        assert!(text.contains("prune step (LCM rule): 32"));
    }

    #[test]
    fn renders_fig5c_like_program() {
        let p = Program {
            spatial_splits: vec![49],
            ff_splits: vec![4, 128],
            ax3_splits: vec![512, 1],
            ic_splits: vec![512],
            parallel: 1,
            vectorize: 1,
            unroll: 1,
        };
        let text = render(&wl(), &p);
        assert!(text.contains("for ff.1 in 0..128"));
        assert!(text.contains("for ax3.0 in 0..512"));
        assert!(text.contains("prune step (LCM rule): 4"));
    }

    #[test]
    fn braces_balance() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..50 {
            let p = Program::sample(&wl(), &mut rng);
            let text = render(&wl(), &p);
            let open = text.matches('{').count();
            let close = text.matches('}').count();
            assert_eq!(open, close, "unbalanced braces:\n{text}");
        }
    }
}
