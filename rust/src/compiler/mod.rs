//! End-to-end compile pipeline: graph → partition → (tune | fallback) →
//! model latency / FPS.
//!
//! Three paths, matching the comparisons in Figs. 1, 7 and 8:
//! * [`compile_tuned`] — TVM auto-tune equivalent (per-task search);
//! * [`compile_fallback`] — target-agnostic library equivalent (TFLite):
//!   one fixed, reasonable-but-untuned schedule per task;
//! * [`latency_with_programs`] — run programs tuned for *another* device
//!   on this one (Fig. 8's cross-device experiment).

use crate::device::Target;
use crate::graph::ops::Graph;
use crate::graph::shape_infer;
use crate::relay::partition::{extract_tasks, partition};
use crate::relay::TaskTable;
use crate::tir::{Program, Workload};
use crate::tuner::TuningSession;
use crate::util::rng::stable_hash;
use std::collections::HashMap;

/// A compiled model: tuned task table + non-tunable overhead.
#[derive(Clone, Debug)]
pub struct CompiledModel {
    pub table: TaskTable,
    /// Latency of pooling/flatten/etc. nodes (seconds).
    pub overhead_latency: f64,
}

impl CompiledModel {
    /// End-to-end single-image latency (seconds).
    pub fn latency(&self) -> f64 {
        self.table.model_latency() + self.overhead_latency
    }

    /// Figures per second — the paper's headline metric.
    pub fn fps(&self) -> f64 {
        1.0 / self.latency()
    }
}

/// Latency contributed by non-fused ops (pooling, flatten): data movement.
pub fn overhead_latency(graph: &Graph, target: &dyn Target) -> f64 {
    let shapes = shape_infer::infer(graph).expect("graph must shape-infer"); // cprune-lint: allow(CPL005, reason="compile entry points require shape-valid graphs")
    let part = partition(graph);
    part.overhead_nodes
        .iter()
        .map(|&id| {
            let out_elems: usize = shapes[id].iter().product();
            let in_elems: usize = graph
                .node(id)
                .inputs
                .iter()
                .map(|&i| shapes[i].iter().product::<usize>())
                .sum();
            target.overhead_latency(((out_elems + in_elems) * 4) as u64)
        })
        .sum()
}

/// Full auto-tuned compilation (the "TVM auto-tune" baseline and the
/// backend CPrune drives every iteration).
pub fn compile_tuned(
    graph: &Graph,
    session: &TuningSession,
    seed_programs: &HashMap<Workload, Program>,
) -> CompiledModel {
    let table = session.tune_graph(graph, seed_programs);
    CompiledModel { table, overhead_latency: overhead_latency(graph, session.target) }
}

/// Target-agnostic compilation: every task gets the naive default
/// schedule (what a generic kernel library achieves without tuning).
pub fn compile_fallback(graph: &Graph, target: &dyn Target) -> CompiledModel {
    let (_, mut table) = extract_tasks(graph);
    let ids: Vec<usize> = table.tasks().map(|t| t.id).collect();
    for tid in ids {
        let w = table.get(tid).workload.clone();
        let p = fallback_program(&w);
        let lat = target.latency(&w, &p);
        table.record_tuned(tid, p, lat);
    }
    CompiledModel { table, overhead_latency: overhead_latency(graph, target) }
}

/// The fallback schedule: modest fixed tiling — better than fully naive
/// (real libraries do block and vectorize), but generic: no per-shape
/// layout optimization (the `ax3` stage stays row-major, cf. Fig. 5 (c)),
/// conservative threading, no reduce-axis tiling.
pub fn fallback_program(w: &Workload) -> Program {
    let sp = w.oh * w.ow;
    let sp_inner = [8usize, 4, 2, 1].iter().copied().find(|f| sp % f == 0).unwrap_or(1);
    let ff_inner = [8usize, 4, 2, 1].iter().copied().find(|f| w.ff % f == 0).unwrap_or(1);
    Program {
        spatial_splits: vec![sp / sp_inner, sp_inner],
        ff_splits: vec![w.ff / ff_inner, ff_inner],
        ax3_splits: vec![w.ff, 1], // generic layout: no cache-write tiling
        ic_splits: vec![w.ic],
        parallel: 2,
        vectorize: 4.min(ff_inner),
        unroll: 1,
    }
}

/// Eager-framework execution (the "before compiler optimization" axis of
/// Fig. 1): every node dispatches its own unfused kernel with framework
/// overhead, and each task runs the naive schedule. This models running
/// the pruned model directly in an eager DL framework (PyTorch) — the
/// paper's pre-compilation measurement.
pub fn compile_eager(graph: &Graph, target: &dyn Target) -> CompiledModel {
    let (_, mut table) = extract_tasks(graph);
    let ids: Vec<usize> = table.tasks().map(|t| t.id).collect();
    for tid in ids {
        let w = table.get(tid).workload.clone();
        let p = Program::naive(&w);
        // Eager libraries (cuDNN/oneDNN behind PyTorch) pick a fixed kernel
        // per shape from a small menu; performance is erratic across channel
        // counts and UNcorrelated with how well the shape tunes in a
        // search-based compiler — the root cause of Fig. 1's decorrelation.
        // Model it as a deterministic per-shape efficiency in [0.25, 1],
        // derived with the repo's stable hash (DefaultHasher's algorithm is
        // unspecified across Rust releases, which would shift these golden
        // latencies on a toolchain upgrade).
        let unit = (stable_hash(&(w.ff, w.ic, w.oh, w.kh)) % 10_000) as f64 / 10_000.0;
        let kernel_eff = 0.25 + 0.75 * unit;
        let lat = target.latency(&w, &p) / kernel_eff;
        table.record_tuned(tid, p, lat);
    }
    // Per-node framework dispatch: every op (not just fused subgraphs)
    // pays an eager-mode launch cost — and that cost is itself erratic per
    // shape (PyTorch dispatch + allocator + cudnnFind vary 0.5–2x with
    // tensor sizes), which is what makes eager FPS a poor predictor of
    // compiled FPS (Fig. 1).
    let eager_per_op = match target.spec().kind {
        crate::device::DeviceKind::Gpu => 40e-6,
        crate::device::DeviceKind::Cpu => 8e-6,
    };
    let shapes = shape_infer::infer(graph).expect("graph must shape-infer"); // cprune-lint: allow(CPL005, reason="compile entry points require shape-valid graphs")
    let mut eager_overhead = 0.0;
    for node in &graph.nodes {
        let unit =
            (stable_hash(&(node.op.mnemonic(), shapes[node.id])) % 10_000) as f64 / 10_000.0;
        eager_overhead += eager_per_op * (0.5 + 1.5 * unit);
    }
    CompiledModel {
        table,
        overhead_latency: overhead_latency(graph, target) + eager_overhead,
    }
}

/// Evaluate a graph on `target` using programs tuned elsewhere: for each task,
/// look up the same workload in `foreign` (falling back to naive when the
/// workload does not exist there). Models Fig. 8's "CPrune model executed
/// on a different processor".
pub fn latency_with_programs(graph: &Graph, foreign: &TaskTable, target: &dyn Target) -> f64 {
    let (_, mut table) = extract_tasks(graph);
    let ids: Vec<usize> = table.tasks().map(|t| t.id).collect();
    for tid in ids {
        let w = table.get(tid).workload.clone();
        let prog = foreign
            .tasks()
            .find(|t| t.workload.same_task(&w))
            .and_then(|t| t.best_program.clone())
            .unwrap_or_else(|| Program::naive(&w));
        let lat = target.latency(&w, &prog);
        table.record_tuned(tid, prog, lat);
    }
    table.model_latency() + overhead_latency(graph, target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::{Model, ModelKind};
    use crate::tuner::TuneOptions;

    #[test]
    fn tuned_fps_exceeds_fallback_fps() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let sess = TuningSession::new(&sim, TuneOptions::default(), 3);
        let tuned = compile_tuned(&m.graph, &sess, &HashMap::new());
        let fallback = compile_fallback(&m.graph, &sim);
        assert!(
            tuned.fps() > fallback.fps() * 1.3,
            "tuned {} vs fallback {}",
            tuned.fps(),
            fallback.fps()
        );
    }

    #[test]
    fn cross_device_programs_are_slower_than_native() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let cpu = Simulator::new(DeviceSpec::kryo585());
        let gpu = Simulator::new(DeviceSpec::mali_g72());
        let cpu_sess = TuningSession::new(&cpu, TuneOptions::default(), 3);
        let gpu_sess = TuningSession::new(&gpu, TuneOptions::default(), 3);
        let native = compile_tuned(&m.graph, &cpu_sess, &HashMap::new());
        let gpu_compiled = compile_tuned(&m.graph, &gpu_sess, &HashMap::new());
        let foreign_lat = latency_with_programs(&m.graph, &gpu_compiled.table, &cpu);
        assert!(
            foreign_lat > native.latency(),
            "foreign {} native {}",
            foreign_lat,
            native.latency()
        );
    }

    #[test]
    fn resnet18_kryo385_fps_in_paper_ballpark() {
        // Paper Table 1: original ResNet-18 + TVM on Kryo 385 = 18.86 FPS.
        // The simulator should land within ~3x of that (shape, not value).
        let m = Model::build(ModelKind::ResNet18ImageNet, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let sess = TuningSession::new(&sim, TuneOptions::quick(), 3);
        let c = compile_tuned(&m.graph, &sess, &HashMap::new());
        let fps = c.fps();
        assert!(
            (6.0..60.0).contains(&fps),
            "ResNet-18/Kryo385 FPS={fps} wildly off paper's 18.9"
        );
    }

    #[test]
    fn overhead_is_small_but_nonzero() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let oh = overhead_latency(&m.graph, &sim);
        assert!(oh > 0.0);
        let c = compile_fallback(&m.graph, &sim);
        assert!(oh < 0.2 * c.latency(), "overhead dominates: {oh}");
    }
}
