//! Perf-trajectory harness: versioned `BENCH_*.json` for every PR
//! (DESIGN.md §10).
//!
//! `cprune bench --tier quick|full` runs the hot-path workloads the
//! standalone benches (`benches/tuner_micro.rs`, `benches/fleet_tuning.rs`)
//! exercise — with pinned seeds — and records wall-clock seconds plus
//! programs-measured counts into `BENCH_tuner.json` / `BENCH_e2e.json`
//! (`cprune-bench` format v1). Wall times vary with the host; the
//! measured-program counts are deterministic for a pinned seed, so CI can
//! smoke-check them while the JSON artifacts accumulate a cross-PR perf
//! trajectory.
//!
//! The tuner suite also times `tune_task` against the straightforward
//! reference search it was optimized from (`tuner::search`), reporting
//! `speedup_vs_reference` — the measured win of the scoring cache, elite
//! pool and allocation-reusing evolution.

use crate::device::{DeviceSpec, TargetRegistry};
use crate::graph::model_zoo::{Model, ModelKind};
use crate::graph::ops::OpKind;
use crate::run::{CPrune, RunBuilder};
use crate::tir::Workload;
use crate::tuner::search::tune_task_reference;
use crate::tuner::{tune_task, FleetOptions, FleetSession, TuneOptions, TuningSession};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Format tag of the `BENCH_*.json` header (guards foreign JSON files).
pub const BENCH_FORMAT: &str = "cprune-bench";
/// Bump when the record schema changes.
pub const BENCH_VERSION: u64 = 1;

/// Benchmark effort tier.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    /// CI-sized: seconds, quick tune budgets, small models.
    Quick,
    /// Trajectory-grade: the full bench workloads (minutes).
    Full,
}

impl Tier {
    pub fn parse(s: &str) -> Option<Tier> {
        match s {
            "quick" => Some(Tier::Quick),
            "full" => Some(Tier::Full),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Tier::Quick => "quick",
            Tier::Full => "full",
        }
    }
}

/// One benchmark's outcome: wall clock, search cost, extra metrics.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub name: String,
    /// Wall-clock seconds for the whole workload (host-dependent).
    pub wall_s: f64,
    /// Programs measured on the simulated device — deterministic for a
    /// pinned seed (the CI smoke contract).
    pub programs_measured: usize,
    /// Named extra metrics (speedups, hit rates, FPS...).
    pub metrics: Vec<(String, f64)>,
}

impl BenchRecord {
    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("wall_s", Json::Num(self.wall_s)),
            ("programs_measured", Json::Num(self.programs_measured as f64)),
        ];
        for (k, v) in &self.metrics {
            pairs.push((k.as_str(), Json::Num(*v)));
        }
        Json::obj(pairs)
    }

    /// Row for `util::bench::print_table` (name, wall, measured).
    pub fn table_row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            format!("{:.3}", self.wall_s),
            self.programs_measured.to_string(),
        ]
    }
}

/// A suite's records, serializable as versioned `BENCH_<suite>.json`.
#[derive(Clone, Debug)]
pub struct PerfReport {
    /// Suite tag — becomes the file name (`tuner` → `BENCH_tuner.json`).
    pub suite: String,
    pub tier: Tier,
    pub seed: u64,
    pub records: Vec<BenchRecord>,
}

impl PerfReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::Str(BENCH_FORMAT.to_string())),
            ("version", Json::Num(BENCH_VERSION as f64)),
            ("suite", Json::Str(self.suite.clone())),
            ("tier", Json::Str(self.tier.name().to_string())),
            ("seed", Json::Num(self.seed as f64)),
            ("records", Json::Arr(self.records.iter().map(BenchRecord::to_json).collect())),
        ])
    }

    /// The report's file name (`BENCH_tuner.json`, `BENCH_e2e.json`).
    pub fn file_name(&self) -> String {
        format!("BENCH_{}.json", self.suite)
    }

    /// Write `BENCH_<suite>.json` into `dir` (created if absent).
    pub fn save(&self, dir: impl AsRef<Path>) -> Result<PathBuf, String> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir).map_err(|e| format!("creating {}: {e}", dir.display()))?;
        let path = dir.join(self.file_name());
        crate::util::io::atomic_write(&path, &self.to_json().to_string(), "report")?;
        Ok(path)
    }
}

/// The benches' hot conv workload (`tuner_micro`'s 256-filter 3×3 conv).
pub fn hot_conv_workload() -> Workload {
    Workload::from_conv(
        &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: 256, stride: 1, padding: 1, groups: 1 },
        [1, 28, 28, 256],
        vec!["bn", "relu"],
    )
}

/// The fleet bench's device set for a tier (`fleet_tuning` uses the full
/// mobile-target roster; quick keeps CI under a minute with three).
pub fn fleet_devices(tier: Tier) -> Vec<DeviceSpec> {
    match tier {
        Tier::Quick => vec![DeviceSpec::kryo385(), DeviceSpec::kryo585(), DeviceSpec::mali_g72()],
        Tier::Full => DeviceSpec::mobile_targets(),
    }
}

/// The fleet bench's model for a tier.
pub fn fleet_model(tier: Tier) -> ModelKind {
    match tier {
        Tier::Quick => ModelKind::ResNet8Cifar,
        Tier::Full => ModelKind::MobileNetV2ImageNet,
    }
}

/// Tuner-hot-path suite → `BENCH_tuner.json`.
///
/// Records: `tune_task` repeats on the hot conv (with the
/// reference-search speedup), a fresh-session `tune_graph`, and a
/// cold+warm fleet compilation.
pub fn run_tuner_suite(tier: Tier, seed: u64) -> PerfReport {
    let mut records = Vec::new();
    let (task_iters, graph_iters) = match tier {
        Tier::Quick => (8usize, 2usize),
        Tier::Full => (48, 8),
    };

    // -- tune_task on the hot conv, optimized vs reference ----------------
    // The device rides the registry like every other caller (DESIGN.md
    // §11); the analytic provider is bit-identical to the old direct
    // Simulator wiring, so the pinned measured counts are unaffected.
    let w = hot_conv_workload();
    let target = TargetRegistry::builtin()
        .resolve("kryo385")
        .expect("builtin device resolves"); // cprune-lint: allow(CPL005, reason="builtin registry always has kryo385")
    let mut measured = 0usize;
    let t0 = Instant::now();
    for i in 0..task_iters {
        let mut rng = crate::util::rng::Rng::new(seed.wrapping_add(i as u64));
        measured += tune_task(&w, target.as_ref(), &TuneOptions::quick(), &mut rng, None).measured;
    }
    let opt_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    for i in 0..task_iters {
        let mut rng = crate::util::rng::Rng::new(seed.wrapping_add(i as u64));
        let _ = tune_task_reference(&w, target.as_ref(), &TuneOptions::quick(), &mut rng, None);
    }
    let ref_s = t1.elapsed().as_secs_f64();
    records.push(BenchRecord {
        name: "tune_task_hot_conv".to_string(),
        wall_s: opt_s,
        programs_measured: measured,
        metrics: vec![
            ("iters".to_string(), task_iters as f64),
            ("reference_wall_s".to_string(), ref_s),
            ("speedup_vs_reference".to_string(), if opt_s > 0.0 { ref_s / opt_s } else { 0.0 }),
        ],
    });

    // -- whole-graph tuning, fresh session each time ----------------------
    let small = Model::build(ModelKind::ResNet8Cifar, 0);
    let mut measured = 0usize;
    let t0 = Instant::now();
    for i in 0..graph_iters {
        let s = seed.wrapping_add(i as u64);
        let session = TuningSession::new(target.as_ref(), TuneOptions::quick(), s);
        let table = session.tune_graph(&small.graph, &HashMap::new());
        std::hint::black_box(table.model_latency());
        measured += session.measured_count();
    }
    records.push(BenchRecord {
        name: "tune_graph_resnet8".to_string(),
        wall_s: t0.elapsed().as_secs_f64(),
        programs_measured: measured,
        metrics: vec![("iters".to_string(), graph_iters as f64)],
    });

    // -- fleet compilation, cold then warm --------------------------------
    let model = Model::build(fleet_model(tier), seed);
    let opts = match tier {
        Tier::Quick => TuneOptions::quick(),
        Tier::Full => TuneOptions::default(),
    };
    let mut fleet = FleetSession::new(
        fleet_devices(tier),
        FleetOptions { tune: opts, threads: 0, cross_seed: true },
        seed,
    );
    let t0 = Instant::now();
    let cold = fleet.tune_graph(&model.graph);
    let cold_s = t0.elapsed().as_secs_f64();
    records.push(BenchRecord {
        name: "fleet_cold".to_string(),
        wall_s: cold_s,
        programs_measured: cold.total_measured(),
        metrics: vec![("devices".to_string(), cold.devices.len() as f64)],
    });
    let t1 = Instant::now();
    let warm = fleet.tune_graph(&model.graph);
    records.push(BenchRecord {
        name: "fleet_warm".to_string(),
        wall_s: t1.elapsed().as_secs_f64(),
        programs_measured: warm.total_measured(),
        metrics: vec![
            ("hit_rate".to_string(), warm.hit_rate()),
            ("measured_saved".to_string(), warm.total_measured_saved() as f64),
        ],
    });

    PerfReport { suite: "tuner".to_string(), tier, seed, records }
}

/// End-to-end suite → `BENCH_e2e.json`: a CPrune run (cold, then warm on
/// the same session cache) through the §9 run layer. Errors propagate so
/// the CLI can fail cleanly without discarding earlier suites.
pub fn run_e2e_suite(tier: Tier, seed: u64) -> Result<PerfReport, String> {
    let iters = match tier {
        Tier::Quick => 4usize,
        Tier::Full => 12,
    };
    let mut run = RunBuilder::new(ModelKind::ResNet8Cifar)
        .device("kryo385")
        .seed(seed)
        .tune_opts(TuneOptions::quick())
        .max_iterations(iters)
        .build()
        .map_err(|e| format!("e2e bench: {e}"))?;
    let pruner = CPrune::default();

    let mut records = Vec::new();
    let t0 = Instant::now();
    let cold = run.execute(&pruner).map_err(|e| format!("e2e bench cold run: {e}"))?;
    records.push(BenchRecord {
        name: "cprune_resnet8_cold".to_string(),
        wall_s: t0.elapsed().as_secs_f64(),
        programs_measured: cold.programs_measured,
        metrics: vec![
            ("fps_increase_rate".to_string(), cold.fps_increase_rate),
            ("search_candidates".to_string(), cold.search_candidates as f64),
            ("accepted_iterations".to_string(), cold.iterations.len() as f64),
        ],
    });
    let t1 = Instant::now();
    let warm = run.execute(&pruner).map_err(|e| format!("e2e bench warm run: {e}"))?;
    records.push(BenchRecord {
        name: "cprune_resnet8_warm".to_string(),
        wall_s: t1.elapsed().as_secs_f64(),
        programs_measured: warm.programs_measured,
        metrics: vec![("cache_hits".to_string(), run.cache().hits() as f64)],
    });

    Ok(PerfReport { suite: "e2e".to_string(), tier, seed, records })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn tier_parses() {
        assert_eq!(Tier::parse("quick"), Some(Tier::Quick));
        assert_eq!(Tier::parse("full"), Some(Tier::Full));
        assert_eq!(Tier::parse("medium"), None);
        assert_eq!(Tier::Quick.name(), "quick");
    }

    #[test]
    fn report_json_roundtrips_and_is_versioned() {
        let report = PerfReport {
            suite: "tuner".to_string(),
            tier: Tier::Quick,
            seed: 7,
            records: vec![BenchRecord {
                name: "x".to_string(),
                wall_s: 1.5,
                programs_measured: 42,
                metrics: vec![("speedup_vs_reference".to_string(), 2.0)],
            }],
        };
        assert_eq!(report.file_name(), "BENCH_tuner.json");
        let j = json::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(j.get("format").and_then(Json::as_str), Some(BENCH_FORMAT));
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("tier").and_then(Json::as_str), Some("quick"));
        let rec = &j.get("records").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(rec.get("programs_measured").and_then(Json::as_usize), Some(42));
        assert_eq!(rec.get("speedup_vs_reference").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn quick_tuner_suite_counts_are_deterministic() {
        // Wall times vary; the search-cost counts must not (the CI smoke
        // contract for the pinned seed).
        let a = run_tuner_suite(Tier::Quick, 42);
        let b = run_tuner_suite(Tier::Quick, 42);
        let counts = |r: &PerfReport| -> Vec<(String, usize)> {
            r.records.iter().map(|x| (x.name.clone(), x.programs_measured)).collect()
        };
        assert_eq!(counts(&a), counts(&b));
        assert!(a.records.iter().any(|r| r.programs_measured > 0));
        // the optimized search must not lose to the reference
        let tt = &a.records[0];
        let speedup = tt
            .metrics
            .iter()
            .find(|(k, _)| k == "speedup_vs_reference")
            .map(|(_, v)| *v)
            .unwrap();
        assert!(speedup > 0.0);
    }

    #[test]
    fn quick_e2e_suite_runs_and_warm_run_measures_nothing() {
        let r = run_e2e_suite(Tier::Quick, 0).expect("quick e2e suite runs");
        assert_eq!(r.records.len(), 2);
        assert!(r.records[0].programs_measured > 0, "cold run measured nothing");
        assert_eq!(r.records[1].programs_measured, 0, "warm run re-measured");
        let dir = std::env::temp_dir().join("cprune_perf_test");
        let path = r.save(&dir).unwrap();
        assert!(path.ends_with("BENCH_e2e.json"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(json::parse(&text).is_ok());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }
}
