//! Minimal JSON support: a writer for experiment reports and a parser for
//! the AOT `manifest.json` (serde is unavailable offline).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization; `.to_string()` comes via the blanket
/// `ToString` impl (an inherent `to_string` would shadow it — clippy's
/// `inherent_to_string` lint).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Parse a JSON document. Supports the full grammar minus `\uXXXX` surrogate
/// pairs (not needed for our manifests).
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or("bad \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let j = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Str("hi".into())),
            ("c", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let s = j.to_string();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"params": [{"name": "stem.w", "shape": [3,3,3,16], "offset": 0}], "train_batch": 64}"#;
        let j = parse(s).unwrap();
        assert_eq!(j.get("train_batch").unwrap().as_usize(), Some(64));
        let p = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("stem.w"));
        assert_eq!(
            p.get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect::<Vec<_>>(),
            vec![3, 3, 3, 16]
        );
    }

    #[test]
    fn parse_nested_and_ws() {
        let s = "  { \"x\" : [ 1 , 2.5 , -3e2 ] }  ";
        let j = parse(s).unwrap();
        let a = j.get("x").unwrap().as_arr().unwrap();
        assert_eq!(a[2].as_f64(), Some(-300.0));
    }

    #[test]
    fn parse_escapes() {
        let j = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn reject_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn string_escaping_on_write() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }
}
