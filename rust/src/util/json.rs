//! Minimal JSON support: a writer for experiment reports and a parser for
//! the AOT `manifest.json` (serde is unavailable offline).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact serialization; `.to_string()` comes via the blanket
/// `ToString` impl (an inherent `to_string` would shadow it — clippy's
/// `inherent_to_string` lint).
impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

/// Parse a JSON document. Supports the full grammar, including `\uXXXX`
/// surrogate pairs (event logs may carry non-BMP characters); unpaired
/// surrogates are rejected with a clear error rather than silently
/// replaced.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => {
                            s.push('"');
                            self.pos += 1;
                        }
                        Some(b'\\') => {
                            s.push('\\');
                            self.pos += 1;
                        }
                        Some(b'/') => {
                            s.push('/');
                            self.pos += 1;
                        }
                        Some(b'n') => {
                            s.push('\n');
                            self.pos += 1;
                        }
                        Some(b't') => {
                            s.push('\t');
                            self.pos += 1;
                        }
                        Some(b'r') => {
                            s.push('\r');
                            self.pos += 1;
                        }
                        Some(b'b') => {
                            s.push('\u{8}');
                            self.pos += 1;
                        }
                        Some(b'f') => {
                            s.push('\u{c}');
                            self.pos += 1;
                        }
                        Some(b'u') => {
                            self.pos += 1;
                            s.push(self.unicode_escape()?);
                        }
                        _ => return Err("bad escape".into()),
                    }
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().ok_or("unterminated string")?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    /// Four hex digits of a `\uXXXX` escape; `pos` sits on the first
    /// digit (the `u` is already consumed) and ends one past the last.
    fn hex4(&mut self) -> Result<u32, String> {
        let hex = self
            .bytes
            .get(self.pos..self.pos + 4)
            .and_then(|b| std::str::from_utf8(b).ok())
            .ok_or_else(|| format!("truncated \\u escape at byte {}", self.pos))?;
        if !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
            return Err(format!("bad \\u escape '{hex}' at byte {}", self.pos));
        }
        let v = u32::from_str_radix(hex, 16)
            .map_err(|_| format!("bad \\u escape '{hex}' at byte {}", self.pos))?;
        self.pos += 4;
        Ok(v)
    }

    /// Decode a `\uXXXX` escape (the `\u` is already consumed),
    /// including UTF-16 surrogate pairs for non-BMP characters. Unpaired
    /// surrogates are an error: a lone `\uD800`–`\uDFFF` cannot encode a
    /// scalar value, and replacing it with U+FFFD would silently corrupt
    /// event logs on a round-trip.
    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        if (0xDC00..=0xDFFF).contains(&hi) {
            return Err(format!(
                "unpaired low surrogate \\u{hi:04x} at byte {}",
                self.pos
            ));
        }
        if !(0xD800..=0xDBFF).contains(&hi) {
            // Plain BMP scalar: every non-surrogate u16 is a valid char.
            return char::from_u32(hi)
                .ok_or_else(|| format!("invalid \\u{hi:04x} at byte {}", self.pos));
        }
        // High surrogate: a low surrogate escape must follow immediately.
        if self.peek() != Some(b'\\') {
            return Err(format!(
                "unpaired high surrogate \\u{hi:04x} at byte {} (expected \\uDC00..\\uDFFF next)",
                self.pos
            ));
        }
        self.pos += 1;
        if self.peek() != Some(b'u') {
            return Err(format!(
                "unpaired high surrogate \\u{hi:04x} at byte {} (expected \\uDC00..\\uDFFF next)",
                self.pos
            ));
        }
        self.pos += 1;
        let lo = self.hex4()?;
        if !(0xDC00..=0xDFFF).contains(&lo) {
            return Err(format!(
                "invalid low surrogate \\u{lo:04x} after \\u{hi:04x}"
            ));
        }
        let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
        char::from_u32(cp).ok_or_else(|| format!("invalid surrogate pair \\u{hi:04x}\\u{lo:04x}"))
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let j = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Str("hi".into())),
            ("c", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let s = j.to_string();
        assert_eq!(parse(&s).unwrap(), j);
    }

    #[test]
    fn parse_manifest_like() {
        let s = r#"{"params": [{"name": "stem.w", "shape": [3,3,3,16], "offset": 0}], "train_batch": 64}"#;
        let j = parse(s).unwrap();
        assert_eq!(j.get("train_batch").unwrap().as_usize(), Some(64));
        let p = &j.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("name").unwrap().as_str(), Some("stem.w"));
        assert_eq!(
            p.get("shape")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_usize().unwrap())
                .collect::<Vec<_>>(),
            vec![3, 3, 3, 16]
        );
    }

    #[test]
    fn parse_nested_and_ws() {
        let s = "  { \"x\" : [ 1 , 2.5 , -3e2 ] }  ";
        let j = parse(s).unwrap();
        let a = j.get("x").unwrap().as_arr().unwrap();
        assert_eq!(a[2].as_f64(), Some(-300.0));
    }

    #[test]
    fn parse_escapes() {
        let j = parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn reject_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{} x").is_err());
    }

    #[test]
    fn string_escaping_on_write() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn bmp_unicode_escapes_decode() {
        assert_eq!(parse(r#""\u0041""#).unwrap(), Json::Str("A".into()));
        assert_eq!(parse(r#""\u00e9""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""\u2713""#).unwrap(), Json::Str("✓".into()));
    }

    #[test]
    fn surrogate_pairs_decode_to_non_bmp_chars() {
        // U+1F600 GRINNING FACE = 😀
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap(), Json::Str("😀".into()));
        // mixed-case hex, embedded in surrounding text
        assert_eq!(
            parse(r#""ok \uD83D\uDE80 go""#).unwrap(),
            Json::Str("ok 🚀 go".into())
        );
        // U+10000, the lowest non-BMP scalar
        assert_eq!(
            parse(r#""\ud800\udc00""#).unwrap(),
            Json::Str("\u{10000}".into())
        );
        // U+10FFFF, the highest
        assert_eq!(
            parse(r#""\udbff\udfff""#).unwrap(),
            Json::Str("\u{10FFFF}".into())
        );
    }

    #[test]
    fn unpaired_surrogates_are_rejected_loudly() {
        // lone high surrogate at end of string
        let e = parse(r#""\ud800""#).unwrap_err();
        assert!(e.contains("unpaired high surrogate"), "{e}");
        // high surrogate followed by ordinary text
        let e = parse(r#""\ud83dx""#).unwrap_err();
        assert!(e.contains("unpaired high surrogate"), "{e}");
        // high surrogate followed by a non-\u escape
        let e = parse(r#""\ud83d\n""#).unwrap_err();
        assert!(e.contains("unpaired high surrogate"), "{e}");
        // lone low surrogate
        let e = parse(r#""\ude00""#).unwrap_err();
        assert!(e.contains("unpaired low surrogate"), "{e}");
        // high surrogate followed by another high surrogate
        let e = parse(r#""\ud83d\ud83d""#).unwrap_err();
        assert!(e.contains("invalid low surrogate"), "{e}");
        // truncated and malformed hex still fail
        assert!(parse(r#""\u12""#).is_err());
        assert!(parse(r#""\uzzzz""#).is_err());
    }

    #[test]
    fn non_bmp_strings_round_trip_through_writer_and_parser() {
        // The writer emits non-BMP characters as raw UTF-8; the parser
        // accepts both that and the escaped surrogate-pair spelling.
        let j = Json::Str("emoji 😀🚀 done".into());
        let text = j.to_string();
        assert_eq!(parse(&text).unwrap(), j);
        let escaped = r#""emoji \ud83d\ude00\ud83d\ude80 done""#;
        assert_eq!(parse(escaped).unwrap(), j);
    }
}
