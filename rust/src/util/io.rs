//! Crash-safe persistence: the single sanctioned write path for every
//! versioned artifact (DESIGN.md §15).
//!
//! [`atomic_write`] is temp + fsync + rename: readers of the target
//! path see either the old document or the new one, never a torn
//! prefix, even if the process dies mid-write. Every artifact saver
//! (tune cache, pareto registry, replay/remote traces, calibration,
//! device specs, bench reports, `prune --out`) routes through here —
//! cprune-lint's CPL007 flags any direct `std::fs::write`/
//! `File::create` in library code outside this module.
//!
//! Both entry points consult the per-thread fault hook
//! ([`crate::util::fault`]) at a named *site* before touching the
//! filesystem, which is how `--faults torn@cache` and the torn-write
//! fuzz tests exercise the recovery path deterministically.

use crate::util::fault::{self, WriteFault};
use std::io::Write;
use std::path::Path;

/// Atomically replace the document at `path` with `text`.
///
/// Discipline (DESIGN.md §15): write to a pid-unique sibling temp file,
/// fsync it, then rename over `path` (and best-effort fsync the parent
/// directory so the rename itself is durable). `site` names the
/// artifact for fault injection — an injected [`WriteFault::Torn`]
/// corrupts only the temp file, so the target keeps old-or-new
/// semantics even under injected tears.
pub fn atomic_write(path: impl AsRef<Path>, text: &str, site: &str) -> Result<(), String> {
    let path = path.as_ref();
    let fail = |e: std::io::Error, what: &str| format!("{}: {what}: {e}", path.display());
    let injected = fault::write_fault(site);
    if injected == Some(WriteFault::FailBefore) {
        return Err(format!("{}: injected write failure at site '{site}'", path.display()));
    }
    // Pid-unique sibling: concurrent writers never share a temp file,
    // and the rename below stays on one filesystem.
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(format!(".{}.tmp", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp);
    let mut file = std::fs::File::create(&tmp).map_err(|e| fail(e, "cannot create temp file"))?;
    let bytes = text.as_bytes();
    if let Some(WriteFault::Torn { keep }) = injected {
        // Simulated mid-write crash: a strict prefix lands in the temp
        // file and the write fails — the target document is untouched.
        let keep = keep.min(bytes.len().saturating_sub(1));
        let _ = file.write_all(&bytes[..keep]);
        let _ = file.sync_all();
        return Err(format!("{}: injected torn write at site '{site}'", path.display()));
    }
    file.write_all(bytes).map_err(|e| fail(e, "cannot write temp file"))?;
    // fsync BEFORE rename: once the new name is visible, its bytes are.
    file.sync_all().map_err(|e| fail(e, "cannot fsync temp file"))?;
    drop(file);
    std::fs::rename(&tmp, path).map_err(|e| fail(e, "cannot rename temp file into place"))?;
    sync_parent_dir(path);
    Ok(())
}

/// Best-effort fsync of `path`'s parent directory, making the rename
/// itself durable on filesystems that need it. Errors are ignored: some
/// platforms/filesystems refuse to fsync directories, and the rename's
/// atomicity does not depend on it.
fn sync_parent_dir(path: &Path) {
    #[cfg(unix)]
    {
        let dir = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    #[cfg(not(unix))]
    let _ = path;
}

/// Open a streaming sink at `path` (truncating any previous document) —
/// for append-as-you-go outputs like the event JSONL, which cannot be
/// written atomically as one document. Consults the fault hook at
/// `site` like [`atomic_write`] does.
pub fn create_sink(path: impl AsRef<Path>, site: &str) -> Result<std::fs::File, String> {
    let path = path.as_ref();
    if fault::write_fault(site) == Some(WriteFault::FailBefore) {
        return Err(format!("{}: injected write failure at site '{site}'", path.display()));
    }
    std::fs::File::create(path).map_err(|e| format!("{}: cannot create: {e}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fault::{FaultHook, WriteFault};

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cprune-io-{}-{name}", std::process::id()))
    }

    #[test]
    fn atomic_write_replaces_the_document() {
        let path = tmp_path("replace.json");
        atomic_write(&path, "old\n", "cache").unwrap();
        atomic_write(&path, "new\n", "cache").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "new\n");
        let _ = std::fs::remove_file(&path);
    }

    /// Hook that tears the k-th write to a single site at byte `keep`.
    struct TearAt {
        site: &'static str,
        keep: usize,
    }

    impl FaultHook for TearAt {
        fn write_fault(&mut self, site: &str) -> Option<WriteFault> {
            (site == self.site).then_some(WriteFault::Torn { keep: self.keep })
        }
    }

    #[test]
    fn torn_write_leaves_old_document_at_every_tear_length() {
        let path = tmp_path("torn.json");
        let old = "{\"doc\":\"old\"}\n";
        let new = "{\"doc\":\"new-and-longer\"}\n";
        for keep in 0..new.len() {
            atomic_write(&path, old, "cache").unwrap();
            let _guard = crate::util::fault::install(Box::new(TearAt { site: "cache", keep }));
            let err = atomic_write(&path, new, "cache").unwrap_err();
            assert!(err.contains("torn"), "unexpected error: {err}");
            assert_eq!(
                std::fs::read_to_string(&path).unwrap(),
                old,
                "target must keep the old document after a tear at byte {keep}"
            );
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn injected_failure_prevents_any_write() {
        let path = tmp_path("fail.json");
        let _ = std::fs::remove_file(&path);
        let _guard = crate::util::fault::install(Box::new(
            crate::util::fault::FaultPlan::parse("fail@report:1,fail@report:2").unwrap(),
        ));
        assert!(atomic_write(&path, "doc\n", "report").is_err());
        assert!(!path.exists(), "nothing may land when the write fails before bytes");
        assert!(create_sink(&path, "report").is_err());
        assert!(!path.exists(), "a failed sink may not create the file either");
    }
}
