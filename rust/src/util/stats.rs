//! Summary statistics used by the experiment harnesses and benches.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (linear interpolation). `p` is clamped to [0, 100]
/// (out-of-range ranks would index past the sample vector); NaN samples
/// sort last via `total_cmp` instead of panicking the harness.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let p = p.clamp(0.0, 100.0);
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation coefficient; NaN-free (returns 0.0 on degenerate input).
///
/// Fig. 1's claim is "no strong correlation between pruned-model FPS before
/// and after compiler optimization" — this is the statistic backing it.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx == 0.0 || dy == 0.0 {
        return 0.0;
    }
    num / (dx.sqrt() * dy.sqrt())
}

/// Spearman rank correlation (rank-transform then Pearson).
pub fn spearman(xs: &[f64], ys: &[f64]) -> f64 {
    pearson(&ranks(xs), &ranks(ys))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut out = vec![0.0; xs.len()];
    // average ranks for ties
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for &k in &idx[i..=j] {
            out[k] = avg;
        }
        i = j + 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn percentile_clamps_out_of_range_p() {
        // p > 100 used to compute a rank past len-1 and index out of
        // bounds; p < 0 silently extrapolated below the minimum.
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, 150.0), 3.0);
        assert_eq!(percentile(&xs, 100.0 + 1e-9), 3.0);
        assert_eq!(percentile(&xs, -20.0), 1.0);
        assert_eq!(percentile(&[], 150.0), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // partial_cmp(..).unwrap() used to panic the whole experiment
        // harness on a single NaN sample; total_cmp sorts NaN last.
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
        assert!(percentile(&xs, 100.0).is_nan());
        // the rank transform behind spearman must not panic either
        let r = ranks(&[1.0, f64::NAN, 3.0]);
        assert_eq!(r.len(), 3);
        let _ = spearman(&[1.0, f64::NAN, 3.0], &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yneg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&xs, &yneg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate() {
        assert_eq!(pearson(&[1.0, 1.0], &[2.0, 3.0]), 0.0);
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
    }

    #[test]
    fn spearman_monotone() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [1.0, 10.0, 100.0, 1000.0]; // nonlinear but monotone
        assert!((spearman(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ranks_with_ties() {
        assert_eq!(ranks(&[10.0, 20.0, 20.0, 30.0]), vec![0.0, 1.5, 1.5, 3.0]);
    }
}
