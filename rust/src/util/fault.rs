//! Deterministic fault injection for the crash-safety plane
//! (DESIGN.md §15).
//!
//! Every recovery path in the project — atomic artifact writes
//! ([`crate::util::io::atomic_write`]), run-journal barriers
//! ([`crate::run::journal::RunJournal`]), and remote-worker
//! death/timeout handling — is exercised through one seam: a per-thread
//! [`FaultHook`] consulted at named *sites*. Production runs install no
//! hook and pay one thread-local read per site; tests and the
//! `--faults SPEC` CLI flag install a [`FaultPlan`], a deterministic,
//! seeded schedule of failures, so every "what if the process dies
//! here?" question is answered by a test or CI job instead of an
//! argument.
//!
//! Site vocabulary (DESIGN.md §15): write sites are the artifact being
//! persisted (`cache`, `registry`, `trace`, `remote-trace`,
//! `calibration`, `devices`, `report`, `out`, `events`, `journal`);
//! barrier sites are `baseline`, `iter:N` and `finish` (the journal's
//! fsync points); `worker` names the loopback measurement workers.

use crate::util::rng::Rng;
use std::cell::RefCell;
use std::collections::HashMap;

/// Exit code of a [`at_barrier`] abort — distinguishable from ordinary
/// error exits (1) so the `crash-resume` CI job can assert the process
/// died *at the injected barrier* and not of an unrelated failure.
pub const ABORT_EXIT_CODE: i32 = 86;

/// What happens to one artifact write at a named site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteFault {
    /// The write fails before any byte reaches the filesystem.
    FailBefore,
    /// The write tears: at most `keep` bytes of the payload land — in
    /// the temp file for [`crate::util::io::atomic_write`] (the target
    /// document is untouched), at the tail for journal appends — and
    /// the write reports failure.
    Torn { keep: usize },
}

/// Fault injected into a loopback measurement worker (death/timeout
/// tests); counts requests served *after* the handshake.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WorkerFault {
    /// Serve faithfully forever.
    #[default]
    None,
    /// Serve `n` requests, then drop the connection (client sees EOF).
    DieAfter(usize),
    /// Serve `n` requests, then swallow requests without replying
    /// (client sees a deadline timeout).
    HangAfter(usize),
}

/// Decides, per named site, whether an operation fails. Installed
/// per-thread via [`install`] so parallel tests cannot interfere.
pub trait FaultHook {
    /// Consulted once per artifact write to `site`; `None` = write
    /// normally.
    fn write_fault(&mut self, site: &str) -> Option<WriteFault> {
        let _ = site;
        None
    }

    /// Consulted at a journal barrier; `true` aborts the process with
    /// [`ABORT_EXIT_CODE`] (a simulated crash whose recovery `--resume`
    /// must handle).
    fn abort_at(&mut self, site: &str) -> bool {
        let _ = site;
        false
    }

    /// Fault to inject into loopback measurement workers spawned from
    /// this thread.
    fn worker_fault(&self) -> WorkerFault {
        WorkerFault::None
    }
}

/// One `fail@`/`torn@` clause: fires on the `nth` write to `site`.
#[derive(Clone, Debug)]
struct WriteClause {
    site: String,
    nth: usize,
    torn: bool,
    fired: bool,
}

/// A deterministic, seeded schedule of injected failures — what
/// `--faults SPEC` parses into.
///
/// Grammar (comma-separated clauses):
///
/// * `seed:S` — seed for the torn-write length draws (default 0);
/// * `abort@SITE` — abort the process at journal barrier `SITE`
///   (`baseline`, `iter:N`, `finish`);
/// * `fail@SITE[:K]` — the `K`-th write to `SITE` fails before any byte
///   lands (`K` is 1-based, default 1);
/// * `torn@SITE[:K]` — the `K`-th write to `SITE` tears mid-payload;
/// * `die@worker:N` — loopback workers die after serving `N` requests;
/// * `hang@worker:N` — loopback workers hang after serving `N`.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    writes: Vec<WriteClause>,
    aborts: Vec<String>,
    worker: WorkerFault,
    counts: HashMap<String, usize>,
    rng: Rng,
}

impl FaultPlan {
    /// Parse a `--faults` spec (see the type-level grammar).
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut writes = Vec::new();
        let mut aborts = Vec::new();
        let mut worker = WorkerFault::None;
        let mut seed = 0u64;
        for clause in spec.split(',').map(str::trim).filter(|c| !c.is_empty()) {
            if let Some(n) = clause.strip_prefix("seed:") {
                seed = n.parse().map_err(|_| format!("bad fault seed in '{clause}'"))?;
            } else if let Some(site) = clause.strip_prefix("abort@") {
                if site.is_empty() {
                    return Err(format!("empty barrier site in '{clause}'"));
                }
                aborts.push(site.to_string());
            } else if let Some(n) = clause.strip_prefix("die@worker:") {
                let n = n.parse().map_err(|_| format!("bad worker count in '{clause}'"))?;
                worker = WorkerFault::DieAfter(n);
            } else if let Some(n) = clause.strip_prefix("hang@worker:") {
                let n = n.parse().map_err(|_| format!("bad worker count in '{clause}'"))?;
                worker = WorkerFault::HangAfter(n);
            } else if clause.starts_with("fail@") || clause.starts_with("torn@") {
                let torn = clause.starts_with("torn@");
                let rest = &clause[5..];
                let (site, nth) = match rest.rsplit_once(':') {
                    Some((s, k)) => match k.parse::<usize>() {
                        Ok(n) if n >= 1 => (s, n),
                        _ => return Err(format!("bad write ordinal in '{clause}'")),
                    },
                    None => (rest, 1),
                };
                if site.is_empty() {
                    return Err(format!("empty write site in '{clause}'"));
                }
                writes.push(WriteClause { site: site.to_string(), nth, torn, fired: false });
            } else {
                return Err(format!(
                    "unknown fault clause '{clause}' (want seed:S, abort@SITE, \
                     fail@SITE[:K], torn@SITE[:K], die@worker:N or hang@worker:N)"
                ));
            }
        }
        Ok(FaultPlan { writes, aborts, worker, counts: HashMap::new(), rng: Rng::new(seed) })
    }
}

impl FaultHook for FaultPlan {
    fn write_fault(&mut self, site: &str) -> Option<WriteFault> {
        let n = self.counts.entry(site.to_string()).or_insert(0);
        *n += 1;
        let count = *n;
        for c in self.writes.iter_mut() {
            if !c.fired && c.site == site && c.nth == count {
                c.fired = true;
                return Some(if c.torn {
                    // Seeded draw: the tear length is reproducible for a
                    // fixed `seed:S`, never wall-clock or address noise.
                    WriteFault::Torn { keep: self.rng.below(4096) }
                } else {
                    WriteFault::FailBefore
                });
            }
        }
        None
    }

    fn abort_at(&mut self, site: &str) -> bool {
        self.aborts.iter().any(|s| s == site)
    }

    fn worker_fault(&self) -> WorkerFault {
        self.worker
    }
}

thread_local! {
    /// The current thread's hook. Thread-local (not global) so parallel
    /// `cargo test` threads cannot inject faults into each other.
    static HOOK: RefCell<Option<Box<dyn FaultHook>>> = RefCell::new(None);
}

/// RAII guard returned by [`install`]: removes the thread's hook on
/// drop, so a panicking test cannot leak its faults into the next test
/// scheduled on the same thread.
pub struct HookGuard {
    _private: (),
}

impl Drop for HookGuard {
    fn drop(&mut self) {
        clear();
    }
}

/// Install `hook` for the current thread (replacing any previous one);
/// hold the returned guard for the hook's intended lifetime.
pub fn install(hook: Box<dyn FaultHook>) -> HookGuard {
    HOOK.with(|h| *h.borrow_mut() = Some(hook));
    HookGuard { _private: () }
}

/// Remove the current thread's hook (also done by [`HookGuard`]).
pub fn clear() {
    HOOK.with(|h| *h.borrow_mut() = None);
}

/// Consult the thread's hook about a write to `site` (`None` without an
/// installed hook — the production path).
pub fn write_fault(site: &str) -> Option<WriteFault> {
    HOOK.with(|h| h.borrow_mut().as_mut().and_then(|hook| hook.write_fault(site)))
}

/// Journal barrier: when the installed plan schedules an abort here the
/// process exits with [`ABORT_EXIT_CODE`] — the journal record for this
/// barrier is already fsync'd, so this simulates the worst-timed crash
/// `cprune run --resume` has to recover from.
pub fn at_barrier(site: &str) {
    let fire = HOOK
        .with(|h| h.borrow_mut().as_mut().map(|hook| hook.abort_at(site)).unwrap_or(false));
    if fire {
        eprintln!("[faults] aborting at barrier '{site}'");
        std::process::exit(ABORT_EXIT_CODE);
    }
}

/// Worker fault for loopback connections spawned from this thread.
pub fn worker_fault() -> WorkerFault {
    HOOK.with(|h| h.borrow().as_ref().map(|hook| hook.worker_fault()).unwrap_or_default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_documented_grammar() {
        let mut plan =
            FaultPlan::parse("seed:3, abort@iter:2, fail@cache, torn@registry:2, die@worker:1")
                .unwrap();
        assert!(plan.abort_at("iter:2"));
        assert!(!plan.abort_at("iter:1"));
        assert_eq!(plan.worker_fault(), WorkerFault::DieAfter(1));
        // fail@cache fires on the first cache write only
        assert_eq!(plan.write_fault("cache"), Some(WriteFault::FailBefore));
        assert_eq!(plan.write_fault("cache"), None);
        // torn@registry:2 skips the first registry write
        assert_eq!(plan.write_fault("registry"), None);
        assert!(matches!(plan.write_fault("registry"), Some(WriteFault::Torn { .. })));
        assert_eq!(plan.write_fault("registry"), None);
    }

    #[test]
    fn parse_rejects_malformed_clauses() {
        for bad in ["explode@cache", "fail@", "fail@cache:0", "seed:x", "abort@", "die@worker:x"] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted '{bad}'");
        }
        // empty and whitespace-only specs are fine (no faults)
        assert!(FaultPlan::parse("").is_ok());
        assert!(FaultPlan::parse(" , ").is_ok());
    }

    #[test]
    fn torn_lengths_are_seeded_and_reproducible() {
        let draw = |seed: u64| {
            let mut p = FaultPlan::parse(&format!("seed:{seed},torn@cache")).unwrap();
            match p.write_fault("cache") {
                Some(WriteFault::Torn { keep }) => keep,
                other => panic!("expected a torn fault, got {other:?}"),
            }
        };
        assert_eq!(draw(7), draw(7));
    }

    #[test]
    fn thread_local_hook_is_consulted_and_cleared() {
        assert_eq!(write_fault("cache"), None, "no hook installed yet");
        {
            let _guard = install(Box::new(FaultPlan::parse("fail@cache").unwrap()));
            assert_eq!(write_fault("cache"), Some(WriteFault::FailBefore));
        }
        assert_eq!(write_fault("cache"), None, "guard drop must clear the hook");
    }
}
