//! Deterministic PRNG: PCG-XSH-RR 64/32, plus distribution helpers.
//!
//! Every stochastic component in the library (tuner mutation, measurement
//! noise, synthetic weights, dataset generation) takes an explicit `Rng`
//! seeded from the experiment config, so whole experiment runs replay
//! bit-identically.

/// PCG-XSH-RR 64/32 — small, fast, statistically solid for simulation use.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    inc: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent stream for the same seed (e.g. per-task tuning).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Rng {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a child RNG (stable split — used to give each task / iteration
    /// its own stream without sharing mutable state).
    pub fn split(&mut self, tag: u64) -> Rng {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Rng::with_stream(s, tag | 1)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias negligible for simulation use.
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal (Box–Muller, one value per call).
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f32()).max(1e-12);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Log-normal multiplicative noise with given sigma (measurement jitter).
    ///
    /// Sigma and the returned factor are `f64` end-to-end so the
    /// measurement plane ([`crate::device::Target::measure_batch`]) never
    /// narrows a latency through `f32`; the underlying normal variate
    /// keeps the RNG's native `f32` resolution (and draw count). At
    /// `sigma == 0.0` the factor is *exactly* 1.0 — a noise-free
    /// measurement is bit-identical to the deterministic latency.
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (self.normal() as f64 * sigma).exp()
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// FNV-1a 64 — the repo's *stable* hasher.
///
/// `std::collections::hash_map::DefaultHasher` makes no cross-release
/// algorithm guarantee, so deriving RNG streams or deterministic "random"
/// per-shape values from it would silently break the "replays
/// bit-identically across sessions" contract (and any persisted tuning
/// cache) on a toolchain upgrade. Everything that needs a reproducible
/// hash goes through [`stable_hash`] instead.
#[derive(Clone, Debug)]
pub struct StableHasher {
    state: u64,
}

impl StableHasher {
    pub fn new() -> StableHasher {
        StableHasher { state: 0xcbf2_9ce4_8422_2325 }
    }
}

impl Default for StableHasher {
    fn default() -> Self {
        StableHasher::new()
    }
}

impl std::hash::Hasher for StableHasher {
    fn finish(&self) -> u64 {
        self.state
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    // The default integer methods feed native-endian bytes, and usize
    // feeds 4 or 8 of them depending on the target — both would make the
    // "stable" hash platform-dependent. Pin little-endian, and widen
    // usize/isize to 8 bytes. (The signed defaults forward to these.)
    fn write_u16(&mut self, i: u16) {
        self.write(&i.to_le_bytes());
    }

    fn write_u32(&mut self, i: u32) {
        self.write(&i.to_le_bytes());
    }

    fn write_u64(&mut self, i: u64) {
        self.write(&i.to_le_bytes());
    }

    fn write_u128(&mut self, i: u128) {
        self.write(&i.to_le_bytes());
    }

    fn write_usize(&mut self, i: usize) {
        self.write(&(i as u64).to_le_bytes());
    }
}

/// Stable 64-bit hash of any `Hash` value (see [`StableHasher`]).
pub fn stable_hash<T: std::hash::Hash + ?Sized>(value: &T) -> u64 {
    let mut h = StableHasher::new();
    value.hash(&mut h);
    std::hash::Hasher::finish(&h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn lognormal_zero_sigma_is_exactly_one() {
        // (normal * 0.0).exp() == 1.0 bit-exactly, for every draw — the
        // foundation of the "sigma = 0 measures the deterministic
        // latency exactly" contract in device::Target.
        let mut r = Rng::new(13);
        for _ in 0..1000 {
            assert_eq!(r.lognormal(0.0), 1.0);
        }
    }

    #[test]
    fn lognormal_draws_are_f64_and_seeded() {
        let mut a = Rng::new(21);
        let mut b = Rng::new(21);
        for _ in 0..100 {
            let x = a.lognormal(0.05);
            assert_eq!(x, b.lognormal(0.05));
            assert!(x > 0.0 && x.is_finite());
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.split(1);
        let mut b = root.split(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn stable_hash_golden_value() {
        // FNV-1a 64 over the little-endian bytes of 42u64. Pins the
        // algorithm on every platform (the hasher feeds LE fixed-width
        // bytes): if this moves, every persisted cache and derived RNG
        // stream silently changes.
        assert_eq!(stable_hash(&42u64), 0xff3a_dd6b_3789_daef);
        // usize hashes with the same widened-to-u64 bytes on every target
        assert_eq!(stable_hash(&42usize), stable_hash(&42u64));
    }

    #[test]
    fn stable_hash_discriminates() {
        assert_ne!(stable_hash(&(1u64, 2u64)), stable_hash(&(2u64, 1u64)));
        assert_ne!(stable_hash("bn"), stable_hash("relu"));
        assert_eq!(stable_hash(&[1usize, 2, 3]), stable_hash(&[1usize, 2, 3]));
    }
}
