//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! The `rust/benches/*` targets use `harness = false` and call into this:
//! warmup + timed iterations, median/mean/stddev reporting, and a
//! machine-grepable `BENCH <name> <median_ns>` line per benchmark.

use std::time::Instant;

/// Result of a timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "BENCH {:<48} median {:>12.0} ns  mean {:>12.0} ns  sd {:>10.0} ns  ({} iters)",
            self.name, self.median_ns, self.mean_ns, self.stddev_ns, self.iters
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, &samples)
}

/// Auto-calibrating variant: picks an iteration count so total time ≈ `budget_ms`.
pub fn bench_auto<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().as_nanos().max(1) as u64;
    let iters = ((budget_ms * 1_000_000) / one).clamp(3, 10_000) as usize;
    bench(name, 1, iters, f)
}

fn summarize(name: &str, samples: &[f64]) -> BenchResult {
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let mean = super::stats::mean(samples);
    let n = sorted.len();
    // True median: even-length sample sets average the two middle
    // elements (taking sorted[n/2] alone biased the BENCH line upward).
    let median = if n % 2 == 0 {
        (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
    } else {
        sorted[n / 2]
    };
    BenchResult {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        median_ns: median,
        stddev_ns: super::stats::stddev(samples),
        min_ns: sorted[0],
        max_ns: *sorted.last().unwrap(), // cprune-lint: allow(CPL005, reason="samples is non-empty by construction")
    }
}

/// Print a markdown-style table (used by the fig/table regenerators).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:<w$} |", c, w = widths.get(i).copied().unwrap_or(8)));
        }
        println!("{s}");
    };
    line(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    line(&sep);
    for row in rows {
        line(row);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", 2, 50, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 50);
        assert!(r.median_ns >= 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
    }

    #[test]
    fn median_averages_middle_pair_for_even_lengths() {
        // Regression: the BENCH line used to report the upper-middle
        // element (3.0 here) as the median of an even-length set.
        let even = summarize("even", &[4.0, 1.0, 3.0, 2.0]);
        assert_eq!(even.median_ns, 2.5);
        let odd = summarize("odd", &[3.0, 1.0, 2.0]);
        assert_eq!(odd.median_ns, 2.0);
        assert_eq!(even.min_ns, 1.0);
        assert_eq!(even.max_ns, 4.0);
    }

    #[test]
    fn nan_samples_do_not_panic_the_summary() {
        let r = summarize("nan", &[1.0, f64::NAN, 2.0]);
        assert_eq!(r.min_ns, 1.0);
        assert!(r.max_ns.is_nan(), "NaN sorts last under total_cmp");
    }

    #[test]
    fn bench_auto_runs() {
        let r = bench_auto("auto", 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.iters >= 3);
    }
}
