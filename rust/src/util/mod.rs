//! Small self-contained utilities (the environment is offline, so the usual
//! crates — `rand`, `serde_json`, `criterion` — are replaced by these).
//!
//! The crash-safety plane lives here too (DESIGN.md §15): [`io`] holds
//! the sanctioned temp+fsync+rename artifact write path, and [`fault`]
//! the deterministic fault-injection seam that exercises it.

pub mod bench;
pub mod fault;
pub mod io;
pub mod json;
pub mod rng;
pub mod stats;

/// Least common multiple (used by the §3.5 pruning-step rule).
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    a / gcd(a, b) * b
}

/// Greatest common divisor (Euclid).
pub fn gcd(a: u64, b: u64) -> u64 {
    let (mut a, mut b) = (a, b);
    while b != 0 {
        let t = b;
        b = a % b;
        a = t;
    }
    a
}

/// All divisors of `n`, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut i = 1;
    while i * i <= n {
        if n % i == 0 {
            out.push(i);
            if i != n / i {
                out.push(n / i);
            }
        }
        i += 1;
    }
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gcd_lcm_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(32, 32), 32);
        assert_eq!(lcm(4, 1), 4); // paper §3.5 slow-program example
        assert_eq!(lcm(0, 5), 0);
    }

    #[test]
    fn divisors_of_12() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(16), vec![1, 2, 4, 8, 16]);
    }
}
