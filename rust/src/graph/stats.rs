//! FLOPs and parameter accounting (Table 1/2 report both).
//!
//! FLOPs counts multiply-adds as 2 ops (the convention the paper's numbers
//! follow: ResNet-18 = 1.81 GFLOPs at 224², MobileNetV2 = 301 MFLOPs…
//! with the paper actually reporting MACs for the mobile nets; we expose
//! both so the tables can print either).

use super::ops::{Graph, OpKind};
use super::shape_infer;

/// (total_flops, total_params) for the whole graph at its builder batch size.
pub fn flops_params(g: &Graph) -> (u64, u64) {
    let shapes = shape_infer::infer(g).expect("graph must shape-infer"); // cprune-lint: allow(CPL005, reason="callers pass validated graphs")
    let mut flops = 0u64;
    let mut params = 0u64;
    for node in &g.nodes {
        let (f, p) = node_cost(g, node.id, &shapes);
        flops += f;
        params += p;
    }
    (flops, params)
}

/// MACs (= flops / 2 for the matmul-like ops) — the mobile-papers convention.
pub fn macs(g: &Graph) -> u64 {
    flops_params(g).0 / 2
}

/// (flops, params) of a single node given precomputed shapes.
pub fn node_cost(g: &Graph, id: usize, shapes: &[shape_infer::Shape]) -> (u64, u64) {
    let node = g.node(id);
    match &node.op {
        OpKind::Conv2d { kh, kw, cin, cout, groups, .. } => {
            let [n, oh, ow, _] = shapes[id];
            let cin_g = cin / groups;
            let macs = (n * oh * ow * cout) as u64 * (kh * kw * cin_g) as u64;
            let params = (kh * kw * cin_g * cout) as u64 + *cout as u64; // + bn fold
            (2 * macs, params)
        }
        OpKind::Dense { cin, cout } => {
            let n = shapes[id][0] as u64;
            let macs = n * (*cin as u64) * (*cout as u64);
            (2 * macs, (*cin as u64) * (*cout as u64) + *cout as u64)
        }
        OpKind::BatchNorm { channels } => {
            let s = shapes[id];
            ((s.iter().product::<usize>()) as u64 * 2, (*channels as u64) * 2)
        }
        OpKind::ReLU | OpKind::ReLU6 | OpKind::Add | OpKind::Softmax => {
            ((shapes[id].iter().product::<usize>()) as u64, 0)
        }
        OpKind::MaxPool { k, .. } => {
            let out: u64 = shapes[id].iter().product::<usize>() as u64;
            (out * (k * k) as u64, 0)
        }
        OpKind::GlobalAvgPool => {
            let inp: u64 = shapes[node.inputs[0]].iter().product::<usize>() as u64;
            (inp, 0)
        }
        OpKind::Input { .. } | OpKind::Flatten => (0, 0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::Graph;

    #[test]
    fn conv_flops_formula() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        g.add(
            "c",
            OpKind::Conv2d { kh: 3, kw: 3, cin: 4, cout: 8, stride: 1, padding: 1, groups: 1 },
            vec![x],
        );
        let (flops, params) = flops_params(&g);
        // 2 * (1*8*8*8) * (3*3*4) = 36864 flops; 3*3*4*8 + 8 = 296 params
        assert_eq!(flops, 36_864);
        assert_eq!(params, 296);
    }

    #[test]
    fn depthwise_cost_is_divided_by_groups() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 8] }, vec![]);
        g.add(
            "dw",
            OpKind::Conv2d { kh: 3, kw: 3, cin: 8, cout: 8, stride: 1, padding: 1, groups: 8 },
            vec![x],
        );
        let (flops, params) = flops_params(&g);
        assert_eq!(flops, 2 * (8 * 8 * 8) as u64 * 9);
        assert_eq!(params, (9 * 8 + 8) as u64);
    }

    #[test]
    fn macs_is_half_of_matmul_flops() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 4, 4, 4] }, vec![]);
        g.add(
            "c",
            OpKind::Conv2d { kh: 1, kw: 1, cin: 4, cout: 4, stride: 1, padding: 0, groups: 1 },
            vec![x],
        );
        assert_eq!(macs(&g), flops_params(&g).0 / 2);
    }
}
