//! FLOPs and parameter accounting (Table 1/2 report both).
//!
//! FLOPs counts multiply-adds as 2 ops (the convention the paper's numbers
//! follow: ResNet-18 = 1.81 GFLOPs at 224², MobileNetV2 = 301 MFLOPs…
//! with the paper actually reporting MACs for the mobile nets; we expose
//! both so the tables can print either).

use super::ops::{Graph, NodeId, OpKind};
use super::shape_infer;
use std::collections::BTreeMap;

/// (total_flops, total_params) for the whole graph at its builder batch size.
pub fn flops_params(g: &Graph) -> (u64, u64) {
    let shapes = shape_infer::infer(g).expect("graph must shape-infer"); // cprune-lint: allow(CPL005, reason="callers pass validated graphs")
    let mut flops = 0u64;
    let mut params = 0u64;
    for node in &g.nodes {
        let (f, p) = node_cost(g, node.id, &shapes);
        flops += f;
        params += p;
    }
    (flops, params)
}

/// MACs (= flops / 2 for the matmul-like ops) — the mobile-papers convention.
pub fn macs(g: &Graph) -> u64 {
    flops_params(g).0 / 2
}

/// (total_flops, total_params) under a per-conv weight-density map — the
/// sparsity-aware variant (DESIGN.md §16). A conv with density `d` in
/// `densities` keeps `round(macs × d)` of its dense multiply-adds and the
/// same fraction of its weight parameters; its per-channel (bias/BN-fold)
/// parameters stay dense, as do all nodes absent from the map. With an
/// empty map this is exactly [`flops_params`] — pinned by test.
pub fn effective_flops_params(g: &Graph, densities: &BTreeMap<NodeId, f64>) -> (u64, u64) {
    let shapes = shape_infer::infer(g).expect("graph must shape-infer"); // cprune-lint: allow(CPL005, reason="callers pass validated graphs")
    let mut flops = 0u64;
    let mut params = 0u64;
    for node in &g.nodes {
        let (f, p) = node_cost(g, node.id, &shapes);
        match (&node.op, densities.get(&node.id)) {
            (OpKind::Conv2d { cout, .. }, Some(&d)) => {
                let dense_bias = *cout as u64;
                let weight_params = p - dense_bias;
                flops += scale(f, d);
                params += scale(weight_params, d) + dense_bias;
            }
            _ => {
                flops += f;
                params += p;
            }
        }
    }
    (flops, params)
}

/// `round(count × density)` in u64 space.
fn scale(count: u64, density: f64) -> u64 {
    (count as f64 * density).round() as u64
}

/// (flops, params) of a single node given precomputed shapes.
pub fn node_cost(g: &Graph, id: usize, shapes: &[shape_infer::Shape]) -> (u64, u64) {
    let node = g.node(id);
    match &node.op {
        OpKind::Conv2d { kh, kw, cin, cout, groups, .. } => {
            let [n, oh, ow, _] = shapes[id];
            let cin_g = cin / groups;
            let macs = (n * oh * ow * cout) as u64 * (kh * kw * cin_g) as u64;
            let params = (kh * kw * cin_g * cout) as u64 + *cout as u64; // + bn fold
            (2 * macs, params)
        }
        OpKind::Dense { cin, cout } => {
            let n = shapes[id][0] as u64;
            let macs = n * (*cin as u64) * (*cout as u64);
            (2 * macs, (*cin as u64) * (*cout as u64) + *cout as u64)
        }
        OpKind::BatchNorm { channels } => {
            let s = shapes[id];
            ((s.iter().product::<usize>()) as u64 * 2, (*channels as u64) * 2)
        }
        OpKind::ReLU | OpKind::ReLU6 | OpKind::Add | OpKind::Softmax => {
            ((shapes[id].iter().product::<usize>()) as u64, 0)
        }
        OpKind::MaxPool { k, .. } => {
            let out: u64 = shapes[id].iter().product::<usize>() as u64;
            (out * (k * k) as u64, 0)
        }
        OpKind::GlobalAvgPool => {
            let inp: u64 = shapes[node.inputs[0]].iter().product::<usize>() as u64;
            (inp, 0)
        }
        OpKind::Input { .. } | OpKind::Flatten => (0, 0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::Graph;

    #[test]
    fn conv_flops_formula() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        g.add(
            "c",
            OpKind::Conv2d { kh: 3, kw: 3, cin: 4, cout: 8, stride: 1, padding: 1, groups: 1 },
            vec![x],
        );
        let (flops, params) = flops_params(&g);
        // 2 * (1*8*8*8) * (3*3*4) = 36864 flops; 3*3*4*8 + 8 = 296 params
        assert_eq!(flops, 36_864);
        assert_eq!(params, 296);
    }

    #[test]
    fn depthwise_cost_is_divided_by_groups() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 8] }, vec![]);
        g.add(
            "dw",
            OpKind::Conv2d { kh: 3, kw: 3, cin: 8, cout: 8, stride: 1, padding: 1, groups: 8 },
            vec![x],
        );
        let (flops, params) = flops_params(&g);
        assert_eq!(flops, 2 * (8 * 8 * 8) as u64 * 9);
        assert_eq!(params, (9 * 8 + 8) as u64);
    }

    #[test]
    fn empty_density_map_reproduces_dense_accounting_exactly() {
        let g = crate::graph::model_zoo::Model::build(
            crate::graph::model_zoo::ModelKind::ResNet8Cifar,
            0,
        )
        .graph;
        assert_eq!(effective_flops_params(&g, &BTreeMap::new()), flops_params(&g));
    }

    #[test]
    fn density_scales_conv_macs_and_weights_but_not_bias() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        g.add(
            "c",
            OpKind::Conv2d { kh: 3, kw: 3, cin: 4, cout: 8, stride: 1, padding: 1, groups: 1 },
            vec![x],
        );
        let mut densities = BTreeMap::new();
        densities.insert(1usize, 0.5);
        let (flops, params) = effective_flops_params(&g, &densities);
        // dense: 36864 flops, 288 weight params + 8 bias
        assert_eq!(flops, 18_432);
        assert_eq!(params, 144 + 8);
    }

    #[test]
    fn macs_is_half_of_matmul_flops() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 4, 4, 4] }, vec![]);
        g.add(
            "c",
            OpKind::Conv2d { kh: 1, kw: 1, cin: 4, cout: 4, stride: 1, padding: 0, groups: 1 },
            vec![x],
        );
        assert_eq!(macs(&g), flops_params(&g).0 / 2);
    }
}
