//! Synthetic-but-seeded convolution weights for filter scoring.
//!
//! The paper scores filters by ℓ1 norm (§3.5, following Li et al.) and the
//! FPGM baseline scores them by distance to the geometric median. Both need
//! actual filter vectors. We have no trained ImageNet checkpoints in this
//! environment, so each conv's filters are drawn from a seeded, layer-scaled
//! He-normal distribution — preserving the *statistical* properties the
//! scoring algorithms consume (spread of norms within a layer, scale
//! differences across layers) while staying fully reproducible.
//! (Substitution documented in DESIGN.md §2.)

use super::ops::{Graph, OpKind};
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// Per-conv filter bank: `filters[f]` is the flattened HWI filter vector.
#[derive(Clone, Debug, Default)]
pub struct Weights {
    /// node id -> filters (cout vectors of kh*kw*cin_per_group floats).
    pub convs: BTreeMap<usize, Vec<Vec<f32>>>,
}

impl Weights {
    /// Generate weights for every conv in the graph.
    pub fn generate(graph: &Graph, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut convs = BTreeMap::new();
        for node in &graph.nodes {
            if let OpKind::Conv2d { kh, kw, cin, cout, groups, .. } = node.op {
                let mut layer_rng = rng.split(node.id as u64);
                let fan_in = kh * kw * (cin / groups);
                let std = (2.0 / fan_in as f32).sqrt();
                let filters = (0..cout)
                    .map(|_| (0..fan_in).map(|_| layer_rng.normal() * std).collect())
                    .collect();
                convs.insert(node.id, filters);
            }
        }
        Weights { convs }
    }

    /// ℓ1 norm of each filter of `conv` (the paper's §3.5 criterion).
    pub fn l1_norms(&self, conv: usize) -> Vec<f32> {
        self.convs[&conv]
            .iter()
            .map(|f| f.iter().map(|w| w.abs()).sum())
            .collect()
    }

    /// Distance of each filter to the layer's geometric median, approximated
    /// by one Weiszfeld step from the arithmetic mean (sufficient for
    /// ranking; exact GM iteration converges to the same order on these
    /// distributions). Used by the FPGM baseline.
    pub fn gm_distances(&self, conv: usize) -> Vec<f32> {
        let filters = &self.convs[&conv];
        let dim = filters[0].len();
        let mut mean = vec![0.0f32; dim];
        for f in filters {
            for (m, w) in mean.iter_mut().zip(f) {
                *m += w;
            }
        }
        for m in &mut mean {
            *m /= filters.len() as f32;
        }
        // one Weiszfeld update
        let mut num = vec![0.0f32; dim];
        let mut den = 0.0f32;
        for f in filters {
            let d = euclid(f, &mean).max(1e-8);
            for (n, w) in num.iter_mut().zip(f) {
                *n += w / d;
            }
            den += 1.0 / d;
        }
        let gm: Vec<f32> = num.iter().map(|n| n / den).collect();
        filters.iter().map(|f| euclid(f, &gm)).collect()
    }

    /// Drop the given filter indices from `conv` (after a pruning decision).
    pub fn remove_filters(&mut self, conv: usize, remove: &[usize]) {
        let filters = self.convs.get_mut(&conv).expect("conv has weights"); // cprune-lint: allow(CPL005, reason="conv ids come from the graph's conv set")
        let removed: std::collections::BTreeSet<usize> = remove.iter().copied().collect();
        *filters = filters
            .iter()
            .enumerate()
            .filter(|(i, _)| !removed.contains(i))
            .map(|(_, f)| f.clone())
            .collect();
    }

    /// Indices of the `k` filters with the smallest score (ties broken by
    /// index for determinism) — the "prune smallest ℓ1 first" rule.
    pub fn lowest_k(scores: &[f32], k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]).then(a.cmp(&b)));
        idx.truncate(k);
        idx.sort_unstable();
        idx
    }
}

fn euclid(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f32>()
        .sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::Graph;

    fn graph_with_conv(cout: usize) -> Graph {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        g.add(
            "c",
            OpKind::Conv2d { kh: 3, kw: 3, cin: 4, cout, stride: 1, padding: 1, groups: 1 },
            vec![x],
        );
        g
    }

    #[test]
    fn generate_is_deterministic() {
        let g = graph_with_conv(8);
        let w1 = Weights::generate(&g, 7);
        let w2 = Weights::generate(&g, 7);
        assert_eq!(w1.convs[&1], w2.convs[&1]);
        let w3 = Weights::generate(&g, 8);
        assert_ne!(w1.convs[&1], w3.convs[&1]);
    }

    #[test]
    fn l1_norms_positive_and_spread() {
        let g = graph_with_conv(16);
        let w = Weights::generate(&g, 1);
        let norms = w.l1_norms(1);
        assert_eq!(norms.len(), 16);
        assert!(norms.iter().all(|&n| n > 0.0));
        let (min, max) = norms
            .iter()
            .fold((f32::MAX, f32::MIN), |(lo, hi), &n| (lo.min(n), hi.max(n)));
        assert!(max > min, "norms should vary across filters");
    }

    #[test]
    fn gm_distances_len() {
        let g = graph_with_conv(8);
        let w = Weights::generate(&g, 2);
        let d = w.gm_distances(1);
        assert_eq!(d.len(), 8);
        assert!(d.iter().all(|&x| x.is_finite() && x >= 0.0));
    }

    #[test]
    fn lowest_k_selects_smallest() {
        let scores = vec![5.0, 1.0, 3.0, 0.5, 4.0];
        assert_eq!(Weights::lowest_k(&scores, 2), vec![1, 3]);
        assert_eq!(Weights::lowest_k(&scores, 0), Vec::<usize>::new());
    }

    #[test]
    fn remove_filters_shrinks_bank() {
        let g = graph_with_conv(8);
        let mut w = Weights::generate(&g, 3);
        let before = w.convs[&1].clone();
        w.remove_filters(1, &[0, 3, 7]);
        assert_eq!(w.convs[&1].len(), 5);
        assert_eq!(w.convs[&1][0], before[1]); // filter 1 became first
    }
}
