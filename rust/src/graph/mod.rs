//! DNN graph intermediate representation.
//!
//! This is the "front-end" substrate the paper assumes from TVM/Relay: a
//! dataflow graph of tensor operators with shape inference, FLOPs/params
//! accounting, a model zoo (the paper's workloads: VGG-16, ResNet-18,
//! MobileNetV2, MnasNet1.0, plus the CIFAR-scale ResNet-8 that matches the
//! L2 JAX model), synthetic-but-seeded weights for filter scoring, and the
//! structured-pruning rewrite that removes output channels from a conv and
//! fixes up every consumer.
//!
//! Graph legality is machine-checked: [`crate::verify::graph`]
//! (DESIGN.md §13) walks the dataflow with per-edge `CPV10x`
//! diagnostics, [`ops::Graph::validate`] delegates its structural pass
//! there, and debug builds re-run the full walk after every
//! [`prune::apply`].
//!
//! Channel pruning is the only rewrite that edits the graph itself.
//! Pattern- and block-sparse schemes (DESIGN.md §16) instead layer
//! per-layer masks *on top of* `prune::PruneState` via
//! [`crate::sparsity`]; `stats::effective_flops_params` accounts for
//! both at once.

pub mod dot;
pub mod model_zoo;
pub mod ops;
pub mod prune;
pub mod shape_infer;
pub mod stats;
pub mod weights;

pub use model_zoo::{Model, ModelKind};
pub use ops::{Graph, Node, NodeId, OpKind};
pub use prune::PruneState;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_models_build_and_infer() {
        for kind in ModelKind::all() {
            let m = Model::build(kind, 42);
            assert!(m.graph.nodes.len() > 5, "{kind:?} too small");
            let shapes = shape_infer::infer(&m.graph).expect("shape inference");
            assert_eq!(shapes.len(), m.graph.nodes.len());
            let (flops, params) = stats::flops_params(&m.graph);
            assert!(flops > 0 && params > 0, "{kind:?}: flops={flops} params={params}");
        }
    }
}
