//! Model zoo: the paper's workloads, built as [`Graph`]s.
//!
//! VGG-16 (CIFAR), ResNet-18 (ImageNet + CIFAR stems), MobileNetV2,
//! MnasNet1.0 — plus the CIFAR-scale ResNet-8 whose architecture matches
//! the L2 JAX model exactly (`python/compile/model.py::CONV_SPECS`), used
//! by the end-to-end real-training driver.
//!
//! Base accuracies are the paper's reported originals (Tables 1–2 and §3);
//! the accuracy proxy treats them as the unpruned anchor points.

use super::ops::{Graph, NodeId, OpKind};
use super::shape_infer;
use super::weights::Weights;

/// Which paper workload to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Vgg16Cifar,
    ResNet18ImageNet,
    ResNet18Cifar,
    ResNet34ImageNet,
    MobileNetV1ImageNet,
    MobileNetV2ImageNet,
    MnasNet10ImageNet,
    ResNet8Cifar,
}

impl ModelKind {
    pub fn all() -> Vec<ModelKind> {
        vec![
            ModelKind::Vgg16Cifar,
            ModelKind::ResNet18ImageNet,
            ModelKind::ResNet18Cifar,
            ModelKind::ResNet34ImageNet,
            ModelKind::MobileNetV1ImageNet,
            ModelKind::MobileNetV2ImageNet,
            ModelKind::MnasNet10ImageNet,
            ModelKind::ResNet8Cifar,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Vgg16Cifar => "VGG-16/CIFAR-10",
            ModelKind::ResNet18ImageNet => "ResNet-18/ImageNet",
            ModelKind::ResNet18Cifar => "ResNet-18/CIFAR-10",
            ModelKind::ResNet34ImageNet => "ResNet-34/ImageNet",
            ModelKind::MobileNetV1ImageNet => "MobileNetV1/ImageNet",
            ModelKind::MobileNetV2ImageNet => "MobileNetV2/ImageNet",
            ModelKind::MnasNet10ImageNet => "MnasNet1.0/ImageNet",
            ModelKind::ResNet8Cifar => "ResNet-8/CIFAR-10 (e2e)",
        }
    }

    /// Paper-reported original top-1 / top-5 accuracy (fractions).
    pub fn base_accuracy(&self) -> (f64, f64) {
        match self {
            ModelKind::Vgg16Cifar => (0.9329, 0.998),          // §3
            ModelKind::ResNet18ImageNet => (0.6976, 0.8908),   // Table 1
            ModelKind::ResNet18Cifar => (0.9437, 0.999),       // Table 2
            ModelKind::ResNet34ImageNet => (0.7331, 0.9142),   // torchvision
            ModelKind::MobileNetV1ImageNet => (0.7060, 0.8950), // original paper
            ModelKind::MobileNetV2ImageNet => (0.7188, 0.9029),
            ModelKind::MnasNet10ImageNet => (0.7346, 0.9151),
            ModelKind::ResNet8Cifar => (0.80, 0.99), // measured by the e2e driver
        }
    }
}

/// A workload: graph + seeded weights + metadata.
#[derive(Clone, Debug)]
pub struct Model {
    pub kind: ModelKind,
    pub graph: Graph,
    pub weights: Weights,
    /// Conv node ids whose output channels the pruner may shrink.
    /// Excludes depthwise convs (channel-tied to their producer) and convs
    /// whose output feeds a residual `Add` (shape-coupled to the partner) —
    /// the same restriction NetAdapt applies.
    pub prunable: Vec<NodeId>,
}

impl Model {
    pub fn build(kind: ModelKind, seed: u64) -> Model {
        let graph = match kind {
            ModelKind::Vgg16Cifar => vgg16_cifar(),
            ModelKind::ResNet18ImageNet => resnet18(true),
            ModelKind::ResNet18Cifar => resnet18(false),
            ModelKind::ResNet34ImageNet => resnet34(),
            ModelKind::MobileNetV1ImageNet => mobilenet_v1(),
            ModelKind::MobileNetV2ImageNet => mobilenet_v2(),
            ModelKind::MnasNet10ImageNet => mnasnet10(),
            ModelKind::ResNet8Cifar => resnet8_cifar(),
        };
        graph.validate().expect("builder produced invalid graph"); // cprune-lint: allow(CPL005, reason="fail fast on builder bugs")
        shape_infer::infer(&graph).expect("builder produced unshapeable graph"); // cprune-lint: allow(CPL005, reason="fail fast on builder bugs")
        let weights = Weights::generate(&graph, seed);
        let prunable = prunable_convs(&graph);
        Model { kind, graph, weights, prunable }
    }
}

/// Identify prunable convs (see [`Model::prunable`]).
pub fn prunable_convs(g: &Graph) -> Vec<NodeId> {
    let mut out = Vec::new();
    'conv: for &cid in &g.conv_ids() {
        if let OpKind::Conv2d { groups, cin, .. } = g.node(cid).op {
            if groups == cin && groups > 1 {
                continue; // depthwise: tied to producer
            }
        }
        // Walk forward through elementwise ops; if we reach an Add, the conv
        // is shape-coupled to the residual partner: skip.
        let mut frontier = vec![cid];
        let mut hops = 0;
        while let Some(id) = frontier.pop() {
            hops += 1;
            if hops > 64 {
                break;
            }
            for c in g.consumers(id) {
                match g.node(c).op {
                    OpKind::Add => continue 'conv,
                    // channel-preserving ops propagate the coupling
                    OpKind::BatchNorm { .. }
                    | OpKind::ReLU
                    | OpKind::ReLU6
                    | OpKind::MaxPool { .. } => frontier.push(c),
                    _ => {}
                }
            }
        }
        out.push(cid);
    }
    out
}

// ---------------------------------------------------------------------------
// Builders. Each returns a validated graph with a single Input and a
// Softmax head. Helper closures keep them readable.
// ---------------------------------------------------------------------------

struct B {
    g: Graph,
}

impl B {
    fn new(shape: [usize; 4]) -> (B, NodeId) {
        let mut g = Graph::new();
        let x = g.add("input", OpKind::Input { shape }, vec![]);
        (B { g }, x)
    }

    fn conv_bn_relu(
        &mut self,
        name: &str,
        x: NodeId,
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        relu: Option<OpKind>,
    ) -> NodeId {
        let pad = k / 2;
        let c = self.g.add(
            format!("{name}.conv"),
            OpKind::Conv2d { kh: k, kw: k, cin, cout, stride, padding: pad, groups: 1 },
            vec![x],
        );
        let b = self
            .g
            .add(format!("{name}.bn"), OpKind::BatchNorm { channels: cout }, vec![c]);
        match relu {
            Some(act) => self.g.add(format!("{name}.act"), act, vec![b]),
            None => b,
        }
    }

    fn dwconv_bn_relu(
        &mut self,
        name: &str,
        x: NodeId,
        k: usize,
        c: usize,
        stride: usize,
        relu: Option<OpKind>,
    ) -> NodeId {
        let pad = k / 2;
        let conv = self.g.add(
            format!("{name}.dw"),
            OpKind::Conv2d { kh: k, kw: k, cin: c, cout: c, stride, padding: pad, groups: c },
            vec![x],
        );
        let b = self
            .g
            .add(format!("{name}.bn"), OpKind::BatchNorm { channels: c }, vec![conv]);
        match relu {
            Some(act) => self.g.add(format!("{name}.act"), act, vec![b]),
            None => b,
        }
    }

    fn head(&mut self, x: NodeId, feat: usize, classes: usize) -> NodeId {
        let gap = self.g.add("gap", OpKind::GlobalAvgPool, vec![x]);
        let fl = self.g.add("flatten", OpKind::Flatten, vec![gap]);
        let fc = self
            .g
            .add("fc", OpKind::Dense { cin: feat, cout: classes }, vec![fl]);
        self.g.add("softmax", OpKind::Softmax, vec![fc])
    }
}

/// VGG-16 with a CIFAR-10 head (the Fig. 1 motivation workload).
fn vgg16_cifar() -> Graph {
    let stages: [(usize, usize); 5] =
        [(2, 64), (2, 128), (3, 256), (3, 512), (3, 512)];
    let (mut b, mut x) = B::new([1, 32, 32, 3]);
    let mut cin = 3;
    for (si, (reps, cout)) in stages.iter().enumerate() {
        for r in 0..*reps {
            x = b.conv_bn_relu(
                &format!("s{si}b{r}"),
                x,
                3,
                cin,
                *cout,
                1,
                Some(OpKind::ReLU),
            );
            cin = *cout;
        }
        x = b.g.add(format!("pool{si}"), OpKind::MaxPool { k: 2, stride: 2 }, vec![x]);
    }
    b.head(x, 512, 10);
    b.g
}

/// ResNet-18. `imagenet` selects the 224×224 7×7-stem variant; otherwise the
/// 32×32 CIFAR stem (3×3, stride 1, no maxpool) used in Table 2.
fn resnet18(imagenet: bool) -> Graph {
    let (mut b, x0) = if imagenet {
        B::new([1, 224, 224, 3])
    } else {
        B::new([1, 32, 32, 3])
    };
    let mut x = if imagenet {
        let s = b.conv_bn_relu("stem", x0, 7, 3, 64, 2, Some(OpKind::ReLU));
        b.g.add("stem.pool", OpKind::MaxPool { k: 3, stride: 2 }, vec![s])
    } else {
        b.conv_bn_relu("stem", x0, 3, 3, 64, 1, Some(OpKind::ReLU))
    };

    let mut cin = 64;
    for (si, cout) in [64usize, 128, 256, 512].iter().enumerate() {
        for blk in 0..2 {
            let stride = if si > 0 && blk == 0 { 2 } else { 1 };
            let name = format!("l{si}b{blk}");
            let c1 = b.conv_bn_relu(&format!("{name}.c1"), x, 3, cin, *cout, stride, Some(OpKind::ReLU));
            let c2 = b.conv_bn_relu(&format!("{name}.c2"), c1, 3, *cout, *cout, 1, None);
            let short = if stride != 1 || cin != *cout {
                b.conv_bn_relu(&format!("{name}.down"), x, 1, cin, *cout, stride, None)
            } else {
                x
            };
            let add = b.g.add(format!("{name}.add"), OpKind::Add, vec![c2, short]);
            x = b.g.add(format!("{name}.relu"), OpKind::ReLU, vec![add]);
            cin = *cout;
        }
    }
    b.head(x, 512, if imagenet { 1000 } else { 10 });
    b.g
}

/// MobileNetV2 (ImageNet): inverted residual bottlenecks.
fn mobilenet_v2() -> Graph {
    // (expansion t, output c, repeats n, first stride s)
    let cfg: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    let (mut b, x0) = B::new([1, 224, 224, 3]);
    let mut x = b.conv_bn_relu("stem", x0, 3, 3, 32, 2, Some(OpKind::ReLU6));
    let mut cin = 32;
    for (bi, (t, c, n, s)) in cfg.iter().enumerate() {
        for r in 0..*n {
            let stride = if r == 0 { *s } else { 1 };
            let name = format!("ir{bi}_{r}");
            let hidden = cin * t;
            let mut h = x;
            if *t != 1 {
                h = b.conv_bn_relu(&format!("{name}.expand"), h, 1, cin, hidden, 1, Some(OpKind::ReLU6));
            }
            h = b.dwconv_bn_relu(&format!("{name}.dw"), h, 3, hidden, stride, Some(OpKind::ReLU6));
            let out = b.conv_bn_relu(&format!("{name}.project"), h, 1, hidden, *c, 1, None);
            x = if stride == 1 && cin == *c {
                b.g.add(format!("{name}.add"), OpKind::Add, vec![out, x])
            } else {
                out
            };
            cin = *c;
        }
    }
    x = b.conv_bn_relu("tail", x, 1, cin, 1280, 1, Some(OpKind::ReLU6));
    b.head(x, 1280, 1000);
    b.g
}

/// MnasNet1.0 (ImageNet), following the torchvision block layout.
fn mnasnet10() -> Graph {
    // (expansion t, output c, repeats n, first stride s, kernel k)
    let cfg: [(usize, usize, usize, usize, usize); 6] = [
        (3, 24, 3, 2, 3),
        (3, 40, 3, 2, 5),
        (6, 80, 3, 2, 5),
        (6, 96, 2, 1, 3),
        (6, 192, 4, 2, 5),
        (6, 320, 1, 1, 3),
    ];
    let (mut b, x0) = B::new([1, 224, 224, 3]);
    let mut x = b.conv_bn_relu("stem", x0, 3, 3, 32, 2, Some(OpKind::ReLU));
    // sepconv 16: depthwise 3x3 + pointwise linear
    x = b.dwconv_bn_relu("sep.dw", x, 3, 32, 1, Some(OpKind::ReLU));
    x = b.conv_bn_relu("sep.pw", x, 1, 32, 16, 1, None);
    let mut cin = 16;
    for (bi, (t, c, n, s, k)) in cfg.iter().enumerate() {
        for r in 0..*n {
            let stride = if r == 0 { *s } else { 1 };
            let name = format!("mb{bi}_{r}");
            let hidden = cin * t;
            let h = b.conv_bn_relu(&format!("{name}.expand"), x, 1, cin, hidden, 1, Some(OpKind::ReLU));
            let h = b.dwconv_bn_relu(&format!("{name}.dw"), h, *k, hidden, stride, Some(OpKind::ReLU));
            let out = b.conv_bn_relu(&format!("{name}.project"), h, 1, hidden, *c, 1, None);
            x = if stride == 1 && cin == *c {
                b.g.add(format!("{name}.add"), OpKind::Add, vec![out, x])
            } else {
                out
            };
            cin = *c;
        }
    }
    x = b.conv_bn_relu("tail", x, 1, cin, 1280, 1, Some(OpKind::ReLU));
    b.head(x, 1280, 1000);
    b.g
}

/// ResNet-34 (ImageNet): the deeper basic-block sibling of ResNet-18 —
/// 3/4/6/3 blocks per stage. Exercises deeper task tables (more repeated
/// subgraphs per task, which is where associated-subgraph pruning pays).
fn resnet34() -> Graph {
    let (mut b, x0) = B::new([1, 224, 224, 3]);
    let s = b.conv_bn_relu("stem", x0, 7, 3, 64, 2, Some(OpKind::ReLU));
    let mut x = b.g.add("stem.pool", OpKind::MaxPool { k: 3, stride: 2 }, vec![s]);
    let mut cin = 64;
    for (si, (cout, reps)) in [(64usize, 3usize), (128, 4), (256, 6), (512, 3)]
        .iter()
        .enumerate()
    {
        for blk in 0..*reps {
            let stride = if si > 0 && blk == 0 { 2 } else { 1 };
            let name = format!("l{si}b{blk}");
            let c1 = b.conv_bn_relu(&format!("{name}.c1"), x, 3, cin, *cout, stride, Some(OpKind::ReLU));
            let c2 = b.conv_bn_relu(&format!("{name}.c2"), c1, 3, *cout, *cout, 1, None);
            let short = if stride != 1 || cin != *cout {
                b.conv_bn_relu(&format!("{name}.down"), x, 1, cin, *cout, stride, None)
            } else {
                x
            };
            let add = b.g.add(format!("{name}.add"), OpKind::Add, vec![c2, short]);
            x = b.g.add(format!("{name}.relu"), OpKind::ReLU, vec![add]);
            cin = *cout;
        }
    }
    b.head(x, 512, 1000);
    b.g
}

/// MobileNetV1 (ImageNet): plain depthwise-separable stacks, no residuals —
/// every pointwise conv is prunable, the friendliest case for pruning.
fn mobilenet_v1() -> Graph {
    // (cout, stride) of each separable block's pointwise conv
    let cfg: [(usize, usize); 13] = [
        (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
        (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1),
    ];
    let (mut b, x0) = B::new([1, 224, 224, 3]);
    let mut x = b.conv_bn_relu("stem", x0, 3, 3, 32, 2, Some(OpKind::ReLU));
    let mut cin = 32;
    for (i, (cout, stride)) in cfg.iter().enumerate() {
        let name = format!("sep{i}");
        x = b.dwconv_bn_relu(&format!("{name}.dw"), x, 3, cin, *stride, Some(OpKind::ReLU));
        x = b.conv_bn_relu(&format!("{name}.pw"), x, 1, cin, *cout, 1, Some(OpKind::ReLU));
        cin = *cout;
    }
    b.head(x, 1024, 1000);
    b.g
}

/// CIFAR-scale ResNet-8, mirroring `python/compile/model.py::CONV_SPECS`
/// one-to-one so the e2e driver's mask indices line up with graph node ids.
fn resnet8_cifar() -> Graph {
    let (mut b, x0) = B::new([1, 32, 32, 3]);
    let x = b.conv_bn_relu("stem", x0, 3, 3, 16, 1, Some(OpKind::ReLU));
    // stage 1: identity residual
    let c1 = b.conv_bn_relu("b1c1", x, 3, 16, 16, 1, Some(OpKind::ReLU));
    let c2 = b.conv_bn_relu("b1c2", c1, 3, 16, 16, 1, None);
    let a1 = b.g.add("b1.add", OpKind::Add, vec![c2, x]);
    let x = b.g.add("b1.relu", OpKind::ReLU, vec![a1]);
    // stage 2: projection residual, stride 2
    let c1 = b.conv_bn_relu("b2c1", x, 3, 16, 32, 2, Some(OpKind::ReLU));
    let c2 = b.conv_bn_relu("b2c2", c1, 3, 32, 32, 1, None);
    let p = b.conv_bn_relu("b2proj", x, 1, 16, 32, 2, None);
    let a2 = b.g.add("b2.add", OpKind::Add, vec![c2, p]);
    let x = b.g.add("b2.relu", OpKind::ReLU, vec![a2]);
    // stage 3: projection residual, stride 2
    let c1 = b.conv_bn_relu("b3c1", x, 3, 32, 64, 2, Some(OpKind::ReLU));
    let c2 = b.conv_bn_relu("b3c2", c1, 3, 64, 64, 1, None);
    let p = b.conv_bn_relu("b3proj", x, 1, 32, 64, 2, None);
    let a3 = b.g.add("b3.add", OpKind::Add, vec![c2, p]);
    let x = b.g.add("b3.relu", OpKind::ReLU, vec![a3]);
    b.head(x, 64, 10);
    b.g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn resnet18_imagenet_flops_params_match_paper_order() {
        // Paper Table 1 reports 1.81B "FLOPS" = MACs; 11.7M params.
        let m = Model::build(ModelKind::ResNet18ImageNet, 0);
        let gmacs = stats::macs(&m.graph) as f64 / 1e9;
        let mparams = stats::flops_params(&m.graph).1 as f64 / 1e6;
        assert!((1.5..2.1).contains(&gmacs), "ResNet-18 GMACs={gmacs}");
        assert!((10.0..13.0).contains(&mparams), "ResNet-18 Mparams={mparams}");
    }

    #[test]
    fn mobilenetv2_flops_params_match_paper_order() {
        // Paper Table 1: 301M "FLOPS" = MACs; 3.47M params.
        let m = Model::build(ModelKind::MobileNetV2ImageNet, 0);
        let mmacs = stats::macs(&m.graph) as f64 / 1e6;
        let mparams = stats::flops_params(&m.graph).1 as f64 / 1e6;
        assert!((280.0..430.0).contains(&mmacs), "MobileNetV2 MMACs={mmacs}");
        assert!((3.0..4.0).contains(&mparams), "MobileNetV2 Mparams={mparams}");
    }

    #[test]
    fn mnasnet_params_match_paper_order() {
        // Paper Table 1: 314 MFLOPs, 4.35M params.
        let m = Model::build(ModelKind::MnasNet10ImageNet, 0);
        let (_, params) = stats::flops_params(&m.graph);
        let mparams = params as f64 / 1e6;
        assert!((3.5..5.2).contains(&mparams), "MnasNet Mparams={mparams}");
    }

    #[test]
    fn vgg16_has_13_convs() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        assert_eq!(m.graph.conv_ids().len(), 13);
    }

    #[test]
    fn resnet18_has_20_convs() {
        // 16 block convs + 3 downsample 1x1s + stem
        let m = Model::build(ModelKind::ResNet18ImageNet, 0);
        assert_eq!(m.graph.conv_ids().len(), 20);
    }

    #[test]
    fn prunable_excludes_residual_feeders_and_depthwise() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let names: Vec<&str> = m
            .prunable
            .iter()
            .map(|&id| m.graph.node(id).name.as_str())
            .collect();
        // b1c1/b2c1/b3c1 are internal (prunable); c2/proj feed adds; the stem
        // feeds the stage-1 residual add, so it is excluded too.
        assert!(names.contains(&"b1c1.conv"));
        assert!(names.contains(&"b2c1.conv"));
        assert!(names.contains(&"b3c1.conv"));
        assert!(!names.contains(&"b1c2.conv"));
        assert!(!names.contains(&"b2proj.conv"));
        assert!(!names.contains(&"stem.conv"));

        let mv2 = Model::build(ModelKind::MobileNetV2ImageNet, 0);
        for &id in &mv2.prunable {
            if let OpKind::Conv2d { groups, cin, .. } = mv2.graph.node(id).op {
                assert!(!(groups == cin && groups > 1), "depthwise conv marked prunable");
            }
        }
        assert!(mv2.prunable.len() >= 10);
    }

    #[test]
    fn resnet8_matches_l2_conv_specs() {
        // Same conv inventory as python/compile/model.py::CONV_SPECS.
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let convs = m.graph.conv_ids();
        assert_eq!(convs.len(), 9);
        let couts: Vec<usize> = convs
            .iter()
            .map(|&id| match m.graph.node(id).op {
                OpKind::Conv2d { cout, .. } => cout,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(couts, vec![16, 16, 16, 32, 32, 32, 64, 64, 64]);
    }
}
