//! Shape inference over the dataflow graph.
//!
//! Every node's output is a 4-D NHWC shape (dense/flatten/softmax use
//! [n, 1, 1, c]). Inference both feeds the compiler substrate (subgraph
//! extraction needs concrete extents for the loop nests) and acts as a
//! validity check after pruning rewrites.

use super::ops::{Graph, OpKind};

/// Output shape per node, NHWC. Dense-ish ops use [n, 1, 1, c].
pub type Shape = [usize; 4];

/// Infer output shapes for all nodes. Errors on any inconsistency — which
/// after a pruning rewrite means the rewrite was wrong, so errors here are
/// load-bearing for the prune tests.
pub fn infer(g: &Graph) -> Result<Vec<Shape>, String> {
    let mut shapes: Vec<Shape> = Vec::with_capacity(g.nodes.len());
    for node in &g.nodes {
        let shape = match &node.op {
            OpKind::Input { shape } => *shape,
            OpKind::Conv2d {
                kh,
                kw,
                cin,
                cout,
                stride,
                padding,
                groups,
            } => {
                let [n, h, w, c] = shapes[node.inputs[0]];
                if c != *cin {
                    return Err(format!(
                        "{}: conv cin={} but input has {} channels",
                        node.name, cin, c
                    ));
                }
                if cin % groups != 0 || cout % groups != 0 {
                    return Err(format!("{}: groups {} do not divide channels", node.name, groups));
                }
                let oh = (h + 2 * padding).checked_sub(*kh).ok_or_else(|| {
                    format!("{}: kernel larger than padded input", node.name)
                })? / stride
                    + 1;
                let ow = (w + 2 * padding - kw) / stride + 1;
                [n, oh, ow, *cout]
            }
            OpKind::Dense { cin, cout } => {
                let [n, h, w, c] = shapes[node.inputs[0]];
                let feat = h * w * c;
                if feat != *cin {
                    return Err(format!(
                        "{}: dense cin={} but input flattens to {}",
                        node.name, cin, feat
                    ));
                }
                [n, 1, 1, *cout]
            }
            OpKind::BatchNorm { channels } => {
                let s = shapes[node.inputs[0]];
                if s[3] != *channels {
                    return Err(format!(
                        "{}: bn over {} channels but input has {}",
                        node.name, channels, s[3]
                    ));
                }
                s
            }
            OpKind::ReLU | OpKind::ReLU6 | OpKind::Softmax => shapes[node.inputs[0]],
            OpKind::Add => {
                let a = shapes[node.inputs[0]];
                let b = shapes[node.inputs[1]];
                if a != b {
                    return Err(format!(
                        "{}: add of mismatched shapes {:?} vs {:?}",
                        node.name, a, b
                    ));
                }
                a
            }
            OpKind::MaxPool { k, stride } => {
                let [n, h, w, c] = shapes[node.inputs[0]];
                [n, (h - k) / stride + 1, (w - k) / stride + 1, c]
            }
            OpKind::GlobalAvgPool => {
                let [n, _, _, c] = shapes[node.inputs[0]];
                [n, 1, 1, c]
            }
            OpKind::Flatten => {
                let [n, h, w, c] = shapes[node.inputs[0]];
                [n, 1, 1, h * w * c]
            }
        };
        shapes.push(shape);
    }
    Ok(shapes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::Graph;

    fn conv(kh: usize, cin: usize, cout: usize, stride: usize, padding: usize) -> OpKind {
        OpKind::Conv2d { kh, kw: kh, cin, cout, stride, padding, groups: 1 }
    }

    #[test]
    fn conv_shapes() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 32, 32, 3] }, vec![]);
        let c1 = g.add("c1", conv(3, 3, 16, 1, 1), vec![x]);
        let c2 = g.add("c2", conv(3, 16, 32, 2, 1), vec![c1]);
        let s = infer(&g).unwrap();
        assert_eq!(s[c1], [1, 32, 32, 16]);
        assert_eq!(s[c2], [1, 16, 16, 32]);
    }

    #[test]
    fn channel_mismatch_is_error() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        g.add("c", conv(3, 8, 16, 1, 1), vec![x]); // cin=8 but input c=4
        assert!(infer(&g).is_err());
    }

    #[test]
    fn add_shape_mismatch_is_error() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        let a = g.add("a", conv(3, 4, 8, 1, 1), vec![x]);
        let b = g.add("b", conv(3, 4, 8, 2, 1), vec![x]); // different spatial
        g.add("add", OpKind::Add, vec![a, b]);
        assert!(infer(&g).is_err());
    }

    #[test]
    fn pool_flatten_dense() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 16] }, vec![]);
        let p = g.add("gap", OpKind::GlobalAvgPool, vec![x]);
        let f = g.add("fl", OpKind::Flatten, vec![p]);
        let d = g.add("fc", OpKind::Dense { cin: 16, cout: 10 }, vec![f]);
        let s = infer(&g).unwrap();
        assert_eq!(s[p], [1, 1, 1, 16]);
        assert_eq!(s[f], [1, 1, 1, 16]);
        assert_eq!(s[d], [1, 1, 1, 10]);
    }

    #[test]
    fn depthwise_conv_shape() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 16, 16, 32] }, vec![]);
        let dw = g.add(
            "dw",
            OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: 32, stride: 1, padding: 1, groups: 32 },
            vec![x],
        );
        assert_eq!(infer(&g).unwrap()[dw], [1, 16, 16, 32]);
    }
}
