//! Graphviz (DOT) export of model graphs and their partition.
//!
//! `cprune dot --model resnet8-cifar > g.dot && dot -Tpng g.dot` renders
//! the Fig. 4-style view: nodes colored by op class, subgraph clusters,
//! task labels on the anchors.

use super::ops::{Graph, OpKind};
use super::shape_infer;
use crate::relay::partition::extract_tasks;
use std::fmt::Write as _;

/// Render the dataflow graph, clustered by fused subgraph, with task ids.
pub fn to_dot(g: &Graph) -> String {
    let shapes = shape_infer::infer(g).expect("graph must shape-infer"); // cprune-lint: allow(CPL005, reason="callers pass validated graphs")
    let (part, table) = extract_tasks(g);
    let mut owner = vec![None::<usize>; g.nodes.len()];
    for sg in &part.subgraphs {
        for &n in &sg.nodes {
            owner[n] = Some(sg.id);
        }
    }

    let mut out = String::from("digraph model {\n  rankdir=TB;\n  node [shape=box, fontsize=10];\n");
    for sg in &part.subgraphs {
        let task = table.task_of_subgraph(sg.id).unwrap_or(usize::MAX);
        let _ = writeln!(
            out,
            "  subgraph cluster_{} {{ label=\"S{} (T{})\"; style=dashed;",
            sg.id, sg.id, task
        );
        for &n in &sg.nodes {
            let _ = writeln!(out, "    n{};", n);
        }
        let _ = writeln!(out, "  }}");
    }
    for node in &g.nodes {
        let color = match node.op {
            OpKind::Conv2d { .. } => "lightblue",
            OpKind::Dense { .. } => "lightsalmon",
            OpKind::BatchNorm { .. } => "lightyellow",
            OpKind::Add => "palegreen",
            OpKind::Input { .. } => "gray90",
            _ => "white",
        };
        let s = shapes[node.id];
        let _ = writeln!(
            out,
            "  n{} [label=\"{}\\n{} {:?}\", style=filled, fillcolor={}];",
            node.id,
            node.name,
            node.op.mnemonic(),
            s,
            color
        );
        for &inp in &node.inputs {
            let _ = writeln!(out, "  n{} -> n{};", inp, node.id);
        }
    }
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::{Model, ModelKind};

    #[test]
    fn dot_output_is_wellformed() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let dot = to_dot(&m.graph);
        assert!(dot.starts_with("digraph model {"));
        assert!(dot.trim_end().ends_with('}'));
        // every node appears
        for node in &m.graph.nodes {
            assert!(dot.contains(&format!("n{} [label=", node.id)), "{}", node.name);
        }
        // at least one cluster per conv anchor
        assert!(dot.matches("subgraph cluster_").count() >= m.graph.conv_ids().len());
        // edge count equals sum of input arities
        let edges: usize = m.graph.nodes.iter().map(|n| n.inputs.len()).sum();
        assert_eq!(dot.matches(" -> ").count(), edges);
    }

    #[test]
    fn dot_labels_tasks() {
        let m = Model::build(ModelKind::ResNet18ImageNet, 0);
        let dot = to_dot(&m.graph);
        assert!(dot.contains("(T0)"));
    }
}
