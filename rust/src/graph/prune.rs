//! Structured-pruning graph rewrite.
//!
//! [`PruneState`] tracks, per prunable conv, how many output channels remain;
//! [`apply`] rebuilds the graph with those counts, propagating the channel
//! change into every consumer (BN widths, downstream conv `cin`s, depthwise
//! chains, dense `cin`s). The result is a *valid standalone graph* — exactly
//! what the compiler substrate re-partitions and re-tunes each CPrune
//! iteration (Algorithm 1, line 7).

use super::ops::{Graph, NodeId, OpKind};
use super::model_zoo::Model;
use std::collections::BTreeMap;

/// Per-conv remaining output-channel counts (only prunable convs appear).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PruneState {
    pub cout: BTreeMap<NodeId, usize>,
}

impl PruneState {
    /// The unpruned state of a model: every prunable conv at full width.
    pub fn full(model: &Model) -> PruneState {
        let mut cout = BTreeMap::new();
        for &id in &model.prunable {
            if let OpKind::Conv2d { cout: c, .. } = model.graph.node(id).op {
                cout.insert(id, c);
            }
        }
        PruneState { cout }
    }

    /// Remaining channels of a conv (panics if not prunable).
    pub fn remaining(&self, conv: NodeId) -> usize {
        self.cout[&conv]
    }

    /// Shrink `conv` by `k` channels; clamps at a floor of 2 channels and
    /// returns how many were actually removed.
    pub fn shrink(&mut self, conv: NodeId, k: usize) -> usize {
        let c = self.cout.get_mut(&conv).expect("conv is prunable"); // cprune-lint: allow(CPL005, reason="conv ids come from this state's own map")
        let removable = c.saturating_sub(2).min(k);
        *c -= removable;
        removable
    }

    /// Fraction of original channels pruned for `conv`, given the original.
    pub fn pruned_fraction(&self, conv: NodeId, original: usize) -> f64 {
        1.0 - self.cout[&conv] as f64 / original as f64
    }
}

/// Rebuild `base` with overridden conv output-channel counts.
///
/// Channel propagation rules:
/// * conv (regular):  `cin` := input channels, `cout` := override or original
/// * conv (depthwise): `cin = cout = groups` := input channels
/// * batch-norm:       width := input channels
/// * dense:            `cin` := flattened input extent
/// * everything else passes channels through untouched.
pub fn apply(base: &Graph, cout_override: &BTreeMap<NodeId, usize>) -> Result<Graph, String> {
    let mut g = Graph::new();
    // Shape tracking mirrors shape_infer but over the *rewritten* ops.
    let mut shapes: Vec<[usize; 4]> = Vec::with_capacity(base.nodes.len());
    for node in &base.nodes {
        let inp = |i: usize| shapes[node.inputs[i]];
        let (op, shape) = match &node.op {
            OpKind::Input { shape } => (node.op.clone(), *shape),
            OpKind::Conv2d { kh, kw, cout, stride, padding, groups, cin } => {
                let [n, h, w, c] = inp(0);
                let depthwise = *groups == *cin && *groups > 1;
                let (new_cin, new_cout, new_groups) = if depthwise {
                    (c, c, c)
                } else {
                    let oc = cout_override.get(&node.id).copied().unwrap_or(*cout);
                    if oc == 0 {
                        return Err(format!("{}: cannot prune to 0 channels", node.name));
                    }
                    (c, oc, 1)
                };
                let oh = (h + 2 * padding - kh) / stride + 1;
                let ow = (w + 2 * padding - kw) / stride + 1;
                (
                    OpKind::Conv2d {
                        kh: *kh,
                        kw: *kw,
                        cin: new_cin,
                        cout: new_cout,
                        stride: *stride,
                        padding: *padding,
                        groups: new_groups,
                    },
                    [n, oh, ow, new_cout],
                )
            }
            OpKind::Dense { cout, .. } => {
                let [n, h, w, c] = inp(0);
                (OpKind::Dense { cin: h * w * c, cout: *cout }, [n, 1, 1, *cout])
            }
            OpKind::BatchNorm { .. } => {
                let s = inp(0);
                (OpKind::BatchNorm { channels: s[3] }, s)
            }
            OpKind::ReLU | OpKind::ReLU6 | OpKind::Softmax => (node.op.clone(), inp(0)),
            OpKind::Add => {
                let a = inp(0);
                let b = inp(1);
                if a != b {
                    return Err(format!(
                        "{}: pruning broke residual add ({:?} vs {:?}) — \
                         a residual feeder was pruned",
                        node.name, a, b
                    ));
                }
                (OpKind::Add, a)
            }
            OpKind::MaxPool { k, stride } => {
                let [n, h, w, c] = inp(0);
                (node.op.clone(), [n, (h - k) / stride + 1, (w - k) / stride + 1, c])
            }
            OpKind::GlobalAvgPool => {
                let [n, _, _, c] = inp(0);
                (OpKind::GlobalAvgPool, [n, 1, 1, c])
            }
            OpKind::Flatten => {
                let [n, h, w, c] = inp(0);
                (OpKind::Flatten, [n, 1, 1, h * w * c])
            }
        };
        shapes.push(shape);
        g.add(node.name.clone(), op, node.inputs.clone());
    }
    g.validate()?;
    super::shape_infer::infer(&g)?; // double-check consistency
    // Debug builds additionally run the full semantic walk (DESIGN.md §13);
    // it must agree with the two release-mode checks above.
    #[cfg(debug_assertions)]
    for d in crate::verify::graph::check_graph(&g) {
        panic!("prune::apply produced a graph the semantic checker rejects: {d}");
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::{Model, ModelKind};
    use crate::graph::stats;

    #[test]
    fn full_state_is_identity() {
        let m = Model::build(ModelKind::ResNet18ImageNet, 0);
        let st = PruneState::full(&m);
        let g = apply(&m.graph, &st.cout).unwrap();
        let (f0, p0) = stats::flops_params(&m.graph);
        let (f1, p1) = stats::flops_params(&g);
        assert_eq!((f0, p0), (f1, p1));
    }

    #[test]
    fn pruning_reduces_flops_and_params() {
        let m = Model::build(ModelKind::ResNet18ImageNet, 0);
        let mut st = PruneState::full(&m);
        let conv = m.prunable[2];
        let removed = st.shrink(conv, 16);
        assert_eq!(removed, 16);
        let g = apply(&m.graph, &st.cout).unwrap();
        let (f0, p0) = stats::flops_params(&m.graph);
        let (f1, p1) = stats::flops_params(&g);
        assert!(f1 < f0 && p1 < p0);
    }

    #[test]
    fn pruned_graph_consumers_are_fixed_up() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let mut st = PruneState::full(&m);
        let conv = m.prunable[0]; // first conv, 64 channels
        st.shrink(conv, 32);
        let g = apply(&m.graph, &st.cout).unwrap();
        // next conv must now take 32 input channels
        let next_conv = g.conv_ids()[1];
        match g.node(next_conv).op {
            OpKind::Conv2d { cin, .. } => assert_eq!(cin, 32),
            _ => unreachable!(),
        }
    }

    #[test]
    fn depthwise_chain_follows_expand_prune() {
        let m = Model::build(ModelKind::MobileNetV2ImageNet, 0);
        // find an expand conv (name contains ".expand")
        let expand = *m
            .prunable
            .iter()
            .find(|&&id| m.graph.node(id).name.contains(".expand"))
            .unwrap();
        let mut st = PruneState::full(&m);
        let orig = st.remaining(expand);
        st.shrink(expand, orig / 2);
        let g = apply(&m.graph, &st.cout).unwrap();
        // the depthwise conv right after must have shrunk to match
        let dw = g
            .nodes
            .iter()
            .find(|n| {
                n.name.starts_with(
                    m.graph.node(expand).name.trim_end_matches(".conv").trim_end_matches(".expand"),
                ) && n.op.mnemonic() == "dwconv2d"
            });
        if let Some(dwn) = dw {
            if let OpKind::Conv2d { cin, cout, groups, .. } = dwn.op {
                assert_eq!(cin, orig - orig / 2);
                assert_eq!(cout, cin);
                assert_eq!(groups, cin);
            }
        }
        // and the whole graph still validates
        assert!(g.validate().is_ok());
    }

    #[test]
    fn shrink_clamps_at_floor() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut st = PruneState::full(&m);
        let conv = m.prunable[0];
        let total = st.remaining(conv);
        let removed = st.shrink(conv, 10_000);
        assert_eq!(removed, total - 2);
        assert_eq!(st.remaining(conv), 2);
    }

    #[test]
    fn pruned_fraction() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut st = PruneState::full(&m);
        let conv = m.prunable[0];
        let orig = st.remaining(conv);
        st.shrink(conv, orig / 4);
        let frac = st.pruned_fraction(conv, orig);
        assert!((frac - 0.25).abs() < 1e-9);
    }
}
