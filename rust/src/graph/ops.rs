//! Operator set and dataflow graph.
//!
//! Layout convention is NHWC activations / HWIO conv weights (matches the
//! L2 JAX model). The op set covers everything in the paper's model zoo:
//! plain + depthwise + pointwise convolutions, dense, batch-norm, ReLU /
//! ReLU6, residual add, pooling, and softmax.

/// Index of a node within its graph.
pub type NodeId = usize;

/// Tensor operator kinds.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    /// Network input: (n, h, w, c).
    Input { shape: [usize; 4] },
    /// 2-D convolution, NHWC x HWIO. `groups == cin` means depthwise.
    Conv2d {
        kh: usize,
        kw: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        padding: usize,
        groups: usize,
    },
    /// Fully connected: (features_in, features_out).
    Dense { cin: usize, cout: usize },
    /// Folded batch normalization (per-channel scale + shift).
    BatchNorm { channels: usize },
    /// Rectifier activations.
    ReLU,
    ReLU6,
    /// Elementwise residual add of two equal-shaped inputs.
    Add,
    /// Max pool (kernel, stride).
    MaxPool { k: usize, stride: usize },
    /// Global average pool NHWC -> N,1,1,C.
    GlobalAvgPool,
    /// Collapse N,1,1,C (or N,H,W,C) to N,(H*W*C).
    Flatten,
    Softmax,
}

impl OpKind {
    /// Short operator mnemonic (used in structural hashes and debug dumps).
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Input { .. } => "input",
            OpKind::Conv2d { groups, cin, .. } if *groups == *cin && *groups > 1 => "dwconv2d",
            OpKind::Conv2d { .. } => "conv2d",
            OpKind::Dense { .. } => "dense",
            OpKind::BatchNorm { .. } => "bn",
            OpKind::ReLU => "relu",
            OpKind::ReLU6 => "relu6",
            OpKind::Add => "add",
            OpKind::MaxPool { .. } => "maxpool",
            OpKind::GlobalAvgPool => "gavgpool",
            OpKind::Flatten => "flatten",
            OpKind::Softmax => "softmax",
        }
    }

    pub fn is_conv(&self) -> bool {
        matches!(self, OpKind::Conv2d { .. })
    }
}

/// A graph node: an operator plus its dataflow inputs.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: OpKind,
    pub inputs: Vec<NodeId>,
}

/// Dataflow graph in topological order (builders append in execution order).
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new() -> Self {
        Graph { nodes: Vec::new() }
    }

    /// Append a node; returns its id. Inputs must already exist.
    pub fn add(&mut self, name: impl Into<String>, op: OpKind, inputs: Vec<NodeId>) -> NodeId {
        let id = self.nodes.len();
        for &i in &inputs {
            assert!(i < id, "input {i} of node {id} not yet defined");
        }
        self.nodes.push(Node {
            id,
            name: name.into(),
            op,
            inputs,
        });
        id
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    /// Ids of nodes that consume `id`'s output.
    pub fn consumers(&self, id: NodeId) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.inputs.contains(&id))
            .map(|n| n.id)
            .collect()
    }

    /// All convolution node ids, in topological order.
    pub fn conv_ids(&self) -> Vec<NodeId> {
        self.nodes
            .iter()
            .filter(|n| n.op.is_conv())
            .map(|n| n.id)
            .collect()
    }

    /// Verify topological ordering + arity invariants. Used by tests and
    /// after every pruning rewrite.
    ///
    /// Delegates to [`crate::verify::graph::check_structure`] (DESIGN.md
    /// §13) so ad-hoc validation and the `cprune check` sweep agree on
    /// what "structurally valid" means; the first finding becomes the
    /// error string. For the full dataflow/shape walk use
    /// [`crate::verify::graph::check_graph`].
    pub fn validate(&self) -> Result<(), String> {
        match crate::verify::graph::check_structure(self).into_iter().next() {
            None => Ok(()),
            Some(d) => Err(d.to_string()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 3] }, vec![]);
        let c = g.add(
            "c1",
            OpKind::Conv2d {
                kh: 3,
                kw: 3,
                cin: 3,
                cout: 16,
                stride: 1,
                padding: 1,
                groups: 1,
            },
            vec![x],
        );
        let b = g.add("bn1", OpKind::BatchNorm { channels: 16 }, vec![c]);
        g.add("r1", OpKind::ReLU, vec![b]);
        g
    }

    #[test]
    fn build_and_validate() {
        let g = tiny();
        assert!(g.validate().is_ok());
        assert_eq!(g.conv_ids(), vec![1]);
        assert_eq!(g.consumers(1), vec![2]);
    }

    #[test]
    #[should_panic(expected = "not yet defined")]
    fn forward_reference_panics() {
        let mut g = Graph::new();
        g.add("bad", OpKind::ReLU, vec![3]);
    }

    #[test]
    fn arity_validation() {
        let mut g = tiny();
        // Add with one input is invalid
        g.nodes.push(Node {
            id: 4,
            name: "bad_add".into(),
            op: OpKind::Add,
            inputs: vec![3],
        });
        assert!(g.validate().is_err());
    }

    #[test]
    fn mnemonics() {
        assert_eq!(
            OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: 32, stride: 1, padding: 1, groups: 32 }
                .mnemonic(),
            "dwconv2d"
        );
        assert_eq!(OpKind::ReLU6.mnemonic(), "relu6");
    }
}
