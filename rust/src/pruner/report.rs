//! JSON export of CPrune runs (uses the in-tree JSON writer).
//!
//! `cprune prune --out run.json` and the experiment harnesses use this to
//! persist machine-readable results; the schema is stable and documented
//! here field-by-field.

use super::cprune::{CPruneResult, IterationLog};
use crate::graph::model_zoo::Model;
use crate::graph::stats;
use crate::run::PruneOutcome;
use crate::util::json::Json;

/// Serialize the per-iteration series (shared by both report flavors).
fn iterations_json(iterations: &[IterationLog]) -> Json {
    Json::Arr(
        iterations
            .iter()
            .map(|it| {
                Json::obj(vec![
                    ("iteration", Json::Num(it.iteration as f64)),
                    (
                        "pruned_convs",
                        Json::Arr(it.pruned_convs.iter().map(|&c| Json::Num(c as f64)).collect()),
                    ),
                    ("filters_removed", Json::Num(it.filters_removed as f64)),
                    ("latency", Json::Num(it.latency)),
                    ("fps_rate", Json::Num(it.fps_rate)),
                    ("short_accuracy", Json::Num(it.short_accuracy)),
                ])
            })
            .collect(),
    )
}

/// Serialize a CPrune run.
///
/// Schema:
/// ```json
/// {
///   "model": "...", "device": "...",
///   "baseline_fps": f, "final_fps": f, "fps_increase_rate": f,
///   "final_top1": f, "final_top5": f,
///   "macs": n, "params": n,
///   "main_step_seconds": f, "candidates_tried": n, "programs_measured": n,
///   "iterations": [ {"iteration": n, "pruned_convs": [n], "filters_removed": n,
///                    "latency": f, "fps_rate": f, "short_accuracy": f} ],
///   "final_channels": { "<conv id>": n }
/// }
/// ```
pub fn to_json(model: &Model, device: &str, r: &CPruneResult) -> Json {
    let (flops, params) = stats::flops_params(&r.final_graph);
    let iterations = iterations_json(&r.iterations);
    let channels = Json::Obj(
        r.final_state
            .cout
            .iter()
            .map(|(&conv, &c)| (conv.to_string(), Json::Num(c as f64)))
            .collect(),
    );
    Json::obj(vec![
        ("model", Json::Str(model.kind.name().to_string())),
        ("device", Json::Str(device.to_string())),
        ("baseline_fps", Json::Num(r.baseline.fps())),
        ("final_fps", Json::Num(r.final_fps)),
        ("fps_increase_rate", Json::Num(r.fps_increase_rate)),
        ("final_top1", Json::Num(r.final_top1)),
        ("final_top5", Json::Num(r.final_top5)),
        ("macs", Json::Num((flops / 2) as f64)),
        ("params", Json::Num(params as f64)),
        ("main_step_seconds", Json::Num(r.main_step_seconds)),
        ("candidates_tried", Json::Num(r.candidates_tried as f64)),
        ("programs_measured", Json::Num(r.programs_measured as f64)),
        ("iterations", iterations),
        ("final_channels", channels),
    ])
}

/// Serialize a [`PruneOutcome`] (any pruner under the run layer) to the
/// same schema as [`to_json`], plus `pruner`/`method` tags. For a CPrune
/// run the shared fields carry identical values to the legacy report.
pub fn outcome_to_json(out: &PruneOutcome) -> Json {
    let channels = Json::Obj(
        out.channels
            .iter()
            .map(|(&conv, &c)| (conv.to_string(), Json::Num(c as f64)))
            .collect(),
    );
    Json::obj(vec![
        ("pruner", Json::Str(out.pruner.clone())),
        ("method", Json::Str(out.method.clone())),
        ("model", Json::Str(out.model.clone())),
        ("device", Json::Str(out.device.clone())),
        ("baseline_fps", Json::Num(1.0 / out.baseline_latency)),
        ("final_fps", Json::Num(out.final_fps)),
        ("fps_increase_rate", Json::Num(out.fps_increase_rate)),
        ("final_top1", Json::Num(out.top1)),
        ("final_top5", Json::Num(out.top5)),
        ("macs", Json::Num(out.macs as f64)),
        ("params", Json::Num(out.params as f64)),
        ("main_step_seconds", Json::Num(out.main_step_seconds)),
        ("candidates_tried", Json::Num(out.search_candidates as f64)),
        ("programs_measured", Json::Num(out.programs_measured as f64)),
        ("iterations", iterations_json(&out.iterations)),
        ("final_channels", channels),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::pruner::{cprune, CPruneConfig};
    use crate::util::json;

    #[test]
    fn report_roundtrips_through_parser() {
        let model = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut oracle = ProxyOracle::new();
        let cfg = CPruneConfig { max_iterations: 4, ..Default::default() };
        let r = cprune(&model, &sim, &mut oracle, &cfg);
        let j = to_json(&model, sim.spec.name, &r);
        let text = j.to_string();
        let parsed = json::parse(&text).expect("report must be valid JSON");
        assert_eq!(
            parsed.get("model").unwrap().as_str().unwrap(),
            model.kind.name()
        );
        assert!(parsed.get("final_fps").unwrap().as_f64().unwrap() > 0.0);
        let iters = parsed.get("iterations").unwrap().as_arr().unwrap();
        assert_eq!(iters.len(), r.iterations.len());
    }
}
