//! Algorithm 1: the CPrune iterative search.
//!
//! Each iteration walks the prioritized task list R (descending pruning
//! impact). For the selected task it derives the minimum structure-
//! preserving filter step from the task's fastest program (§3.5), prunes
//! the lowest-ℓ1 filters of *all* associated subgraphs (§4.5's default),
//! re-tunes the candidate (seeding the pruned task's search with the
//! structure-adjusted fastest program), and accepts iff the latency target
//! `l_t = β·l_m` and the short-term accuracy gate `a_s ≥ α·a_p` both hold.
//! Tasks that fail the accuracy gate are banned for the rest of the run
//! (line 12). The run ends when no task can be pruned any further or the
//! accuracy budget `a_g` is exhausted.

use crate::accuracy::{AccuracyOracle, Criterion, TrainPhase};
use crate::compiler::{self, CompiledModel};
use crate::device::Target;
use crate::graph::model_zoo::Model;
use crate::graph::ops::{Graph, NodeId};
use crate::graph::prune::{apply, PruneState};
use crate::graph::weights::Weights;
use crate::relay::partition::partition;
use crate::relay::TaskTable;
use crate::run::{RejectReason, RunContext, RunEvent};
use crate::serve::{Checkpoint, ParetoSet};
use crate::tir::{Program, Workload};
use crate::tuner::{TuneOptions, TuningSession};
use std::collections::{BTreeSet, HashMap};
use std::time::Instant;

/// Knobs of Algorithm 1 (α, β, a_g) plus the ablation switches of §4.5–4.7.
#[derive(Clone, Debug)]
pub struct CPruneConfig {
    /// Minimum allowable short-term accuracy ratio per iteration (α).
    pub alpha: f64,
    /// Latency-target ratio for the next iteration (β): `l_t = β · l_m`.
    pub beta: f64,
    /// Required (short-term) accuracy floor a_g, as a fraction.
    pub target_accuracy: f64,
    /// Safety cap on iterations.
    pub max_iterations: usize,
    /// Tuning budget per task.
    pub tune_opts: TuneOptions,
    /// RNG seed for tuning/measurement streams.
    pub seed: u64,
    /// §4.5: prune every subgraph of the task (CPrune) vs. only one
    /// (NetAdapt-style single-subgraph ablation).
    pub associated_subgraphs: bool,
    /// §4.6: tune candidates (CPrune) vs. measure untuned defaults.
    pub with_tuning: bool,
    /// Filter-selection criterion (ℓ1 in the paper).
    pub criterion: Criterion,
    /// Search-effort cap: stop after this many candidate models have been
    /// compiled+measured (Figs. 9/11 compare searches at fixed effort).
    pub max_candidates: usize,
}

impl Default for CPruneConfig {
    fn default() -> Self {
        CPruneConfig {
            alpha: 0.98,
            beta: 0.97,
            target_accuracy: 0.0,
            max_iterations: 60,
            tune_opts: TuneOptions::quick(),
            seed: 0,
            associated_subgraphs: true,
            with_tuning: true,
            criterion: Criterion::L1Norm,
            max_candidates: usize::MAX,
        }
    }
}

/// One accepted pruning iteration (Fig. 6's x-axis).
#[derive(Clone, Debug)]
pub struct IterationLog {
    pub iteration: usize,
    /// Anchor convs pruned this iteration.
    pub pruned_convs: Vec<NodeId>,
    /// Filters removed per conv.
    pub filters_removed: usize,
    /// Candidate latency l_m (seconds).
    pub latency: f64,
    /// FPS increase rate vs. the tuned-but-unpruned baseline.
    pub fps_rate: f64,
    /// Short-term accuracy a_s.
    pub short_accuracy: f64,
    /// Candidates evaluated (tuned + measured) before this acceptance.
    pub candidates_tried: usize,
}

/// Output of a CPrune run.
#[derive(Debug)]
pub struct CPruneResult {
    pub final_graph: Graph,
    pub final_state: PruneState,
    pub final_table: TaskTable,
    /// Tuned-but-unpruned reference (the "TVM auto-tune" row).
    pub baseline: CompiledModel,
    pub final_latency: f64,
    pub final_fps: f64,
    pub fps_increase_rate: f64,
    pub final_top1: f64,
    pub final_top5: f64,
    pub iterations: Vec<IterationLog>,
    /// The non-dominated latency/accuracy frontier of the run: the
    /// tuned-but-unpruned baseline plus every accepted iteration's
    /// deployable checkpoint (DESIGN.md §8). This is what
    /// [`crate::serve::Registry`] publishes and the serving simulator
    /// picks models from.
    pub pareto: ParetoSet,
    /// Wall-clock seconds spent in the Main step (Fig. 9/11's cost metric).
    pub main_step_seconds: f64,
    /// Total candidate models tuned+measured during the search.
    pub candidates_tried: usize,
    /// Total programs measured by the tuner (search cost, Fig. 11).
    pub programs_measured: usize,
}

/// Run CPrune for `model` on the device behind `target` (any
/// measurement provider — DESIGN.md §11).
pub fn cprune(
    model: &Model,
    target: &dyn Target,
    oracle: &mut dyn AccuracyOracle,
    cfg: &CPruneConfig,
) -> CPruneResult {
    let session = TuningSession::new(target, cfg.tune_opts, cfg.seed);
    cprune_with_session(model, oracle, cfg, &session)
}

/// Run CPrune against a caller-owned [`TuningSession`] — the warm-start
/// entry point: load a persisted [`crate::tuner::TuneCache`] into the
/// session first and identical workloads skip re-measurement entirely.
/// The session's own options/seed govern tuning (`cfg.tune_opts` /
/// `cfg.seed` only matter to sessions built by [`cprune`]); the target
/// device is the session's simulator.
///
/// Thin shim over [`cprune_run`] with no observers; prefer
/// [`crate::run::RunBuilder`] + [`crate::run::CPrune`] for new call
/// sites — same algorithm, same results, plus the typed event stream.
pub fn cprune_with_session(
    model: &Model,
    oracle: &mut dyn AccuracyOracle,
    cfg: &CPruneConfig,
    session: &TuningSession,
) -> CPruneResult {
    let mut ctx = RunContext::standalone(model, session, oracle);
    cprune_run(&mut ctx, cfg)
}

/// The observed entry point: Algorithm 1 narrating every baseline tune,
/// candidate measurement, gate decision, task ban and emitted checkpoint
/// through the context's [`crate::run::RunObserver`]s (DESIGN.md §9).
pub fn cprune_run(ctx: &mut RunContext, cfg: &CPruneConfig) -> CPruneResult {
    let t0 = Instant::now();
    let model = ctx.model;
    let session = ctx.session;
    let target = session.target;

    // -- Line 1: initial tune of M --------------------------------------
    let baseline = compiler::compile_tuned(&model.graph, session, &HashMap::new());
    let base_latency = baseline.latency();
    ctx.set_baseline(base_latency, baseline.fps());
    // The latency-gate chain must compare like with like: in the w/o-tuning
    // ablation candidates are measured with default schedules, so the chain
    // starts from the default-schedule baseline (the final model still gets
    // one full tune at the end, as in the paper).
    let gate_baseline = if cfg.with_tuning {
        base_latency
    } else {
        compiler::compile_fallback(&model.graph, target).latency()
    };

    let mut state = PruneState::full(model);
    let mut weights = model.weights.clone();
    let mut graph = model.graph.clone();
    let mut table = if cfg.with_tuning {
        baseline.table.clone()
    } else {
        compiler::compile_fallback(&model.graph, target).table
    };
    let mut l_t = cfg.beta * gate_baseline;
    let initial_summary = super::summarize(model, &state, cfg.criterion);
    let mut a_p = ctx.oracle.top1(&initial_summary, TrainPhase::Short);
    let mut banned: BTreeSet<NodeId> = BTreeSet::new();
    let mut iterations: Vec<IterationLog> = Vec::new();
    let mut candidates_tried = 0usize;
    // Iteration-0 checkpoint: the unpruned model is always a deployable
    // fallback — the slowest, highest-accuracy end of the frontier. Uses
    // the same latency chain the acceptance gates compare against so the
    // frontier is internally consistent in the w/o-tuning ablation too.
    let mut pareto = ParetoSet::new();
    let baseline_checkpoint = Checkpoint {
        iteration: 0,
        latency: gate_baseline,
        accuracy: a_p,
        channels: state.cout.clone(),
        schemes: std::collections::BTreeMap::new(),
    };
    ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: baseline_checkpoint.clone() });
    pareto.insert(baseline_checkpoint);

    // -- Lines 2–16: main loop -------------------------------------------
    'outer: for iter_no in 0..cfg.max_iterations {
        if a_p <= cfg.target_accuracy || candidates_tried >= cfg.max_candidates {
            break;
        }
        // R (re)built every iteration: tasks by descending pruning impact.
        let part = partition(&graph);
        let ordered = table.by_pruning_impact();

        let mut accepted = false;
        for tid in ordered {
            let tinfo = table.get(tid).clone();
            // Anchor convs of the task's subgraphs.
            let anchors: Vec<NodeId> = tinfo
                .subgraphs
                .iter()
                .filter_map(|&sgid| part.subgraphs.get(sgid).map(|s| s.anchor))
                .collect();
            if anchors.is_empty()
                || anchors.iter().any(|a| banned.contains(a))
                || !anchors.iter().all(|a| state.cout.contains_key(a))
            {
                continue; // unprunable or banned task
            }
            let Some(prog) = tinfo.best_program.clone() else { continue };

            // -- Line 5: pruning step from the program structure (§3.5) --
            let step = prog.min_filter_prune_step().max(1);
            let remaining = state.remaining(anchors[0]);
            if remaining <= 2 || remaining.saturating_sub(step) < 2 {
                banned.insert(anchors[0]);
                ctx.emit(&RunEvent::TaskBanned {
                    conv: anchors[0],
                    reason: "channel_floor".to_string(),
                });
                continue;
            }

            // -- Line 6: prune candidate (all subgraphs or just one) -------
            let targets: Vec<NodeId> = if cfg.associated_subgraphs {
                anchors.clone()
            } else {
                vec![anchors[0]]
            };

            // Pruning one minimum step often moves latency by less than the
            // β margin; escalate through *multiples* of the step (every
            // multiple still preserves the program structure) until the
            // latency target is met or the layer floor is hit.
            for mult in [1usize, 2, 4, 8] {
                let k_want = step * mult;
                if k_want >= remaining.saturating_sub(2) && mult > 1 {
                    break;
                }
                let mut cand_state = state.clone();
                let mut cand_weights = weights.clone();
                let mut removed_total = 0usize;
                for &conv in &targets {
                    let scores = match cfg.criterion {
                        Criterion::GeomMedian => cand_weights.gm_distances(conv),
                        _ => cand_weights.l1_norms(conv),
                    };
                    let k = k_want.min(cand_state.remaining(conv).saturating_sub(2));
                    if k == 0 {
                        continue;
                    }
                    let idx = Weights::lowest_k(&scores, k);
                    cand_weights.remove_filters(conv, &idx);
                    removed_total += cand_state.shrink(conv, k);
                }
                if removed_total == 0 {
                    banned.insert(anchors[0]);
                    ctx.emit(&RunEvent::TaskBanned {
                        conv: anchors[0],
                        reason: "no_channels_removed".to_string(),
                    });
                    break;
                }
                let cand_graph = match apply(&model.graph, &cand_state.cout) {
                    Ok(g) => g,
                    Err(_) => {
                        banned.insert(anchors[0]);
                        ctx.emit(&RunEvent::TaskBanned {
                            conv: anchors[0],
                            reason: "invalid_graph".to_string(),
                        });
                        break;
                    }
                };

                // -- Lines 7–9: extract tasks, tune, measure l_m -----------
                // Seed the pruned task's search with the structure-preserved
                // program (§3.5's whole point).
                let mut seeds: HashMap<Workload, Program> = HashMap::new();
                let new_ff = cand_state.remaining(targets[0]);
                if let Some(adj) = prog.with_pruned_filters(new_ff) {
                    let mut w2 = tinfo.workload.clone();
                    w2.ff = new_ff;
                    seeds.insert(w2, adj);
                }
                let cand = if cfg.with_tuning {
                    compiler::compile_tuned(&cand_graph, session, &seeds)
                } else {
                    compiler::compile_fallback(&cand_graph, target)
                };
                let l_m = cand.latency();
                candidates_tried += 1;
                ctx.emit(&RunEvent::CandidateMeasured {
                    iteration: iter_no + 1,
                    latency: l_m,
                    latency_target: l_t,
                    candidates_tried,
                    scheme: None,
                });
                if candidates_tried > cfg.max_candidates {
                    break 'outer;
                }

                // -- Line 10: latency gate ---------------------------------
                if l_m >= l_t {
                    ctx.emit(&RunEvent::IterationRejected {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: l_t,
                        short_accuracy: None,
                        accuracy_gate: None,
                        reason: RejectReason::LatencyGate,
                    });
                    continue; // escalate the step multiple
                }

                // -- Lines 11–12: short-term train, accuracy gate -----------
                let cand_summary = super::summarize(model, &cand_state, cfg.criterion);
                let a_s = ctx.oracle.top1(&cand_summary, TrainPhase::Short);
                if a_s < cfg.alpha * a_p {
                    banned.insert(anchors[0]);
                    ctx.emit(&RunEvent::IterationRejected {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: l_t,
                        short_accuracy: Some(a_s),
                        accuracy_gate: Some(cfg.alpha * a_p),
                        reason: RejectReason::AccuracyGate,
                    });
                    ctx.emit(&RunEvent::TaskBanned {
                        conv: anchors[0],
                        reason: "accuracy_gate".to_string(),
                    });
                    break; // a bigger prune would only be less accurate
                }
                if a_s <= cfg.target_accuracy {
                    // Accepting would blow the budget a_g: stop here.
                    ctx.emit(&RunEvent::IterationRejected {
                        iteration: iter_no + 1,
                        latency: l_m,
                        latency_target: l_t,
                        short_accuracy: Some(a_s),
                        accuracy_gate: Some(cfg.target_accuracy),
                        reason: RejectReason::AccuracyBudget,
                    });
                    break 'outer;
                }

                // -- Line 13: accept ----------------------------------------
                state = cand_state;
                weights = cand_weights;
                graph = cand_graph;
                table = cand.table;
                ctx.emit(&RunEvent::IterationAccepted {
                    iteration: iter_no + 1,
                    latency: l_m,
                    latency_target: l_t,
                    short_accuracy: a_s,
                    accuracy_gate: cfg.alpha * a_p,
                    filters_removed: removed_total,
                    scheme: None,
                });
                // The journal barrier below records the gates this
                // candidate was judged against — capture them before the
                // line-14 updates move the targets.
                let accepted_target = l_t;
                let accepted_gate = cfg.alpha * a_p;
                l_t = cfg.beta * l_m;
                a_p = a_s;
                // Snapshot the accepted candidate as a deployable
                // checkpoint; the frontier keeps it iff non-dominated.
                let accepted_checkpoint = Checkpoint {
                    iteration: iter_no + 1,
                    latency: l_m,
                    accuracy: a_s,
                    channels: state.cout.clone(),
                    schemes: std::collections::BTreeMap::new(),
                };
                ctx.emit(&RunEvent::CheckpointEmitted {
                    checkpoint: accepted_checkpoint.clone(),
                });
                // Recovery barrier (DESIGN.md §15): fsync the accepted
                // iteration + tune-cache delta into the run journal.
                ctx.journal_accept(crate::run::journal::IterationRecord {
                    iteration: iter_no + 1,
                    latency: l_m,
                    latency_target: accepted_target,
                    short_accuracy: a_s,
                    accuracy_gate: accepted_gate,
                    filters_removed: removed_total,
                    candidates_tried,
                    checkpoint: accepted_checkpoint.clone(),
                });
                pareto.insert(accepted_checkpoint);
                iterations.push(IterationLog {
                    iteration: iter_no + 1,
                    pruned_convs: targets.clone(),
                    filters_removed: removed_total,
                    latency: l_m,
                    fps_rate: gate_baseline / l_m,
                    short_accuracy: a_s,
                    candidates_tried,
                });
                accepted = true;
                break;
            }
            if accepted {
                break;
            }
        }
        if !accepted {
            break; // R exhausted (line 2's R = {})
        }
    }
    let main_step_seconds = t0.elapsed().as_secs_f64();

    // -- Line 17: final training + tuning ----------------------------------
    let final_compiled = compiler::compile_tuned(&graph, session, &HashMap::new());
    let final_latency = final_compiled.latency();
    let summary = super::summarize(model, &state, cfg.criterion);
    let final_top1 = ctx.oracle.top1(&summary, TrainPhase::Final);
    let final_top5 = ctx.oracle.top5(&summary, TrainPhase::Final);

    CPruneResult {
        final_graph: graph,
        final_state: state,
        final_table: final_compiled.table.clone(),
        final_latency,
        final_fps: 1.0 / final_latency,
        fps_increase_rate: base_latency / final_latency,
        baseline,
        final_top1,
        final_top5,
        iterations,
        pareto,
        main_step_seconds,
        candidates_tried,
        programs_measured: session.measured_count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::graph::stats;

    fn run(kind: ModelKind, cfg: &CPruneConfig) -> (Model, CPruneResult) {
        let m = Model::build(kind, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut oracle = ProxyOracle::new();
        let r = cprune(&m, &sim, &mut oracle, cfg);
        (m, r)
    }

    #[test]
    fn cprune_speeds_up_resnet8() {
        let cfg = CPruneConfig { max_iterations: 20, ..Default::default() };
        let (_, r) = run(ModelKind::ResNet8Cifar, &cfg);
        assert!(!r.iterations.is_empty(), "no iteration accepted");
        assert!(
            r.fps_increase_rate > 1.1,
            "FPS rate {} too small",
            r.fps_increase_rate
        );
        // latency target chain: each accepted iteration strictly faster
        for w in r.iterations.windows(2) {
            assert!(w[1].latency < w[0].latency);
        }
    }

    #[test]
    fn pruned_model_keeps_accuracy_above_alpha_chain() {
        let cfg = CPruneConfig { max_iterations: 12, ..Default::default() };
        let (m, r) = run(ModelKind::ResNet8Cifar, &cfg);
        let (base, _) = m.kind.base_accuracy();
        for it in &r.iterations {
            assert!(it.short_accuracy <= base);
            assert!(it.short_accuracy > 0.5 * base);
        }
        assert!(r.final_top1 <= base);
    }

    #[test]
    fn flops_shrink_after_pruning() {
        let cfg = CPruneConfig { max_iterations: 15, ..Default::default() };
        let (m, r) = run(ModelKind::ResNet8Cifar, &cfg);
        let (f0, p0) = stats::flops_params(&m.graph);
        let (f1, p1) = stats::flops_params(&r.final_graph);
        assert!(f1 < f0, "FLOPs did not shrink");
        assert!(p1 < p0, "params did not shrink");
    }

    #[test]
    fn accuracy_floor_stops_the_search() {
        // An impossibly high floor → accept nothing.
        let cfg = CPruneConfig {
            target_accuracy: 0.999,
            ..Default::default()
        };
        let (_, r) = run(ModelKind::ResNet8Cifar, &cfg);
        assert!(r.iterations.is_empty());
        assert!((r.fps_increase_rate - 1.0).abs() < 0.35);
    }

    #[test]
    fn pareto_frontier_tracks_accepted_iterations() {
        let cfg = CPruneConfig { max_iterations: 12, ..Default::default() };
        let (m, r) = run(ModelKind::ResNet8Cifar, &cfg);
        // baseline + accepted iterations, minus any dominated points
        assert!(!r.pareto.is_empty());
        assert!(r.pareto.len() <= r.iterations.len() + 1);
        // the frontier's fast end is an accepted candidate, not slower
        // than the final accepted latency chain
        let fastest = r.pareto.fastest().unwrap();
        if let Some(last) = r.iterations.last() {
            assert_eq!(fastest.latency, last.latency);
            assert_eq!(fastest.iteration, last.iteration);
        }
        // the slow end is the unpruned baseline (iteration 0)
        let slow = r.pareto.most_accurate().unwrap();
        assert!(slow.accuracy >= fastest.accuracy);
        // every checkpoint instantiates to a valid deployable graph
        for c in r.pareto.points() {
            let g = c.instantiate(&m).expect("checkpoint must instantiate");
            assert_eq!(g.conv_ids().len(), m.graph.conv_ids().len());
        }
        // non-dominated and sorted in both objectives
        for w in r.pareto.points().windows(2) {
            assert!(w[0].latency < w[1].latency);
            assert!(w[0].accuracy < w[1].accuracy);
        }

        // the floor-blocked search still exposes a one-point frontier
        let strict = CPruneConfig { target_accuracy: 0.999, ..Default::default() };
        let (_, r2) = run(ModelKind::ResNet8Cifar, &strict);
        assert!(r2.iterations.is_empty());
        assert_eq!(r2.pareto.len(), 1);
        assert_eq!(r2.pareto.fastest().unwrap().iteration, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = CPruneConfig { max_iterations: 6, ..Default::default() };
        let (_, a) = run(ModelKind::ResNet8Cifar, &cfg);
        let (_, b) = run(ModelKind::ResNet8Cifar, &cfg);
        assert_eq!(a.iterations.len(), b.iterations.len());
        assert_eq!(a.final_latency, b.final_latency);
        assert_eq!(a.final_state, b.final_state);
    }

    #[test]
    fn warm_started_run_measures_no_new_programs() {
        // The acceptance path for the persistent cache: a deterministic
        // re-run against the previous run's cache hits on every workload
        // (the ≥90%-fewer-measurements criterion, here exactly 100%).
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let cfg = CPruneConfig { max_iterations: 6, ..Default::default() };
        let cold_session = TuningSession::new(&sim, cfg.tune_opts, cfg.seed);
        let mut oracle = ProxyOracle::new();
        let cold = cprune_with_session(&m, &mut oracle, &cfg, &cold_session);
        assert!(cold.programs_measured > 0);
        let warm_session =
            TuningSession::with_cache(&sim, cfg.tune_opts, cfg.seed, cold_session.cache);
        let mut oracle2 = ProxyOracle::new();
        let warm = cprune_with_session(&m, &mut oracle2, &cfg, &warm_session);
        assert_eq!(warm.programs_measured, 0, "warm run re-measured");
        assert_eq!(warm.final_latency, cold.final_latency);
        assert_eq!(warm.iterations.len(), cold.iterations.len());
    }

    #[test]
    fn single_subgraph_ablation_prunes_fewer_filters_per_iter() {
        let assoc_cfg = CPruneConfig { max_iterations: 6, ..Default::default() };
        let single_cfg = CPruneConfig {
            max_iterations: 6,
            associated_subgraphs: false,
            ..Default::default()
        };
        let (_, assoc) = run(ModelKind::Vgg16Cifar, &assoc_cfg);
        let (_, single) = run(ModelKind::Vgg16Cifar, &single_cfg);
        // single-subgraph mode touches exactly one conv per acceptance
        for it in &single.iterations {
            assert_eq!(it.pruned_convs.len(), 1);
        }
        // associated mode prunes all subgraphs of the task at once for
        // multi-subgraph tasks (VGG stages repeat, so they exist)
        assert!(
            assoc.iterations.iter().any(|it| it.pruned_convs.len() > 1),
            "no multi-subgraph task was ever pruned in associated mode"
        );
    }

    #[test]
    fn without_tuning_is_slower_final_model() {
        let tuned_cfg = CPruneConfig { max_iterations: 10, ..Default::default() };
        let untuned_cfg = CPruneConfig {
            max_iterations: 10,
            with_tuning: false,
            ..Default::default()
        };
        let (_, with_tuning) = run(ModelKind::ResNet8Cifar, &tuned_cfg);
        let (_, without) = run(ModelKind::ResNet8Cifar, &untuned_cfg);
        // Table 2: w/o tuning reaches a clearly lower FPS increase rate.
        assert!(
            with_tuning.fps_increase_rate >= without.fps_increase_rate * 0.95,
            "tuned {} vs untuned {}",
            with_tuning.fps_increase_rate,
            without.fps_increase_rate
        );
    }
}
