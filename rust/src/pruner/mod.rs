//! The paper's contribution: CPrune (Algorithm 1) and its support pieces.
//!
//! * task ordering by pruning impact — §3.3 (lives on `relay::TaskTable`);
//! * task ↔ subgraph ↔ program table — §3.4 (`relay::TaskTable`);
//! * iterator-split LCM pruning decision — §3.5
//!   (`tir::Program::min_filter_prune_step`);
//! * the iterative search loop — §3.2 ([`cprune::cprune`]).
//!
//! The search also runs behind the uniform [`crate::run::Pruner`] trait
//! (as [`crate::run::CPrune`]) with a typed event stream; the free
//! functions here are thin shims over [`cprune::cprune_run`]
//! (DESIGN.md §9). [`crate::sparsity::SchemeSelect`] extends the same
//! subgraph-informed loop with per-layer sparsity-scheme selection
//! (pattern/block masks priced by the compiler, DESIGN.md §16).

pub mod cprune;
pub mod report;

pub use cprune::{cprune, cprune_run, cprune_with_session, CPruneConfig, CPruneResult, IterationLog};

use crate::accuracy::{Criterion, LayerPrune, PruneSummary};
use crate::graph::model_zoo::Model;
use crate::graph::ops::OpKind;
use crate::graph::prune::PruneState;

/// Build the oracle-facing summary of a pruning state.
pub fn summarize(model: &Model, state: &PruneState, criterion: Criterion) -> PruneSummary {
    let convs = model.graph.conv_ids();
    let n = convs.len().max(1) as f64;
    let layers = convs
        .iter()
        .enumerate()
        .filter_map(|(pos, &id)| {
            let orig = match model.graph.node(id).op {
                OpKind::Conv2d { cout, .. } => cout,
                _ => return None,
            };
            let remaining = state.cout.get(&id).copied().unwrap_or(orig);
            Some(LayerPrune {
                conv: id,
                original_channels: orig,
                remaining_channels: remaining,
                depth: (pos as f64 + 1.0) / n,
            })
        })
        .collect();
    PruneSummary { model: model.kind, layers, criterion }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::ModelKind;

    #[test]
    fn summarize_covers_every_conv() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let st = PruneState::full(&m);
        let s = summarize(&m, &st, Criterion::L1Norm);
        assert_eq!(s.layers.len(), m.graph.conv_ids().len());
        assert!(s.is_identity());
        // depths ascend in (0, 1]
        for w in s.layers.windows(2) {
            assert!(w[0].depth < w[1].depth);
        }
        assert!(s.layers.last().unwrap().depth <= 1.0);
    }

    #[test]
    fn summarize_reflects_pruning() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let mut st = PruneState::full(&m);
        let conv = m.prunable[0];
        st.shrink(conv, 4);
        let s = summarize(&m, &st, Criterion::L1Norm);
        let l = s.layers.iter().find(|l| l.conv == conv).unwrap();
        assert_eq!(l.original_channels - l.remaining_channels, 4);
        assert!(!s.is_identity());
    }
}
