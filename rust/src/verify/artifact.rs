//! ArtifactCheck: deep validation of every versioned JSON document the
//! project persists (DESIGN.md §13).
//!
//! [`check_text`] recognizes a document by its `format` tag and
//! dispatches to a per-format checker. Each checker verifies the header
//! (CPV120), that every entry parses back into its typed form (CPV121),
//! and the *semantic* invariants the writers guarantee: workload/program
//! keys round-trip byte-identically through [`crate::tir::jsonio`] and
//! entries arrive sorted by their canonical key (CPV122), numeric fields
//! sit inside their domains (CPV123), cached/traced programs are legal
//! for their workloads (CPV110–112 via [`super::program`]), persisted
//! Pareto frontiers are mutually non-dominated and ascending in both
//! objectives (CPV130/131 via [`frontier_diagnostics`]), remote
//! traces carry well-formed jitter samples (CPV150–152, DESIGN.md §14),
//! and sparsity mask sets arrive ordered with internally consistent
//! scheme parameters (CPV170–172, DESIGN.md §16).
//!
//! A document that does not claim a `cprune-*` format is not ours:
//! `check_text` returns `None` and the [`super::sweep`] walker skips it.

use super::program::check_program;
use super::{Code, Diagnostic};
use crate::device::calibration::{CALIBRATION_FORMAT, CALIBRATION_VERSION};
use crate::device::registry::{DEVICES_FORMAT, DEVICES_VERSION};
use crate::device::remote::trace::{REMOTE_TRACE_FORMAT, REMOTE_TRACE_VERSION};
use crate::device::replay::{TRACE_FORMAT, TRACE_VERSION};
use crate::device::DeviceSpec;
use crate::perf::{BENCH_FORMAT, BENCH_VERSION};
use crate::run::events::{EVENTS_FORMAT, EVENTS_VERSION};
use crate::run::journal::{JOURNAL_FORMAT, JOURNAL_VERSION};
use crate::serve::{Checkpoint, REGISTRY_FORMAT, REGISTRY_VERSION};
use crate::sparsity::{pattern, Scheme, MASKS_FORMAT, MASKS_VERSION};
use crate::tir::jsonio::{program_from_json, program_to_json, workload_from_json, workload_to_json};
use crate::tuner::cache::{CACHE_FORMAT, CACHE_VERSION};
use crate::util::json::{self, Json};

/// Format tag of `bench/golden-*.json` (written by hand, read by the
/// bench-quick CI job; no Rust struct owns it, so the tag lives here).
pub const BENCH_GOLDEN_FORMAT: &str = "cprune-bench-golden";

/// Every format tag the checker understands. A file that fails to parse
/// is only reported (CPV190) when it mentions one of these — arbitrary
/// foreign JSON is none of our business.
const KNOWN_FORMATS: [&str; 11] = [
    CACHE_FORMAT,
    TRACE_FORMAT,
    REMOTE_TRACE_FORMAT,
    REGISTRY_FORMAT,
    DEVICES_FORMAT,
    CALIBRATION_FORMAT,
    BENCH_FORMAT,
    BENCH_GOLDEN_FORMAT,
    EVENTS_FORMAT,
    JOURNAL_FORMAT,
    MASKS_FORMAT,
];

/// Check a document. `None` = not a cprune artifact; `Some(vec![])` = a
/// recognized, clean artifact.
pub fn check_text(text: &str) -> Option<Vec<Diagnostic>> {
    // Events logs and run journals are JSONL — the whole file is not one
    // JSON value, so recognize them by their header line before
    // whole-document parsing.
    if let Some(line) = text.lines().find(|l| !l.trim().is_empty()) {
        if let Ok(j) = json::parse(line) {
            match j.get("format").and_then(Json::as_str) {
                Some(EVENTS_FORMAT) => return Some(check_events(text)),
                Some(JOURNAL_FORMAT) => return Some(check_journal(text)),
                _ => {}
            }
        }
    }
    match json::parse(text) {
        Ok(j) => {
            let format = j.get("format").and_then(Json::as_str)?.to_string();
            let mut out = Vec::new();
            match format.as_str() {
                CACHE_FORMAT => check_cache(&j, &mut out),
                TRACE_FORMAT => check_trace(&j, &mut out),
                REMOTE_TRACE_FORMAT => check_remote_trace(&j, &mut out),
                REGISTRY_FORMAT => check_registry(&j, &mut out),
                DEVICES_FORMAT => check_devices(&j, &mut out),
                CALIBRATION_FORMAT => check_calibration(&j, &mut out),
                BENCH_FORMAT => check_bench(&j, &mut out),
                BENCH_GOLDEN_FORMAT => check_bench_golden(&j, &mut out),
                MASKS_FORMAT => check_masks(&j, &mut out),
                other if other.starts_with("cprune-") => {
                    out.push(Diagnostic::new(
                        Code::BadHeader,
                        "header",
                        format!(
                            "unrecognized cprune format '{other}' — teach verify::artifact about it"
                        ),
                    ));
                }
                _ => return None,
            }
            Some(out)
        }
        Err(e) => {
            if KNOWN_FORMATS.iter().any(|f| text.contains(f)) {
                Some(vec![Diagnostic::new(
                    Code::CorruptDocument,
                    "document",
                    format!("claims a cprune format but does not parse: {e}"),
                )])
            } else {
                None
            }
        }
    }
}

/// Header version gate shared by every single-document format.
fn check_version(j: &Json, want: u64, out: &mut Vec<Diagnostic>) {
    match j.get("version").and_then(Json::as_usize) {
        Some(v) if v as u64 == want => {}
        other => out.push(Diagnostic::new(
            Code::BadHeader,
            "header",
            format!("unsupported version {other:?} (want {want})"),
        )),
    }
}

/// The document's `entries`-style array, or a CPV120 when absent.
fn doc_array<'j>(j: &'j Json, key: &str, out: &mut Vec<Diagnostic>) -> Option<&'j [Json]> {
    match j.get(key).and_then(Json::as_arr) {
        Some(a) => Some(a),
        None => {
            out.push(Diagnostic::new(
                Code::BadHeader,
                "header",
                format!("missing top-level array '{key}'"),
            ));
            None
        }
    }
}

fn finite_positive(v: f64) -> bool {
    v.is_finite() && v > 0.0
}

/// Emit CPV122 for adjacent canonical keys out of strictly ascending
/// order (the byte-stability contract every writer sorts for; equality
/// means a duplicate key, which a typed map could never have written).
fn check_sorted(keys: &[Option<String>], what: &str, out: &mut Vec<Diagnostic>) {
    for (i, w) in keys.windows(2).enumerate() {
        if let (Some(a), Some(b)) = (&w[0], &w[1]) {
            if a >= b {
                out.push(Diagnostic::new(
                    Code::NonCanonicalKey,
                    format!("{what}[{}]", i + 1),
                    format!("entries not sorted by canonical {what} key"),
                ));
            }
        }
    }
}

/// Parse `e[key]` as a workload/program pair, verifying both parse
/// (CPV121), round-trip canonically (CPV122), and that the program is
/// legal for the workload (nested CPV110–112). Returns the canonical
/// workload/program key strings when both parsed.
fn check_wp_entry(
    e: &Json,
    ctx: &str,
    out: &mut Vec<Diagnostic>,
) -> Option<(String, String)> {
    let wj = match e.get("workload") {
        Some(wj) => wj,
        None => {
            out.push(Diagnostic::new(Code::MalformedEntry, ctx, "missing workload"));
            return None;
        }
    };
    let pj = match e.get("program") {
        Some(pj) => pj,
        None => {
            out.push(Diagnostic::new(Code::MalformedEntry, ctx, "missing program"));
            return None;
        }
    };
    let w = match workload_from_json(wj) {
        Ok(w) => w,
        Err(err) => {
            out.push(Diagnostic::new(Code::MalformedEntry, ctx, format!("workload: {err}")));
            return None;
        }
    };
    let p = match program_from_json(pj) {
        Ok(p) => p,
        Err(err) => {
            out.push(Diagnostic::new(Code::MalformedEntry, ctx, format!("program: {err}")));
            return None;
        }
    };
    let wk = workload_to_json(&w).to_string();
    let pk = program_to_json(&p).to_string();
    if wk != wj.to_string() {
        out.push(Diagnostic::new(
            Code::NonCanonicalKey,
            ctx,
            "workload key does not round-trip canonically through tir::jsonio",
        ));
    }
    if pk != pj.to_string() {
        out.push(Diagnostic::new(
            Code::NonCanonicalKey,
            ctx,
            "program key does not round-trip canonically through tir::jsonio",
        ));
    }
    for d in check_program(&p, &w) {
        out.push(d.nested(ctx));
    }
    Some((wk, pk))
}

/// `cprune-tune-cache` v1 (`TuneCache::to_json`).
fn check_cache(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, CACHE_VERSION, out);
    if j.get("device").and_then(Json::as_str).is_none() {
        out.push(Diagnostic::new(Code::BadHeader, "header", "missing device name"));
    }
    let Some(entries) = doc_array(j, "entries", out) else { return };
    let mut keys = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let ctx = format!("entries[{i}]");
        let key = check_wp_entry(e, &ctx, out).map(|(wk, _)| wk);
        match e.get("latency").and_then(Json::as_f64) {
            Some(lat) if finite_positive(lat) => {}
            Some(lat) => out.push(Diagnostic::new(
                Code::NumericRange,
                &ctx,
                format!("latency {lat} is not finite and positive"),
            )),
            None => out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing latency")),
        }
        if e.get("measured").and_then(Json::as_usize).is_none() {
            out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing measured count"));
        }
        keys.push(key);
    }
    check_sorted(&keys, "entries", out);
}

/// `device` + `noise_sigma` header checks shared by both trace formats;
/// returns the parsed sigma when present (remote jitter-domain checks
/// depend on it).
fn check_trace_header(j: &Json, out: &mut Vec<Diagnostic>) -> Option<f64> {
    match j.get("device") {
        Some(dj) => match DeviceSpec::from_json(dj) {
            Ok(spec) => {
                if spec.to_json().to_string() != dj.to_string() {
                    out.push(Diagnostic::new(
                        Code::NonCanonicalKey,
                        "device",
                        "device spec does not round-trip canonically",
                    ));
                }
            }
            Err(err) => out.push(Diagnostic::new(Code::MalformedEntry, "device", err)),
        },
        None => out.push(Diagnostic::new(Code::BadHeader, "header", "missing device spec")),
    }
    match j.get("noise_sigma").and_then(Json::as_f64) {
        Some(s) if s.is_finite() && s >= 0.0 => Some(s),
        Some(s) => {
            out.push(Diagnostic::new(
                Code::NumericRange,
                "header",
                format!("noise_sigma {s} is not finite and non-negative"),
            ));
            Some(s)
        }
        None => {
            out.push(Diagnostic::new(Code::BadHeader, "header", "missing noise_sigma"));
            None
        }
    }
}

/// The `latencies` array shared by both trace formats.
fn check_latency_entries(j: &Json, out: &mut Vec<Diagnostic>) {
    if let Some(lats) = doc_array(j, "latencies", out) {
        let mut keys = Vec::with_capacity(lats.len());
        for (i, e) in lats.iter().enumerate() {
            let ctx = format!("latencies[{i}]");
            let key = check_wp_entry(e, &ctx, out).map(|(wk, pk)| format!("{wk}|{pk}"));
            match e.get("seconds").and_then(Json::as_f64) {
                Some(s) if finite_positive(s) => {}
                Some(s) => out.push(Diagnostic::new(
                    Code::NumericRange,
                    &ctx,
                    format!("seconds {s} is not finite and positive"),
                )),
                None => out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing seconds")),
            }
            keys.push(key);
        }
        check_sorted(&keys, "latencies", out);
    }
}

/// `cprune-measure-trace` v1 (`ReplayTarget::to_json`).
fn check_trace(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, TRACE_VERSION, out);
    let _ = check_trace_header(j, out);
    check_latency_entries(j, out);
    if let Some(batches) = doc_array(j, "measurements", out) {
        let mut keys = Vec::with_capacity(batches.len());
        for (i, e) in batches.iter().enumerate() {
            let ctx = format!("measurements[{i}]");
            let wp = check_wp_entry(e, &ctx, out);
            let repeats = e.get("repeats").and_then(Json::as_usize);
            match repeats {
                Some(r) if r >= 1 => {}
                Some(r) => out.push(Diagnostic::new(
                    Code::NumericRange,
                    &ctx,
                    format!("repeats {r} must be at least 1"),
                )),
                None => out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing repeats")),
            }
            match e.get("means").and_then(Json::as_arr) {
                Some(means) => {
                    for (k, m) in means.iter().enumerate() {
                        match m.as_f64() {
                            Some(v) if finite_positive(v) => {}
                            Some(v) => out.push(Diagnostic::new(
                                Code::NumericRange,
                                format!("{ctx}.means[{k}]"),
                                format!("mean {v} is not finite and positive"),
                            )),
                            None => out.push(Diagnostic::new(
                                Code::MalformedEntry,
                                format!("{ctx}.means[{k}]"),
                                "non-number mean",
                            )),
                        }
                    }
                }
                None => out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing means")),
            }
            keys.push(match (wp, repeats) {
                (Some((wk, pk)), Some(r)) => Some(format!("{wk}|{pk}|r{r}")),
                _ => None,
            });
        }
        check_sorted(&keys, "measurements", out);
    }
}

/// `cprune-remote-trace` v1 (`RemoteTrace::to_json`, DESIGN.md §14):
/// the measure-trace invariants plus the remote plane's own — a worker
/// count ≥ 1, and per-sample jitter draws that exist (CPV150), match
/// `repeats` in number (CPV151) and sit in the lognormal's domain
/// (CPV152; exactly 1 when the header's noise_sigma is 0).
fn check_remote_trace(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, REMOTE_TRACE_VERSION, out);
    let sigma = check_trace_header(j, out);
    match j.get("workers").and_then(Json::as_usize) {
        Some(n) if n >= 1 => {}
        Some(n) => out.push(Diagnostic::new(
            Code::NumericRange,
            "header",
            format!("workers {n} must be at least 1"),
        )),
        None => out.push(Diagnostic::new(Code::BadHeader, "header", "missing workers")),
    }
    check_latency_entries(j, out);
    let Some(batches) = doc_array(j, "measurements", out) else { return };
    let mut keys = Vec::with_capacity(batches.len());
    for (i, e) in batches.iter().enumerate() {
        let ctx = format!("measurements[{i}]");
        let wp = check_wp_entry(e, &ctx, out);
        let repeats = e.get("repeats").and_then(Json::as_usize);
        match repeats {
            Some(r) if r >= 1 => {}
            Some(r) => out.push(Diagnostic::new(
                Code::NumericRange,
                &ctx,
                format!("repeats {r} must be at least 1"),
            )),
            None => out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing repeats")),
        }
        match e.get("samples").and_then(Json::as_arr) {
            Some(samples) => {
                for (k, s) in samples.iter().enumerate() {
                    check_remote_sample(s, &format!("{ctx}.samples[{k}]"), repeats, sigma, out);
                }
            }
            None => out.push(Diagnostic::new(Code::RemoteEntry, &ctx, "missing samples")),
        }
        keys.push(match (wp, repeats) {
            (Some((wk, pk)), Some(r)) => Some(format!("{wk}|{pk}|r{r}")),
            _ => None,
        });
    }
    check_sorted(&keys, "measurements", out);
}

/// One remote-trace sample: structure (CPV150), jitter arity (CPV151),
/// jitter domain (CPV152) and mean range (CPV123).
fn check_remote_sample(
    s: &Json,
    ctx: &str,
    repeats: Option<usize>,
    sigma: Option<f64>,
    out: &mut Vec<Diagnostic>,
) {
    match s.get("jitter").and_then(Json::as_arr) {
        Some(draws) => {
            if let Some(r) = repeats {
                if draws.len() != r {
                    out.push(Diagnostic::new(
                        Code::RemoteJitterArity,
                        ctx,
                        format!("{} jitter draws for repeats {r}", draws.len()),
                    ));
                }
            }
            for (d, v) in draws.iter().enumerate() {
                match v.as_f64() {
                    Some(x) if finite_positive(x) => {
                        // lognormal(0.0) is exactly 1, so a sigma-0 trace
                        // with any other draw was not written by our client
                        if sigma == Some(0.0) && x != 1.0 {
                            out.push(Diagnostic::new(
                                Code::RemoteJitterRange,
                                format!("{ctx}.jitter[{d}]"),
                                format!("jitter {x} with noise_sigma 0 must be exactly 1"),
                            ));
                        }
                    }
                    Some(x) => out.push(Diagnostic::new(
                        Code::RemoteJitterRange,
                        format!("{ctx}.jitter[{d}]"),
                        format!("jitter {x} is not finite and positive"),
                    )),
                    None => out.push(Diagnostic::new(
                        Code::RemoteEntry,
                        format!("{ctx}.jitter[{d}]"),
                        "non-number jitter draw",
                    )),
                }
            }
        }
        None => out.push(Diagnostic::new(Code::RemoteEntry, ctx, "missing jitter")),
    }
    match s.get("mean").and_then(Json::as_f64) {
        Some(m) if finite_positive(m) => {}
        Some(m) => out.push(Diagnostic::new(
            Code::NumericRange,
            ctx,
            format!("mean {m} is not finite and positive"),
        )),
        None => out.push(Diagnostic::new(Code::RemoteEntry, ctx, "missing mean")),
    }
}

/// `cprune-pareto-registry` v1 (`Registry::to_json`).
fn check_registry(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, REGISTRY_VERSION, out);
    let Some(entries) = doc_array(j, "entries", out) else { return };
    let mut keys = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let ctx = format!("entries[{i}]");
        let model = e.get("model").and_then(Json::as_str);
        let device = e.get("device").and_then(Json::as_str);
        if model.is_none() {
            out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing model"));
        }
        if device.is_none() {
            out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing device"));
        }
        keys.push(match (model, device) {
            (Some(m), Some(d)) => Some(format!("{m}\u{0}{d}")),
            _ => None,
        });
        let Some(points) = e.get("pareto").and_then(|p| p.get("points")).and_then(Json::as_arr)
        else {
            out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing pareto points"));
            continue;
        };
        let mut frontier = Vec::with_capacity(points.len());
        for (k, pj) in points.iter().enumerate() {
            match Checkpoint::from_json(pj) {
                Ok(cp) => {
                    if cp.to_json().to_string() != pj.to_string() {
                        out.push(Diagnostic::new(
                            Code::NonCanonicalKey,
                            format!("{ctx}.points[{k}]"),
                            "checkpoint does not round-trip canonically",
                        ));
                    }
                    frontier.push(cp);
                }
                Err(err) => {
                    out.push(Diagnostic::new(
                        Code::MalformedEntry,
                        format!("{ctx}.points[{k}]"),
                        err,
                    ));
                }
            }
        }
        for d in frontier_diagnostics(&frontier) {
            out.push(d.nested(&ctx));
        }
    }
    check_sorted(&keys, "entries", out);
}

/// The [`crate::serve::ParetoSet`] invariant over a slice of persisted
/// checkpoints: every objective in range (CPV123), no dominated or
/// duplicate point (CPV130), strictly ascending latency *and* accuracy
/// (CPV131). Shared by the registry checker, the strict
/// `ParetoSet::from_json`, and the frontier mutation `debug_assert`s.
pub fn frontier_diagnostics(points: &[Checkpoint]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (i, c) in points.iter().enumerate() {
        if !finite_positive(c.latency) {
            out.push(Diagnostic::new(
                Code::NumericRange,
                format!("points[{i}]"),
                format!("latency {} is not finite and positive", c.latency),
            ));
        }
        if !c.accuracy.is_finite() || !(0.0..=1.0).contains(&c.accuracy) {
            out.push(Diagnostic::new(
                Code::NumericRange,
                format!("points[{i}]"),
                format!("accuracy {} outside [0, 1]", c.accuracy),
            ));
        }
    }
    if !out.is_empty() {
        // Dominance over NaN/absurd objectives produces noise, not signal.
        return out;
    }
    for (i, a) in points.iter().enumerate() {
        for (k, b) in points.iter().enumerate().skip(i + 1) {
            if a.dominates(b) || b.dominates(a) {
                out.push(Diagnostic::new(
                    Code::FrontierDominated,
                    format!("points[{k}]"),
                    format!("dominated pair: points[{i}] and points[{k}]"),
                ));
            } else if a.latency == b.latency && a.accuracy == b.accuracy {
                out.push(Diagnostic::new(
                    Code::FrontierDominated,
                    format!("points[{k}]"),
                    format!("duplicate objectives: points[{i}] and points[{k}]"),
                ));
            }
        }
    }
    for (i, w) in points.windows(2).enumerate() {
        if w[0].latency >= w[1].latency || w[0].accuracy >= w[1].accuracy {
            out.push(Diagnostic::new(
                Code::FrontierOrder,
                format!("points[{}]", i + 1),
                "frontier not strictly ascending in latency and accuracy",
            ));
        }
    }
    out
}

/// `cprune-devices` v1 (`TargetRegistry::load_str` input).
fn check_devices(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, DEVICES_VERSION, out);
    let Some(devices) = doc_array(j, "devices", out) else { return };
    for (i, e) in devices.iter().enumerate() {
        let ctx = format!("devices[{i}]");
        if let Err(err) = DeviceSpec::from_json(e) {
            out.push(Diagnostic::new(Code::MalformedEntry, &ctx, err));
        }
        if let Some(short) = e.get("short") {
            if short.as_str().is_none() {
                out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "non-string short name"));
            }
        }
    }
}

/// `cprune-calibration` v1 (`CalibrationTable::to_json`).
fn check_calibration(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, CALIBRATION_VERSION, out);
    let Some(entries) = doc_array(j, "entries", out) else { return };
    for (i, e) in entries.iter().enumerate() {
        let ctx = format!("entries[{i}]");
        if e.get("device").and_then(Json::as_str).is_none() {
            out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing device"));
        }
        match e.get("scale").and_then(Json::as_f64) {
            Some(s) if finite_positive(s) => {}
            Some(s) => out.push(Diagnostic::new(
                Code::NumericRange,
                &ctx,
                format!("scale {s} is not finite and positive"),
            )),
            None => out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing scale")),
        }
        match e.get("residual").and_then(Json::as_f64) {
            Some(r) if r.is_finite() => {}
            Some(r) => out.push(Diagnostic::new(
                Code::NumericRange,
                &ctx,
                format!("residual {r} is not finite"),
            )),
            None => out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing residual")),
        }
    }
}

/// `cprune-bench` v1 (`PerfReport::to_json`).
fn check_bench(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, BENCH_VERSION, out);
    if j.get("suite").and_then(Json::as_str).is_none() {
        out.push(Diagnostic::new(Code::BadHeader, "header", "missing suite"));
    }
    match j.get("tier").and_then(Json::as_str) {
        Some("quick" | "full") => {}
        other => out.push(Diagnostic::new(
            Code::BadHeader,
            "header",
            format!("tier {other:?} is not 'quick' or 'full'"),
        )),
    }
    if j.get("seed").and_then(Json::as_usize).is_none() {
        out.push(Diagnostic::new(Code::BadHeader, "header", "missing seed"));
    }
    let Some(records) = doc_array(j, "records", out) else { return };
    for (i, r) in records.iter().enumerate() {
        let ctx = format!("records[{i}]");
        if r.get("name").and_then(Json::as_str).is_none() {
            out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing name"));
        }
        match r.get("wall_s").and_then(Json::as_f64) {
            Some(w) if w.is_finite() && w >= 0.0 => {}
            Some(w) => out.push(Diagnostic::new(
                Code::NumericRange,
                &ctx,
                format!("wall_s {w} is not finite and non-negative"),
            )),
            None => out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing wall_s")),
        }
        if r.get("programs_measured").and_then(Json::as_usize).is_none() {
            out.push(Diagnostic::new(Code::MalformedEntry, &ctx, "missing programs_measured"));
        }
        if let Json::Obj(m) = r {
            for (k, v) in m {
                if k != "name" && v.as_f64().map(|n| !n.is_finite()).unwrap_or(false) {
                    out.push(Diagnostic::new(
                        Code::NumericRange,
                        format!("{ctx}.{k}"),
                        "non-finite metric",
                    ));
                }
            }
        }
    }
}

/// `cprune-bench-golden` v1 (`bench/golden-*.json`; hand-maintained).
fn check_bench_golden(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, 1, out);
    if j.get("pinned").and_then(Json::as_bool).is_none() {
        out.push(Diagnostic::new(Code::BadHeader, "header", "missing boolean 'pinned'"));
    }
    let Json::Obj(m) = j else { return };
    for (key, v) in m {
        if matches!(key.as_str(), "format" | "version" | "pinned" | "note") {
            continue;
        }
        let ctx = key.as_str();
        let Some(rows) = v.as_arr() else {
            out.push(Diagnostic::new(Code::MalformedEntry, ctx, "suite entry is not an array"));
            continue;
        };
        for (i, row) in rows.iter().enumerate() {
            let ok = matches!(
                row.as_arr(),
                Some([name, count])
                    if name.as_str().is_some()
                        && (matches!(count, Json::Null) || count.as_usize().is_some())
            );
            if !ok {
                out.push(Diagnostic::new(
                    Code::MalformedEntry,
                    format!("{ctx}[{i}]"),
                    "expected a [record-name, count-or-null] pair",
                ));
            }
        }
    }
}

/// `cprune-sparsity-masks` v1 (`MaskSet::save` output, DESIGN.md §16):
/// entries strictly ascending by conv id with the exact field set
/// (CPV170), densities inside (0, 1] (CPV171), and scheme/params pairs
/// that are internally consistent (CPV172) — pattern params are
/// ascending indexes into the fixed pattern library, block params are a
/// `[keep, group]` shape with `0 < keep < group`, and channel masks
/// carry no params at all.
fn check_masks(j: &Json, out: &mut Vec<Diagnostic>) {
    check_version(j, MASKS_VERSION, out);
    let masks = match doc_array(j, "masks", out) {
        Some(m) => m,
        None => return,
    };
    let mut last_conv: Option<usize> = None;
    for (i, e) in masks.iter().enumerate() {
        let ctx = format!("masks[{i}]");
        let obj = match e {
            Json::Obj(m) => m,
            _ => {
                out.push(Diagnostic::new(Code::MaskEntry, &ctx, "entry is not an object"));
                continue;
            }
        };
        for key in obj.keys() {
            if !matches!(key.as_str(), "conv" | "density" | "params" | "scheme") {
                out.push(Diagnostic::new(
                    Code::MaskEntry,
                    &ctx,
                    format!("unexpected field '{key}'"),
                ));
            }
        }
        match e.get("conv").and_then(Json::as_usize) {
            Some(conv) => {
                if let Some(prev) = last_conv {
                    if conv <= prev {
                        out.push(Diagnostic::new(
                            Code::MaskEntry,
                            &ctx,
                            format!("conv {conv} does not ascend past {prev}"),
                        ));
                    }
                }
                last_conv = Some(conv);
            }
            None => out.push(Diagnostic::new(Code::MaskEntry, &ctx, "missing conv id")),
        }
        match e.get("density").and_then(Json::as_f64) {
            Some(d) if d.is_finite() && d > 0.0 && d <= 1.0 => {}
            Some(d) => out.push(Diagnostic::new(
                Code::MaskDensity,
                &ctx,
                format!("density {d} is outside (0, 1]"),
            )),
            None => out.push(Diagnostic::new(Code::MaskDensity, &ctx, "missing density")),
        }
        let params: Vec<usize> = match e.get("params").and_then(Json::as_arr) {
            Some(a) => {
                let parsed: Vec<Option<usize>> = a.iter().map(Json::as_usize).collect();
                if parsed.iter().any(Option::is_none) {
                    out.push(Diagnostic::new(
                        Code::MaskEntry,
                        &ctx,
                        "params must be non-negative integers",
                    ));
                    continue;
                }
                parsed.into_iter().flatten().collect()
            }
            None => {
                out.push(Diagnostic::new(Code::MaskEntry, &ctx, "missing params array"));
                continue;
            }
        };
        match e.get("scheme").and_then(Json::as_str) {
            Some("channel") => {
                if !params.is_empty() {
                    out.push(Diagnostic::new(
                        Code::MaskScheme,
                        &ctx,
                        "channel masks carry no params",
                    ));
                }
            }
            Some("pattern") => {
                let ascending = params.windows(2).all(|w| w[0] < w[1]);
                if params.is_empty()
                    || !ascending
                    || params.iter().any(|&p| p >= pattern::PATTERNS.len())
                {
                    out.push(Diagnostic::new(
                        Code::MaskScheme,
                        &ctx,
                        format!(
                            "pattern params {params:?} must be ascending indexes into the \
                             {}-entry pattern library",
                            pattern::PATTERNS.len()
                        ),
                    ));
                }
            }
            Some("block") => {
                if params.len() != 2 || params[0] == 0 || params[0] >= params[1] {
                    out.push(Diagnostic::new(
                        Code::MaskScheme,
                        &ctx,
                        format!("block params {params:?} must be [keep, group] with 0 < keep < group"),
                    ));
                }
            }
            Some(other) => out.push(Diagnostic::new(
                Code::MaskScheme,
                &ctx,
                format!("unknown scheme '{other}'"),
            )),
            None => out.push(Diagnostic::new(Code::MaskScheme, &ctx, "missing scheme name")),
        }
    }
}

/// `cprune-run-events` v1 JSONL (`JsonlSink` output): a header line then
/// one event object per line, each matching its kind's exact field set.
fn check_events(text: &str) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    match lines.next() {
        Some((_, header)) => match json::parse(header) {
            Ok(h) => {
                match h.get("format").and_then(Json::as_str) {
                    Some(EVENTS_FORMAT) => {}
                    other => out.push(Diagnostic::new(
                        Code::BadHeader,
                        "line 1",
                        format!("not an events header (format {other:?})"),
                    )),
                }
                match h.get("version").and_then(Json::as_usize) {
                    Some(v) if v as u64 == EVENTS_VERSION => {}
                    other => out.push(Diagnostic::new(
                        Code::BadHeader,
                        "line 1",
                        format!("unsupported events version {other:?} (want {EVENTS_VERSION})"),
                    )),
                }
            }
            Err(e) => {
                out.push(Diagnostic::new(Code::CorruptDocument, "line 1", e));
                return out;
            }
        },
        None => {
            out.push(Diagnostic::new(Code::BadHeader, "line 1", "empty events log"));
            return out;
        }
    }
    for (idx, line) in lines {
        let ctx = format!("line {}", idx + 1);
        let ev = match json::parse(line) {
            Ok(ev) => ev,
            Err(e) => {
                out.push(Diagnostic::new(Code::EventSchema, &ctx, format!("unparseable: {e}")));
                continue;
            }
        };
        check_event_line(&ev, &ctx, &mut out);
    }
    out
}

/// Per-kind required field names and their value shapes, mirroring
/// `RunEvent::to_json` exactly (the golden-file contract).
fn check_event_line(ev: &Json, ctx: &str, out: &mut Vec<Diagnostic>) {
    #[derive(Clone, Copy)]
    enum F {
        Num,
        NumOrNull,
        Str,
        Reason,
        Checkpoint,
    }
    let kind = match ev.get("event").and_then(Json::as_str) {
        Some(k) => k,
        None => {
            out.push(Diagnostic::new(Code::EventSchema, ctx, "missing 'event' kind tag"));
            return;
        }
    };
    let fields: &[(&str, F)] = match kind {
        "baseline_tuned" => &[("latency", F::Num), ("fps", F::Num)],
        "candidate_measured" => &[
            ("iteration", F::Num),
            ("latency", F::Num),
            ("latency_target", F::Num),
            ("candidates_tried", F::Num),
        ],
        "iteration_accepted" => &[
            ("iteration", F::Num),
            ("latency", F::Num),
            ("latency_target", F::Num),
            ("short_accuracy", F::Num),
            ("accuracy_gate", F::Num),
            ("filters_removed", F::Num),
        ],
        "iteration_rejected" => &[
            ("iteration", F::Num),
            ("latency", F::Num),
            ("latency_target", F::Num),
            ("short_accuracy", F::NumOrNull),
            ("accuracy_gate", F::NumOrNull),
            ("reason", F::Reason),
        ],
        "task_banned" => &[("conv", F::Num), ("reason", F::Str)],
        "checkpoint_emitted" => &[("checkpoint", F::Checkpoint)],
        "finished" => &[
            ("pruner", F::Str),
            ("method", F::Str),
            ("model", F::Str),
            ("device", F::Str),
            ("final_latency", F::Num),
            ("final_fps", F::Num),
            ("fps_increase_rate", F::Num),
            ("top1", F::Num),
            ("top5", F::Num),
            ("macs", F::Num),
            ("params", F::Num),
            ("iterations", F::Num),
            ("search_candidates", F::Num),
            ("pareto_points", F::Num),
        ],
        other => {
            out.push(Diagnostic::new(
                Code::EventSchema,
                ctx,
                format!("unknown event kind '{other}'"),
            ));
            return;
        }
    };
    for (name, shape) in fields {
        let v = match ev.get(name) {
            Some(v) => v,
            None => {
                out.push(Diagnostic::new(
                    Code::EventSchema,
                    ctx,
                    format!("{kind} missing field '{name}'"),
                ));
                continue;
            }
        };
        let ok = match shape {
            F::Num => v.as_f64().is_some(),
            F::NumOrNull => v.as_f64().is_some() || matches!(v, Json::Null),
            F::Str => v.as_str().is_some(),
            F::Reason => matches!(
                v.as_str(),
                Some("latency_gate" | "accuracy_gate" | "accuracy_budget")
            ),
            F::Checkpoint => match Checkpoint::from_json(v) {
                Ok(_) => true,
                Err(e) => {
                    out.push(Diagnostic::new(
                        Code::EventSchema,
                        ctx,
                        format!("checkpoint: {e}"),
                    ));
                    continue;
                }
            },
        };
        if !ok {
            out.push(Diagnostic::new(
                Code::EventSchema,
                ctx,
                format!("{kind} field '{name}' has the wrong shape"),
            ));
        }
    }
    // `scheme` is an optional extension on the two measurement events:
    // absent on channel-only runs (the v1 golden logs), a known scheme
    // name when a sparsity-aware pruner emitted the line.
    let scheme_ok = matches!(kind, "candidate_measured" | "iteration_accepted");
    if scheme_ok {
        if let Some(v) = ev.get("scheme") {
            if v.as_str().and_then(Scheme::from_name).is_none() {
                out.push(Diagnostic::new(
                    Code::EventSchema,
                    ctx,
                    format!("{kind} field 'scheme' is not a known scheme name"),
                ));
            }
        }
    }
    if let Json::Obj(m) = ev {
        for key in m.keys() {
            if key == "event" || (scheme_ok && key == "scheme") {
                continue;
            }
            if !fields.iter().any(|(name, _)| *name == key.as_str()) {
                out.push(Diagnostic::new(
                    Code::EventSchema,
                    ctx,
                    format!("{kind} has unexpected field '{key}'"),
                ));
            }
        }
    }
}

/// `cprune-run-journal` v1 JSONL (`RunJournal` output, DESIGN.md §15):
/// a header line, a `config` record, then `baseline` / `iteration` /
/// `resumed` records in order, optionally ending with `finished`. The
/// checker is deliberately strict about torn tails — a journal
/// interrupted mid-append flags CPV160 until `cprune run --resume`
/// truncates it; committed golden journals are always complete.
fn check_journal(text: &str) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let mut lines = text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty());
    match lines.next() {
        Some((_, header)) => match json::parse(header) {
            Ok(h) => {
                match h.get("format").and_then(Json::as_str) {
                    Some(JOURNAL_FORMAT) => {}
                    other => out.push(Diagnostic::new(
                        Code::BadHeader,
                        "line 1",
                        format!("not a journal header (format {other:?})"),
                    )),
                }
                match h.get("version").and_then(Json::as_usize) {
                    Some(v) if v as u64 == JOURNAL_VERSION => {}
                    other => out.push(Diagnostic::new(
                        Code::BadHeader,
                        "line 1",
                        format!("unsupported journal version {other:?} (want {JOURNAL_VERSION})"),
                    )),
                }
            }
            Err(e) => {
                out.push(Diagnostic::new(Code::CorruptDocument, "line 1", e));
                return out;
            }
        },
        None => {
            out.push(Diagnostic::new(Code::BadHeader, "line 1", "empty journal"));
            return out;
        }
    }
    let mut seen_config = false;
    let mut seen_baseline = false;
    let mut finished = false;
    let mut last_iteration = 0usize;
    for (idx, line) in lines {
        let ctx = format!("line {}", idx + 1);
        let rec = match json::parse(line) {
            Ok(r) => r,
            Err(e) => {
                out.push(Diagnostic::new(
                    Code::JournalRecord,
                    &ctx,
                    format!("unparseable record (torn tail?): {e}"),
                ));
                continue;
            }
        };
        let kind = check_journal_record(&rec, &ctx, &mut out);
        if finished {
            out.push(Diagnostic::new(Code::JournalSequence, &ctx, "record after 'finished'"));
        }
        if !seen_config && kind != Some("config") {
            out.push(Diagnostic::new(
                Code::JournalSequence,
                &ctx,
                "record before the config record",
            ));
        }
        match kind {
            Some("config") => {
                if seen_config {
                    out.push(Diagnostic::new(
                        Code::JournalSequence,
                        &ctx,
                        "duplicate config record",
                    ));
                }
                seen_config = true;
            }
            Some("baseline") => {
                if seen_baseline {
                    out.push(Diagnostic::new(
                        Code::JournalSequence,
                        &ctx,
                        "duplicate baseline record",
                    ));
                }
                seen_baseline = true;
            }
            Some("iteration") => {
                if !seen_baseline {
                    out.push(Diagnostic::new(
                        Code::JournalSequence,
                        &ctx,
                        "iteration record before the baseline record",
                    ));
                }
                if let Some(n) = rec.get("iteration").and_then(Json::as_usize) {
                    if n <= last_iteration {
                        out.push(Diagnostic::new(
                            Code::JournalSequence,
                            &ctx,
                            format!("iteration {n} does not increase past {last_iteration}"),
                        ));
                    }
                    last_iteration = n;
                }
            }
            Some("finished") => finished = true,
            _ => {} // resumed has no ordering constraint; unknown already flagged
        }
    }
    if !seen_config {
        out.push(Diagnostic::new(Code::JournalSequence, "document", "no config record"));
    }
    out
}

/// One journal record: kind tag, exact field set (CPV160), and — for
/// `baseline`/`iteration` — the embedded tune-cache delta (CPV162).
/// Returns the record kind when the tag parsed.
fn check_journal_record<'j>(
    rec: &'j Json,
    ctx: &str,
    out: &mut Vec<Diagnostic>,
) -> Option<&'j str> {
    #[derive(Clone, Copy)]
    enum F {
        Num,
        NumOrNull,
        Str,
        Checkpoint,
        CacheArr,
    }
    let kind = match rec.get("record").and_then(Json::as_str) {
        Some(k) => k,
        None => {
            out.push(Diagnostic::new(Code::JournalRecord, ctx, "missing 'record' kind tag"));
            return None;
        }
    };
    let fields: &[(&str, F)] = match kind {
        "config" => &[
            ("seed", F::Num),
            ("pruner", F::Str),
            ("model", F::Str),
            ("device", F::Str),
            ("iters", F::Num),
            ("target_acc", F::NumOrNull),
        ],
        "baseline" => {
            &[("latency", F::Num), ("fps", F::Num), ("events", F::Num), ("cache", F::CacheArr)]
        }
        "iteration" => &[
            ("iteration", F::Num),
            ("latency", F::Num),
            ("latency_target", F::Num),
            ("short_accuracy", F::Num),
            ("accuracy_gate", F::Num),
            ("filters_removed", F::Num),
            ("candidates_tried", F::Num),
            ("checkpoint", F::Checkpoint),
            ("programs_measured", F::Num),
            ("events", F::Num),
            ("cache", F::CacheArr),
        ],
        "resumed" => &[("from_iteration", F::Num)],
        "finished" => &[("events", F::Num)],
        other => {
            out.push(Diagnostic::new(
                Code::JournalRecord,
                ctx,
                format!("unknown record kind '{other}'"),
            ));
            return Some(kind);
        }
    };
    for (name, shape) in fields {
        let v = match rec.get(name) {
            Some(v) => v,
            None => {
                out.push(Diagnostic::new(
                    Code::JournalRecord,
                    ctx,
                    format!("{kind} missing field '{name}'"),
                ));
                continue;
            }
        };
        let ok = match shape {
            F::Num => v.as_f64().is_some(),
            F::NumOrNull => v.as_f64().is_some() || matches!(v, Json::Null),
            F::Str => v.as_str().is_some(),
            F::Checkpoint => match Checkpoint::from_json(v) {
                Ok(_) => true,
                Err(e) => {
                    out.push(Diagnostic::new(
                        Code::JournalRecord,
                        ctx,
                        format!("checkpoint: {e}"),
                    ));
                    continue;
                }
            },
            F::CacheArr => match v.as_arr() {
                Some(entries) => {
                    check_journal_cache_delta(entries, &format!("{ctx}.cache"), out);
                    true
                }
                None => false,
            },
        };
        if !ok {
            out.push(Diagnostic::new(
                Code::JournalRecord,
                ctx,
                format!("{kind} field '{name}' has the wrong shape"),
            ));
        }
    }
    if let Json::Obj(m) = rec {
        for key in m.keys() {
            if key != "record" && !fields.iter().any(|(name, _)| *name == key.as_str()) {
                out.push(Diagnostic::new(
                    Code::JournalRecord,
                    ctx,
                    format!("{kind} has unexpected field '{key}'"),
                ));
            }
        }
    }
    Some(kind)
}

/// A journaled tune-cache delta: each entry carries the same invariants
/// as a `cprune-tune-cache` entry (parse, canonical round-trip, legal
/// program, positive latency, sorted by workload key), all reported as
/// CPV162 so a finding names the journal layer it sits in.
fn check_journal_cache_delta(entries: &[Json], ctx: &str, out: &mut Vec<Diagnostic>) {
    let mut inner = Vec::new();
    let mut keys = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let ectx = format!("{ctx}[{i}]");
        let key = check_wp_entry(e, &ectx, &mut inner).map(|(wk, _)| wk);
        match e.get("latency").and_then(Json::as_f64) {
            Some(lat) if finite_positive(lat) => {}
            Some(lat) => inner.push(Diagnostic::new(
                Code::NumericRange,
                &ectx,
                format!("latency {lat} is not finite and positive"),
            )),
            None => inner.push(Diagnostic::new(Code::MalformedEntry, &ectx, "missing latency")),
        }
        if e.get("measured").and_then(Json::as_usize).is_none() {
            inner.push(Diagnostic::new(Code::MalformedEntry, &ectx, "missing measured count"));
        }
        keys.push(key);
    }
    check_sorted(&keys, ctx, &mut inner);
    for mut d in inner {
        d.code = Code::JournalCacheEntry;
        out.push(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::{ParetoSet, Registry};
    use crate::tir::{Program, Workload};
    use crate::tuner::TuneCache;
    use std::collections::BTreeMap;

    fn wl(ff: usize) -> Workload {
        use crate::graph::ops::OpKind;
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec!["bn", "relu"],
        )
    }

    fn cp(iteration: usize, latency: f64, accuracy: f64) -> Checkpoint {
        Checkpoint {
            iteration,
            latency,
            accuracy,
            channels: BTreeMap::new(),
            schemes: BTreeMap::new(),
        }
    }

    fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code.id()).collect()
    }

    #[test]
    fn clean_cache_registry_and_foreign_json() {
        let cache = TuneCache::new();
        cache.put(wl(128), Program::naive(&wl(128)), 0.001, 5);
        let text = cache.to_json("devA").to_string();
        assert_eq!(check_text(&text), Some(vec![]));

        let mut reg = Registry::new();
        let mut set = ParetoSet::new();
        set.insert(cp(0, 0.010, 0.93));
        set.insert(cp(2, 0.004, 0.91));
        reg.publish("m", "d", &set);
        assert_eq!(check_text(&reg.to_json().to_string()), Some(vec![]));

        assert_eq!(check_text(r#"{"hello": "world"}"#), None);
        assert_eq!(check_text("not json at all"), None);
    }

    #[test]
    fn truncated_cprune_document_is_cpv190() {
        let diags = check_text(r#"{"format":"cprune-tune-cache","version":1,"#).unwrap();
        assert_eq!(ids(&diags), ["CPV190"]);
    }

    #[test]
    fn non_canonical_workload_key_is_cpv122() {
        let cache = TuneCache::new();
        cache.put(wl(64), Program::naive(&wl(64)), 0.001, 5);
        let text = cache.to_json("devA").to_string();
        // 64 → 64.5: as_usize truncates back to 64, so the file parses
        // fine but its key no longer matches its canonical serialization.
        let broken = text.replace("\"ff\":64", "\"ff\":64.5");
        assert_ne!(text, broken);
        let diags = check_text(&broken).unwrap();
        assert!(ids(&diags).contains(&"CPV122"), "{diags:?}");
    }

    #[test]
    fn dominated_frontier_point_is_cpv130_and_order_break_cpv131() {
        // dominated: same accuracy, slower
        let d = frontier_diagnostics(&[cp(0, 0.004, 0.91), cp(1, 0.010, 0.91)]);
        assert_eq!(ids(&d), ["CPV130", "CPV131"]);
        // out of order but mutually non-dominated
        let d = frontier_diagnostics(&[cp(0, 0.010, 0.93), cp(1, 0.004, 0.91)]);
        assert_eq!(ids(&d), ["CPV131"]);
        // clean
        assert!(frontier_diagnostics(&[cp(0, 0.004, 0.91), cp(1, 0.010, 0.93)]).is_empty());
        // range problems mask dominance noise
        let d = frontier_diagnostics(&[cp(0, -1.0, 0.91)]);
        assert_eq!(ids(&d), ["CPV123"]);
    }

    #[test]
    fn events_log_schema_violations_are_cpv140() {
        let good = "{\"format\":\"cprune-run-events\",\"version\":1}\n\
                    {\"event\":\"baseline_tuned\",\"fps\":4,\"latency\":0.25}\n";
        assert_eq!(check_text(good), Some(vec![]));
        let bad_kind = "{\"format\":\"cprune-run-events\",\"version\":1}\n\
                        {\"event\":\"warp_core_breach\"}\n";
        assert_eq!(ids(&check_text(bad_kind).unwrap()), ["CPV140"]);
        let missing_field = "{\"format\":\"cprune-run-events\",\"version\":1}\n\
                             {\"event\":\"baseline_tuned\",\"fps\":4}\n";
        assert_eq!(ids(&check_text(missing_field).unwrap()), ["CPV140"]);
        let bad_reason = "{\"format\":\"cprune-run-events\",\"version\":1}\n\
            {\"event\":\"iteration_rejected\",\"iteration\":1,\"latency\":0.5,\
             \"latency_target\":0.25,\"short_accuracy\":null,\"accuracy_gate\":null,\
             \"reason\":\"vibes\"}\n";
        assert_eq!(ids(&check_text(bad_reason).unwrap()), ["CPV140"]);
    }

    #[test]
    fn unsorted_cache_entries_are_cpv122() {
        let a = wl(64);
        let b = wl(128);
        let mk = |w: &Workload| {
            Json::obj(vec![
                ("workload", workload_to_json(w)),
                ("program", program_to_json(&Program::naive(w))),
                ("latency", Json::Num(0.001)),
                ("measured", Json::Num(1.0)),
            ])
        };
        let sorted_pair = {
            let mut keys = [workload_to_json(&a).to_string(), workload_to_json(&b).to_string()];
            keys.sort();
            keys
        };
        // deliberately emit in descending canonical-key order
        let (first, second) =
            if workload_to_json(&a).to_string() == sorted_pair[0] { (b, a) } else { (a, b) };
        let doc = Json::obj(vec![
            ("format", Json::Str(CACHE_FORMAT.into())),
            ("version", Json::Num(1.0)),
            ("device", Json::Str("d".into())),
            ("entries", Json::Arr(vec![mk(&first), mk(&second)])),
        ]);
        let diags = check_text(&doc.to_string()).unwrap();
        assert_eq!(ids(&diags), ["CPV122"]);
    }

    fn journal_header_and_config() -> String {
        "{\"format\":\"cprune-run-journal\",\"version\":1}\n\
         {\"record\":\"config\",\"device\":\"kryo385\",\"iters\":3,\"model\":\"resnet8-cifar\",\
          \"pruner\":\"cprune\",\"seed\":7,\"target_acc\":null}\n"
            .to_string()
    }

    fn journal_baseline(cache: &str) -> String {
        format!(
            "{{\"record\":\"baseline\",\"cache\":[{cache}],\"events\":1,\
              \"fps\":4,\"latency\":0.25}}\n"
        )
    }

    #[test]
    fn clean_journal_is_recognized_and_clean() {
        let text = format!(
            "{}{}{}{}",
            journal_header_and_config(),
            journal_baseline(""),
            "{\"record\":\"iteration\",\"accuracy_gate\":0.8,\"cache\":[],\
              \"candidates_tried\":4,\"checkpoint\":{\"accuracy\":0.9,\"channels\":{},\
              \"iteration\":1,\"latency\":0.2},\"events\":5,\"filters_removed\":8,\
              \"iteration\":1,\"latency\":0.2,\"latency_target\":0.25,\
              \"programs_measured\":12,\"short_accuracy\":0.9}\n",
            "{\"record\":\"finished\",\"events\":7}\n"
        );
        assert_eq!(check_text(&text), Some(vec![]));
    }

    #[test]
    fn torn_journal_tail_is_cpv160() {
        let text = format!("{}{{\"record\":\"baseli", journal_header_and_config());
        assert_eq!(ids(&check_text(&text).unwrap()), ["CPV160"]);
    }

    #[test]
    fn journal_sequence_violations_are_cpv161() {
        // iteration before baseline
        let text = format!(
            "{}{}",
            journal_header_and_config(),
            "{\"record\":\"iteration\",\"accuracy_gate\":0.8,\"cache\":[],\
              \"candidates_tried\":4,\"checkpoint\":{\"accuracy\":0.9,\"channels\":{},\
              \"iteration\":1,\"latency\":0.2},\"events\":5,\"filters_removed\":8,\
              \"iteration\":1,\"latency\":0.2,\"latency_target\":0.25,\
              \"programs_measured\":12,\"short_accuracy\":0.9}\n"
        );
        assert_eq!(ids(&check_text(&text).unwrap()), ["CPV161"]);
        // record after finished
        let text = format!(
            "{}{}{}{}",
            journal_header_and_config(),
            journal_baseline(""),
            "{\"record\":\"finished\",\"events\":7}\n",
            "{\"record\":\"finished\",\"events\":7}\n"
        );
        assert_eq!(ids(&check_text(&text).unwrap()), ["CPV161"]);
        // baseline before config
        let text = format!(
            "{}{}{}",
            "{\"format\":\"cprune-run-journal\",\"version\":1}\n",
            journal_baseline(""),
            "{\"record\":\"config\",\"device\":\"kryo385\",\"iters\":3,\
              \"model\":\"resnet8-cifar\",\"pruner\":\"cprune\",\"seed\":7,\
              \"target_acc\":null}\n"
        );
        assert_eq!(ids(&check_text(&text).unwrap()), ["CPV161"]);
    }

    #[test]
    fn journal_record_and_cache_violations_are_cpv160_and_cpv162() {
        // missing field + unexpected field
        let text = format!(
            "{}{}",
            journal_header_and_config(),
            "{\"record\":\"baseline\",\"cache\":[],\"events\":1,\"fps\":4,\
              \"latency\":0.25,\"surprise\":1}\n"
        );
        assert_eq!(ids(&check_text(&text).unwrap()), ["CPV160"]);
        let text = format!(
            "{}{}",
            journal_header_and_config(),
            "{\"record\":\"baseline\",\"cache\":[],\"events\":1,\"fps\":4}\n"
        );
        assert_eq!(ids(&check_text(&text).unwrap()), ["CPV160"]);
        // malformed cache delta entry
        let text = format!(
            "{}{}",
            journal_header_and_config(),
            journal_baseline("{\"latency\":0.001,\"measured\":1}")
        );
        assert_eq!(ids(&check_text(&text).unwrap()), ["CPV162"]);
    }

    #[test]
    fn sparsity_mask_documents_are_checked() {
        let clean = r#"{"format":"cprune-sparsity-masks","version":1,"masks":[
            {"conv":3,"density":0.4444444444444444,"params":[0,2],"scheme":"pattern"},
            {"conv":7,"density":0.5,"params":[2,4],"scheme":"block"}]}"#;
        assert_eq!(check_text(clean), Some(vec![]));
        let unsorted = clean.replace("\"conv\":7", "\"conv\":3");
        assert_eq!(ids(&check_text(&unsorted).unwrap()), ["CPV170"]);
        let dense = clean.replace("\"density\":0.5", "\"density\":1.5");
        assert_eq!(ids(&check_text(&dense).unwrap()), ["CPV171"]);
        let scheme = clean.replace("\"scheme\":\"block\"", "\"scheme\":\"vibes\"");
        assert_eq!(ids(&check_text(&scheme).unwrap()), ["CPV172"]);
        let shape = clean.replace("\"params\":[2,4]", "\"params\":[4,2]");
        assert_eq!(ids(&check_text(&shape).unwrap()), ["CPV172"]);
    }

    #[test]
    fn event_scheme_field_is_optional_but_must_be_known() {
        let with = "{\"format\":\"cprune-run-events\",\"version\":1}\n\
            {\"event\":\"candidate_measured\",\"candidates_tried\":1,\"iteration\":1,\
             \"latency\":0.2,\"latency_target\":0.25,\"scheme\":\"pattern\"}\n";
        assert_eq!(check_text(with), Some(vec![]));
        let bad = with.replace("\"pattern\"", "\"vibes\"");
        assert_eq!(ids(&check_text(&bad).unwrap()), ["CPV140"]);
    }

    #[test]
    fn bench_golden_document_is_recognized_and_checked() {
        let good = r#"{"format":"cprune-bench-golden","version":1,"pinned":false,
                       "BENCH_tuner.json":[["tune_task_hot_conv",null]]}"#;
        assert_eq!(check_text(good), Some(vec![]));
        let bad = r#"{"format":"cprune-bench-golden","version":1,"pinned":false,
                      "BENCH_tuner.json":[["tune_task_hot_conv"]]}"#;
        assert_eq!(ids(&check_text(bad).unwrap()), ["CPV121"]);
    }
}
