//! `cprune-verify` — the semantic checker over the project's three
//! meaning-carrying layers (DESIGN.md §13 "Semantic verification").
//!
//! `cprune-lint` (DESIGN.md §12) polices *source* invariants; this module
//! polices *data* invariants — the structures whose silent corruption
//! would poison the search itself:
//!
//! * [`graph`] — dataflow legality of a [`crate::graph::ops::Graph`]:
//!   channel agreement along every edge, group divisibility, residual-add
//!   shape coupling, min-channel floors, and a full
//!   [`crate::graph::shape_infer`] recheck;
//! * [`program`] — schedule legality of a [`crate::tir::Program`]
//!   against its [`crate::tir::Workload`]: split-tree coverage and
//!   annotation bounds;
//! * [`artifact`] — deep validation of every versioned JSON document the
//!   project persists (tune caches, measurement traces, Pareto
//!   registries, device files, calibration tables, bench reports,
//!   run-event JSONL): schema shape *plus* semantic invariants such as
//!   frontier non-domination and canonical key round-trips through
//!   [`crate::tir::jsonio`].
//!
//! Every finding is a [`Diagnostic`] with a stable [`Code`] (`CPV1xx`,
//! IDs never reused — the mirror of cprune-lint's `CPL0xx`), a context
//! string locating the finding (node, entry, line), and a `Display` form
//! matching the linter's `location: ID: message` output.
//!
//! Enforcement happens at three boundaries: `debug_assert`-gated checks
//! at every mutation site (`graph::prune::apply`, `ParetoSet` insertion,
//! artifact save/load), the `cprune check [PATH...]` CLI subcommand
//! ([`sweep`]) that CI runs over the committed tree, and the
//! mutation-fuzz tests in `rust/tests/verify_tests.rs` that pin each
//! corruption class to its `CPV` ID.

pub mod artifact;
pub mod graph;
pub mod program;

use std::fmt;
use std::path::{Path, PathBuf};

/// Stable diagnostic identifiers. IDs are never reused; retired checks
/// leave holes. Grouped by layer: `CPV10x` graph, `CPV11x` program,
/// `CPV12x` artifact schema, `CPV13x` frontier, `CPV14x` event stream,
/// `CPV15x` remote traces, `CPV16x` run journals, `CPV17x` sparsity
/// masks, `CPV19x` document-level corruption.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Code {
    /// CPV100 — graph structure: id/index mismatch, forward-referencing
    /// input, wrong operator arity, or a non-positive kernel/stride.
    GraphStructure,
    /// CPV101 — channel disagreement along a dataflow edge (conv `cin`
    /// vs producer channels, bn width, dense flatten width).
    ChannelMismatch,
    /// CPV102 — residual `Add` of two differently-shaped operands.
    ResidualMismatch,
    /// CPV103 — grouped conv whose `groups` no longer divide `cin`/`cout`.
    GroupDivisibility,
    /// CPV104 — conv pruned below the 2-channel floor
    /// (`graph::prune::PruneState` clamps there; a graph below it cannot
    /// have come from a legal prune).
    ChannelFloor,
    /// CPV105 — shape inference fails or would underflow (kernel larger
    /// than its padded input, pool larger than its input).
    ShapeInference,
    /// CPV110 — malformed split tree: empty, or containing a zero factor.
    SplitMalformed,
    /// CPV111 — split tree does not cover its extent, or pads ≥ 2×
    /// (`extent ≤ Π factors < 2·extent` is the schedule-space contract).
    SplitCoverage,
    /// CPV112 — annotation out of bounds: zero parallel/vectorize/unroll,
    /// or a non-power-of-two vector/unroll width.
    AnnotationBounds,
    /// CPV120 — versioned-document header problems: wrong/missing
    /// `format`, unsupported `version`, missing top-level field.
    BadHeader,
    /// CPV121 — an entry of a versioned document fails to parse back
    /// into its typed form.
    MalformedEntry,
    /// CPV122 — a persisted key is not canonical: it does not round-trip
    /// byte-identically through [`crate::tir::jsonio`], or entries are
    /// not sorted by their canonical key.
    NonCanonicalKey,
    /// CPV123 — a numeric field outside its domain: non-finite or
    /// non-positive latency/seconds, accuracy outside `[0, 1]`, negative
    /// noise sigma, zero repeats.
    NumericRange,
    /// CPV124 — a replayed run queried outside its recorded trace (the
    /// [`crate::device::ReplayTarget`] divergence diagnostic — raised at
    /// run time, not by a document checker).
    ReplayDivergence,
    /// CPV130 — a persisted frontier holds a dominated or duplicate
    /// point (the [`crate::serve::ParetoSet`] invariant).
    FrontierDominated,
    /// CPV131 — frontier points not strictly ascending in both latency
    /// and accuracy.
    FrontierOrder,
    /// CPV140 — a run-event JSONL line violates the event schema:
    /// unparseable, unknown kind, missing/mistyped field, bad reason.
    EventSchema,
    /// CPV150 — a `cprune-remote-trace` measurement entry is malformed:
    /// missing/mistyped `samples`, `jitter` or `mean`.
    RemoteEntry,
    /// CPV151 — a remote-trace sample's jitter draw count differs from
    /// its entry's `repeats` (replaying it would desynchronize the RNG
    /// stream the measurement contract guarantees).
    RemoteJitterArity,
    /// CPV152 — a remote-trace jitter multiplier outside its domain:
    /// non-finite, non-positive, or ≠ 1 under `noise_sigma` 0 (lognormal
    /// jitter with sigma 0 is exactly 1).
    RemoteJitterRange,
    /// CPV160 — a `cprune-run-journal` record is malformed: unknown
    /// record kind, missing/mistyped field, unexpected field, or an
    /// unparseable (torn) line — a crashed journal flags this until
    /// `cprune run --resume` truncates the torn tail.
    JournalRecord,
    /// CPV161 — journal records out of sequence: config not first,
    /// an iteration before the baseline, non-increasing iteration
    /// numbers, or a record after `finished`.
    JournalSequence,
    /// CPV162 — a journaled tune-cache delta entry is malformed,
    /// non-canonical, or unsorted (the [`crate::tuner::TuneCache`]
    /// entry invariants, applied per record).
    JournalCacheEntry,
    /// CPV170 — a `cprune-sparsity-masks` entry is malformed: missing or
    /// mistyped field, unexpected field, or entries not strictly
    /// ascending by conv id.
    MaskEntry,
    /// CPV171 — a mask density outside its domain: non-finite, or
    /// outside `(0, 1]` (a channel layer is simply absent from the set).
    MaskDensity,
    /// CPV172 — an unknown scheme name, or scheme parameters
    /// inconsistent with the scheme: pattern indices out of the library
    /// range or unsorted, a block shape other than `[keep, group]` with
    /// `keep < group`.
    MaskScheme,
    /// CPV190 — a document that claims a `cprune-*` format but cannot be
    /// parsed at all.
    CorruptDocument,
}

impl Code {
    /// Every code, in ID order.
    pub const ALL: [Code; 27] = [
        Code::GraphStructure,
        Code::ChannelMismatch,
        Code::ResidualMismatch,
        Code::GroupDivisibility,
        Code::ChannelFloor,
        Code::ShapeInference,
        Code::SplitMalformed,
        Code::SplitCoverage,
        Code::AnnotationBounds,
        Code::BadHeader,
        Code::MalformedEntry,
        Code::NonCanonicalKey,
        Code::NumericRange,
        Code::ReplayDivergence,
        Code::FrontierDominated,
        Code::FrontierOrder,
        Code::EventSchema,
        Code::RemoteEntry,
        Code::RemoteJitterArity,
        Code::RemoteJitterRange,
        Code::JournalRecord,
        Code::JournalSequence,
        Code::JournalCacheEntry,
        Code::MaskEntry,
        Code::MaskDensity,
        Code::MaskScheme,
        Code::CorruptDocument,
    ];

    /// Stable ID string (`CPV100`…). Never renumbered.
    pub fn id(self) -> &'static str {
        match self {
            Code::GraphStructure => "CPV100",
            Code::ChannelMismatch => "CPV101",
            Code::ResidualMismatch => "CPV102",
            Code::GroupDivisibility => "CPV103",
            Code::ChannelFloor => "CPV104",
            Code::ShapeInference => "CPV105",
            Code::SplitMalformed => "CPV110",
            Code::SplitCoverage => "CPV111",
            Code::AnnotationBounds => "CPV112",
            Code::BadHeader => "CPV120",
            Code::MalformedEntry => "CPV121",
            Code::NonCanonicalKey => "CPV122",
            Code::NumericRange => "CPV123",
            Code::ReplayDivergence => "CPV124",
            Code::FrontierDominated => "CPV130",
            Code::FrontierOrder => "CPV131",
            Code::EventSchema => "CPV140",
            Code::RemoteEntry => "CPV150",
            Code::RemoteJitterArity => "CPV151",
            Code::RemoteJitterRange => "CPV152",
            Code::JournalRecord => "CPV160",
            Code::JournalSequence => "CPV161",
            Code::JournalCacheEntry => "CPV162",
            Code::MaskEntry => "CPV170",
            Code::MaskDensity => "CPV171",
            Code::MaskScheme => "CPV172",
            Code::CorruptDocument => "CPV190",
        }
    }

    /// One-line description (for `cprune check --codes`).
    pub fn summary(self) -> &'static str {
        match self {
            Code::GraphStructure => "graph structure: ids, forward inputs, arity, kernel/stride",
            Code::ChannelMismatch => "channel disagreement along a dataflow edge",
            Code::ResidualMismatch => "residual add of mismatched shapes",
            Code::GroupDivisibility => "grouped conv whose groups do not divide its channels",
            Code::ChannelFloor => "conv pruned below the 2-channel floor",
            Code::ShapeInference => "shape inference fails or underflows",
            Code::SplitMalformed => "empty split tree or zero split factor",
            Code::SplitCoverage => "split product outside [extent, 2*extent)",
            Code::AnnotationBounds => "parallel/vectorize/unroll annotation out of bounds",
            Code::BadHeader => "versioned-document header missing/unsupported",
            Code::MalformedEntry => "document entry fails to parse into its typed form",
            Code::NonCanonicalKey => "persisted key not canonical or entries unsorted",
            Code::NumericRange => "numeric field outside its domain",
            Code::ReplayDivergence => "replayed run queried outside its recorded trace",
            Code::FrontierDominated => "frontier holds a dominated or duplicate point",
            Code::FrontierOrder => "frontier not ascending in latency and accuracy",
            Code::EventSchema => "run-event line violates the event schema",
            Code::RemoteEntry => "remote-trace entry missing samples/jitter/mean",
            Code::RemoteJitterArity => "remote-trace jitter draw count differs from repeats",
            Code::RemoteJitterRange => "remote-trace jitter multiplier outside its domain",
            Code::JournalRecord => "run-journal record malformed or torn",
            Code::JournalSequence => "run-journal records out of sequence",
            Code::JournalCacheEntry => "run-journal cache delta malformed or unsorted",
            Code::MaskEntry => "sparsity-mask entry malformed or out of order",
            Code::MaskDensity => "sparsity-mask density outside (0, 1]",
            Code::MaskScheme => "unknown scheme or inconsistent scheme parameters",
            Code::CorruptDocument => "cprune-format document does not parse",
        }
    }
}

/// One verification finding: a stable code, a context string locating it
/// (graph node, document entry, JSONL line), and a human message.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub code: Code,
    /// Where in the checked structure the finding sits, e.g.
    /// `node 3 (conv2d 'c1')`, `entries[2]`, `line 5`.
    pub context: String,
    pub message: String,
}

impl Diagnostic {
    pub fn new(code: Code, context: impl Into<String>, message: impl Into<String>) -> Diagnostic {
        Diagnostic { code, context: context.into(), message: message.into() }
    }

    /// The same diagnostic, nested one level deeper (artifact checkers
    /// prefix entry context onto the program/frontier checkers' output).
    pub fn nested(mut self, prefix: &str) -> Diagnostic {
        self.context = format!("{prefix}: {}", self.context);
        self
    }
}

/// `context: CPVnnn: message` — the same shape as cprune-lint's
/// `file:line: CPLnnn: message`, so CI output reads uniformly.
impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}: {}", self.context, self.code.id(), self.message)
    }
}

/// Directory names [`sweep`] never descends into — the same skip set as
/// `cprune-lint`'s workspace walker (`fixtures` keeps intentionally
/// corrupt test inputs out of the deny-by-default CI sweep).
pub const SKIP_DIRS: [&str; 3] = ["target", ".git", "fixtures"];

/// Walk `root` for `.json`/`.jsonl` files, run [`artifact::check_text`]
/// on each, and return `(workspace-relative path, findings)` for every
/// file recognized as a cprune artifact — including clean ones (empty
/// findings), so callers can report coverage. Sorted by path.
pub fn sweep(root: &Path) -> Result<Vec<(String, Vec<Diagnostic>)>, String> {
    let mut files = Vec::new();
    collect_artifact_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("failed to read {}: {e}", path.display()))?;
        if let Some(diags) = artifact::check_text(&text) {
            out.push((relative_path(root, path), diags));
        }
    }
    Ok(out)
}

/// Check one file directly (the CLI's file-argument path). Returns
/// `None` when the file is not a recognized cprune artifact.
pub fn check_file(path: &Path) -> Result<Option<Vec<Diagnostic>>, String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("failed to read {}: {e}", path.display()))?;
    Ok(artifact::check_text(&text))
}

/// Recursively gather `.json`/`.jsonl` files, skipping [`SKIP_DIRS`].
fn collect_artifact_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        std::fs::read_dir(dir).map_err(|e| format!("failed to list {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("failed to list {}: {e}", dir.display()))?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            if !SKIP_DIRS.contains(&name.as_ref()) {
                collect_artifact_files(&path, out)?;
            }
        } else if name.ends_with(".json") || name.ends_with(".jsonl") {
            out.push(path);
        }
    }
    Ok(())
}

/// `path` relative to `root`, `/`-separated (falls back to the full path
/// when `path` is not under `root`, e.g. explicit absolute arguments).
fn relative_path(root: &Path, path: &Path) -> String {
    match path.strip_prefix(root) {
        Ok(rel) => {
            let parts: Vec<String> =
                rel.components().map(|c| c.as_os_str().to_string_lossy().into_owned()).collect();
            parts.join("/")
        }
        Err(_) => path.display().to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn code_ids_are_stable() {
        let ids: Vec<&str> = Code::ALL.iter().map(|c| c.id()).collect();
        assert_eq!(
            ids,
            [
                "CPV100", "CPV101", "CPV102", "CPV103", "CPV104", "CPV105", "CPV110", "CPV111",
                "CPV112", "CPV120", "CPV121", "CPV122", "CPV123", "CPV124", "CPV130", "CPV131",
                "CPV140", "CPV150", "CPV151", "CPV152", "CPV160", "CPV161", "CPV162", "CPV170",
                "CPV171", "CPV172", "CPV190",
            ]
        );
    }

    #[test]
    fn diagnostic_display_matches_lint_shape() {
        let d = Diagnostic::new(Code::ChannelMismatch, "node 3 (conv2d 'c1')", "cin 64 != 32");
        assert_eq!(d.to_string(), "node 3 (conv2d 'c1'): CPV101: cin 64 != 32");
        let n = d.nested("entries[2]");
        assert_eq!(n.to_string(), "entries[2]: node 3 (conv2d 'c1'): CPV101: cin 64 != 32");
    }

    #[test]
    fn summaries_are_nonempty_and_distinct() {
        let mut seen = std::collections::BTreeSet::new();
        for c in Code::ALL {
            assert!(!c.summary().is_empty());
            assert!(seen.insert(c.summary()), "duplicate summary for {}", c.id());
        }
    }
}
