//! ProgramCheck: schedule legality of a [`Program`] against its
//! [`Workload`] (DESIGN.md §13).
//!
//! Grown out of `Program::validate` (which now delegates here and
//! surfaces the first finding): every tile-split axis must be
//! well-formed (CPV110) and cover its loop extent without more than 2×
//! overshoot (CPV111), and the parallel/vectorize/unroll annotations
//! must be positive — with vectorize and unroll powers of two, matching
//! the tuner's sample sets and the lowering's assumptions (CPV112).
//! The check is allocation-free on the passing path so the tuner's
//! `debug_assert!(validate(..).is_ok())` in `sample_into` stays cheap.

use super::{Code, Diagnostic};
use crate::tir::loopnest::Workload;
use crate::tir::program::Program;

/// Every schedule-legality finding for `p` scheduled over `w` (empty =
/// legal program).
pub fn check_program(p: &Program, w: &Workload) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    let axes: [(&str, &[usize], usize); 4] = [
        ("spatial", &p.spatial_splits, w.oh * w.ow),
        ("ff", &p.ff_splits, w.ff),
        ("ax3", &p.ax3_splits, w.ff),
        ("ic", &p.ic_splits, w.ic),
    ];
    for (name, splits, extent) in axes {
        if splits.is_empty() {
            out.push(Diagnostic::new(
                Code::SplitMalformed,
                format!("{name} splits"),
                "axis has no tile factors",
            ));
            continue;
        }
        if splits.contains(&0) {
            out.push(Diagnostic::new(
                Code::SplitMalformed,
                format!("{name} splits"),
                format!("zero tile factor in {splits:?}"),
            ));
            continue;
        }
        let prod: usize = splits.iter().product();
        if prod < extent || prod >= 2 * extent.max(1) {
            out.push(Diagnostic::new(
                Code::SplitCoverage,
                format!("{name} splits"),
                format!("{splits:?} (product {prod}) do not cover extent {extent} within 2x"),
            ));
        }
    }
    if p.parallel == 0 {
        out.push(Diagnostic::new(Code::AnnotationBounds, "annotations", "parallel degree is 0"));
    }
    if p.vectorize == 0 || !p.vectorize.is_power_of_two() {
        out.push(Diagnostic::new(
            Code::AnnotationBounds,
            "annotations",
            format!("vectorize width {} is not a power of two", p.vectorize),
        ));
    }
    if p.unroll == 0 || !p.unroll.is_power_of_two() {
        out.push(Diagnostic::new(
            Code::AnnotationBounds,
            "annotations",
            format!("unroll factor {} is not a power of two", p.unroll),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::OpKind;

    fn wl(ff: usize) -> Workload {
        let op =
            OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 };
        Workload::from_conv(&op, [1, 14, 14, 64], vec![])
    }

    fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code.id()).collect()
    }

    #[test]
    fn naive_program_is_legal() {
        let w = wl(128);
        let p = Program::naive(&w);
        assert!(check_program(&p, &w).is_empty());
        assert!(p.validate(&w).is_ok());
    }

    #[test]
    fn undercovering_axis_is_cpv111() {
        let w = wl(128);
        let mut p = Program::naive(&w);
        p.ff_splits = vec![4, 4]; // product 16 < 128
        assert_eq!(ids(&check_program(&p, &w)), ["CPV111"]);
    }

    #[test]
    fn zero_factor_and_empty_axis_are_cpv110() {
        let w = wl(128);
        let mut p = Program::naive(&w);
        p.ff_splits = vec![128, 0];
        p.ic_splits = Vec::new();
        assert_eq!(ids(&check_program(&p, &w)), ["CPV110", "CPV110"]);
    }

    #[test]
    fn non_pow2_vectorize_is_cpv112() {
        let w = wl(128);
        let mut p = Program::naive(&w);
        p.vectorize = 3;
        assert_eq!(ids(&check_program(&p, &w)), ["CPV112"]);
    }

    #[test]
    fn findings_accumulate_across_axes_and_annotations() {
        let w = wl(128);
        let mut p = Program::naive(&w);
        p.spatial_splits = vec![7]; // 7 < 196
        p.unroll = 0;
        assert_eq!(ids(&check_program(&p, &w)), ["CPV111", "CPV112"]);
    }
}
