//! GraphCheck: dataflow legality of a [`Graph`] (DESIGN.md §13).
//!
//! Two passes. [`check_structure`] verifies the purely structural
//! invariants `Graph::validate` has always enforced (id/index agreement,
//! no forward inputs, operator arity) plus non-positive kernel/stride
//! parameters — everything that must hold before shapes are even
//! meaningful. [`check_graph`] then walks the dataflow in topological
//! order with *checked* arithmetic, accumulating one diagnostic per edge
//! problem (channel mismatch, group divisibility, residual mismatch,
//! channel floor, spatial underflow) instead of stopping at the first,
//! and finishes with a [`shape_infer::infer`] recheck so the two
//! implementations can never silently disagree.

use super::{Code, Diagnostic};
use crate::graph::ops::{Graph, Node, OpKind};
use crate::graph::shape_infer::{self, Shape};

/// `node 3 (conv2d 'c1')` — the context string for a node finding.
fn ctx(n: &Node) -> String {
    format!("node {} ({} '{}')", n.id, n.op.mnemonic(), n.name)
}

/// Structural invariants only (what `Graph::validate` enforces; that
/// method now delegates here and surfaces the first finding).
pub fn check_structure(g: &Graph) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for (i, n) in g.nodes.iter().enumerate() {
        if n.id != i {
            out.push(Diagnostic::new(
                Code::GraphStructure,
                ctx(n),
                format!("node at index {i} has mismatched id {}", n.id),
            ));
        }
        for &inp in &n.inputs {
            if inp >= i {
                out.push(Diagnostic::new(
                    Code::GraphStructure,
                    ctx(n),
                    format!("uses forward input {inp}"),
                ));
            }
        }
        let arity_ok = match n.op {
            OpKind::Input { .. } => n.inputs.is_empty(),
            OpKind::Add => n.inputs.len() == 2,
            _ => n.inputs.len() == 1,
        };
        if !arity_ok {
            out.push(Diagnostic::new(
                Code::GraphStructure,
                ctx(n),
                format!("wrong arity {}", n.inputs.len()),
            ));
        }
        match n.op {
            OpKind::Conv2d { kh, kw, stride, .. } => {
                if kh == 0 || kw == 0 || stride == 0 {
                    out.push(Diagnostic::new(
                        Code::GraphStructure,
                        ctx(n),
                        format!("non-positive kernel/stride (kh {kh}, kw {kw}, stride {stride})"),
                    ));
                }
            }
            OpKind::MaxPool { k, stride } => {
                if k == 0 || stride == 0 {
                    out.push(Diagnostic::new(
                        Code::GraphStructure,
                        ctx(n),
                        format!("non-positive pool kernel/stride (k {k}, stride {stride})"),
                    ));
                }
            }
            _ => {}
        }
    }
    out
}

/// Full dataflow check: structure, then a tolerant shape walk, then the
/// `shape_infer` recheck. Returns every finding (empty = legal graph).
pub fn check_graph(g: &Graph) -> Vec<Diagnostic> {
    let mut out = check_structure(g);
    if !out.is_empty() {
        // Shapes are meaningless on a structurally broken graph.
        return out;
    }
    let mut shapes: Vec<Option<Shape>> = Vec::with_capacity(g.nodes.len());
    for n in &g.nodes {
        let shape = walk_node(g, n, &shapes, &mut out);
        shapes.push(shape);
    }
    if out.is_empty() {
        // The walk above mirrors every error/underflow condition in
        // `shape_infer::infer` with checked arithmetic, so a clean walk
        // guarantees `infer` cannot panic; run it anyway as the
        // authoritative recheck (one implementation must not drift from
        // the other unnoticed).
        if let Err(e) = shape_infer::infer(g) {
            out.push(Diagnostic::new(
                Code::ShapeInference,
                "graph",
                format!("shape inference rejected a graph the dataflow walk passed: {e}"),
            ));
        }
    }
    out
}

/// One node of the tolerant walk: emit diagnostics for every violated
/// edge invariant; return the node's output shape when it is still
/// derivable (`None` poisons downstream shape checks without cascading
/// spurious findings).
fn walk_node(
    g: &Graph,
    n: &Node,
    shapes: &[Option<Shape>],
    out: &mut Vec<Diagnostic>,
) -> Option<Shape> {
    let input = |i: usize| shapes.get(n.inputs[i]).copied().flatten();
    match &n.op {
        OpKind::Input { shape } => Some(*shape),
        OpKind::Conv2d { kh, kw, cin, cout, stride, padding, groups } => {
            if *groups == 0 {
                out.push(Diagnostic::new(Code::GroupDivisibility, ctx(n), "groups is 0"));
                return None;
            }
            if cin % groups != 0 || cout % groups != 0 {
                out.push(Diagnostic::new(
                    Code::GroupDivisibility,
                    ctx(n),
                    format!("groups {groups} do not divide cin {cin} / cout {cout}"),
                ));
            }
            if *cout < 2 {
                out.push(Diagnostic::new(
                    Code::ChannelFloor,
                    ctx(n),
                    format!("cout {cout} is below the 2-channel prune floor"),
                ));
            }
            let [b, h, w, c] = input(0)?;
            if c != *cin {
                out.push(Diagnostic::new(
                    Code::ChannelMismatch,
                    ctx(n),
                    format!("conv cin={cin} but input '{}' has {c} channels", producer(g, n, 0)),
                ));
                return None;
            }
            let oh = match (h + 2 * padding).checked_sub(*kh) {
                Some(d) => d / stride + 1,
                None => {
                    out.push(Diagnostic::new(
                        Code::ShapeInference,
                        ctx(n),
                        format!("kernel {kh} larger than padded input height {}", h + 2 * padding),
                    ));
                    return None;
                }
            };
            let ow = match (w + 2 * padding).checked_sub(*kw) {
                Some(d) => d / stride + 1,
                None => {
                    out.push(Diagnostic::new(
                        Code::ShapeInference,
                        ctx(n),
                        format!("kernel {kw} larger than padded input width {}", w + 2 * padding),
                    ));
                    return None;
                }
            };
            Some([b, oh, ow, *cout])
        }
        OpKind::Dense { cin, cout } => {
            let [b, h, w, c] = input(0)?;
            let feat = h * w * c;
            if feat != *cin {
                out.push(Diagnostic::new(
                    Code::ChannelMismatch,
                    ctx(n),
                    format!("dense cin={cin} but input flattens to {feat}"),
                ));
                return None;
            }
            Some([b, 1, 1, *cout])
        }
        OpKind::BatchNorm { channels } => {
            let s = input(0)?;
            if s[3] != *channels {
                out.push(Diagnostic::new(
                    Code::ChannelMismatch,
                    ctx(n),
                    format!("bn over {channels} channels but input has {}", s[3]),
                ));
                return None;
            }
            Some(s)
        }
        OpKind::ReLU | OpKind::ReLU6 | OpKind::Softmax => input(0),
        OpKind::Add => {
            let a = input(0)?;
            let b = input(1)?;
            if a != b {
                out.push(Diagnostic::new(
                    Code::ResidualMismatch,
                    ctx(n),
                    format!(
                        "add of mismatched shapes {a:?} (from '{}') vs {b:?} (from '{}')",
                        producer(g, n, 0),
                        producer(g, n, 1)
                    ),
                ));
                return None;
            }
            Some(a)
        }
        OpKind::MaxPool { k, stride } => {
            let [b, h, w, c] = input(0)?;
            match (h.checked_sub(*k), w.checked_sub(*k)) {
                (Some(dh), Some(dw)) => Some([b, dh / stride + 1, dw / stride + 1, c]),
                _ => {
                    out.push(Diagnostic::new(
                        Code::ShapeInference,
                        ctx(n),
                        format!("pool kernel {k} larger than input {h}x{w}"),
                    ));
                    None
                }
            }
        }
        OpKind::GlobalAvgPool => {
            let [b, _, _, c] = input(0)?;
            Some([b, 1, 1, c])
        }
        OpKind::Flatten => {
            let [b, h, w, c] = input(0)?;
            Some([b, 1, 1, h * w * c])
        }
    }
}

/// Name of the node feeding `n`'s `i`-th input (diagnostics only).
fn producer<'g>(g: &'g Graph, n: &Node, i: usize) -> &'g str {
    &g.node(n.inputs[i]).name
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(cin: usize, cout: usize, groups: usize) -> OpKind {
        OpKind::Conv2d { kh: 3, kw: 3, cin, cout, stride: 1, padding: 1, groups }
    }

    fn ids(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code.id()).collect()
    }

    #[test]
    fn clean_graph_has_no_findings() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 3] }, vec![]);
        let c = g.add("c", conv(3, 16, 1), vec![x]);
        g.add("bn", OpKind::BatchNorm { channels: 16 }, vec![c]);
        assert!(check_graph(&g).is_empty());
    }

    #[test]
    fn channel_break_is_cpv101() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        g.add("c", conv(8, 16, 1), vec![x]);
        assert_eq!(ids(&check_graph(&g)), ["CPV101"]);
    }

    #[test]
    fn residual_break_is_cpv102_and_does_not_cascade() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 4] }, vec![]);
        let a = g.add("a", conv(4, 8, 1), vec![x]);
        let b = g.add("b", conv(4, 16, 1), vec![x]);
        let s = g.add("add", OpKind::Add, vec![a, b]);
        g.add("relu", OpKind::ReLU, vec![s]);
        assert_eq!(ids(&check_graph(&g)), ["CPV102"]);
    }

    #[test]
    fn group_and_floor_violations_found_together() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 9] }, vec![]);
        let c = g.add("c", conv(9, 2, 2), vec![x]); // 2 does not divide 9
        g.add("c2", conv(2, 1, 1), vec![c]); // cout 1 below the floor
        assert_eq!(ids(&check_graph(&g)), ["CPV103", "CPV104"]);
    }

    #[test]
    fn structural_breaks_short_circuit_the_shape_walk() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 8, 8, 3] }, vec![]);
        let c = g.add("c", conv(3, 16, 1), vec![x]);
        g.nodes[c].inputs.push(x); // conv with arity 2
        assert_eq!(ids(&check_graph(&g)), ["CPV100"]);
        assert_eq!(check_structure(&g).len(), 1);
    }

    #[test]
    fn oversized_pool_is_cpv105_not_a_panic() {
        let mut g = Graph::new();
        let x = g.add("x", OpKind::Input { shape: [1, 2, 2, 4] }, vec![]);
        g.add("p", OpKind::MaxPool { k: 5, stride: 1 }, vec![x]);
        assert_eq!(ids(&check_graph(&g)), ["CPV105"]);
    }
}
