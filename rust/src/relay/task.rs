//! Task table: the task ↔ subgraph ↔ fastest-program relationship (§3.4).
//!
//! Structurally identical subgraphs (same workload extents, strides and
//! epilogue — e.g. Fig. 4's S11 and S14) share one task: the tuner
//! optimizes the task once and the result applies to all its subgraphs.
//! After tuning, each task records its fastest [`Program`] and measured
//! latency; CPrune reads both for task ordering (§3.3) and the pruning
//! decision (§3.5).

use crate::tir::{Program, Workload};

/// Task index within a [`TaskTable`].
pub type TaskId = usize;

/// One deduplicated tuning task.
#[derive(Clone, Debug)]
pub struct TaskInfo {
    pub id: TaskId,
    pub workload: Workload,
    /// Subgraph ids associated with this task.
    pub subgraphs: Vec<usize>,
    /// Fastest program found by tuning (None before tuning).
    pub best_program: Option<Program>,
    /// Measured latency of the fastest program, seconds per execution.
    pub best_latency: Option<f64>,
}

impl TaskInfo {
    /// §3.3 pruning impact: task latency × number of associated subgraphs.
    /// Untuned tasks have zero impact (they cannot be ranked yet).
    pub fn pruning_impact(&self) -> f64 {
        self.best_latency.unwrap_or(0.0) * self.subgraphs.len() as f64
    }
}

/// The table of ③/④ in Fig. 3: tasks, their subgraphs and best programs.
#[derive(Clone, Debug, Default)]
pub struct TaskTable {
    tasks: Vec<TaskInfo>,
}

impl TaskTable {
    pub fn new() -> TaskTable {
        TaskTable { tasks: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn get(&self, id: TaskId) -> &TaskInfo {
        &self.tasks[id]
    }

    pub fn get_mut(&mut self, id: TaskId) -> &mut TaskInfo {
        &mut self.tasks[id]
    }

    pub fn tasks(&self) -> impl Iterator<Item = &TaskInfo> {
        self.tasks.iter()
    }

    /// Register a subgraph; returns the task it joined (deduplicating by
    /// workload structural identity).
    pub fn add_subgraph(&mut self, subgraph_id: usize, workload: &Workload) -> TaskId {
        if let Some(t) = self.tasks.iter_mut().find(|t| t.workload.same_task(workload)) {
            t.subgraphs.push(subgraph_id);
            return t.id;
        }
        let id = self.tasks.len();
        self.tasks.push(TaskInfo {
            id,
            workload: workload.clone(),
            subgraphs: vec![subgraph_id],
            best_program: None,
            best_latency: None,
        });
        id
    }

    /// Store a tuning result for a task.
    pub fn record_tuned(&mut self, id: TaskId, program: Program, latency: f64) {
        let t = &mut self.tasks[id];
        t.best_program = Some(program);
        t.best_latency = Some(latency);
    }

    /// The task owning a given subgraph id.
    pub fn task_of_subgraph(&self, subgraph_id: usize) -> Option<TaskId> {
        self.tasks
            .iter()
            .find(|t| t.subgraphs.contains(&subgraph_id))
            .map(|t| t.id)
    }

    /// Tasks ordered by descending pruning impact (§3.3). Ties broken by id
    /// for determinism.
    pub fn by_pruning_impact(&self) -> Vec<TaskId> {
        let mut ids: Vec<TaskId> = (0..self.tasks.len()).collect();
        ids.sort_by(|&a, &b| {
            self.tasks[b]
                .pruning_impact()
                .total_cmp(&self.tasks[a].pruning_impact())
                .then(a.cmp(&b))
        });
        ids
    }

    /// Total model latency: Σ task latency × #subgraphs (every subgraph
    /// executes once per inference).
    pub fn model_latency(&self) -> f64 {
        self.tasks
            .iter()
            .map(|t| t.best_latency.unwrap_or(0.0) * t.subgraphs.len() as f64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::OpKind;

    fn wl(ff: usize, oh: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, oh, oh, ff],
            vec!["bn", "relu"],
        )
    }

    fn prog(w: &Workload) -> Program {
        Program::naive(w)
    }

    #[test]
    fn dedup_identical_workloads() {
        let mut t = TaskTable::new();
        let a = t.add_subgraph(0, &wl(64, 14));
        let b = t.add_subgraph(1, &wl(64, 14));
        let c = t.add_subgraph(2, &wl(128, 14));
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(t.len(), 2);
        assert_eq!(t.get(a).subgraphs, vec![0, 1]);
    }

    #[test]
    fn pruning_impact_ordering_matches_fig3_example() {
        // Fig. 3: T1 = 0.954 x 2 = 1.908, T2 = 0.473 x 3 = 1.419,
        // T3 = 1.632 x 1 = 1.632 → order T1, T3, T2.
        let mut t = TaskTable::new();
        let w1 = wl(64, 14);
        let w2 = wl(128, 14);
        let w3 = wl(256, 14);
        let t1 = t.add_subgraph(0, &w1);
        t.add_subgraph(1, &w1);
        let t2 = t.add_subgraph(2, &w2);
        t.add_subgraph(3, &w2);
        t.add_subgraph(4, &w2);
        let t3 = t.add_subgraph(5, &w3);
        t.record_tuned(t1, prog(&w1), 0.954);
        t.record_tuned(t2, prog(&w2), 0.473);
        t.record_tuned(t3, prog(&w3), 1.632);
        assert_eq!(t.by_pruning_impact(), vec![t1, t3, t2]);
    }

    #[test]
    fn model_latency_weights_by_subgraph_count() {
        let mut t = TaskTable::new();
        let w1 = wl(64, 14);
        let id = t.add_subgraph(0, &w1);
        t.add_subgraph(1, &w1);
        t.record_tuned(id, prog(&w1), 2.0);
        assert_eq!(t.model_latency(), 4.0);
    }

    #[test]
    fn task_of_subgraph_lookup() {
        let mut t = TaskTable::new();
        let a = t.add_subgraph(7, &wl(64, 14));
        assert_eq!(t.task_of_subgraph(7), Some(a));
        assert_eq!(t.task_of_subgraph(99), None);
    }

    #[test]
    fn untuned_tasks_have_zero_impact() {
        let mut t = TaskTable::new();
        t.add_subgraph(0, &wl(64, 14));
        assert_eq!(t.get(0).pruning_impact(), 0.0);
    }
}
