//! Relay-style graph partitioning and task extraction (§3.4, Fig. 4).
//!
//! The compiler front-end splits the DNN graph into *subgraphs* — a conv or
//! dense anchor plus the elementwise epilogue fused onto it (BN, ReLU,
//! residual add) — and deduplicates structurally identical subgraphs into
//! *tasks*: the unit the auto-tuner optimizes once and reuses everywhere.
//! CPrune's task/subgraph/program table is built on top of this mapping.

pub mod partition;
pub mod task;

pub use partition::{partition, Subgraph};
pub use task::{TaskId, TaskInfo, TaskTable};
