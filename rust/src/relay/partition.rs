//! Graph → subgraph partitioning.
//!
//! Mirrors TVM/Relay operator fusion for the patterns our model zoo
//! produces: every conv/dense node anchors a subgraph; the chain of
//! single-consumer elementwise ops hanging off it (batch-norm, ReLU,
//! ReLU6, residual add, softmax) is fused into the subgraph's epilogue.
//! Remaining ops (pooling, flatten) are bookkept as `overhead` nodes —
//! they contribute a fixed small latency in the device model but are not
//! tunable tasks.

use super::task::{TaskTable};
use crate::graph::ops::{Graph, NodeId, OpKind};
use crate::graph::shape_infer;
use crate::tir::Workload;

/// A fused region: one anchor (conv/dense) + elementwise epilogue.
#[derive(Clone, Debug)]
pub struct Subgraph {
    pub id: usize,
    pub anchor: NodeId,
    /// All node ids in the region (anchor first, epilogue in fusion order).
    pub nodes: Vec<NodeId>,
    /// The iteration-domain description handed to the tuner.
    pub workload: Workload,
}

/// Partition result: subgraphs + non-fused overhead ops.
#[derive(Clone, Debug)]
pub struct Partition {
    pub subgraphs: Vec<Subgraph>,
    pub overhead_nodes: Vec<NodeId>,
}

/// Partition `g` into fused subgraphs (Fig. 4's ①).
pub fn partition(g: &Graph) -> Partition {
    let shapes = shape_infer::infer(g).expect("graph must shape-infer"); // cprune-lint: allow(CPL005, reason="callers pass validated graphs")
    let mut claimed = vec![false; g.nodes.len()];
    let mut subgraphs = Vec::new();

    for node in &g.nodes {
        let anchored = matches!(node.op, OpKind::Conv2d { .. } | OpKind::Dense { .. });
        if !anchored {
            continue;
        }
        let mut nodes = vec![node.id];
        let mut epilogue: Vec<&'static str> = Vec::new();
        claimed[node.id] = true;

        // Greedily fuse the single-consumer elementwise chain.
        let mut cur = node.id;
        loop {
            let consumers = g.consumers(cur);
            if consumers.len() != 1 {
                break;
            }
            let c = consumers[0];
            let fuse = match g.node(c).op {
                OpKind::BatchNorm { .. } => Some("bn"),
                OpKind::ReLU => Some("relu"),
                OpKind::ReLU6 => Some("relu6"),
                OpKind::Softmax => Some("softmax"),
                // A residual add fuses into the branch that *computes* last
                // (the conv branch); the skip side just feeds a buffer.
                OpKind::Add => Some("add"),
                _ => None,
            };
            match fuse {
                Some(tag) if !claimed[c] => {
                    claimed[c] = true;
                    nodes.push(c);
                    epilogue.push(tag);
                    cur = c;
                    // after an add, allow one trailing relu (resnet pattern)
                    if tag == "add" {
                        let next = g.consumers(cur);
                        if next.len() == 1 {
                            if let OpKind::ReLU = g.node(next[0]).op {
                                claimed[next[0]] = true;
                                nodes.push(next[0]);
                                epilogue.push("relu");
                            }
                        }
                        break;
                    }
                }
                _ => break,
            }
        }

        let workload = Workload::from_conv(&node.op, shapes[node.id], epilogue);
        subgraphs.push(Subgraph { id: subgraphs.len(), anchor: node.id, nodes, workload });
    }

    let overhead_nodes = g
        .nodes
        .iter()
        .filter(|n| !claimed[n.id] && !matches!(n.op, OpKind::Input { .. }))
        .map(|n| n.id)
        .collect();

    Partition { subgraphs, overhead_nodes }
}

/// Partition + deduplicate into the task table (Fig. 4's ④ without the
/// tuned programs, which the tuner fills in).
pub fn extract_tasks(g: &Graph) -> (Partition, TaskTable) {
    let part = partition(g);
    let mut table = TaskTable::new();
    for sg in &part.subgraphs {
        table.add_subgraph(sg.id, &sg.workload);
    }
    (part, table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::model_zoo::{Model, ModelKind};

    #[test]
    fn every_conv_and_dense_is_anchored_once() {
        for kind in [ModelKind::ResNet18ImageNet, ModelKind::MobileNetV2ImageNet] {
            let m = Model::build(kind, 0);
            let part = partition(&m.graph);
            let anchors: Vec<usize> = part.subgraphs.iter().map(|s| s.anchor).collect();
            let mut expected = m.graph.conv_ids();
            expected.extend(
                m.graph
                    .nodes
                    .iter()
                    .filter(|n| matches!(n.op, OpKind::Dense { .. }))
                    .map(|n| n.id),
            );
            assert_eq!(anchors.len(), expected.len(), "{kind:?}");
            for a in expected {
                assert!(anchors.contains(&a), "{kind:?}: anchor {a} missing");
            }
        }
    }

    #[test]
    fn epilogues_capture_bn_relu() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let part = partition(&m.graph);
        // first VGG conv: conv+bn+relu fused
        let sg = &part.subgraphs[0];
        assert_eq!(sg.workload.epilogue, vec!["bn", "relu"]);
        assert_eq!(sg.nodes.len(), 3);
    }

    #[test]
    fn resnet_block_add_fuses_with_trailing_relu() {
        let m = Model::build(ModelKind::ResNet18ImageNet, 0);
        let part = partition(&m.graph);
        // some subgraph must end with ... bn, add, relu (block second conv)
        assert!(
            part.subgraphs
                .iter()
                .any(|s| s.workload.epilogue == vec!["bn", "add", "relu"]),
            "no conv+bn+add+relu fusion found"
        );
    }

    #[test]
    fn no_node_claimed_twice() {
        let m = Model::build(ModelKind::MnasNet10ImageNet, 0);
        let part = partition(&m.graph);
        let mut seen = std::collections::BTreeSet::new();
        for sg in &part.subgraphs {
            for &n in &sg.nodes {
                assert!(seen.insert(n), "node {n} in two subgraphs");
            }
        }
    }

    #[test]
    fn task_dedup_matches_repeated_blocks() {
        // ResNet-18 has repeated identical blocks → tasks < subgraphs.
        let m = Model::build(ModelKind::ResNet18ImageNet, 0);
        let (part, table) = extract_tasks(&m.graph);
        assert!(table.len() < part.subgraphs.len());
        // and every subgraph maps to exactly one task
        let covered: usize = table.tasks().map(|t| t.subgraphs.len()).sum();
        assert_eq!(covered, part.subgraphs.len());
    }

    #[test]
    fn overhead_nodes_are_pools_and_flatten() {
        let m = Model::build(ModelKind::Vgg16Cifar, 0);
        let part = partition(&m.graph);
        for &id in &part.overhead_nodes {
            let mn = m.graph.node(id).op.mnemonic();
            assert!(
                matches!(mn, "maxpool" | "gavgpool" | "flatten"),
                "unexpected overhead node {mn}"
            );
        }
    }
}
