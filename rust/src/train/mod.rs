//! Rust-driven training over the AOT-compiled PJRT executables.
//!
//! The paper's Algorithm 1 needs "short-term train and measure a_s"
//! (line 11). For ImageNet-scale workloads that is the analytic proxy; for
//! the CIFAR-scale end-to-end driver it is *real*: `driver` owns the
//! parameters/momentum/masks as PJRT literals, streams synthetic CIFAR-like
//! batches through `train_step.hlo.txt` (whose conv hot-spots are the L1
//! Pallas GEMM), and evaluates with `eval_batch.hlo.txt`. No Python
//! anywhere on this path.
//!
//! Only `driver` touches XLA, so only it is gated behind the `pjrt`
//! feature; the synthetic dataset and the AOT manifest parser are plain
//! Rust and always available (`cprune e2e-info` uses the latter).

pub mod dataset;
#[cfg(feature = "pjrt")]
pub mod driver;
pub mod manifest;

pub use dataset::Dataset;
#[cfg(feature = "pjrt")]
pub use driver::{TrainConfig, TrainedOracle, Trainer};
pub use manifest::Manifest;
