//! Synthetic CIFAR-like dataset (no dataset downloads in this
//! environment; DESIGN.md §2).
//!
//! Ten classes, each a fixed random 32×32×3 template; a sample is its
//! class template blended with per-sample noise and a random spatial
//! jitter. Linearly-nontrivial but learnable: the e2e driver's CNN climbs
//! well above chance within a few hundred SGD steps, which is all the
//! short-term-accuracy signal of Algorithm 1 needs.

use crate::util::rng::Rng;

/// An in-memory labeled image set (NHWC f32 in [0,1], i32 labels).
pub struct Dataset {
    pub img: usize,
    pub classes: usize,
    pub xs: Vec<f32>,
    pub ys: Vec<i32>,
    pub n: usize,
}

impl Dataset {
    /// Generate `n` samples with the given seed.
    pub fn synthetic(n: usize, img: usize, classes: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let pix = img * img * 3;
        // class templates: smooth random fields (low-frequency sums)
        let templates: Vec<Vec<f32>> = (0..classes)
            .map(|c| {
                let mut t_rng = rng.split(c as u64 + 1);
                let fx = 1.0 + t_rng.f32() * 3.0;
                let fy = 1.0 + t_rng.f32() * 3.0;
                let phase = t_rng.f32() * std::f32::consts::TAU;
                let mut t = vec![0.0f32; pix];
                for y in 0..img {
                    for x in 0..img {
                        for ch in 0..3 {
                            let v = ((x as f32 * fx / img as f32
                                + y as f32 * fy / img as f32)
                                * std::f32::consts::TAU
                                + phase
                                + ch as f32 * 1.3)
                                .sin();
                            t[(y * img + x) * 3 + ch] = 0.5 + 0.35 * v;
                        }
                    }
                }
                t
            })
            .collect();

        let mut xs = Vec::with_capacity(n * pix);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let c = (i % classes) as i32;
            let mut s_rng = rng.split(1000 + i as u64);
            let tpl = &templates[c as usize];
            let dx = s_rng.below(5) as isize - 2;
            let dy = s_rng.below(5) as isize - 2;
            for y in 0..img {
                for x in 0..img {
                    let sy = (y as isize + dy).clamp(0, img as isize - 1) as usize;
                    let sx = (x as isize + dx).clamp(0, img as isize - 1) as usize;
                    for ch in 0..3 {
                        let noise = (s_rng.f32() - 0.5) * 0.25;
                        let v = tpl[(sy * img + sx) * 3 + ch] + noise;
                        xs.push(v.clamp(0.0, 1.0));
                    }
                }
            }
            ys.push(c);
        }
        Dataset { img, classes, xs, ys, n }
    }

    /// Split off the last `n_eval` samples as a held-out set (same class
    /// templates — the templates are part of the task definition, so train
    /// and eval must share them).
    pub fn split(mut self, n_eval: usize) -> (Dataset, Dataset) {
        assert!(n_eval < self.n);
        let pix = self.img * self.img * 3;
        let n_train = self.n - n_eval;
        let eval_xs = self.xs.split_off(n_train * pix);
        let eval_ys = self.ys.split_off(n_train);
        let eval = Dataset {
            img: self.img,
            classes: self.classes,
            xs: eval_xs,
            ys: eval_ys,
            n: n_eval,
        };
        self.n = n_train;
        (self, eval)
    }

    /// Copy batch `idx` (of size `bs`, wrapping) into contiguous buffers.
    pub fn batch(&self, idx: usize, bs: usize) -> (Vec<f32>, Vec<i32>) {
        let pix = self.img * self.img * 3;
        let mut xs = Vec::with_capacity(bs * pix);
        let mut ys = Vec::with_capacity(bs);
        for k in 0..bs {
            let i = (idx * bs + k) % self.n;
            xs.extend_from_slice(&self.xs[i * pix..(i + 1) * pix]);
            ys.push(self.ys[i]);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let d = Dataset::synthetic(100, 32, 10, 0);
        assert_eq!(d.xs.len(), 100 * 32 * 32 * 3);
        assert_eq!(d.ys.len(), 100);
        assert!(d.xs.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.ys.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn deterministic() {
        let a = Dataset::synthetic(50, 32, 10, 7);
        let b = Dataset::synthetic(50, 32, 10, 7);
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.ys, b.ys);
    }

    #[test]
    fn classes_are_distinguishable() {
        // mean inter-class template distance must exceed intra-class spread
        let d = Dataset::synthetic(200, 16, 4, 1);
        let pix = 16 * 16 * 3;
        let mean_of = |c: i32| -> Vec<f32> {
            let idx: Vec<usize> = (0..d.n).filter(|&i| d.ys[i] == c).collect();
            let mut m = vec![0.0; pix];
            for &i in &idx {
                for (j, v) in d.xs[i * pix..(i + 1) * pix].iter().enumerate() {
                    m[j] += v;
                }
            }
            m.iter().map(|v| v / idx.len() as f32).collect()
        };
        let m0 = mean_of(0);
        let m1 = mean_of(1);
        let dist: f32 = m0.iter().zip(&m1).map(|(a, b)| (a - b) * (a - b)).sum();
        assert!(dist > 1.0, "class means too close: {dist}");
    }

    #[test]
    fn batch_wraps() {
        let d = Dataset::synthetic(10, 8, 2, 0);
        let (xs, ys) = d.batch(3, 4); // starts at 12 % 10
        assert_eq!(xs.len(), 4 * 8 * 8 * 3);
        assert_eq!(ys.len(), 4);
    }
}

#[cfg(test)]
mod split_tests {
    use super::*;

    #[test]
    fn split_preserves_totals_and_templates() {
        let full = Dataset::synthetic(120, 16, 4, 3);
        let snapshot = full.xs.clone();
        let (train, eval) = full.split(40);
        assert_eq!(train.n, 80);
        assert_eq!(eval.n, 40);
        assert_eq!(train.xs.len() + eval.xs.len(), snapshot.len());
        // eval is exactly the tail of the original
        assert_eq!(eval.xs[..], snapshot[80 * 16 * 16 * 3..]);
    }
}
