//! `artifacts/manifest.json` — the AOT calling convention emitted by
//! `python/compile/aot.py`: parameter order/shapes/offsets, mask shapes,
//! conv inventory, batch sizes.
//!
//! Errors are plain `String`s (like `util::json`): this parser must stay
//! available in the dependency-free default build — only the PJRT
//! execution side lives behind the `pjrt` feature.

use crate::util::json::{self, Json};
use std::path::Path;

type Result<T> = std::result::Result<T, String>;

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Byte offset in params_init.bin.
    pub offset: usize,
    pub numel: usize,
}

#[derive(Clone, Debug)]
pub struct MaskEntry {
    pub name: String,
    pub channels: usize,
}

#[derive(Clone, Debug)]
pub struct ConvEntry {
    pub name: String,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub train_batch: usize,
    pub eval_batch: usize,
    pub img: usize,
    pub num_classes: usize,
    pub params: Vec<ParamEntry>,
    pub masks: Vec<MaskEntry>,
    pub convs: Vec<ConvEntry>,
    pub momentum: f64,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("reading {}: {e}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = json::parse(text).map_err(|e| format!("manifest parse: {e}"))?;
        let usize_of = |v: &Json, key: &str| -> Result<usize> {
            v.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("manifest missing {key}"))
        };
        let params = j
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("manifest missing params"))?
            .iter()
            .map(|p| {
                Ok(ParamEntry {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("param missing name"))?
                        .to_string(),
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| format!("param missing shape"))?
                        .iter()
                        .filter_map(Json::as_usize)
                        .collect(),
                    offset: usize_of(p, "offset")?,
                    numel: usize_of(p, "numel")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let masks = j
            .get("masks")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("manifest missing masks"))?
            .iter()
            .map(|m| {
                Ok(MaskEntry {
                    name: m
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("mask missing name"))?
                        .to_string(),
                    channels: m
                        .get("shape")
                        .and_then(Json::as_arr)
                        .and_then(|a| a.first())
                        .and_then(Json::as_usize)
                        .ok_or_else(|| format!("mask missing shape"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let convs = j
            .get("convs")
            .and_then(Json::as_arr)
            .ok_or_else(|| format!("manifest missing convs"))?
            .iter()
            .map(|c| {
                Ok(ConvEntry {
                    name: c
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("conv missing name"))?
                        .to_string(),
                    kh: usize_of(c, "kh")?,
                    kw: usize_of(c, "kw")?,
                    cin: usize_of(c, "cin")?,
                    cout: usize_of(c, "cout")?,
                    stride: usize_of(c, "stride")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            train_batch: usize_of(&j, "train_batch")?,
            eval_batch: usize_of(&j, "eval_batch")?,
            img: usize_of(&j, "img")?,
            num_classes: usize_of(&j, "num_classes")?,
            params,
            masks,
            convs,
            momentum: j
                .get("momentum")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("manifest missing momentum"))?,
        })
    }

    /// Load the initial parameters binary as per-entry f32 vectors.
    pub fn load_params(&self, bin_path: impl AsRef<Path>) -> Result<Vec<Vec<f32>>> {
        let bytes = std::fs::read(bin_path.as_ref())
            .map_err(|e| format!("reading {}: {e}", bin_path.as_ref().display()))?;
        self.params
            .iter()
            .map(|p| {
                let start = p.offset;
                let end = start + p.numel * 4;
                let slice = bytes
                    .get(start..end)
                    .ok_or_else(|| format!("params_init.bin too short for {}", p.name))?;
                Ok(slice
                    .chunks_exact(4)
                    .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                    .collect())
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "train_batch": 64, "eval_batch": 200, "img": 32, "num_classes": 10,
        "momentum": 0.9,
        "params": [
            {"name": "stem.w", "shape": [3,3,3,16], "offset": 0, "numel": 432},
            {"name": "stem.scale", "shape": [16], "offset": 1728, "numel": 16}
        ],
        "masks": [{"name": "stem.mask", "shape": [16]}],
        "convs": [{"name": "stem", "kh":3, "kw":3, "cin":3, "cout":16,
                   "stride":1, "relu":true}]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.train_batch, 64);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[0].shape, vec![3, 3, 3, 16]);
        assert_eq!(m.masks[0].channels, 16);
        assert_eq!(m.convs[0].cout, 16);
        assert!((m.momentum - 0.9).abs() < 1e-12);
    }

    #[test]
    fn parse_rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"train_batch": 1}"#).is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert_eq!(m.img, 32);
            assert_eq!(m.convs.len(), 9);
            assert_eq!(m.masks.len(), 9);
            // params: 9 convs x 3 + fc.w + fc.b
            assert_eq!(m.params.len(), 29);
            let bin = path.parent().unwrap().join("params_init.bin");
            let params = m.load_params(&bin).unwrap();
            assert_eq!(params.len(), m.params.len());
            for (p, e) in params.iter().zip(&m.params) {
                assert_eq!(p.len(), e.numel);
            }
        }
    }
}
