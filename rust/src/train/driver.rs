//! The training driver: owns parameters on the host, executes the
//! AOT-compiled `train_step` / `eval_batch` via PJRT, and exposes a *real*
//! [`AccuracyOracle`] for the CIFAR-scale end-to-end run.
//!
//! Structured pruning is applied through the channel masks the L2 model
//! takes as inputs (static shapes → one artifact for every pruning state).
//! Mask selection follows the paper: lowest-ℓ1 filters of the *live*
//! parameters are dropped first.

use super::dataset::Dataset;
use super::manifest::Manifest;
use crate::accuracy::{AccuracyOracle, PruneSummary, TrainPhase};
use crate::runtime::{literal_f32, literal_i32, literal_scalar, to_vec_f32, Executable, Runtime};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;

/// Training hyper-parameters for the oracle's phases.
#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub lr: f32,
    pub short_steps: usize,
    pub final_steps: usize,
    pub eval_batches: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { lr: 0.05, short_steps: 40, final_steps: 160, eval_batches: 2 }
    }
}

/// Parameter + momentum + mask state living on the Rust side.
pub struct Trainer {
    pub manifest: Manifest,
    train_exe: Executable,
    eval_exe: Executable,
    params: Vec<Vec<f32>>,
    momentum: Vec<Vec<f32>>,
    /// Mask vectors, in manifest mask order (1.0 = keep).
    masks: Vec<Vec<f32>>,
    pub cfg: TrainConfig,
    pub steps_run: usize,
}

impl Trainer {
    /// Load artifacts and initial parameters.
    pub fn new(rt: &Runtime, cfg: TrainConfig) -> Result<Trainer> {
        // Manifest errors are plain Strings (the parser lives outside the
        // pjrt feature); lift them into anyhow here.
        let manifest =
            Manifest::load(rt.artifact("manifest.json")).map_err(anyhow::Error::msg)?;
        let params = manifest
            .load_params(rt.artifact("params_init.bin"))
            .map_err(anyhow::Error::msg)?;
        let momentum = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let masks = manifest
            .masks
            .iter()
            .map(|m| vec![1.0f32; m.channels])
            .collect();
        Ok(Trainer {
            manifest,
            train_exe: rt.load("train_step")?,
            eval_exe: rt.load("eval_batch")?,
            params,
            momentum,
            masks,
            cfg,
            steps_run: 0,
        })
    }

    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        self.params
            .iter()
            .zip(&self.manifest.params)
            .map(|(data, e)| {
                let dims: Vec<i64> = e.shape.iter().map(|&d| d as i64).collect();
                literal_f32(data, &dims)
            })
            .collect()
    }

    fn mask_literals(&self) -> Result<Vec<xla::Literal>> {
        self.masks
            .iter()
            .map(|m| literal_f32(m, &[m.len() as i64]))
            .collect()
    }

    /// One SGD step; returns the loss.
    pub fn step(&mut self, xs: &[f32], ys: &[i32], lr: f32) -> Result<f32> {
        let b = self.manifest.train_batch;
        let img = self.manifest.img as i64;
        let mut inputs = self.param_literals()?;
        for (data, e) in self.momentum.iter().zip(&self.manifest.params) {
            let dims: Vec<i64> = e.shape.iter().map(|&d| d as i64).collect();
            inputs.push(literal_f32(data, &dims)?);
        }
        inputs.extend(self.mask_literals()?);
        inputs.push(literal_f32(xs, &[b as i64, img, img, 3])?);
        inputs.push(literal_i32(ys, &[b as i64])?);
        inputs.push(literal_scalar(lr));

        let out = self.train_exe.run(&inputs)?;
        let np = self.manifest.params.len();
        if out.len() != 2 * np + 1 {
            return Err(anyhow!("train_step returned {} outputs, want {}", out.len(), 2 * np + 1));
        }
        for (i, lit) in out[..np].iter().enumerate() {
            self.params[i] = to_vec_f32(lit)?;
        }
        for (i, lit) in out[np..2 * np].iter().enumerate() {
            self.momentum[i] = to_vec_f32(lit)?;
        }
        let loss = out[2 * np].to_vec::<f32>().context("loss literal")?[0];
        self.steps_run += 1;
        Ok(loss)
    }

    /// Accuracy over `n_batches` eval batches of the dataset.
    pub fn evaluate(&self, data: &Dataset, n_batches: usize) -> Result<f64> {
        let b = self.manifest.eval_batch;
        let img = self.manifest.img as i64;
        let mut correct = 0.0f64;
        let mut total = 0.0f64;
        for i in 0..n_batches {
            let (xs, ys) = data.batch(i, b);
            let mut inputs = self.param_literals()?;
            inputs.extend(self.mask_literals()?);
            inputs.push(literal_f32(&xs, &[b as i64, img, img, 3])?);
            inputs.push(literal_i32(&ys, &[b as i64])?);
            let out = self.eval_exe.run(&inputs)?;
            correct += out[0].to_vec::<f32>()?[0] as f64;
            total += b as f64;
        }
        Ok(correct / total)
    }

    /// Train for `steps` over `data`, returning the loss curve.
    pub fn train(&mut self, data: &Dataset, steps: usize, lr: f32) -> Result<Vec<f32>> {
        let b = self.manifest.train_batch;
        let mut losses = Vec::with_capacity(steps);
        for s in 0..steps {
            let (xs, ys) = data.batch(self.steps_run + s, b);
            losses.push(self.step(&xs, &ys, lr)?);
        }
        Ok(losses)
    }

    /// Per-filter ℓ1 norms of a conv's live weights (HWIO layout: the
    /// filter index is the fastest-varying dimension).
    pub fn filter_l1(&self, conv_name: &str) -> Result<Vec<f32>> {
        let w_name = format!("{conv_name}.w");
        let (idx, entry) = self
            .manifest
            .params
            .iter()
            .enumerate()
            .find(|(_, e)| e.name == w_name)
            .ok_or_else(|| anyhow!("no param {w_name}"))?;
        let cout = *entry.shape.last().ok_or_else(|| anyhow!("param {w_name} has empty shape"))?;
        let mut norms = vec![0.0f32; cout];
        for (i, v) in self.params[idx].iter().enumerate() {
            norms[i % cout] += v.abs();
        }
        Ok(norms)
    }

    /// Apply a pruning state: for each conv keep the `remaining` filters of
    /// largest live ℓ1 norm (mask the rest to 0). `remaining_by_conv` maps
    /// manifest conv names (e.g. "b1c1") to channel counts; absent convs
    /// stay fully unmasked.
    pub fn set_masks(&mut self, remaining_by_conv: &BTreeMap<String, usize>) -> Result<()> {
        for (mi, mask_entry) in self.manifest.masks.iter().enumerate() {
            let conv_name = mask_entry
                .name
                .strip_suffix(".mask")
                .unwrap_or(&mask_entry.name)
                .to_string();
            let channels = mask_entry.channels;
            let keep = remaining_by_conv
                .get(&conv_name)
                .copied()
                .unwrap_or(channels)
                .min(channels);
            let mut mask = vec![0.0f32; channels];
            if keep == channels {
                mask.iter_mut().for_each(|m| *m = 1.0);
            } else {
                let norms = self.filter_l1(&conv_name)?;
                let mut order: Vec<usize> = (0..channels).collect();
                order.sort_by(|&a, &b| {
                    norms[b].total_cmp(&norms[a]).then(a.cmp(&b))
                });
                for &f in order.iter().take(keep) {
                    mask[f] = 1.0;
                }
            }
            self.masks[mi] = mask;
        }
        Ok(())
    }

    /// Snapshot / restore for stateless oracle queries.
    pub fn snapshot(&self) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>, usize) {
        (self.params.clone(), self.momentum.clone(), self.masks.clone(), self.steps_run)
    }

    pub fn restore(&mut self, snap: (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>, usize)) {
        self.params = snap.0;
        self.momentum = snap.1;
        self.masks = snap.2;
        self.steps_run = snap.3;
    }

    pub fn mask_vectors(&self) -> &[Vec<f32>] {
        &self.masks
    }
}

/// A real [`AccuracyOracle`]: short-term/final accuracy measured by actual
/// PJRT training of the masked CNN. Only meaningful for
/// `ModelKind::ResNet8Cifar` (the e2e workload).
pub struct TrainedOracle<'a> {
    pub trainer: &'a mut Trainer,
    pub train_data: &'a Dataset,
    pub eval_data: &'a Dataset,
    /// Graph-node-id → manifest conv name, built from the model.
    pub conv_names: BTreeMap<usize, String>,
}

impl<'a> TrainedOracle<'a> {
    pub fn new(
        trainer: &'a mut Trainer,
        train_data: &'a Dataset,
        eval_data: &'a Dataset,
        model: &crate::graph::model_zoo::Model,
    ) -> TrainedOracle<'a> {
        // graph nodes are named "<conv>.conv"
        let conv_names = model
            .graph
            .conv_ids()
            .into_iter()
            .map(|id| {
                let nm = model.graph.node(id).name.clone();
                (id, nm.trim_end_matches(".conv").to_string())
            })
            .collect();
        TrainedOracle { trainer, train_data, eval_data, conv_names }
    }

    fn remaining_map(&self, summary: &PruneSummary) -> BTreeMap<String, usize> {
        summary
            .layers
            .iter()
            .filter_map(|l| {
                self.conv_names
                    .get(&l.conv)
                    .map(|n| (n.clone(), l.remaining_channels))
            })
            .collect()
    }
}

impl AccuracyOracle for TrainedOracle<'_> {
    fn top1(&mut self, summary: &PruneSummary, phase: TrainPhase) -> f64 {
        let snap = self.trainer.snapshot();
        let remaining = self.remaining_map(summary);
        let steps = match phase {
            TrainPhase::Short => self.trainer.cfg.short_steps,
            TrainPhase::Final => self.trainer.cfg.final_steps,
        };
        let lr = self.trainer.cfg.lr;
        let result = (|| -> Result<f64> {
            self.trainer.set_masks(&remaining)?;
            self.trainer.train(self.train_data, steps, lr)?;
            self.trainer.evaluate(self.eval_data, self.trainer.cfg.eval_batches)
        })();
        self.trainer.restore(snap);
        result.unwrap_or(0.0)
    }
}
