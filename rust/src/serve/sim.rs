//! Deterministic discrete-event serving simulator (DESIGN.md §8).
//!
//! Answers the deployment question the Pareto registry exists for: given
//! the frontiers CPrune produced for every device of a fleet, what
//! latency distribution, throughput and SLO-violation rate does a given
//! request load see? The model:
//!
//! * **Arrivals** — a seeded Poisson process (exponential inter-arrival
//!   gaps from [`Rng`]), so a trace is a pure function of
//!   `(trace_seed, rps, requests)`.
//! * **Batching queue** — one global FIFO; a dispatch takes up to
//!   `max_batch` requests that have already arrived when service starts.
//!   Batched execution amortizes dispatch and weight traffic: a batch of
//!   `b` costs `latency · (1 + 0.5·(b−1))`, i.e. each extra request costs
//!   half a solo run.
//! * **Dispatch** — work-conserving across device lanes: each batch goes
//!   to the lane that frees earliest (ties to the lowest lane index).
//! * **SLO-aware policy** — per lane, prefer the *fastest* frontier
//!   point meeting the accuracy floor; while the batch's oldest request
//!   would still miss the SLO, degrade down the frontier to faster,
//!   less-accurate checkpoints (never past the fastest point). Load
//!   sheds accuracy before it sheds latency.
//!
//! Everything is pure arithmetic over the trace — no wall clock, no
//! threads — so a report is byte-identical across runs and across the
//! `threads` budget of whatever tuning produced the frontiers.

use super::pareto::ParetoSet;
use super::registry::Registry;
use crate::device::Target;
use crate::tuner::FleetSession;
use crate::util::rng::Rng;
use crate::util::stats;
use std::fmt::Write as _;

/// Marginal cost of each request beyond the first in a batch, as a
/// fraction of a solo execution (see module docs).
const BATCH_MARGINAL: f64 = 0.5;

/// Serving-simulation knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// Mean arrival rate of the synthetic trace, requests/second.
    pub rps: f64,
    /// Trace length in requests.
    pub requests: usize,
    /// Per-request latency SLO (arrival → completion), milliseconds.
    pub slo_ms: f64,
    /// Accuracy the policy serves when the SLO allows it; under load it
    /// degrades below this floor rather than miss the SLO.
    pub accuracy_floor: f64,
    /// Seed of the arrival trace (independent of tuning seeds).
    pub trace_seed: u64,
    /// Maximum requests per dispatched batch.
    pub max_batch: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            rps: 50.0,
            requests: 2000,
            slo_ms: 50.0,
            accuracy_floor: 0.0,
            trace_seed: 0,
            max_batch: 8,
        }
    }
}

struct Lane {
    name: String,
    frontier: ParetoSet,
    /// Index into the frontier of the fastest point meeting the accuracy
    /// floor (the policy's preferred model on this lane).
    preferred: usize,
}

/// Aggregate statistics of one simulated trace.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeReport {
    pub opts_rps: f64,
    pub slo_ms: f64,
    pub accuracy_floor: f64,
    pub max_batch: usize,
    pub requests: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Completed requests per second over the trace's makespan.
    pub throughput_rps: f64,
    pub slo_violations: usize,
    pub violation_rate: f64,
    /// Mean accuracy of the checkpoints requests were actually served by.
    pub mean_served_accuracy: f64,
    /// Requests served by a point faster (less accurate) than the lane's
    /// preferred model because the SLO was under pressure.
    pub degraded_requests: usize,
    pub batches: usize,
    pub mean_batch: f64,
    /// Requests served per device lane, in lane order.
    pub per_device: Vec<(String, usize)>,
}

impl ServeReport {
    /// Render the report as a fixed-format block. Every field prints with
    /// a fixed precision from deterministic inputs, so two runs with the
    /// same seed produce byte-identical text (the CLI prints exactly
    /// this).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "serve: {} requests @ {:.1} rps, SLO {:.1} ms, accuracy floor {:.3}, max batch {}",
            self.requests, self.opts_rps, self.slo_ms, self.accuracy_floor, self.max_batch
        );
        let _ = writeln!(
            out,
            "latency: p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms  mean {:.3} ms",
            self.p50_ms, self.p95_ms, self.p99_ms, self.mean_ms
        );
        let _ = writeln!(
            out,
            "throughput: {:.2} rps in {} batches (mean batch {:.2})",
            self.throughput_rps, self.batches, self.mean_batch
        );
        let _ = writeln!(
            out,
            "slo: {} violations ({:.2}%) | served accuracy {:.4} | degraded {} requests ({:.2}%)",
            self.slo_violations,
            self.violation_rate * 100.0,
            self.mean_served_accuracy,
            self.degraded_requests,
            100.0 * self.degraded_requests as f64 / self.requests.max(1) as f64
        );
        for (name, served) in &self.per_device {
            let _ = writeln!(
                out,
                "lane {name}: {served} requests ({:.1}%)",
                100.0 * *served as f64 / self.requests.max(1) as f64
            );
        }
        out
    }
}

/// The serving simulator: device lanes + knobs. Build with
/// [`Simulator::new`] + [`Simulator::add_device`] (or
/// [`Simulator::across_fleet`]), then [`Simulator::run`] as many times as
/// needed — `run` never mutates the simulator, so repeated runs replay
/// the identical trace.
pub struct Simulator {
    lanes: Vec<Lane>,
    opts: ServeOptions,
}

impl Simulator {
    pub fn new(opts: ServeOptions) -> Simulator {
        Simulator { lanes: Vec::new(), opts }
    }

    /// Add a device lane serving from `frontier`. Rejects empty frontiers
    /// (a lane with nothing deployable cannot serve).
    pub fn add_device(&mut self, name: &str, frontier: &ParetoSet) -> Result<(), String> {
        if frontier.is_empty() {
            return Err(format!("device '{name}': empty Pareto frontier"));
        }
        let preferred = frontier
            .points()
            .iter()
            .position(|c| c.accuracy >= self.opts.accuracy_floor)
            // no point meets the floor: serve the most accurate one
            .unwrap_or(frontier.len() - 1);
        self.lanes.push(Lane { name: name.to_string(), frontier: frontier.clone(), preferred });
        Ok(())
    }

    /// Build a simulator whose lanes are the devices of `fleet`, each
    /// serving the registry's frontier for `model` on that device.
    pub fn across_fleet(
        fleet: &FleetSession,
        registry: &Registry,
        model: &str,
        opts: ServeOptions,
    ) -> Result<Simulator, String> {
        let mut sim = Simulator::new(opts);
        for i in 0..fleet.num_devices() {
            let device = fleet.target(i).spec().name;
            let set = registry.get(model, device).ok_or_else(|| {
                format!("registry holds no Pareto set for ({model}, {device})")
            })?;
            sim.add_device(device, set)?;
        }
        Ok(sim)
    }

    pub fn num_devices(&self) -> usize {
        self.lanes.len()
    }

    /// Simulate one trace and aggregate the statistics.
    pub fn run(&self) -> Result<ServeReport, String> {
        if self.lanes.is_empty() {
            return Err("serving simulator has no device lanes".into());
        }
        if !(self.opts.rps.is_finite() && self.opts.rps > 0.0) {
            return Err(format!("--rps must be positive, got {}", self.opts.rps));
        }
        let n = self.opts.requests.max(1);
        let max_batch = self.opts.max_batch.max(1);
        let slo_s = self.opts.slo_ms / 1e3;

        // -- Arrivals: seeded Poisson process ------------------------------
        let mut rng = Rng::new(self.opts.trace_seed);
        let mut arrivals = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for _ in 0..n {
            t += -(1.0 - rng.f64()).ln() / self.opts.rps;
            arrivals.push(t);
        }

        // -- Event loop ----------------------------------------------------
        let mut free_at = vec![0.0f64; self.lanes.len()];
        let mut served = vec![0usize; self.lanes.len()];
        let mut sojourn_ms = Vec::with_capacity(n);
        let mut slo_violations = 0usize;
        let mut degraded_requests = 0usize;
        let mut accuracy_sum = 0.0f64;
        let mut batches = 0usize;
        let mut makespan = 0.0f64;
        let mut i = 0usize;
        while i < n {
            // `min_by` keeps the FIRST of equally-minimum elements
            // (std::cmp::min_by returns its first argument on Equal), so
            // free-lane ties deterministically go to the lowest index.
            let lane_idx = (0..self.lanes.len())
                .min_by(|&a, &b| free_at[a].total_cmp(&free_at[b]))
                .expect("at least one lane"); // cprune-lint: allow(CPL005, reason="run() already errored if lanes were empty")
            let lane = &self.lanes[lane_idx];
            let start = arrivals[i].max(free_at[lane_idx]);

            // Batch: everything already queued when service starts.
            let mut end = i + 1;
            while end < n && end - i < max_batch && arrivals[end] <= start {
                end += 1;
            }
            let batch = end - i;

            // Policy: degrade down the frontier while the oldest request
            // in the batch would miss the SLO.
            let points = lane.frontier.points();
            let mut k = lane.preferred;
            loop {
                let service = batch_service(points[k].latency, batch);
                if start + service - arrivals[i] <= slo_s || k == 0 {
                    break;
                }
                k -= 1;
            }
            let service = batch_service(points[k].latency, batch);
            let done = start + service;
            for r in i..end {
                let s_ms = (done - arrivals[r]) * 1e3;
                sojourn_ms.push(s_ms);
                if s_ms > self.opts.slo_ms {
                    slo_violations += 1;
                }
                if k < lane.preferred {
                    degraded_requests += 1;
                }
                accuracy_sum += points[k].accuracy;
            }
            served[lane_idx] += batch;
            free_at[lane_idx] = done;
            makespan = makespan.max(done);
            batches += 1;
            i = end;
        }

        Ok(ServeReport {
            opts_rps: self.opts.rps,
            slo_ms: self.opts.slo_ms,
            accuracy_floor: self.opts.accuracy_floor,
            max_batch,
            requests: n,
            p50_ms: stats::percentile(&sojourn_ms, 50.0),
            p95_ms: stats::percentile(&sojourn_ms, 95.0),
            p99_ms: stats::percentile(&sojourn_ms, 99.0),
            mean_ms: stats::mean(&sojourn_ms),
            throughput_rps: n as f64 / makespan,
            slo_violations,
            violation_rate: slo_violations as f64 / n as f64,
            mean_served_accuracy: accuracy_sum / n as f64,
            degraded_requests,
            batches,
            mean_batch: n as f64 / batches as f64,
            per_device: self
                .lanes
                .iter()
                .zip(&served)
                .map(|(l, &s)| (l.name.clone(), s))
                .collect(),
        })
    }
}

/// Service time of a `b`-request batch with per-request base `latency`.
fn batch_service(latency: f64, b: usize) -> f64 {
    latency * (1.0 + BATCH_MARGINAL * (b - 1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::pareto::Checkpoint;
    use std::collections::BTreeMap;

    fn cp(iteration: usize, latency: f64, accuracy: f64) -> Checkpoint {
        Checkpoint {
            iteration,
            latency,
            accuracy,
            channels: BTreeMap::new(),
            schemes: BTreeMap::new(),
        }
    }

    /// 3-point frontier: 2 ms @ 0.80, 5 ms @ 0.85, 20 ms @ 0.92.
    fn frontier() -> ParetoSet {
        let mut s = ParetoSet::new();
        s.insert(cp(2, 0.002, 0.80));
        s.insert(cp(1, 0.005, 0.85));
        s.insert(cp(0, 0.020, 0.92));
        s
    }

    fn sim(rps: f64, slo_ms: f64, floor: f64) -> Simulator {
        let mut sim = Simulator::new(ServeOptions {
            rps,
            requests: 800,
            slo_ms,
            accuracy_floor: floor,
            trace_seed: 7,
            max_batch: 8,
        });
        sim.add_device("devA", &frontier()).unwrap();
        sim
    }

    #[test]
    fn repeated_runs_are_byte_identical() {
        let s = sim(80.0, 30.0, 0.90);
        let a = s.run().unwrap();
        let b = s.run().unwrap();
        assert_eq!(a, b);
        assert_eq!(a.render(), b.render());
        // a different trace seed produces a different trace
        let mut other = Simulator::new(ServeOptions { trace_seed: 8, ..ServeOptions::default() });
        other.add_device("devA", &frontier()).unwrap();
        assert_ne!(other.run().unwrap().render(), a.render());
    }

    #[test]
    fn light_load_serves_the_preferred_model_within_slo() {
        // 5 rps against a 20 ms model: no queueing to speak of.
        let r = sim(5.0, 100.0, 0.90).run().unwrap();
        assert_eq!(r.degraded_requests, 0);
        assert_eq!(r.slo_violations, 0);
        assert!((r.mean_served_accuracy - 0.92).abs() < 1e-12);
        // ≈ the 20 ms service time (less one ulp of float rounding)
        assert!(r.p50_ms >= 19.9, "sojourn below pure service time");
        assert!(r.p99_ms <= 100.0);
    }

    #[test]
    fn overload_degrades_down_the_frontier_and_batches() {
        // 400 rps against a 20 ms preferred model on one lane is far past
        // capacity; the policy must shed accuracy and batch heavily.
        let heavy = sim(400.0, 30.0, 0.90).run().unwrap();
        let light = sim(5.0, 100.0, 0.90).run().unwrap();
        assert!(heavy.degraded_requests > 0, "no degradation under overload");
        assert!(heavy.mean_served_accuracy < light.mean_served_accuracy);
        assert!(heavy.mean_batch > 1.5, "batching never kicked in");
        assert!(heavy.throughput_rps > light.throughput_rps);
    }

    #[test]
    fn extra_lanes_raise_throughput_and_cut_tail_latency() {
        let one = sim(300.0, 30.0, 0.90).run().unwrap();
        let mut two = Simulator::new(ServeOptions {
            rps: 300.0,
            requests: 800,
            slo_ms: 30.0,
            accuracy_floor: 0.90,
            trace_seed: 7,
            max_batch: 8,
        });
        two.add_device("devA", &frontier()).unwrap();
        two.add_device("devB", &frontier()).unwrap();
        let two = two.run().unwrap();
        assert!(two.p99_ms < one.p99_ms, "second lane did not help the tail");
        assert!(two.violation_rate <= one.violation_rate);
        let lane_total: usize = two.per_device.iter().map(|(_, s)| s).sum();
        assert_eq!(lane_total, two.requests);
        assert!(two.per_device.iter().all(|(_, s)| *s > 0), "a lane sat idle");
    }

    #[test]
    fn floor_above_frontier_serves_most_accurate_point() {
        let r = sim(5.0, 1000.0, 0.99).run().unwrap();
        assert!((r.mean_served_accuracy - 0.92).abs() < 1e-12);
    }

    #[test]
    fn empty_frontier_and_no_lanes_are_rejected() {
        let mut s = Simulator::new(ServeOptions::default());
        assert!(s.run().is_err(), "ran with no lanes");
        assert!(s.add_device("devA", &ParetoSet::new()).is_err());
    }
}
