//! The serving layer: Pareto-set model registry + deterministic serving
//! simulator (DESIGN.md §8).
//!
//! CPrune's whole premise is that the compiler-measured latency/accuracy
//! trade-off should drive which model you run — so the search's accepted
//! iterations are not intermediate garbage, they are the deployment
//! candidates. This module keeps them and serves from them:
//!
//! * [`pareto`] — [`Checkpoint`] (a deployable snapshot of an accepted
//!   iteration, including any per-layer sparsity schemes from
//!   [`crate::sparsity`], DESIGN.md §16) and [`ParetoSet`] (the
//!   non-dominated latency/accuracy frontier a
//!   [`crate::pruner::CPruneResult`] now exposes);
//! * [`registry`] — [`Registry`], frontiers per `(model, device)` pair
//!   with versioned-JSON persistence following the
//!   [`crate::tuner::cache`] conventions;
//! * [`sim`] — [`Simulator`], a seeded discrete-event loop (Poisson
//!   arrivals, batching queue, work-conserving dispatch across
//!   [`crate::tuner::FleetSession`] devices, SLO-aware frontier
//!   degradation) reporting p50/p95/p99 latency, throughput and
//!   SLO-violation rate via [`crate::util::stats`].
//!
//! `cprune serve` wires this end-to-end; `exp::serving` sweeps the
//! throughput-vs-SLO grid the `serving` bench regenerates.
//!
//! Determinism here is machine-enforced: `cprune-lint` (DESIGN.md §12)
//! denies wall-clock/env reads, f32 latency math and hash-ordered
//! iteration throughout `serve/`. Frontier and registry data are
//! machine-checked too: [`crate::verify::artifact`] (DESIGN.md §13)
//! validates persisted registries (`CPV13x` frontier invariants),
//! [`ParetoSet`] re-checks itself after every mutation in debug builds,
//! and loading refuses to silently repair a corrupt frontier.

pub mod pareto;
pub mod registry;
pub mod sim;

pub use pareto::{Checkpoint, ParetoSet};
pub use registry::{Registry, REGISTRY_FORMAT, REGISTRY_VERSION};
pub use sim::{ServeOptions, ServeReport, Simulator};
