//! Pareto-set model registry: frontiers per (model, device) pair with
//! versioned-JSON persistence (DESIGN.md §8).
//!
//! The serving layer never asks "which single model did the search
//! return" — it asks "what frontier do I hold for this model on this
//! device". The registry is that lookup, following the
//! [`crate::tuner::cache`] persistence conventions: a `format`/`version`
//! header that rejects foreign documents loudly, entries sorted on write
//! so files are byte-stable, and temp-file + rename saves so an
//! interrupted write never leaves a truncated registry behind.
//!
//! Unlike a tune cache, one registry file spans *many* devices — each
//! entry's key carries the device name, so no `expected_device` guard is
//! needed on load.

use super::pareto::ParetoSet;
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::path::Path;

/// Format tag of the on-disk header (guards against foreign JSON files).
pub const REGISTRY_FORMAT: &str = "cprune-pareto-registry";
/// Bump when the entry schema changes; `parse` rejects other versions.
pub const REGISTRY_VERSION: u64 = 1;

/// Pareto frontiers keyed by `(model, device)`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    sets: BTreeMap<(String, String), ParetoSet>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Merge `set` into the frontier stored for `(model, device)` —
    /// repeated runs union their frontiers rather than overwriting.
    /// Returns the frontier size after the merge.
    pub fn publish(&mut self, model: &str, device: &str, set: &ParetoSet) -> usize {
        let entry = self
            .sets
            .entry((model.to_string(), device.to_string()))
            .or_default();
        entry.merge(set);
        entry.len()
    }

    pub fn get(&self, model: &str, device: &str) -> Option<&ParetoSet> {
        self.sets.get(&(model.to_string(), device.to_string()))
    }

    /// Number of (model, device) pairs held.
    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// All entries as `(model, device, frontier)`, in key order.
    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &ParetoSet)> {
        self.sets.iter().map(|((m, d), s)| (m.as_str(), d.as_str(), s))
    }

    /// Serialize to the versioned JSON document. The `sets` map is a
    /// `BTreeMap`, so output order (and therefore the file's bytes) is
    /// stable across runs.
    pub fn to_json(&self) -> Json {
        let entries = self
            .sets
            .iter()
            .map(|((model, device), set)| {
                Json::obj(vec![
                    ("model", Json::Str(model.clone())),
                    ("device", Json::Str(device.clone())),
                    ("pareto", set.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("format", Json::Str(REGISTRY_FORMAT.to_string())),
            ("version", Json::Num(REGISTRY_VERSION as f64)),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Parse a document produced by [`Registry::to_json`].
    pub fn parse(text: &str) -> Result<Registry, String> {
        let j = json::parse(text)?;
        match j.get("format").and_then(Json::as_str) {
            Some(REGISTRY_FORMAT) => {}
            other => return Err(format!("not a pareto registry (format {other:?})")),
        }
        match j.get("version").and_then(Json::as_usize) {
            Some(v) if v as u64 == REGISTRY_VERSION => {}
            other => {
                return Err(format!(
                    "unsupported registry version {other:?} (want {REGISTRY_VERSION})"
                ))
            }
        }
        let mut reg = Registry::new();
        let entries = j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("registry missing entries")?;
        for e in entries {
            let model = e
                .get("model")
                .and_then(Json::as_str)
                .ok_or("entry missing model")?;
            let device = e
                .get("device")
                .and_then(Json::as_str)
                .ok_or("entry missing device")?;
            let set = ParetoSet::from_json(e.get("pareto").ok_or("entry missing pareto")?)?;
            reg.publish(model, device, &set);
        }
        Ok(reg)
    }

    /// Write the registry atomically ([`crate::util::io::atomic_write`],
    /// DESIGN.md §15).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let path = path.as_ref();
        let text = self.to_json().to_string();
        // Debug builds sweep the serialized document through the artifact
        // checker (DESIGN.md §13) before it can reach disk.
        #[cfg(debug_assertions)]
        if let Some(d) =
            crate::verify::artifact::check_text(&text).and_then(|ds| ds.into_iter().next())
        {
            panic!("Registry::save produced a non-canonical document: {d}");
        }
        crate::util::io::atomic_write(path, &text, "registry")
    }

    /// Load a registry previously written by [`Registry::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Registry, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::pareto::Checkpoint;
    use std::collections::BTreeMap;

    fn cp(iteration: usize, latency: f64, accuracy: f64) -> Checkpoint {
        Checkpoint {
            iteration,
            latency,
            accuracy,
            channels: BTreeMap::new(),
            schemes: BTreeMap::new(),
        }
    }

    fn sample_set() -> ParetoSet {
        let mut s = ParetoSet::new();
        s.insert(cp(0, 0.010, 0.93));
        s.insert(cp(2, 0.004, 0.91));
        s
    }

    #[test]
    fn publish_merges_instead_of_overwriting() {
        let mut reg = Registry::new();
        assert_eq!(reg.publish("m", "d", &sample_set()), 2);
        let mut more = ParetoSet::new();
        more.insert(cp(5, 0.002, 0.90));
        assert_eq!(reg.publish("m", "d", &more), 3);
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get("m", "d").unwrap().len(), 3);
        assert!(reg.get("m", "other").is_none());
    }

    #[test]
    fn json_roundtrip_and_stable_bytes() {
        let mut reg = Registry::new();
        reg.publish("resnet-8", "devB", &sample_set());
        reg.publish("resnet-8", "devA", &sample_set());
        let text = reg.to_json().to_string();
        let back = Registry::parse(&text).unwrap();
        assert_eq!(back, reg);
        assert_eq!(back.to_json().to_string(), text);
        // entries come out in key order (devA before devB)
        let devices: Vec<&str> = back.entries().map(|(_, d, _)| d).collect();
        assert_eq!(devices, vec!["devA", "devB"]);
    }

    #[test]
    fn rejects_foreign_and_versioned_documents() {
        assert!(Registry::parse("{}").is_err());
        assert!(Registry::parse("not json").is_err());
        assert!(
            Registry::parse(r#"{"format":"other","version":1,"entries":[]}"#).is_err()
        );
        assert!(Registry::parse(
            r#"{"format":"cprune-pareto-registry","version":999,"entries":[]}"#
        )
        .is_err());
        // a tune-cache file must not silently load as a registry
        assert!(Registry::parse(
            r#"{"format":"cprune-tune-cache","version":1,"device":"d","entries":[]}"#
        )
        .is_err());
        let ok = r#"{"format":"cprune-pareto-registry","version":1,"entries":[]}"#;
        assert!(Registry::parse(ok).unwrap().is_empty());
    }

    #[test]
    fn save_load_via_disk() {
        let mut reg = Registry::new();
        reg.publish("m", "d", &sample_set());
        let path = std::env::temp_dir().join("cprune_registry_unit_test.json");
        reg.save(&path).unwrap();
        let back = Registry::load(&path).unwrap();
        assert_eq!(back, reg);
        let _ = std::fs::remove_file(&path);
    }
}
