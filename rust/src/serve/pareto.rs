//! The latency/accuracy Pareto frontier of a CPrune run (DESIGN.md §8).
//!
//! Algorithm 1 walks a chain of accepted candidates, each strictly faster
//! and usually slightly less accurate than the last — exactly the
//! deployment candidates NetAdapt-style progressive pruning emits. Instead
//! of discarding everything but the final model, every accepted iteration
//! snapshots a [`Checkpoint`] (enough to rebuild the deployable graph) and
//! [`ParetoSet`] keeps the non-dominated subset: the serving layer then
//! picks a point per request-class instead of shipping one fixed model.

use crate::graph::model_zoo::Model;
use crate::graph::ops::{Graph, NodeId};
use crate::sparsity::{SchemeChoice, SchemeMap};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One deployable model snapshot from an accepted CPrune iteration.
///
/// The pruned graph itself is not stored — `channels` is the accepted
/// [`crate::graph::prune::PruneState`]'s per-conv remaining-channel map,
/// and [`Checkpoint::instantiate`] rebuilds the graph from the base model
/// deterministically. That keeps checkpoints cheap to hold, merge and
/// persist while remaining fully deployable.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// Accepted iteration number (0 = the tuned-but-unpruned baseline).
    pub iteration: usize,
    /// Measured latency l_m on the target device, seconds.
    pub latency: f64,
    /// Short-term top-1 accuracy a_s at acceptance time.
    pub accuracy: f64,
    /// Remaining output channels per prunable conv.
    pub channels: BTreeMap<NodeId, usize>,
    /// Sparsity scheme per masked conv (DESIGN.md §16). Convs absent
    /// from the map are dense channel layers, so an empty map is the
    /// classic channel-pruned checkpoint — and serializes identically
    /// to the pre-scheme v1 format (the field is omitted when empty,
    /// and absent on parse means empty), keeping old registries loadable
    /// and old readers working on scheme-free runs.
    pub schemes: SchemeMap,
}

impl Checkpoint {
    /// True iff `self` is at least as good in both objectives and strictly
    /// better in one (lower latency, higher accuracy).
    pub fn dominates(&self, other: &Checkpoint) -> bool {
        self.latency <= other.latency
            && self.accuracy >= other.accuracy
            && (self.latency < other.latency || self.accuracy > other.accuracy)
    }

    /// Rebuild the deployable pruned graph from the base `model`.
    pub fn instantiate(&self, model: &Model) -> Result<Graph, String> {
        crate::graph::prune::apply(&model.graph, &self.channels)
    }

    /// Versioned serialization shared by [`crate::serve::Registry`]
    /// files and the run layer's JSONL event stream (DESIGN.md §9).
    pub fn to_json(&self) -> Json {
        let channels = Json::Obj(
            self.channels
                .iter()
                .map(|(&conv, &c)| (conv.to_string(), Json::Num(c as f64)))
                .collect(),
        );
        let mut fields = vec![
            ("iteration", Json::Num(self.iteration as f64)),
            ("latency", Json::Num(self.latency)),
            ("accuracy", Json::Num(self.accuracy)),
            ("channels", channels),
        ];
        if !self.schemes.is_empty() {
            fields.push((
                "schemes",
                Json::Obj(
                    self.schemes
                        .iter()
                        .map(|(&conv, choice)| (conv.to_string(), choice.to_json()))
                        .collect(),
                ),
            ));
        }
        Json::obj(fields)
    }

    /// Parse a checkpoint serialized by [`Checkpoint::to_json`].
    pub fn from_json(j: &Json) -> Result<Checkpoint, String> {
        let mut channels = BTreeMap::new();
        match j.get("channels") {
            Some(Json::Obj(m)) => {
                for (k, v) in m {
                    let conv: NodeId =
                        k.parse().map_err(|_| format!("bad conv id '{k}' in checkpoint"))?;
                    let c = v.as_usize().ok_or("non-integer channel count")?;
                    channels.insert(conv, c);
                }
            }
            _ => return Err("checkpoint missing channels".into()),
        }
        let mut schemes = SchemeMap::new();
        match j.get("schemes") {
            None => {} // pre-scheme v1 checkpoint: all layers dense
            Some(Json::Obj(m)) => {
                for (k, v) in m {
                    let conv: NodeId = k
                        .parse()
                        .map_err(|_| format!("bad conv id '{k}' in checkpoint schemes"))?;
                    schemes.insert(conv, SchemeChoice::from_json(v)?);
                }
            }
            Some(_) => return Err("checkpoint schemes must be an object".into()),
        }
        Ok(Checkpoint {
            iteration: j
                .get("iteration")
                .and_then(Json::as_usize)
                .ok_or("checkpoint missing iteration")?,
            latency: j
                .get("latency")
                .and_then(Json::as_f64)
                .ok_or("checkpoint missing latency")?,
            accuracy: j
                .get("accuracy")
                .and_then(Json::as_f64)
                .ok_or("checkpoint missing accuracy")?,
            channels,
            schemes,
        })
    }
}

/// The non-dominated latency/accuracy frontier of a run.
///
/// Invariant: points are mutually non-dominated and sorted by ascending
/// latency — which, on a frontier, means ascending accuracy too (a slower
/// point survives only by being more accurate).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ParetoSet {
    points: Vec<Checkpoint>,
}

impl ParetoSet {
    pub fn new() -> ParetoSet {
        ParetoSet::default()
    }

    /// Offer a checkpoint to the frontier. Returns `false` when it was
    /// rejected (dominated by an existing point, an exact duplicate, or
    /// carrying non-finite objectives); dominated incumbents are evicted.
    pub fn insert(&mut self, c: Checkpoint) -> bool {
        if !c.latency.is_finite() || !c.accuracy.is_finite() {
            return false;
        }
        if self
            .points
            .iter()
            .any(|p| p.dominates(&c) || (p.latency == c.latency && p.accuracy == c.accuracy))
        {
            return false;
        }
        self.points.retain(|p| !c.dominates(p));
        let pos = self.points.partition_point(|p| p.latency < c.latency);
        self.points.insert(pos, c);
        self.debug_check_canonical("insert");
        true
    }

    /// Debug-build re-check of the frontier invariant after a mutation,
    /// through the same pass (DESIGN.md §13) the `cprune check` artifact
    /// sweep applies to persisted registries.
    fn debug_check_canonical(&self, _op: &str) {
        #[cfg(debug_assertions)]
        for d in crate::verify::artifact::frontier_diagnostics(&self.points) {
            panic!("ParetoSet::{_op} broke the frontier invariant: {d}");
        }
    }

    /// Frontier points, fastest (lowest-accuracy) first.
    pub fn points(&self) -> &[Checkpoint] {
        &self.points
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The lowest-latency point on the frontier.
    pub fn fastest(&self) -> Option<&Checkpoint> {
        self.points.first()
    }

    /// The highest-accuracy (slowest) point on the frontier.
    pub fn most_accurate(&self) -> Option<&Checkpoint> {
        self.points.last()
    }

    /// The fastest point whose accuracy meets `floor` — the serving
    /// policy's preferred model. `None` when no point qualifies.
    pub fn fastest_meeting(&self, floor: f64) -> Option<&Checkpoint> {
        self.points.iter().find(|c| c.accuracy >= floor)
    }

    /// Fold another frontier into this one (used by
    /// [`crate::serve::Registry`] to merge runs of the same pair).
    pub fn merge(&mut self, other: &ParetoSet) {
        for c in &other.points {
            self.insert(c.clone());
        }
        self.debug_check_canonical("merge");
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "points",
            Json::Arr(self.points.iter().map(Checkpoint::to_json).collect()),
        )])
    }

    /// Parse a frontier serialized by [`ParetoSet::to_json`].
    ///
    /// Strict (DESIGN.md §13): the persisted points must already *be* a
    /// canonical frontier — objectives in range, mutually non-dominated,
    /// ascending in both latency and accuracy. A document that fails
    /// [`crate::verify::artifact::frontier_diagnostics`] is refused with
    /// the diagnostic rather than silently repaired, so registry
    /// corruption surfaces instead of quietly dropping deployable
    /// checkpoints.
    pub fn from_json(j: &Json) -> Result<ParetoSet, String> {
        let arr = j
            .get("points")
            .and_then(Json::as_arr)
            .ok_or("pareto set missing points")?;
        let mut points = Vec::with_capacity(arr.len());
        for p in arr {
            points.push(Checkpoint::from_json(p)?);
        }
        if let Some(d) = crate::verify::artifact::frontier_diagnostics(&points).into_iter().next()
        {
            return Err(format!(
                "persisted frontier is not canonical ({d}); refusing to repair silently"
            ));
        }
        Ok(ParetoSet { points })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cp(iteration: usize, latency: f64, accuracy: f64) -> Checkpoint {
        Checkpoint {
            iteration,
            latency,
            accuracy,
            channels: BTreeMap::new(),
            schemes: SchemeMap::new(),
        }
    }

    #[test]
    fn frontier_keeps_only_non_dominated_points() {
        let mut s = ParetoSet::new();
        assert!(s.insert(cp(0, 0.010, 0.90)));
        assert!(s.insert(cp(1, 0.005, 0.88)));
        // dominated: slower AND less accurate than point 0
        assert!(!s.insert(cp(2, 0.020, 0.85)));
        // dominates point 1: same latency, higher accuracy
        assert!(s.insert(cp(3, 0.005, 0.89)));
        assert_eq!(s.len(), 2);
        assert_eq!(s.fastest().unwrap().iteration, 3);
        assert_eq!(s.most_accurate().unwrap().iteration, 0);
        // sorted ascending in both objectives
        for w in s.points().windows(2) {
            assert!(w[0].latency < w[1].latency);
            assert!(w[0].accuracy < w[1].accuracy);
        }
    }

    #[test]
    fn duplicates_and_non_finite_points_are_rejected() {
        let mut s = ParetoSet::new();
        assert!(s.insert(cp(0, 0.010, 0.90)));
        assert!(!s.insert(cp(1, 0.010, 0.90)), "exact duplicate accepted");
        assert!(!s.insert(cp(2, f64::NAN, 0.95)));
        assert!(!s.insert(cp(3, 0.001, f64::INFINITY)));
        assert_eq!(s.len(), 1);
    }

    #[test]
    fn fastest_meeting_walks_up_the_frontier() {
        let mut s = ParetoSet::new();
        s.insert(cp(0, 0.002, 0.80));
        s.insert(cp(1, 0.005, 0.85));
        s.insert(cp(2, 0.020, 0.92));
        assert_eq!(s.fastest_meeting(0.0).unwrap().iteration, 0);
        assert_eq!(s.fastest_meeting(0.84).unwrap().iteration, 1);
        assert_eq!(s.fastest_meeting(0.90).unwrap().iteration, 2);
        assert!(s.fastest_meeting(0.99).is_none());
    }

    #[test]
    fn json_roundtrip_preserves_the_frontier() {
        let mut s = ParetoSet::new();
        let mut channels = BTreeMap::new();
        channels.insert(3usize, 48usize);
        channels.insert(11, 96);
        s.insert(Checkpoint {
            iteration: 4,
            latency: 0.00123456789,
            accuracy: 0.9125,
            channels,
            schemes: SchemeMap::new(),
        });
        s.insert(cp(0, 0.0101, 0.93));
        let back = ParetoSet::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
        // byte-stable serialization (registry files must not churn)
        assert_eq!(back.to_json().to_string(), s.to_json().to_string());
    }

    #[test]
    fn scheme_field_round_trips_and_is_omitted_when_empty() {
        // empty map serializes exactly like a pre-scheme checkpoint
        let plain = cp(1, 0.004, 0.90);
        let text = plain.to_json().to_string();
        assert!(!text.contains("schemes"), "empty schemes must be omitted: {text}");
        // and a pre-scheme document parses back to an empty map
        let back = Checkpoint::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, plain);

        let mut masked = cp(2, 0.003, 0.89);
        masked.channels.insert(3, 48);
        masked.schemes.insert(3, SchemeChoice::pattern());
        masked.schemes.insert(7, SchemeChoice::block());
        let mtext = masked.to_json().to_string();
        assert!(mtext.contains("\"schemes\""));
        let mback = Checkpoint::from_json(&crate::util::json::parse(&mtext).unwrap()).unwrap();
        assert_eq!(mback, masked);
        assert_eq!(mback.to_json().to_string(), mtext, "byte-stable");

        // a malformed schemes field is refused, not ignored
        let bad = r#"{"accuracy":0.9,"channels":{},"iteration":1,"latency":0.004,"schemes":[]}"#;
        assert!(Checkpoint::from_json(&crate::util::json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn merge_unions_two_frontiers() {
        let mut a = ParetoSet::new();
        a.insert(cp(0, 0.010, 0.90));
        let mut b = ParetoSet::new();
        b.insert(cp(1, 0.004, 0.91)); // dominates a's point
        b.insert(cp(2, 0.002, 0.70));
        a.merge(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.most_accurate().unwrap().iteration, 1);
    }
}
