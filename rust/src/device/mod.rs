//! Target-device models and the latency simulator.
//!
//! The paper measures real phones (Kryo 280/385/585 CPUs, Mali-G72 GPU) and
//! desktop GPUs. None exist in this environment, so `spec.rs` captures each
//! target's architectural parameters and `sim.rs` estimates the latency of a
//! *scheduled program* on a *device* analytically (roofline + schedule
//! efficiency + cache behaviour + measurement noise).
//!
//! What matters for reproducing the paper is not absolute numbers but the
//! *decision landscape*: schedule quality spreads of ~5–30× between worst
//! and best programs, step-function latency vs. channel count (Tang et
//! al. [38]), device-specific optima (a program tuned for 8 cores/
//! 128-bit NEON is wrong for a 18-core GPU), and task latencies that rank
//! consistently. The simulator produces all four (see `sim.rs` tests).

pub mod calibration;
pub mod lut;
pub mod sim;
pub mod spec;

pub use sim::Simulator;
pub use spec::{DeviceKind, DeviceSpec};
