//! Target-device models and the measurement plane (DESIGN.md §11).
//!
//! The paper measures real phones (Kryo 280/385/585 CPUs, Mali-G72 GPU) and
//! desktop GPUs. None exist in this environment, so `spec.rs` captures each
//! target's architectural parameters and `sim.rs` estimates the latency of a
//! *scheduled program* on a *device* analytically (roofline + schedule
//! efficiency + cache behaviour + measurement noise).
//!
//! Everything above this module talks to devices through one seam: the
//! [`Target`] trait (`target.rs`) — `spec()`, `latency()`,
//! `measure_batch()` — with four providers: [`AnalyticTarget`] (the
//! roofline), [`LutTarget`] (calibrated per-layer tables from `lut.rs` /
//! `calibration.rs`, analytic fallback), [`ReplayTarget`]
//! (`replay.rs`: record every measurement to a versioned JSON trace,
//! replay it byte-identically) and [`RemoteTarget`] (`remote/`: a pool
//! of out-of-process workers speaking the `cprune-remote` wire protocol,
//! DESIGN.md §14 — bit-identical to the in-process provider it wraps).
//! Devices resolve by name through [`TargetRegistry`] (`registry.rs`):
//! the five built-ins plus user-defined JSON specs (`--device-file` /
//! `CPRUNE_DEVICES`).
//!
//! What matters for reproducing the paper is not absolute numbers but the
//! *decision landscape*: schedule quality spreads of ~5–30× between worst
//! and best programs, step-function latency vs. channel count (Tang et
//! al. [38]), device-specific optima (a program tuned for 8 cores/
//! 128-bit NEON is wrong for a 18-core GPU), and task latencies that rank
//! consistently. The simulator produces all four (see `sim.rs` tests).
//!
//! `sparse.rs` extends the analytic model to pattern/block-sparse
//! layers (DESIGN.md §16): [`sparse::scheme_factor`] prices a
//! [`crate::tir::sparse::SparseLowering`] per [`DeviceKind`], so CPUs
//! and GPUs rank sparsity schemes differently and the scheme-select
//! pruner can pick per layer by measured latency.
//!
//! Determinism here is machine-enforced: `cprune-lint` (DESIGN.md §12)
//! denies wall-clock/env reads, f32 latency math and hash-ordered
//! iteration throughout `device/`. One documented carve-out: `remote/`'s
//! IO edge may read `Instant` for deadlines/backoff (the values it
//! returns stay RNG-derived and timing-independent — see the lint's
//! `WALLCLOCK_EXEMPT_PREFIXES`).

pub mod calibration;
pub mod lut;
pub mod registry;
pub mod remote;
pub mod replay;
pub mod sim;
pub mod sparse;
pub mod spec;
pub mod target;

pub use registry::{TargetRegistry, DEVICES_ENV};
pub use remote::{RemoteOptions, RemoteTarget};
pub use replay::ReplayTarget;
pub use sim::Simulator;
pub use spec::{DeviceKind, DeviceSpec};
pub use target::{AnalyticTarget, LutTarget, Target};
