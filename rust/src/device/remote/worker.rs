//! The worker side of the remote measurement plane: a serve loop that
//! answers `cprune-remote` v1 frames against any local [`Target`].
//!
//! Workers are deliberately dumb: they hold no RNG and no retry logic.
//! The client draws every jitter multiplier and ships it in the request
//! (see [`super::protocol::Frame::MeasureBatch`]); the worker computes
//! `base = target.latency(w, p)` and folds `mean(base * jitter)` in the
//! exact order [`Target::measure_batch`]'s default does, so a pool of N
//! workers reproduces an in-process provider bit-for-bit.
//!
//! Protocol errors on a request are answered with an `error` frame and
//! the loop keeps serving; a malformed *stream* (bad framing, non-JSON)
//! ends the loop with `Err` — the transport is gone, not one request.

use super::protocol::{read_frame, write_frame, Frame};
use crate::device::Target;
use crate::util::fault::WorkerFault;
use std::io::{BufReader, Read, Write};
use std::net::TcpListener;

/// Serve one connection until EOF or `shutdown`.
pub fn serve(reader: impl Read, writer: impl Write, target: &dyn Target) -> Result<(), String> {
    serve_with_fault(reader, writer, target, WorkerFault::None)
}

/// [`serve`] with an injected fault (loopback tests and `--faults`
/// `die@worker:N`/`hang@worker:N` clauses — real workers always serve
/// with [`WorkerFault::None`]).
pub fn serve_with_fault(
    reader: impl Read,
    writer: impl Write,
    target: &dyn Target,
    fault: WorkerFault,
) -> Result<(), String> {
    let mut r = BufReader::new(reader);
    let mut w = writer;
    let mut served = 0usize;
    loop {
        let frame = match read_frame(&mut r)? {
            Some(f) => f,
            None => return Ok(()), // client closed the stream
        };
        let is_request = matches!(frame, Frame::MeasureBatch { .. } | Frame::Latency { .. });
        if is_request {
            served += 1;
            match fault {
                WorkerFault::DieAfter(n) if served > n => return Ok(()),
                WorkerFault::HangAfter(n) if served > n => continue,
                _ => {}
            }
        }
        let reply = match frame {
            Frame::Hello => {
                Frame::HelloAck { spec: target.spec().clone(), noise_sigma: target.noise_sigma() }
            }
            Frame::MeasureBatch { id, workload, programs, repeats, jitter } => {
                measure_reply(target, id, &workload, &programs, repeats, &jitter)
            }
            Frame::Latency { id, workload, program } => {
                Frame::LatencyResult { id, seconds: target.latency(&workload, &program) }
            }
            Frame::Shutdown => {
                let _ = write_frame(&mut w, &Frame::Bye);
                let _ = w.flush();
                return Ok(());
            }
            other => Frame::Error {
                id: None,
                message: format!("worker cannot serve a {} frame", other.kind()),
            },
        };
        write_frame(&mut w, &reply)?;
        w.flush().map_err(|e| format!("flush failed: {e}"))?;
    }
}

/// Compute one `measure_batch` reply. The fold per program must stay
/// identical to [`Target::measure_batch`]'s default — sum of
/// `base * jitter[k]` in draw order, divided by `repeats` — or remote
/// runs stop being bit-identical to in-process ones.
fn measure_reply(
    target: &dyn Target,
    id: u64,
    workload: &crate::tir::Workload,
    programs: &[crate::tir::Program],
    repeats: usize,
    jitter: &[Vec<f64>],
) -> Frame {
    if repeats == 0 {
        return Frame::Error { id: Some(id), message: "measure_batch with repeats 0".to_string() };
    }
    if jitter.len() != programs.len() {
        return Frame::Error {
            id: Some(id),
            message: format!(
                "measure_batch has {} programs but {} jitter rows",
                programs.len(),
                jitter.len()
            ),
        };
    }
    let mut means = Vec::with_capacity(programs.len());
    for (p, draws) in programs.iter().zip(jitter) {
        if draws.len() != repeats {
            return Frame::Error {
                id: Some(id),
                message: format!(
                    "measure_batch has {} jitter draws for repeats {repeats}",
                    draws.len()
                ),
            };
        }
        let base = target.latency(workload, p);
        means.push(draws.iter().map(|j| base * j).sum::<f64>() / repeats as f64);
    }
    Frame::MeasureResult { id, means }
}

/// Serve frames over stdin/stdout (the `cprune worker --stdio` mode).
/// Stdout carries the protocol, so anything human-readable a worker
/// wants to say must go to stderr.
pub fn serve_stdio(target: &dyn Target) -> Result<(), String> {
    serve(std::io::stdin(), std::io::stdout(), target)
}

/// Serve TCP clients sequentially (the `cprune worker --listen ADDR`
/// mode): one connection at a time, accepting the next after the
/// current client disconnects. N-worker TCP deployments run N processes.
pub fn serve_listen(addr: &str, target: &dyn Target) -> Result<(), String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("cannot listen on {addr}: {e}"))?;
    eprintln!("cprune worker: listening on {addr} (device '{}')", target.spec().name);
    for stream in listener.incoming() {
        let stream = stream.map_err(|e| format!("accept failed: {e}"))?;
        let peer = stream
            .peer_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| "?".to_string());
        let reader = stream.try_clone().map_err(|e| format!("cannot clone socket: {e}"))?;
        match serve(reader, stream, target) {
            Ok(()) => eprintln!("cprune worker: client {peer} disconnected"),
            Err(e) => eprintln!("cprune worker: client {peer} failed: {e}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{AnalyticTarget, DeviceSpec};
    use crate::tir::{Program, Workload};
    use crate::util::rng::Rng;

    fn wl(ff: usize) -> Workload {
        Workload {
            n: 1,
            oh: 8,
            ow: 8,
            ff,
            ic: 16,
            kh: 3,
            kw: 3,
            groups: 1,
            stride: 1,
            epilogue: vec![],
        }
    }

    /// Run `frames` through a serve loop and return the replies.
    fn serve_script(target: &dyn Target, frames: &[Frame]) -> Vec<Frame> {
        let mut input = Vec::new();
        for f in frames {
            write_frame(&mut input, f).unwrap();
        }
        let mut output = Vec::new();
        serve(&input[..], &mut output, target).unwrap();
        let mut r = BufReader::new(&output[..]);
        let mut replies = Vec::new();
        while let Some(f) = read_frame(&mut r).unwrap() {
            replies.push(f);
        }
        replies
    }

    #[test]
    fn serve_answers_hello_measure_latency_and_shutdown() {
        let spec = DeviceSpec::kryo385();
        let target = AnalyticTarget::new(spec.clone());
        let w = wl(64);
        let p = Program::naive(&w);
        let mut rng = Rng::new(11);
        let jitter: Vec<f64> = (0..3).map(|_| rng.lognormal(target.noise_sigma())).collect();
        let replies = serve_script(
            &target,
            &[
                Frame::Hello,
                Frame::MeasureBatch {
                    id: 1,
                    workload: w.clone(),
                    programs: vec![p.clone()],
                    repeats: 3,
                    jitter: vec![jitter.clone()],
                },
                Frame::Latency { id: 2, workload: w.clone(), program: p.clone() },
                Frame::Shutdown,
            ],
        );
        assert_eq!(replies.len(), 4);
        match &replies[0] {
            Frame::HelloAck { spec: s, noise_sigma } => {
                assert_eq!(s.name, spec.name);
                assert_eq!(noise_sigma.to_bits(), target.noise_sigma().to_bits());
            }
            other => panic!("wanted hello_ack, got {other:?}"),
        }
        // the fold matches the in-process default bit-for-bit
        let base = target.latency(&w, &p);
        let want = jitter.iter().map(|j| base * j).sum::<f64>() / 3.0;
        match &replies[1] {
            Frame::MeasureResult { means, .. } => {
                assert_eq!(means.len(), 1);
                assert_eq!(means[0].to_bits(), want.to_bits());
            }
            other => panic!("wanted measure_result, got {other:?}"),
        }
        match &replies[2] {
            Frame::LatencyResult { seconds, .. } => {
                assert_eq!(seconds.to_bits(), base.to_bits());
            }
            other => panic!("wanted latency_result, got {other:?}"),
        }
        assert_eq!(replies[3], Frame::Bye);
    }

    #[test]
    fn malformed_requests_get_error_frames_not_a_dead_worker() {
        let target = AnalyticTarget::new(DeviceSpec::kryo385());
        let w = wl(64);
        let p = Program::naive(&w);
        let replies = serve_script(
            &target,
            &[
                // jitter arity mismatch
                Frame::MeasureBatch {
                    id: 5,
                    workload: w.clone(),
                    programs: vec![p.clone()],
                    repeats: 3,
                    jitter: vec![vec![1.0, 1.0]],
                },
                // a frame only clients should receive
                Frame::MeasureResult { id: 6, means: vec![] },
                // the worker must still be alive to answer this
                Frame::Latency { id: 7, workload: w, program: p },
                Frame::Shutdown,
            ],
        );
        assert!(matches!(&replies[0], Frame::Error { id: Some(5), .. }), "{:?}", replies[0]);
        assert!(matches!(&replies[1], Frame::Error { id: None, .. }), "{:?}", replies[1]);
        assert!(matches!(&replies[2], Frame::LatencyResult { id: 7, .. }), "{:?}", replies[2]);
    }

    #[test]
    fn die_after_fault_cuts_the_stream() {
        let target = AnalyticTarget::new(DeviceSpec::kryo385());
        let w = wl(64);
        let p = Program::naive(&w);
        let mut input = Vec::new();
        for f in [
            Frame::Hello,
            Frame::Latency { id: 1, workload: w.clone(), program: p.clone() },
            Frame::Latency { id: 2, workload: w, program: p },
        ] {
            write_frame(&mut input, &f).unwrap();
        }
        let mut output = Vec::new();
        serve_with_fault(&input[..], &mut output, &target, WorkerFault::DieAfter(1)).unwrap();
        let mut r = BufReader::new(&output[..]);
        let mut replies = Vec::new();
        while let Some(f) = read_frame(&mut r).unwrap() {
            replies.push(f);
        }
        // hello + first latency answered; the second died unanswered
        assert_eq!(replies.len(), 2);
        assert!(matches!(&replies[1], Frame::LatencyResult { id: 1, .. }));
    }
}
