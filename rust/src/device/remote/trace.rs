//! `cprune-remote-trace` v1 — the remote plane's recording format
//! (DESIGN.md §14).
//!
//! Where a `cprune-measure-trace` stores batch *means*, a remote trace
//! stores each measurement's jitter draws *and* its mean: the jitter is
//! what the client drew from the run's RNG, so the trace documents the
//! exact randomness a remote run consumed. `cprune check` validates the
//! extra structure under the `CPV15x` codes
//! ([`crate::verify::Code::RemoteEntry`] and friends).
//!
//! [`RemoteTrace::replay`] converts a trace into a
//! [`ReplayTarget`] (dropping the per-draw detail, keeping the means in
//! call order), so `--replay-trace` accepts either format — see
//! [`load_trace_target`].

use crate::device::replay::ReplayTarget;
use crate::device::spec::DeviceSpec;
use crate::tir::jsonio::{program_from_json, program_to_json, workload_from_json, workload_to_json};
use crate::tir::{Program, Workload};
use crate::util::json::{self, Json};
use std::collections::{HashMap, VecDeque};
use std::path::Path;

/// Format tag of the on-disk remote trace header.
pub const REMOTE_TRACE_FORMAT: &str = "cprune-remote-trace";
/// Bump when the trace schema changes; `parse` rejects other versions.
pub const REMOTE_TRACE_VERSION: u64 = 1;

/// One recorded `measure_batch` result for one program: the jitter
/// multipliers the client drew (exactly `repeats` of them) and the mean
/// the worker folded from them.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub jitter: Vec<f64>,
    pub mean: f64,
}

/// In-memory recording of a remote run, serializable as
/// [`REMOTE_TRACE_FORMAT`] v[`REMOTE_TRACE_VERSION`].
pub struct RemoteTrace {
    spec: DeviceSpec,
    noise_sigma: f64,
    /// Worker count the pool started with (documentation, not replay
    /// input — results do not depend on it).
    workers: usize,
    latencies: HashMap<(Workload, Program), f64>,
    /// Samples per (workload, program, repeats), in call order.
    measurements: HashMap<(Workload, Program, usize), Vec<Sample>>,
}

/// Serialized ordering key — same discipline as the measure-trace's.
fn sort_key(w: &Workload, p: &Program, repeats: Option<usize>) -> String {
    match repeats {
        Some(r) => format!("{}|{}|r{r}", workload_to_json(w), program_to_json(p)),
        None => format!("{}|{}", workload_to_json(w), program_to_json(p)),
    }
}

impl RemoteTrace {
    pub fn new(spec: DeviceSpec, noise_sigma: f64, workers: usize) -> RemoteTrace {
        RemoteTrace {
            spec,
            noise_sigma,
            workers,
            latencies: HashMap::new(),
            measurements: HashMap::new(),
        }
    }

    pub fn record_latency(&mut self, w: &Workload, p: &Program, seconds: f64) {
        self.latencies.entry((w.clone(), p.clone())).or_insert(seconds);
    }

    pub fn record_measurement(
        &mut self,
        w: &Workload,
        p: &Program,
        repeats: usize,
        jitter: Vec<f64>,
        mean: f64,
    ) {
        self.measurements
            .entry((w.clone(), p.clone(), repeats))
            .or_default()
            .push(Sample { jitter, mean });
    }

    /// Total samples recorded.
    pub fn recorded_measurements(&self) -> usize {
        let samples_by_key = &self.measurements;
        samples_by_key.values().map(|s| s.len()).sum()
    }

    /// Serialize (header + sorted entries; byte-stable).
    pub fn to_json(&self) -> Json {
        let lats = &self.latencies;
        let mut lat_entries: Vec<(String, Json)> = lats
            .iter()
            .map(|((w, p), seconds)| {
                (
                    sort_key(w, p, None),
                    Json::obj(vec![
                        ("workload", workload_to_json(w)),
                        ("program", program_to_json(p)),
                        ("seconds", Json::Num(*seconds)),
                    ]),
                )
            })
            .collect();
        lat_entries.sort_by(|a, b| a.0.cmp(&b.0));
        let samples_by_key = &self.measurements;
        // iteration order is immaterial: entries are sorted by their
        // serialized key below, so the document is byte-stable
        let mut batch_entries: Vec<(String, Json)> = samples_by_key
            .iter()
            .map(|((w, p, repeats), samples)| {
                (
                    sort_key(w, p, Some(*repeats)),
                    Json::obj(vec![
                        ("workload", workload_to_json(w)),
                        ("program", program_to_json(p)),
                        ("repeats", Json::Num(*repeats as f64)),
                        (
                            "samples",
                            Json::Arr(
                                samples
                                    .iter()
                                    .map(|s| {
                                        Json::obj(vec![
                                            (
                                                "jitter",
                                                Json::Arr(
                                                    s.jitter
                                                        .iter()
                                                        .map(|&j| Json::Num(j))
                                                        .collect(),
                                                ),
                                            ),
                                            ("mean", Json::Num(s.mean)),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ]),
                )
            })
            .collect();
        batch_entries.sort_by(|a, b| a.0.cmp(&b.0));
        Json::obj(vec![
            ("format", Json::Str(REMOTE_TRACE_FORMAT.to_string())),
            ("version", Json::Num(REMOTE_TRACE_VERSION as f64)),
            ("device", self.spec.to_json()),
            ("noise_sigma", Json::Num(self.noise_sigma)),
            ("workers", Json::Num(self.workers as f64)),
            ("latencies", Json::Arr(lat_entries.into_iter().map(|(_, e)| e).collect())),
            ("measurements", Json::Arr(batch_entries.into_iter().map(|(_, e)| e).collect())),
        ])
    }

    /// Parse a remote-trace document.
    pub fn parse(text: &str) -> Result<RemoteTrace, String> {
        let j = json::parse(text)?;
        match j.get("format").and_then(Json::as_str) {
            Some(REMOTE_TRACE_FORMAT) => {}
            other => return Err(format!("not a remote trace (format {other:?})")),
        }
        match j.get("version").and_then(Json::as_usize) {
            Some(v) if v as u64 == REMOTE_TRACE_VERSION => {}
            other => {
                return Err(format!(
                    "unsupported remote-trace version {other:?} (want {REMOTE_TRACE_VERSION})"
                ))
            }
        }
        let spec = DeviceSpec::from_json(j.get("device").ok_or("remote trace missing device")?)?;
        let noise_sigma = j
            .get("noise_sigma")
            .and_then(Json::as_f64)
            .ok_or("remote trace missing noise_sigma")?;
        let workers = j
            .get("workers")
            .and_then(Json::as_usize)
            .ok_or("remote trace missing workers")?;
        let mut trace = RemoteTrace::new(spec, noise_sigma, workers);
        for e in j.get("latencies").and_then(Json::as_arr).ok_or("remote trace missing latencies")?
        {
            let workload =
                workload_from_json(e.get("workload").ok_or("latency missing workload")?)?;
            let program = program_from_json(e.get("program").ok_or("latency missing program")?)?;
            let seconds =
                e.get("seconds").and_then(Json::as_f64).ok_or("latency missing seconds")?;
            trace.latencies.insert((workload, program), seconds);
        }
        for e in j
            .get("measurements")
            .and_then(Json::as_arr)
            .ok_or("remote trace missing measurements")?
        {
            let workload = workload_from_json(e.get("workload").ok_or("batch missing workload")?)?;
            let program = program_from_json(e.get("program").ok_or("batch missing program")?)?;
            let repeats =
                e.get("repeats").and_then(Json::as_usize).ok_or("batch missing repeats")?;
            let mut samples = Vec::new();
            for s in e.get("samples").and_then(Json::as_arr).ok_or("batch missing samples")? {
                let jitter = s
                    .get("jitter")
                    .and_then(Json::as_arr)
                    .ok_or("sample missing jitter")?
                    .iter()
                    .map(|v| v.as_f64().ok_or_else(|| "non-number jitter draw".to_string()))
                    .collect::<Result<Vec<f64>, _>>()?;
                let mean = s.get("mean").and_then(Json::as_f64).ok_or("sample missing mean")?;
                samples.push(Sample { jitter, mean });
            }
            trace.measurements.insert((workload, program, repeats), samples);
        }
        Ok(trace)
    }

    /// Persist the trace atomically ([`crate::util::io::atomic_write`],
    /// DESIGN.md §15; debug builds sweep the output through the artifact
    /// checker first, like [`ReplayTarget::save`]).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let path = path.as_ref();
        let text = self.to_json().to_string();
        #[cfg(debug_assertions)]
        if let Some(d) =
            crate::verify::artifact::check_text(&text).and_then(|ds| ds.into_iter().next())
        {
            panic!("RemoteTrace::save produced a non-canonical document: {d}");
        }
        crate::util::io::atomic_write(path, &text, "remote-trace")
    }

    /// Load a remote trace from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<RemoteTrace, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Convert into a replay-mode [`ReplayTarget`]: per-sample means in
    /// call order become the replay queues. `source` labels divergence
    /// diagnostics (a file path, or `<remote-trace>`).
    pub fn replay(&self, source: &str) -> ReplayTarget {
        let samples_by_key = &self.measurements;
        // hash-order safe: collected straight back into a map
        let queues: HashMap<(Workload, Program, usize), VecDeque<f64>> = samples_by_key
            .iter()
            .map(|(k, samples)| (k.clone(), samples.iter().map(|s| s.mean).collect()))
            .collect();
        ReplayTarget::from_parts(
            self.spec.clone(),
            self.noise_sigma,
            source.to_string(),
            self.latencies.clone(),
            queues,
        )
    }
}

/// Open either trace format as a replayable target: peeks the `format`
/// tag and dispatches to [`ReplayTarget::load`] (measure traces) or
/// [`RemoteTrace::load`] + [`RemoteTrace::replay`] (remote traces).
/// `--replay-trace` accepts both.
pub fn load_trace_target(path: impl AsRef<Path>) -> Result<ReplayTarget, String> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {}: {e}", path.display()))?;
    let format = json::parse(&text)
        .ok()
        .and_then(|j| j.get("format").and_then(Json::as_str).map(str::to_string));
    if format.as_deref() == Some(REMOTE_TRACE_FORMAT) {
        let trace = RemoteTrace::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(trace.replay(&path.display().to_string()))
    } else {
        ReplayTarget::load(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Target;
    use crate::util::rng::Rng;

    fn wl(ff: usize) -> Workload {
        Workload {
            n: 1,
            oh: 8,
            ow: 8,
            ff,
            ic: 16,
            kh: 3,
            kw: 3,
            groups: 1,
            stride: 1,
            epilogue: vec!["relu"],
        }
    }

    fn sample_trace() -> (RemoteTrace, Workload, Program, Vec<f64>, f64) {
        let w = wl(64);
        let p = Program::naive(&w);
        let mut trace = RemoteTrace::new(DeviceSpec::kryo385(), 0.03, 2);
        let mut rng = Rng::new(3);
        let jitter: Vec<f64> = (0..2).map(|_| rng.lognormal(0.03)).collect();
        let mean = jitter.iter().map(|j| 1.5e-3 * j).sum::<f64>() / 2.0;
        trace.record_latency(&w, &p, 1.5e-3);
        trace.record_measurement(&w, &p, 2, jitter.clone(), mean);
        (trace, w, p, jitter, mean)
    }

    #[test]
    fn remote_trace_round_trips_byte_stably() {
        let (trace, ..) = sample_trace();
        let a = trace.to_json().to_string();
        assert_eq!(a, trace.to_json().to_string());
        let j = json::parse(&a).unwrap();
        assert_eq!(j.get("format").and_then(Json::as_str), Some(REMOTE_TRACE_FORMAT));
        assert_eq!(j.get("workers").and_then(Json::as_usize), Some(2));
        // parse → serialize is the identity
        assert_eq!(RemoteTrace::parse(&a).unwrap().to_json().to_string(), a);
        // foreign documents rejected
        assert!(RemoteTrace::parse("{}").is_err());
    }

    #[test]
    fn replay_conversion_reproduces_means_and_rng_stream() {
        let (trace, w, p, _, mean) = sample_trace();
        let rep = trace.replay("<remote-trace>");
        assert_eq!(rep.spec().name, "Kryo 385 (Galaxy S9)");
        let mut rng = Rng::new(99);
        let got = rep.measure_batch(&w, &[&p], &mut rng, 2);
        assert_eq!(got[0].to_bits(), mean.to_bits());
        assert_eq!(rep.latency(&w, &p).to_bits(), 1.5e-3_f64.to_bits());
        // replay burned exactly the contract's two draws
        let mut fresh = Rng::new(99);
        let _ = fresh.lognormal(0.0);
        let _ = fresh.lognormal(0.0);
        assert_eq!(rng.next_u64(), fresh.next_u64());
    }

    #[test]
    fn save_load_and_format_dispatch() {
        let (trace, w, p, _, mean) = sample_trace();
        let path = std::env::temp_dir().join("cprune_remote_trace_unit_test.json");
        trace.save(&path).unwrap();
        let back = RemoteTrace::load(&path).unwrap();
        assert_eq!(back.recorded_measurements(), 1);
        // load_trace_target dispatches on the format tag
        let rep = load_trace_target(&path).unwrap();
        let mut rng = Rng::new(0);
        assert_eq!(rep.measure_batch(&w, &[&p], &mut rng, 2)[0].to_bits(), mean.to_bits());
        let _ = std::fs::remove_file(&path);
    }
}
