//! The remote measurement plane: out-of-process workers behind the
//! [`crate::device::Target`] seam (DESIGN.md §14).
//!
//! The reference CPrune measures candidate programs over TVM RPC on
//! real phones; this subsystem is that seam's equivalent. A
//! [`RemoteTarget`] multiplexes N workers — `cprune worker` child
//! processes over stdin/stdout, TCP peers, or in-memory loopback
//! threads — behind one `Target`, so the tuner, fleet, compiler and
//! serve layers work unchanged.
//!
//! Layout:
//!
//! * [`protocol`] — `cprune-remote` v1 frames and length-prefixed
//!   framing;
//! * [`transport`] — [`transport::Connection`]: stdio child processes,
//!   TCP, loopback; the wall-clock (deadline) edge;
//! * [`worker`] — the serve loop behind `cprune worker`;
//! * [`pool`] — [`RemoteTarget`]/partitioning/retry (the determinism
//!   invariant lives here);
//! * [`trace`] — `cprune-remote-trace` v1 recording for offline replay.
//!
//! Worker death/hang injection now rides the crate-wide fault plane
//! ([`crate::util::fault::WorkerFault`], DESIGN.md §15): `--faults
//! die@worker:N`/`hang@worker:N` reaches loopback workers through the
//! per-thread hook, and the pool's dead-worker recovery is what those
//! tests exercise.

pub mod pool;
pub mod protocol;
pub mod trace;
pub mod transport;
pub mod worker;

pub use crate::util::fault::WorkerFault;
pub use pool::{RemoteOptions, RemoteTarget};
pub use trace::{load_trace_target, RemoteTrace};
pub use transport::Connection;
