//! Connections to measurement workers: child processes over
//! stdin/stdout, TCP sockets, and in-memory loopback threads for tests.
//!
//! Every transport is wrapped in the same [`Connection`] shape: a boxed
//! writer for requests plus a dedicated reader thread that parses frames
//! into a channel. The channel is what gives every transport a portable
//! deadline — [`Connection::recv_deadline`] is a `recv_timeout`, whether
//! the peer is a pipe, a socket, or a thread.
//!
//! This module is the remote plane's wall-clock edge: deadlines and
//! backoff need `Instant`, which is why `rust/src/device/remote/` holds
//! cprune-lint's one documented CPL003 wall-clock exemption (DESIGN.md
//! §14). Nothing here feeds timing into a measurement value — the
//! numbers a pool returns are computed from client-drawn RNG jitter.

use super::protocol::{read_frame, write_frame, Frame};
use super::worker;
use crate::device::Target;
use crate::util::fault::{self, WorkerFault};
use std::io::{self, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Writer half of an in-memory byte pipe.
struct PipeWriter {
    tx: mpsc::Sender<Vec<u8>>,
}

impl Write for PipeWriter {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.tx
            .send(buf.to_vec())
            .map_err(|_| io::Error::new(io::ErrorKind::BrokenPipe, "loopback peer closed"))?;
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Reader half of an in-memory byte pipe; a dropped sender reads as EOF.
struct PipeReader {
    rx: mpsc::Receiver<Vec<u8>>,
    buf: Vec<u8>,
    pos: usize,
}

impl PipeReader {
    fn new(rx: mpsc::Receiver<Vec<u8>>) -> PipeReader {
        PipeReader { rx, buf: Vec::new(), pos: 0 }
    }
}

impl Read for PipeReader {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if self.pos >= self.buf.len() {
            match self.rx.recv() {
                Ok(chunk) => {
                    self.buf = chunk;
                    self.pos = 0;
                }
                Err(_) => return Ok(0),
            }
        }
        let n = out.len().min(self.buf.len() - self.pos);
        out[..n].copy_from_slice(&self.buf[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// One live worker connection, transport-agnostic.
pub struct Connection {
    desc: String,
    writer: Box<dyn Write + Send>,
    rx: mpsc::Receiver<Result<Frame, String>>,
    child: Option<Child>,
}

impl Connection {
    /// Wrap a raw reader/writer pair: spawns the reader thread that
    /// parses frames into the receive channel.
    fn over(
        desc: String,
        reader: impl Read + Send + 'static,
        writer: impl Write + Send + 'static,
        child: Option<Child>,
    ) -> Connection {
        let (tx, rx) = mpsc::channel();
        std::thread::Builder::new()
            .name(format!("cprune-remote-rx {desc}"))
            .spawn(move || {
                let mut r = BufReader::new(reader);
                loop {
                    match read_frame(&mut r) {
                        Ok(Some(frame)) => {
                            if tx.send(Ok(frame)).is_err() {
                                return; // connection dropped client-side
                            }
                        }
                        Ok(None) => return, // clean EOF: channel disconnect
                        Err(e) => {
                            let _ = tx.send(Err(e));
                            return;
                        }
                    }
                }
            })
            .map(drop)
            .unwrap_or_else(|e| panic!("cannot spawn reader thread for {desc}: {e}"));
        Connection { desc, writer: Box::new(writer), rx, child }
    }

    /// Human-readable peer description (`loopback#2`, `worker-pid:1234`,
    /// `tcp:host:port`) used in every diagnostic about this worker.
    pub fn desc(&self) -> &str {
        &self.desc
    }

    /// Send one frame and flush it to the peer.
    pub fn send(&mut self, frame: &Frame) -> Result<(), String> {
        write_frame(&mut self.writer, frame)
            .and_then(|()| self.writer.flush().map_err(|e| format!("flush failed: {e}")))
            .map_err(|e| format!("{}: {e}", self.desc))
    }

    /// Receive the next frame, failing once `deadline` passes.
    pub fn recv_deadline(&mut self, deadline: Instant) -> Result<Frame, String> {
        let timeout = deadline.saturating_duration_since(Instant::now());
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(frame)) => Ok(frame),
            Ok(Err(e)) => Err(format!("{}: {e}", self.desc)),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                Err(format!("{}: no response within the deadline", self.desc))
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(format!("{}: connection closed", self.desc))
            }
        }
    }

    /// In-memory worker serving `target` on its own thread. Consults the
    /// calling thread's fault plan ([`crate::util::fault`]) — a
    /// `die@worker:N`/`hang@worker:N` clause from `--faults` injects the
    /// corresponding [`WorkerFault`] into every loopback worker spawned
    /// here (DESIGN.md §15).
    pub fn loopback(target: Box<dyn Target>, index: usize) -> Connection {
        Self::loopback_with(target, fault::worker_fault(), index)
    }

    /// In-memory worker with an explicit injected fault (tests).
    pub fn loopback_with(
        target: Box<dyn Target>,
        fault: WorkerFault,
        index: usize,
    ) -> Connection {
        let (client_tx, worker_rx) = mpsc::channel::<Vec<u8>>();
        let (worker_tx, client_rx) = mpsc::channel::<Vec<u8>>();
        std::thread::Builder::new()
            .name(format!("cprune-remote-loopback#{index}"))
            .spawn(move || {
                let r = PipeReader::new(worker_rx);
                let w = PipeWriter { tx: worker_tx };
                // A loopback worker's failure surfaces client-side as
                // EOF/timeout; the Err itself carries no extra signal.
                let _ = worker::serve_with_fault(r, w, target.as_ref(), fault);
            })
            .map(drop)
            .unwrap_or_else(|e| panic!("cannot spawn loopback worker: {e}"));
        Connection::over(
            format!("loopback#{index}"),
            PipeReader::new(client_rx),
            PipeWriter { tx: client_tx },
            None,
        )
    }

    /// Spawn `exe worker --stdio --device NAME` as a child process and
    /// connect over its stdin/stdout. `exe` is normally
    /// [`std::env::current_exe`]; tests pass `CARGO_BIN_EXE_cprune`.
    pub fn spawn_with_exe(exe: &Path, device: &str) -> Result<Connection, String> {
        let mut child = Command::new(exe)
            .args(["worker", "--stdio", "--device", device])
            .stdin(Stdio::piped())
            .stdout(Stdio::piped())
            .spawn()
            .map_err(|e| format!("cannot spawn worker {}: {e}", exe.display()))?;
        let stdin = child.stdin.take().ok_or("worker child has no stdin")?;
        let stdout = child.stdout.take().ok_or("worker child has no stdout")?;
        let desc = format!("worker-pid:{}", child.id());
        Ok(Connection::over(desc, stdout, stdin, Some(child)))
    }

    /// Spawn a worker subprocess from the currently running executable.
    pub fn spawn_worker(device: &str) -> Result<Connection, String> {
        let exe =
            std::env::current_exe().map_err(|e| format!("cannot locate own executable: {e}"))?;
        Self::spawn_with_exe(&exe, device)
    }

    /// Connect to a `cprune worker --listen ADDR` over TCP.
    pub fn connect_tcp(addr: &str) -> Result<Connection, String> {
        let stream = TcpStream::connect(addr).map_err(|e| format!("cannot connect {addr}: {e}"))?;
        let reader = stream.try_clone().map_err(|e| format!("cannot clone socket: {e}"))?;
        Ok(Connection::over(format!("tcp:{addr}"), reader, stream, None))
    }
}

impl Drop for Connection {
    /// Orderly close: ask the worker to shut down, then reap a child
    /// process with a bounded wait (a wedged child is killed rather than
    /// hanging our own exit).
    fn drop(&mut self) {
        let _ = write_frame(&mut self.writer, &Frame::Shutdown);
        let _ = self.writer.flush();
        if let Some(child) = self.child.as_mut() {
            for _ in 0..200 {
                match child.try_wait() {
                    Ok(Some(_)) => return,
                    Ok(None) => std::thread::sleep(Duration::from_millis(10)),
                    Err(_) => break,
                }
            }
            let _ = child.kill();
            let _ = child.wait();
        }
    }
}
