//! Wire protocol of the remote measurement plane: `cprune-remote` v1
//! (DESIGN.md §14).
//!
//! Frames are JSON documents with a length prefix: an ASCII decimal byte
//! count, `\n`, the payload, `\n`. The prefix lets both sides read a
//! frame without a streaming JSON parser, and the trailing newline keeps
//! the stream greppable when captured to a file.
//!
//! Version negotiation happens in the opening exchange: the client's
//! [`Frame::Hello`] and the worker's [`Frame::HelloAck`] each carry
//! `format`/`version`, and either side drops the connection on a
//! mismatch. `HelloAck` also carries the worker's device spec and
//! `noise_sigma` so the pool can verify every worker measures the same
//! device before any measurement is issued.
//!
//! Floats cross the wire as plain JSON numbers: [`Json`]'s writer uses
//! Rust's shortest-round-trip formatting, so every `f64` parses back to
//! the identical bits — the same property the `cprune-measure-trace`
//! schema already relies on.

use crate::device::DeviceSpec;
use crate::tir::jsonio::{
    program_from_json, program_to_json, workload_from_json, workload_to_json,
};
use crate::tir::{Program, Workload};
use crate::util::json::{self, Json};
use std::io::{BufRead, Write};

/// Format tag carried by `Hello`/`HelloAck`.
pub const REMOTE_FORMAT: &str = "cprune-remote";
/// Protocol version negotiated in the opening exchange.
pub const REMOTE_VERSION: u64 = 1;

/// One protocol message (either direction).
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client → worker: opening handshake (carries format/version).
    Hello,
    /// Worker → client: handshake reply with the worker's device.
    HelloAck {
        /// The device the worker measures.
        spec: DeviceSpec,
        /// The worker's measurement-noise sigma (the client draws the
        /// actual jitter — see [`Frame::MeasureBatch::jitter`]).
        noise_sigma: f64,
    },
    /// Client → worker: measure a batch of programs.
    MeasureBatch {
        /// Request id echoed by the matching [`Frame::MeasureResult`].
        id: u64,
        workload: Workload,
        programs: Vec<Program>,
        repeats: usize,
        /// Per-program jitter multipliers, drawn client-side from the
        /// run's RNG (`jitter[i]` has exactly `repeats` draws): shipping
        /// the draws keeps the RNG stream — and therefore every result —
        /// bit-identical to an in-process provider, regardless of how
        /// the pool partitions the batch.
        jitter: Vec<Vec<f64>>,
    },
    /// Worker → client: one mean latency per program, in request order.
    MeasureResult { id: u64, means: Vec<f64> },
    /// Client → worker: noise-free latency of one program.
    Latency { id: u64, workload: Workload, program: Program },
    /// Worker → client: reply to [`Frame::Latency`].
    LatencyResult { id: u64, seconds: f64 },
    /// Client → worker: finish up; the worker replies [`Frame::Bye`]
    /// and exits its serve loop.
    Shutdown,
    /// Worker → client: acknowledges [`Frame::Shutdown`].
    Bye,
    /// Either direction: the peer could not serve a request.
    Error {
        /// The request that failed, when attributable.
        id: Option<u64>,
        message: String,
    },
}

impl Frame {
    /// Frame type tag on the wire.
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::Hello => "hello",
            Frame::HelloAck { .. } => "hello_ack",
            Frame::MeasureBatch { .. } => "measure_batch",
            Frame::MeasureResult { .. } => "measure_result",
            Frame::Latency { .. } => "latency",
            Frame::LatencyResult { .. } => "latency_result",
            Frame::Shutdown => "shutdown",
            Frame::Bye => "bye",
            Frame::Error { .. } => "error",
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("type", Json::Str(self.kind().to_string()))];
        match self {
            Frame::Hello => {
                pairs.push(("format", Json::Str(REMOTE_FORMAT.to_string())));
                pairs.push(("version", Json::Num(REMOTE_VERSION as f64)));
            }
            Frame::HelloAck { spec, noise_sigma } => {
                pairs.push(("format", Json::Str(REMOTE_FORMAT.to_string())));
                pairs.push(("version", Json::Num(REMOTE_VERSION as f64)));
                pairs.push(("device", spec.to_json()));
                pairs.push(("noise_sigma", Json::Num(*noise_sigma)));
            }
            Frame::MeasureBatch { id, workload, programs, repeats, jitter } => {
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("workload", workload_to_json(workload)));
                pairs.push((
                    "programs",
                    Json::Arr(programs.iter().map(program_to_json).collect()),
                ));
                pairs.push(("repeats", Json::Num(*repeats as f64)));
                pairs.push((
                    "jitter",
                    Json::Arr(
                        jitter
                            .iter()
                            .map(|js| Json::Arr(js.iter().map(|&j| Json::Num(j)).collect()))
                            .collect(),
                    ),
                ));
            }
            Frame::MeasureResult { id, means } => {
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("means", Json::Arr(means.iter().map(|&m| Json::Num(m)).collect())));
            }
            Frame::Latency { id, workload, program } => {
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("workload", workload_to_json(workload)));
                pairs.push(("program", program_to_json(program)));
            }
            Frame::LatencyResult { id, seconds } => {
                pairs.push(("id", Json::Num(*id as f64)));
                pairs.push(("seconds", Json::Num(*seconds)));
            }
            Frame::Shutdown | Frame::Bye => {}
            Frame::Error { id, message } => {
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                pairs.push(("message", Json::Str(message.clone())));
            }
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Frame, String> {
        let kind = j.get("type").and_then(Json::as_str).ok_or("frame missing type")?;
        let id = |j: &Json| -> Result<u64, String> {
            j.get("id")
                .and_then(Json::as_f64)
                .map(|n| n as u64)
                .ok_or_else(|| format!("{kind} frame missing id"))
        };
        let f64_field = |j: &Json, key: &str| -> Result<f64, String> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("{kind} frame missing {key}"))
        };
        let workload = |j: &Json| -> Result<Workload, String> {
            let w = j.get("workload").ok_or_else(|| format!("{kind} frame missing workload"))?;
            workload_from_json(w)
        };
        let check_version = |j: &Json| -> Result<(), String> {
            let format = j.get("format").and_then(Json::as_str).unwrap_or("?");
            let version = j.get("version").and_then(Json::as_f64).map(|v| v as u64);
            if format != REMOTE_FORMAT || version != Some(REMOTE_VERSION) {
                return Err(format!(
                    "peer speaks {format} v{} but this side speaks {REMOTE_FORMAT} v{REMOTE_VERSION}",
                    version.map(|v| v.to_string()).unwrap_or_else(|| "?".to_string()),
                ));
            }
            Ok(())
        };
        match kind {
            "hello" => {
                check_version(j)?;
                Ok(Frame::Hello)
            }
            "hello_ack" => {
                check_version(j)?;
                let spec = DeviceSpec::from_json(
                    j.get("device").ok_or("hello_ack frame missing device")?,
                )?;
                Ok(Frame::HelloAck { spec, noise_sigma: f64_field(j, "noise_sigma")? })
            }
            "measure_batch" => {
                let programs = j
                    .get("programs")
                    .and_then(Json::as_arr)
                    .ok_or("measure_batch frame missing programs")?
                    .iter()
                    .map(program_from_json)
                    .collect::<Result<Vec<_>, _>>()?;
                let jitter = j
                    .get("jitter")
                    .and_then(Json::as_arr)
                    .ok_or("measure_batch frame missing jitter")?
                    .iter()
                    .map(|row| {
                        row.as_arr()
                            .ok_or("measure_batch jitter row is not an array")?
                            .iter()
                            .map(|v| v.as_f64().ok_or("jitter draw is not a number".to_string()))
                            .collect::<Result<Vec<f64>, String>>()
                    })
                    .collect::<Result<Vec<Vec<f64>>, String>>()?;
                Ok(Frame::MeasureBatch {
                    id: id(j)?,
                    workload: workload(j)?,
                    programs,
                    repeats: j
                        .get("repeats")
                        .and_then(Json::as_usize)
                        .ok_or("measure_batch frame missing repeats")?,
                    jitter,
                })
            }
            "measure_result" => {
                let means = j
                    .get("means")
                    .and_then(Json::as_arr)
                    .ok_or("measure_result frame missing means")?
                    .iter()
                    .map(|v| v.as_f64().ok_or("measure_result mean is not a number".to_string()))
                    .collect::<Result<Vec<f64>, String>>()?;
                Ok(Frame::MeasureResult { id: id(j)?, means })
            }
            "latency" => Ok(Frame::Latency {
                id: id(j)?,
                workload: workload(j)?,
                program: program_from_json(
                    j.get("program").ok_or("latency frame missing program")?,
                )?,
            }),
            "latency_result" => {
                Ok(Frame::LatencyResult { id: id(j)?, seconds: f64_field(j, "seconds")? })
            }
            "shutdown" => Ok(Frame::Shutdown),
            "bye" => Ok(Frame::Bye),
            "error" => Ok(Frame::Error {
                id: j.get("id").and_then(Json::as_f64).map(|n| n as u64),
                message: j
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or("unspecified peer error")
                    .to_string(),
            }),
            other => Err(format!("unknown frame type '{other}'")),
        }
    }
}

/// Write one length-prefixed frame. The caller flushes (transports
/// decide their own flush cadence; the serve loop flushes per reply).
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<(), String> {
    let payload = frame.to_json().to_string();
    writeln!(w, "{}\n{payload}", payload.len()).map_err(|e| format!("write failed: {e}"))
}

/// Read one frame; `Ok(None)` is a clean EOF *between* frames (the peer
/// closed the stream). EOF inside a frame is an error — a truncated
/// frame must not look like an orderly close.
pub fn read_frame(r: &mut impl BufRead) -> Result<Option<Frame>, String> {
    let mut header = String::new();
    let n = r.read_line(&mut header).map_err(|e| format!("read failed: {e}"))?;
    if n == 0 {
        return Ok(None);
    }
    let len: usize = header
        .trim()
        .parse()
        .map_err(|_| format!("bad frame length prefix {:?}", header.trim()))?;
    let mut payload = vec![0u8; len + 1];
    r.read_exact(&mut payload)
        .map_err(|e| format!("truncated frame (wanted {len} bytes): {e}"))?;
    let text = std::str::from_utf8(&payload[..len])
        .map_err(|e| format!("frame payload is not UTF-8: {e}"))?;
    let j = json::parse(text).map_err(|e| format!("frame payload is not JSON: {e}"))?;
    Frame::from_json(&j).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn wl(ff: usize) -> Workload {
        Workload {
            n: 1,
            oh: 8,
            ow: 8,
            ff,
            ic: 16,
            kh: 3,
            kw: 3,
            groups: 1,
            stride: 1,
            epilogue: vec!["relu"],
        }
    }

    fn frames() -> Vec<Frame> {
        let w = wl(64);
        let p = Program::naive(&w);
        vec![
            Frame::Hello,
            Frame::HelloAck { spec: DeviceSpec::kryo385(), noise_sigma: 0.03 },
            Frame::MeasureBatch {
                id: 7,
                workload: w.clone(),
                programs: vec![p.clone(), p.clone()],
                repeats: 3,
                jitter: vec![vec![1.0, 0.981_234_567_8, 1.019_999_999_3]; 2],
            },
            Frame::MeasureResult { id: 7, means: vec![1.5e-3, 2.5e-3] },
            Frame::Latency { id: 8, workload: w, program: p },
            Frame::LatencyResult { id: 8, seconds: 1.25e-3 },
            Frame::Shutdown,
            Frame::Bye,
            Frame::Error { id: Some(9), message: "boom".to_string() },
            Frame::Error { id: None, message: "handshake refused".to_string() },
        ]
    }

    #[test]
    fn frames_round_trip_through_the_wire_format() {
        let mut buf = Vec::new();
        for f in frames() {
            write_frame(&mut buf, &f).unwrap();
        }
        let mut r = BufReader::new(&buf[..]);
        for want in frames() {
            let got = read_frame(&mut r).unwrap().expect("frame expected");
            assert_eq!(got, want);
        }
        assert_eq!(read_frame(&mut r).unwrap(), None, "then clean EOF");
    }

    #[test]
    fn jitter_round_trips_bit_exactly() {
        // Shortest-round-trip float formatting is what makes the wire
        // format determinism-safe; pin it on awkward values.
        let vals = [1.0, 0.030_000_000_000_000_002, 1e-300, 0.981_234_567_891_234_5];
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Frame::MeasureResult { id: 1, means: vals.to_vec() },
        )
        .unwrap();
        match read_frame(&mut BufReader::new(&buf[..])).unwrap().unwrap() {
            Frame::MeasureResult { means, .. } => {
                for (a, b) in vals.iter().zip(&means) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} mangled into {b}");
                }
            }
            other => panic!("wrong frame {other:?}"),
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let j = json::parse(r#"{"type":"hello","format":"cprune-remote","version":2}"#).unwrap();
        let err = Frame::from_json(&j).unwrap_err();
        assert!(err.contains("v2") && err.contains("v1"), "{err}");
    }

    #[test]
    fn truncated_frame_is_an_error_not_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Hello).unwrap();
        buf.truncate(buf.len() - 4);
        let err = read_frame(&mut BufReader::new(&buf[..])).unwrap_err();
        assert!(err.contains("truncated"), "{err}");
    }
}
