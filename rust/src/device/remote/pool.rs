//! [`RemoteTarget`]: a pool of out-of-process workers behind the
//! [`Target`] seam (DESIGN.md §14).
//!
//! ## Determinism invariant
//!
//! A remote run is bit-identical to the same run on the in-process
//! provider the workers wrap, for any worker count ≥ 1, because nothing
//! the result depends on happens remotely:
//!
//! 1. the client draws every jitter multiplier from the run's RNG —
//!    exactly `repeats` per program, in batch order, preserving the
//!    measurement contract — and ships the draws in the request;
//! 2. each worker folds `mean(latency(w, p) * jitter)` in the same
//!    order and with the same f64 operations as the provided
//!    [`Target::measure_batch`];
//! 3. results reassemble by original batch index, so partitioning and
//!    completion order are invisible.
//!
//! Worker death or a deadline miss re-partitions the *pending* programs
//! over the surviving workers (bounded retries with exponential
//! backoff); the values are reproduced identically on whichever worker
//! re-runs them.
//!
//! ## Concurrency shape
//!
//! Within one `measure_batch` call the pool writes every worker's chunk
//! before reading any reply, so N workers compute concurrently while
//! the client assembles results. Across tuner threads the pool is
//! serialized by a mutex — each in-flight batch owns all workers, which
//! keeps request routing deterministic; the fleet's work-stealing
//! threads interleave *batches*, not frames.

use super::protocol::Frame;
use super::trace::RemoteTrace;
use super::transport::Connection;
use crate::device::spec::DeviceSpec;
use crate::device::target::Target;
use crate::tir::{Program, Workload};
use crate::util::rng::Rng;
use std::path::Path;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Timeout/retry policy of a [`RemoteTarget`].
#[derive(Clone, Copy, Debug)]
pub struct RemoteOptions {
    /// Per-round deadline for a worker's reply.
    pub timeout: Duration,
    /// How many re-partition rounds a failed batch may consume.
    pub retries: usize,
    /// First retry backoff; doubles per round (capped at 2^16×).
    pub backoff: Duration,
}

impl Default for RemoteOptions {
    fn default() -> RemoteOptions {
        RemoteOptions {
            timeout: Duration::from_secs(30),
            retries: 3,
            backoff: Duration::from_millis(50),
        }
    }
}

/// Mutable pool state behind the [`RemoteTarget`] mutex.
struct WorkerPool {
    workers: Vec<Connection>,
    next_id: u64,
}

impl WorkerPool {
    fn fresh_id(&mut self) -> u64 {
        self.next_id += 1;
        self.next_id
    }
}

/// N remote workers multiplexed behind one [`Target`].
pub struct RemoteTarget {
    spec: DeviceSpec,
    noise_sigma: f64,
    opts: RemoteOptions,
    pool: Mutex<WorkerPool>,
    trace: Mutex<Option<RemoteTrace>>,
}

impl RemoteTarget {
    /// Handshake every connection and build the pool. Fails unless every
    /// worker reports a byte-identical device spec and noise sigma — a
    /// pool mixing devices would silently corrupt the search.
    pub fn new(connections: Vec<Connection>, opts: RemoteOptions) -> Result<RemoteTarget, String> {
        if connections.is_empty() {
            return Err("remote target needs at least one worker".to_string());
        }
        let mut workers = Vec::with_capacity(connections.len());
        let mut head: Option<(DeviceSpec, f64, String)> = None;
        for mut conn in connections {
            conn.send(&Frame::Hello)?;
            let deadline = Instant::now() + opts.timeout;
            match conn.recv_deadline(deadline)? {
                Frame::HelloAck { spec, noise_sigma } => {
                    let key = spec.to_json().to_string();
                    match &head {
                        None => head = Some((spec, noise_sigma, key)),
                        Some((_, sigma0, key0)) => {
                            if *key0 != key || sigma0.to_bits() != noise_sigma.to_bits() {
                                return Err(format!(
                                    "{}: worker measures a different device than the pool \
                                     ({key} / sigma {noise_sigma} vs {key0} / sigma {sigma0})",
                                    conn.desc()
                                ));
                            }
                        }
                    }
                }
                Frame::Error { message, .. } => {
                    return Err(format!("{}: handshake refused: {message}", conn.desc()))
                }
                other => {
                    return Err(format!(
                        "{}: unexpected handshake reply '{}'",
                        conn.desc(),
                        other.kind()
                    ))
                }
            }
            workers.push(conn);
        }
        let Some((spec, noise_sigma, _)) = head else {
            return Err("remote target needs at least one worker".to_string());
        };
        Ok(RemoteTarget {
            spec,
            noise_sigma,
            opts,
            pool: Mutex::new(WorkerPool { workers, next_id: 0 }),
            trace: Mutex::new(None),
        })
    }

    /// Pool of in-process loopback workers, each an
    /// [`crate::device::AnalyticTarget`] over `spec` (tests, CI).
    pub fn loopback(
        spec: DeviceSpec,
        workers: usize,
        opts: RemoteOptions,
    ) -> Result<RemoteTarget, String> {
        let conns = (0..workers)
            .map(|i| {
                Connection::loopback(
                    Box::new(crate::device::target::AnalyticTarget::new(spec.clone())),
                    i,
                )
            })
            .collect();
        RemoteTarget::new(conns, opts)
    }

    /// Pool of `workers` stdio subprocess workers spawned from `exe`
    /// (`exe worker --stdio --device NAME`).
    pub fn spawn_with_exe(
        exe: &Path,
        device: &str,
        workers: usize,
        opts: RemoteOptions,
    ) -> Result<RemoteTarget, String> {
        let conns = (0..workers.max(1))
            .map(|_| Connection::spawn_with_exe(exe, device))
            .collect::<Result<Vec<_>, _>>()?;
        RemoteTarget::new(conns, opts)
    }

    /// Pool of stdio subprocess workers spawned from the running
    /// executable (the CLI's `--target remote:NAME` path).
    pub fn spawn(
        device: &str,
        workers: usize,
        opts: RemoteOptions,
    ) -> Result<RemoteTarget, String> {
        let exe =
            std::env::current_exe().map_err(|e| format!("cannot locate own executable: {e}"))?;
        RemoteTarget::spawn_with_exe(&exe, device, workers, opts)
    }

    /// Pool of TCP workers, one connection per address
    /// (`--target remote:NAME@HOST:PORT,HOST:PORT`).
    pub fn connect(addrs: &[String], opts: RemoteOptions) -> Result<RemoteTarget, String> {
        let conns = addrs
            .iter()
            .map(|a| Connection::connect_tcp(a))
            .collect::<Result<Vec<_>, _>>()?;
        RemoteTarget::new(conns, opts)
    }

    /// Workers still alive (drops as failures remove them).
    pub fn healthy_workers(&self) -> usize {
        self.lock_pool().workers.len()
    }

    /// Start recording every query into a `cprune-remote-trace`
    /// (retrievable via [`RemoteTarget::save_trace`]).
    pub fn start_trace(&self) {
        let workers = self.healthy_workers();
        let mut trace = self.lock_trace();
        *trace = Some(RemoteTrace::new(self.spec.clone(), self.noise_sigma, workers));
    }

    /// Persist the recording started by [`RemoteTarget::start_trace`].
    pub fn save_trace(&self, path: impl AsRef<Path>) -> Result<(), String> {
        match self.lock_trace().as_ref() {
            Some(trace) => trace.save(path),
            None => Err("save_trace without start_trace".to_string()),
        }
    }

    fn lock_pool(&self) -> std::sync::MutexGuard<'_, WorkerPool> {
        self.pool.lock().unwrap() // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
    }

    fn lock_trace(&self) -> std::sync::MutexGuard<'_, Option<RemoteTrace>> {
        self.trace.lock().unwrap() // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
    }

    /// Remove `failed` workers (descending-index order) from the pool,
    /// loudly: a silent shrink would hide capacity loss until the last
    /// worker died.
    fn remove_failed(pool: &mut WorkerPool, mut failed: Vec<(usize, String)>) {
        failed.sort_by(|a, b| b.0.cmp(&a.0));
        failed.dedup_by_key(|f| f.0);
        for (idx, why) in failed {
            let conn = pool.workers.remove(idx);
            eprintln!(
                "cprune-remote: removing dead worker {} ({} left): {why}",
                conn.desc(),
                pool.workers.len()
            );
        }
    }

    /// Back off before retry round `attempt` (1-based): base * 2^(n-1).
    fn backoff(&self, attempt: usize) {
        let shift = (attempt - 1).min(16) as u32;
        std::thread::sleep(self.opts.backoff * (1u32 << shift));
    }

    /// One latency request against the first healthy worker, with the
    /// same retry/removal discipline as batches.
    fn request_latency(&self, w: &Workload, p: &Program) -> f64 {
        let mut pool = self.lock_pool();
        for attempt in 0..=self.opts.retries {
            if attempt > 0 {
                self.backoff(attempt);
            }
            if pool.workers.is_empty() {
                break;
            }
            let id = pool.fresh_id();
            let conn = &mut pool.workers[0];
            let outcome = conn
                .send(&Frame::Latency { id, workload: w.clone(), program: p.clone() })
                .and_then(|()| {
                    let deadline = Instant::now() + self.opts.timeout;
                    loop {
                        match conn.recv_deadline(deadline)? {
                            Frame::LatencyResult { id: rid, seconds } if rid == id => {
                                return Ok(seconds)
                            }
                            Frame::Error { message, .. } => return Err(message),
                            _stale => continue,
                        }
                    }
                });
            match outcome {
                Ok(seconds) => return seconds,
                Err(why) => Self::remove_failed(&mut pool, vec![(0, why)]),
            }
        }
        panic!(
            "cprune-remote: latency query failed on every worker of the '{}' pool",
            self.spec.name
        );
    }

    /// Partition `pending` (original batch indices) into one contiguous
    /// chunk per worker. Purely a throughput decision — results
    /// reassemble by index, so the partition never affects values.
    fn partition(pending: &[usize], workers: usize) -> Vec<Vec<usize>> {
        let base = pending.len() / workers;
        let extra = pending.len() % workers;
        let mut chunks = Vec::with_capacity(workers);
        let mut at = 0;
        for w in 0..workers {
            let len = base + usize::from(w < extra);
            chunks.push(pending[at..at + len].to_vec());
            at += len;
        }
        chunks
    }

    /// Measure `pending` programs over the pool, retrying failures on
    /// the survivors. Returns means indexed like `programs`.
    fn measure_on_pool(
        &self,
        pool: &mut WorkerPool,
        w: &Workload,
        programs: &[&Program],
        repeats: usize,
        jitter: &[Vec<f64>],
    ) -> Vec<f64> {
        let n = programs.len();
        let mut results: Vec<Option<f64>> = vec![None; n];
        let mut pending: Vec<usize> = (0..n).collect();
        for attempt in 0..=self.opts.retries {
            if attempt > 0 {
                self.backoff(attempt);
            }
            if pool.workers.is_empty() {
                break;
            }
            let chunks = Self::partition(&pending, pool.workers.len());
            // Submit every chunk before reading any reply: the workers
            // overlap while this thread turns around to collect.
            let mut inflight: Vec<(usize, u64, Vec<usize>)> = Vec::new();
            let mut failed: Vec<(usize, String)> = Vec::new();
            for (widx, chunk) in chunks.into_iter().enumerate() {
                if chunk.is_empty() {
                    continue;
                }
                let id = pool.next_id + 1;
                pool.next_id = id;
                let frame = Frame::MeasureBatch {
                    id,
                    workload: w.clone(),
                    programs: chunk.iter().map(|&i| programs[i].clone()).collect(),
                    repeats,
                    jitter: chunk.iter().map(|&i| jitter[i].clone()).collect(),
                };
                match pool.workers[widx].send(&frame) {
                    Ok(()) => inflight.push((widx, id, chunk)),
                    Err(why) => failed.push((widx, why)),
                }
            }
            let deadline = Instant::now() + self.opts.timeout;
            for (widx, id, chunk) in inflight {
                match Self::collect_means(&mut pool.workers[widx], id, chunk.len(), deadline) {
                    Ok(means) => {
                        for (&i, mean) in chunk.iter().zip(means) {
                            results[i] = Some(mean);
                        }
                    }
                    Err(why) => failed.push((widx, why)),
                }
            }
            Self::remove_failed(pool, failed);
            pending.retain(|&i| results[i].is_none());
            if pending.is_empty() {
                return results.into_iter().flatten().collect();
            }
        }
        panic!(
            "cprune-remote: {} measurements still unserved after {} retries \
             ({} worker(s) left) on the '{}' pool",
            pending.len(),
            self.opts.retries,
            pool.workers.len(),
            self.spec.name
        );
    }

    /// Collect one worker's `measure_result`, validating shape and
    /// domain (a malformed reply condemns the worker, not the run).
    fn collect_means(
        conn: &mut Connection,
        id: u64,
        want: usize,
        deadline: Instant,
    ) -> Result<Vec<f64>, String> {
        loop {
            match conn.recv_deadline(deadline)? {
                Frame::MeasureResult { id: rid, means } if rid == id => {
                    if means.len() != want {
                        return Err(format!("{} means for a {want}-program chunk", means.len()));
                    }
                    if let Some(bad) = means.iter().find(|m| !m.is_finite() || **m <= 0.0) {
                        return Err(format!("non-positive/non-finite mean {bad}"));
                    }
                    return Ok(means);
                }
                Frame::Error { message, .. } => return Err(message),
                // A reply to an older request on a reused connection:
                // skip it and keep waiting for ours.
                _stale => continue,
            }
        }
    }
}

impl Target for RemoteTarget {
    fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    fn noise_sigma(&self) -> f64 {
        self.noise_sigma
    }

    fn latency(&self, w: &Workload, p: &Program) -> f64 {
        let seconds = self.request_latency(w, p);
        if let Some(trace) = self.lock_trace().as_mut() {
            trace.record_latency(w, p, seconds);
        }
        seconds
    }

    fn measure_batch(
        &self,
        w: &Workload,
        programs: &[&Program],
        rng: &mut Rng,
        repeats: usize,
    ) -> Vec<f64> {
        // Draw the contract's jitter here, client-side, in batch order —
        // the RNG stream must be byte-identical to an in-process run's.
        let sigma = self.noise_sigma;
        let jitter: Vec<Vec<f64>> = programs
            .iter()
            .map(|_| (0..repeats).map(|_| rng.lognormal(sigma)).collect())
            .collect();
        if programs.is_empty() {
            return Vec::new();
        }
        let means = {
            let mut pool = self.lock_pool();
            self.measure_on_pool(&mut pool, w, programs, repeats, &jitter)
        };
        if let Some(trace) = self.lock_trace().as_mut() {
            for (i, &p) in programs.iter().enumerate() {
                trace.record_measurement(w, p, repeats, jitter[i].clone(), means[i]);
            }
        }
        means
    }

    fn as_remote(&self) -> Option<&RemoteTarget> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::target::AnalyticTarget;

    fn wl(ff: usize) -> Workload {
        Workload {
            n: 1,
            oh: 8,
            ow: 8,
            ff,
            ic: 16,
            kh: 3,
            kw: 3,
            groups: 1,
            stride: 1,
            epilogue: vec![],
        }
    }

    #[test]
    fn partition_is_contiguous_and_covers_everything() {
        let pending: Vec<usize> = (0..7).collect();
        for workers in 1..=8 {
            let chunks = RemoteTarget::partition(&pending, workers);
            assert_eq!(chunks.len(), workers);
            let flat: Vec<usize> = chunks.concat();
            assert_eq!(flat, pending, "workers={workers}");
        }
    }

    #[test]
    fn mismatched_worker_specs_fail_construction() {
        let a = Connection::loopback(
            Box::new(AnalyticTarget::new(DeviceSpec::kryo385())),
            0,
        );
        let b = Connection::loopback(
            Box::new(AnalyticTarget::new(DeviceSpec::kryo585())),
            1,
        );
        let err = RemoteTarget::new(vec![a, b], RemoteOptions::default())
            .err()
            .expect("mixed pool must fail");
        assert!(err.contains("different device"), "{err}");
    }

    #[test]
    fn empty_pool_fails_construction() {
        let err = RemoteTarget::new(vec![], RemoteOptions::default()).err().unwrap();
        assert!(err.contains("at least one worker"), "{err}");
    }

    #[test]
    fn empty_batch_is_served_locally() {
        let remote =
            RemoteTarget::loopback(DeviceSpec::kryo385(), 1, RemoteOptions::default()).unwrap();
        let mut rng = Rng::new(0);
        assert!(remote.measure_batch(&wl(64), &[], &mut rng, 3).is_empty());
    }
}
