//! Analytic latency simulator: "runs" a scheduled program on a device.
//!
//! Replaces the paper's on-device measurement harness (RPC to a phone).
//! The model is a roofline with schedule-dependent efficiency terms:
//!
//! * **parallel**: threads = min(program.parallel, cores), discounted by
//!   load imbalance over the outer tile count;
//! * **vector**: fraction of SIMD lanes the innermost tile keeps busy,
//!   with penalties for non-dividing widths (this term produces the
//!   step-function latency vs. channel count of Tang et al. [38]);
//! * **cache**: per-thread tile footprint vs. L1/L2, which also amplifies
//!   DRAM traffic on the memory-bound side;
//! * **layout**: the `ax3` cache-write stage mismatching the vector width
//!   (the Fig. 5 (c) pathology);
//! * **dispatch**: fixed per-subgraph launch overhead (dominant for tiny
//!   subgraphs, especially on the GPU).
//!
//! `measure()` adds seeded log-normal jitter: the tuner sees realistic
//! noisy measurements; experiments average repeated measures exactly as
//! the paper's harness does.

use super::spec::{DeviceKind, DeviceSpec};
use crate::tir::{Program, Workload};
use crate::util::rng::Rng;

/// Latency simulator for one device.
///
/// This is the *analytic* measurement provider behind
/// [`super::AnalyticTarget`]; it also implements [`super::Target`]
/// directly so existing `&Simulator` call sites coerce onto the
/// measurement plane unchanged.
#[derive(Clone, Debug)]
pub struct Simulator {
    pub spec: DeviceSpec,
    /// Log-normal sigma of measurement jitter (0 disables noise).
    /// `f64` end-to-end — latencies are `f64`, and narrowing the jitter
    /// through `f32` would quantize every measured value.
    pub noise_sigma: f64,
}

impl Simulator {
    pub fn new(spec: DeviceSpec) -> Simulator {
        Simulator { spec, noise_sigma: 0.03 }
    }

    /// Deterministic latency estimate (seconds) of `p` on this device.
    pub fn latency(&self, w: &Workload, p: &Program) -> f64 {
        let s = &self.spec;
        // Padded tiles compute garbage lanes: charge the wasted fraction.
        let (waste_sp, waste_ff) = p.waste(w);
        let macs = w.macs() as f64 * waste_sp * waste_ff;

        let outer_tiles = (p.spatial_splits.first().copied().unwrap_or(1)
            * p.ff_splits.first().copied().unwrap_or(1))
        .max(1);
        let (sp_tile, ff_tile) = p.inner_tile();
        let ic_tile = *p.ic_splits.last().unwrap_or(&1);

        // --- parallel efficiency ------------------------------------------
        let threads = match s.kind {
            DeviceKind::Cpu => p.parallel.min(s.cores).min(outer_tiles).max(1),
            // GPUs derive parallelism from the tile grid, not an annotation.
            DeviceKind::Gpu => outer_tiles.min(s.cores).max(1),
        };
        let rounds = (outer_tiles as f64 / threads as f64).ceil();
        let imbalance = outer_tiles as f64 / (rounds * threads as f64); // ≤ 1

        // --- vector efficiency --------------------------------------------
        let lanes = s.simd_lanes;
        let veff = match s.kind {
            DeviceKind::Cpu => {
                let v = p.vectorize.max(1);
                if v > lanes {
                    0.45 // over-wide vectors spill to multiple ops badly
                } else {
                    let base = v as f64 / lanes as f64;
                    // vectorized innermost ff tile must be divisible by v
                    if ff_tile % v == 0 {
                        base
                    } else {
                        base * 0.5
                    }
                }
            }
            DeviceKind::Gpu => {
                // lane occupancy of the inner tile
                let inner = sp_tile * ff_tile;
                let filled = inner.min(lanes) as f64 / lanes as f64;
                if inner % lanes == 0 || inner >= 4 * lanes {
                    filled.min(1.0)
                } else {
                    filled.min(1.0) * 0.7
                }
            }
        };

        // --- unroll ---------------------------------------------------------
        let ueff = match p.unroll {
            1 => 0.92,           // loop overhead
            2..=4 => 1.0,
            _ => {
                if sp_tile * ff_tile >= 64 {
                    0.97
                } else {
                    0.85 // icache pressure on tiny tiles
                }
            }
        };

        // --- cache behaviour -------------------------------------------------
        let footprint = 4
            * (sp_tile * ic_tile * w.kh * w.kw    // input patch tile
                + ff_tile * ic_tile * w.kh * w.kw // filter tile
                + sp_tile * ff_tile); // output tile
        let (ceff, traffic_amp) = if footprint <= s.l1_bytes {
            (1.0, 1.0)
        } else if footprint <= s.l2_bytes / s.cores.max(1) {
            (0.62, 1.6)
        } else {
            (0.30, 3.2)
        };

        // --- layout (ax3) stage ----------------------------------------------
        let ax3_inner = *p.ax3_splits.last().unwrap_or(&1);
        let leff = if ax3_inner >= lanes && ax3_inner % lanes == 0 {
            1.0
        } else if ax3_inner >= lanes / 2 {
            0.85
        } else {
            0.65 // Fig. 5 (c): cache-write stage serializes
        };

        // --- depthwise penalty -------------------------------------------------
        // Depthwise convs reuse each weight once per output pixel (arithmetic
        // intensity ~1 MAC/byte): on real mobile CPUs they run at a fraction
        // of dense-conv efficiency (MobileNetV2's measured 28 FPS vs its MAC
        // count implies ~4x lower efficiency than ResNet-18 — paper Table 1).
        let dweff = if w.is_depthwise() { 0.28 } else { 1.0 };

        // --- roofline ---------------------------------------------------------
        let eff = (veff * ueff * ceff * leff * imbalance * dweff).max(1e-4);
        let compute_time = macs / (s.peak_macs_per_core * threads as f64 * eff);
        let traffic = w.working_set_bytes() as f64 * traffic_amp;
        let mem_time = traffic / s.mem_bytes_per_s;
        compute_time.max(mem_time) + s.dispatch_overhead_s
    }

    /// One noisy measurement (what the tuner / Algorithm 1 line 9 sees).
    pub fn measure(&self, w: &Workload, p: &Program, rng: &mut Rng) -> f64 {
        self.latency(w, p) * rng.lognormal(self.noise_sigma)
    }

    /// Mean of `n` noisy measurements.
    pub fn measure_avg(&self, w: &Workload, p: &Program, rng: &mut Rng, n: usize) -> f64 {
        (0..n).map(|_| self.measure(w, p, rng)).sum::<f64>() / n as f64
    }

    /// Latency of a non-tunable overhead op that moves `bytes` of data
    /// (pooling, flatten): pure memory movement + dispatch.
    pub fn overhead_latency(&self, bytes: u64) -> f64 {
        bytes as f64 / self.spec.mem_bytes_per_s + self.spec.dispatch_overhead_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::OpKind;

    fn wl(ff: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 64, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 28, 28, ff],
            vec!["bn", "relu"],
        )
    }

    fn good_program(w: &Workload) -> Program {
        Program {
            spatial_splits: vec![w.oh * w.ow / 4, 4],
            ff_splits: vec![w.ff / 16, 1, 16],
            ax3_splits: vec![w.ff / 16, 1, 16],
            ic_splits: vec![w.ic / 4, 4],
            parallel: 4,
            vectorize: 4,
            unroll: 4,
        }
    }

    #[test]
    fn tuned_beats_naive_by_a_wide_margin() {
        let w = wl(128);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let naive = sim.latency(&w, &Program::naive(&w));
        let tuned = sim.latency(&w, &good_program(&w));
        assert!(
            naive / tuned > 5.0,
            "tuned/naive spread too small: {naive} vs {tuned}"
        );
    }

    #[test]
    fn latency_is_deterministic() {
        let w = wl(64);
        let sim = Simulator::new(DeviceSpec::kryo280());
        let p = good_program(&w);
        assert_eq!(sim.latency(&w, &p), sim.latency(&w, &p));
    }

    #[test]
    fn measurement_noise_is_small_and_seeded() {
        let w = wl(64);
        let sim = Simulator::new(DeviceSpec::kryo280());
        let p = good_program(&w);
        let base = sim.latency(&w, &p);
        let mut rng = Rng::new(0);
        let m = sim.measure(&w, &p, &mut rng);
        assert!((m / base - 1.0).abs() < 0.25);
        let mut rng2 = Rng::new(0);
        assert_eq!(m, sim.measure(&w, &p, &mut rng2));
    }

    #[test]
    fn zero_sigma_measurement_is_exactly_the_deterministic_latency() {
        // noise_sigma is f64 end-to-end: at sigma = 0 the jitter factor
        // is exactly 1.0, so measure/measure_avg are bit-identical to
        // latency (no f32 round trip anywhere on the path).
        let w = wl(96);
        let mut sim = Simulator::new(DeviceSpec::kryo585());
        sim.noise_sigma = 0.0;
        let p = good_program(&w);
        let base = sim.latency(&w, &p);
        let mut rng = Rng::new(3);
        assert_eq!(sim.measure(&w, &p, &mut rng).to_bits(), base.to_bits());
        assert_eq!(sim.measure_avg(&w, &p, &mut rng, 1).to_bits(), base.to_bits());
        // n = 2: (x + x) / 2 is exact in IEEE; larger n would round the
        // running sum, so "exact" is only promised per measurement.
        assert_eq!(sim.measure_avg(&w, &p, &mut rng, 2).to_bits(), base.to_bits());
    }

    #[test]
    fn step_pattern_vs_channel_count() {
        // Latency should NOT be linear in ff: awkward channel counts (poor
        // divisor structure) tune worse than round ones — Tang et al. [38].
        let sim = Simulator::new(DeviceSpec::kryo385());
        let mut rng = Rng::new(7);
        let mut best = |ff: usize| -> f64 {
            let w = wl(ff);
            let mut best = f64::MAX;
            for _ in 0..300 {
                let p = Program::sample(&w, &mut rng);
                best = best.min(sim.latency(&w, &p));
            }
            best
        };
        let l128 = best(128);
        let l124 = best(124); // 124 = 4*31: poor tiling structure
        // per-mac cost must be clearly worse for the awkward size
        let per128 = l128 / 128.0;
        let per124 = l124 / 124.0;
        assert!(
            per124 > per128 * 1.05,
            "no step effect: per-channel cost {per124} vs {per128}"
        );
    }

    #[test]
    fn devices_prefer_different_programs() {
        // The argmin program over a shared candidate set must differ between
        // a 4-core/4-lane CPU and an 18-core/8-lane GPU (Fig. 8's premise).
        let w = wl(256);
        let cpu = Simulator::new(DeviceSpec::kryo385());
        let gpu = Simulator::new(DeviceSpec::mali_g72());
        let mut rng = Rng::new(3);
        let cands: Vec<Program> = (0..400).map(|_| Program::sample(&w, &mut rng)).collect();
        let argmin = |sim: &Simulator| {
            cands
                .iter()
                .enumerate()
                .min_by(|(_, a), (_, b)| sim.latency(&w, a).total_cmp(&sim.latency(&w, b)))
                .unwrap()
                .0
        };
        assert_ne!(argmin(&cpu), argmin(&gpu));
    }

    #[test]
    fn cross_device_execution_is_slower_than_native() {
        let w = wl(256);
        let cpu = Simulator::new(DeviceSpec::kryo385());
        let gpu = Simulator::new(DeviceSpec::mali_g72());
        let mut rng = Rng::new(3);
        let cands: Vec<Program> = (0..400).map(|_| Program::sample(&w, &mut rng)).collect();
        let best_for = |sim: &Simulator| {
            cands
                .iter()
                .min_by(|a, b| sim.latency(&w, a).total_cmp(&sim.latency(&w, b)))
                .unwrap()
                .clone()
        };
        let cpu_best = best_for(&cpu);
        let gpu_best = best_for(&gpu);
        // running the GPU-tuned program on the CPU is slower than native
        assert!(cpu.latency(&w, &gpu_best) > cpu.latency(&w, &cpu_best));
        assert!(gpu.latency(&w, &cpu_best) > gpu.latency(&w, &gpu_best));
    }

    #[test]
    fn faster_device_is_faster() {
        let w = wl(128);
        let p = good_program(&w);
        let l280 = Simulator::new(DeviceSpec::kryo280()).latency(&w, &p);
        let l585 = Simulator::new(DeviceSpec::kryo585()).latency(&w, &p);
        assert!(l585 < l280);
    }

    #[test]
    fn dispatch_overhead_floors_tiny_workloads() {
        let w = Workload::from_conv(
            &OpKind::Conv2d { kh: 1, kw: 1, cin: 4, cout: 4, stride: 1, padding: 0, groups: 1 },
            [1, 2, 2, 4],
            vec![],
        );
        let sim = Simulator::new(DeviceSpec::mali_g72());
        let l = sim.latency(&w, &Program::naive(&w));
        assert!(l >= sim.spec.dispatch_overhead_s);
    }

    #[test]
    fn random_programs_have_wide_quality_spread() {
        let w = wl(512);
        let sim = Simulator::new(DeviceSpec::kryo585());
        let mut rng = Rng::new(11);
        let lats: Vec<f64> = (0..500)
            .map(|_| sim.latency(&w, &Program::sample(&w, &mut rng)))
            .collect();
        let min = lats.iter().cloned().fold(f64::MAX, f64::min);
        let max = lats.iter().cloned().fold(f64::MIN, f64::max);
        assert!(max / min > 5.0, "spread {}", max / min);
    }
}
