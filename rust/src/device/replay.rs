//! The record/replay measurement provider (DESIGN.md §11).
//!
//! [`ReplayTarget`] has two modes:
//!
//! * **record** — wraps any inner [`Target`], forwards every query, and
//!   logs (workload, program) → result into an in-memory trace that
//!   [`ReplayTarget::save`] persists as versioned JSON
//!   ([`TRACE_FORMAT`] v[`TRACE_VERSION`]);
//! * **replay** — built from a saved trace; answers every query from the
//!   recording, byte-identically, without consulting any device model.
//!
//! Because all measurement flows through [`Target::measure_batch`] and a
//! run's decisions depend only on (measured values, RNG stream), a
//! replayed run reproduces the recorded run's entire `RunEvent` stream
//! exactly — on any machine, regardless of libm differences in `exp`/
//! `ln`/`cos` that make the analytic provider's floats host-sensitive.
//! That is the deterministic-CI story: record a trace once, replay it
//! everywhere. Replay keeps the RNG stream aligned by burning exactly
//! the `repeats` jitter draws per program the measurement contract
//! guarantees the recorder consumed (see `device::target`).
//!
//! Replay is strict: a query the trace does not cover means the
//! replayed run is not the recorded run (different model/seed/budget),
//! and silently falling back to the analytic model would defeat the
//! point. Divergence unwinds with a [`Divergence`] payload — a
//! [`crate::verify::Diagnostic`] (code `CPV124`) rendered
//! `source: pointer: CPV124: message`, the same shape `cprune check`
//! prints — which `run::Run::execute` catches and converts into a plain
//! `Err`, so the CLI reports it with exit 1 instead of a crash.
//!
//! In memory the trace is keyed by the typed `(Workload, Program)`
//! values themselves (both are `Eq + Hash`) — the tuner hot loop never
//! serializes anything. JSON (via the canonical [`crate::tir::jsonio`]
//! encoding the tuning cache shares) happens only at
//! [`ReplayTarget::save`]/[`ReplayTarget::load`] time, where entries are
//! sorted by their serialized keys so documents are byte-stable.

use super::spec::DeviceSpec;
use super::target::Target;
use crate::tir::jsonio::{program_from_json, program_to_json, workload_from_json, workload_to_json};
use crate::tir::{Program, Workload};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use crate::verify::{Code, Diagnostic};
use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::{Mutex, Once};

/// Format tag of the on-disk trace header.
pub const TRACE_FORMAT: &str = "cprune-measure-trace";
/// Bump when the trace schema changes; `parse` rejects other versions.
pub const TRACE_VERSION: u64 = 1;

/// Panic payload of a replay divergence: a structured diagnostic
/// (`CPV124`) instead of a bare string, so catchers up the stack —
/// `run::Run::execute`, thence the CLI — can recognize the failure and
/// turn it into an error message + exit 1.
pub struct Divergence(pub Diagnostic);

impl std::fmt::Display for Divergence {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.0.fmt(f)
    }
}

/// The default panic hook prints `Box<dyn Any>` for non-string payloads,
/// which is useless noise on top of the message the catcher renders.
/// Install (once) a hook that stays silent for [`Divergence`] payloads
/// and delegates everything else to the previous hook.
fn silence_divergence_hook() {
    static INSTALL: Once = Once::new();
    INSTALL.call_once(|| {
        let previous = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<Divergence>().is_none() {
                previous(info);
            }
        }));
    });
}

enum Mode {
    Record(Box<dyn Target>),
    Replay,
}

/// The record/replay provider. See the module docs for semantics.
pub struct ReplayTarget {
    spec: DeviceSpec,
    noise_sigma: f64,
    mode: Mode,
    /// Where the trace came from (a file path for [`ReplayTarget::load`],
    /// `<trace>`/`<recording>` otherwise) — the `file` half of a
    /// divergence diagnostic's `file: pointer: CPVnnn: message` shape.
    source: String,
    /// Deterministic-latency queries: (workload, program) → seconds.
    latencies: Mutex<HashMap<(Workload, Program), f64>>,
    /// Batch means per (workload, program, repeats), in call order;
    /// replay pops from the front (the shrinking queue is the implicit
    /// consumed-count cursor).
    batches: Mutex<HashMap<(Workload, Program, usize), VecDeque<f64>>>,
}

/// Serialized ordering key (save/load only — never on the query path).
fn sort_key(w: &Workload, p: &Program, repeats: Option<usize>) -> String {
    match repeats {
        Some(r) => format!("{}|{}|r{r}", workload_to_json(w), program_to_json(p)),
        None => format!("{}|{}", workload_to_json(w), program_to_json(p)),
    }
}

impl ReplayTarget {
    /// Start recording every query against `inner` (whose spec and noise
    /// model the trace inherits).
    pub fn record(inner: Box<dyn Target>) -> ReplayTarget {
        ReplayTarget {
            spec: inner.spec().clone(),
            noise_sigma: inner.noise_sigma(),
            mode: Mode::Record(inner),
            source: "<recording>".to_string(),
            latencies: Mutex::new(HashMap::new()),
            batches: Mutex::new(HashMap::new()),
        }
    }

    /// Assemble a replay-mode target from already-decoded parts — how a
    /// `cprune-remote-trace` ([`super::remote::trace::RemoteTrace`])
    /// becomes replayable without re-encoding itself as a measure-trace
    /// document. `source` labels divergence diagnostics.
    pub(crate) fn from_parts(
        spec: DeviceSpec,
        noise_sigma: f64,
        source: String,
        latencies: HashMap<(Workload, Program), f64>,
        batches: HashMap<(Workload, Program, usize), VecDeque<f64>>,
    ) -> ReplayTarget {
        ReplayTarget {
            spec,
            noise_sigma,
            mode: Mode::Replay,
            source,
            latencies: Mutex::new(latencies),
            batches: Mutex::new(batches),
        }
    }

    /// Unwind with a structured divergence diagnostic (see the module
    /// docs): `pointer` locates the query within the trace, `message`
    /// says what was missing.
    fn diverge(&self, pointer: &str, message: String) -> ! {
        silence_divergence_hook();
        std::panic::panic_any(Divergence(Diagnostic::new(
            Code::ReplayDivergence,
            format!("{}: {pointer}", self.source),
            message,
        )))
    }

    /// True in record mode.
    pub fn is_recording(&self) -> bool {
        matches!(self.mode, Mode::Record(_))
    }

    /// Total batch means currently held (recorded so far, or not yet
    /// consumed by a replay).
    pub fn recorded_measurements(&self) -> usize {
        self.batches.lock().unwrap().values().map(|q| q.len()).sum() // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
    }

    /// Serialize the trace (header + sorted entries; byte-stable).
    pub fn to_json(&self) -> Json {
        let lats = self.latencies.lock().unwrap(); // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
        let mut lat_entries: Vec<(String, Json)> = lats
            .iter()
            .map(|((w, p), seconds)| {
                (
                    sort_key(w, p, None),
                    Json::obj(vec![
                        ("workload", workload_to_json(w)),
                        ("program", program_to_json(p)),
                        ("seconds", Json::Num(*seconds)),
                    ]),
                )
            })
            .collect();
        lat_entries.sort_by(|a, b| a.0.cmp(&b.0));
        let batches = self.batches.lock().unwrap(); // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
        // cprune-lint: allow(CPL002, reason="entries are sorted by their serialized key below")
        let mut batch_entries: Vec<(String, Json)> = batches
            .iter()
            .map(|((w, p, repeats), means)| {
                (
                    sort_key(w, p, Some(*repeats)),
                    Json::obj(vec![
                        ("workload", workload_to_json(w)),
                        ("program", program_to_json(p)),
                        ("repeats", Json::Num(*repeats as f64)),
                        (
                            "means",
                            Json::Arr(means.iter().map(|&v| Json::Num(v)).collect()),
                        ),
                    ]),
                )
            })
            .collect();
        batch_entries.sort_by(|a, b| a.0.cmp(&b.0));
        Json::obj(vec![
            ("format", Json::Str(TRACE_FORMAT.to_string())),
            ("version", Json::Num(TRACE_VERSION as f64)),
            ("device", self.spec.to_json()),
            ("noise_sigma", Json::Num(self.noise_sigma)),
            (
                "latencies",
                Json::Arr(lat_entries.into_iter().map(|(_, e)| e).collect()),
            ),
            (
                "measurements",
                Json::Arr(batch_entries.into_iter().map(|(_, e)| e).collect()),
            ),
        ])
    }

    /// Parse a trace document into a replay-mode target.
    pub fn parse(text: &str) -> Result<ReplayTarget, String> {
        let j = json::parse(text)?;
        match j.get("format").and_then(Json::as_str) {
            Some(TRACE_FORMAT) => {}
            other => return Err(format!("not a measurement trace (format {other:?})")),
        }
        match j.get("version").and_then(Json::as_usize) {
            Some(v) if v as u64 == TRACE_VERSION => {}
            other => {
                return Err(format!(
                    "unsupported trace version {other:?} (want {TRACE_VERSION})"
                ))
            }
        }
        let spec = DeviceSpec::from_json(j.get("device").ok_or("trace missing device")?)?;
        let noise_sigma = j
            .get("noise_sigma")
            .and_then(Json::as_f64)
            .ok_or("trace missing noise_sigma")?;
        let mut latencies = HashMap::new();
        for e in j
            .get("latencies")
            .and_then(Json::as_arr)
            .ok_or("trace missing latencies")?
        {
            let workload =
                workload_from_json(e.get("workload").ok_or("latency missing workload")?)?;
            let program = program_from_json(e.get("program").ok_or("latency missing program")?)?;
            let seconds = e
                .get("seconds")
                .and_then(Json::as_f64)
                .ok_or("latency missing seconds")?;
            latencies.insert((workload, program), seconds);
        }
        let mut batches = HashMap::new();
        for e in j
            .get("measurements")
            .and_then(Json::as_arr)
            .ok_or("trace missing measurements")?
        {
            let workload = workload_from_json(e.get("workload").ok_or("batch missing workload")?)?;
            let program = program_from_json(e.get("program").ok_or("batch missing program")?)?;
            let repeats = e
                .get("repeats")
                .and_then(Json::as_usize)
                .ok_or("batch missing repeats")?;
            let means = e
                .get("means")
                .and_then(Json::as_arr)
                .ok_or("batch missing means")?
                .iter()
                .map(|v| v.as_f64().ok_or_else(|| "non-number mean".to_string()))
                .collect::<Result<VecDeque<f64>, _>>()?;
            batches.insert((workload, program, repeats), means);
        }
        Ok(ReplayTarget {
            spec,
            noise_sigma,
            mode: Mode::Replay,
            source: "<trace>".to_string(),
            latencies: Mutex::new(latencies),
            batches: Mutex::new(batches),
        })
    }

    /// Persist the trace atomically ([`crate::util::io::atomic_write`],
    /// DESIGN.md §15).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        let path = path.as_ref();
        let text = self.to_json().to_string();
        // Debug builds sweep the serialized trace through the artifact
        // checker (DESIGN.md §13) before it can reach disk.
        #[cfg(debug_assertions)]
        if let Some(d) =
            crate::verify::artifact::check_text(&text).and_then(|ds| ds.into_iter().next())
        {
            panic!("ReplayTarget::save produced a non-canonical document: {d}");
        }
        crate::util::io::atomic_write(path, &text, "trace")
    }

    /// Load a trace into a replay-mode target.
    pub fn load(path: impl AsRef<Path>) -> Result<ReplayTarget, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let mut target = Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        target.source = path.display().to_string();
        Ok(target)
    }
}

impl Target for ReplayTarget {
    fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    fn noise_sigma(&self) -> f64 {
        self.noise_sigma
    }

    fn latency(&self, w: &Workload, p: &Program) -> f64 {
        match &self.mode {
            Mode::Record(inner) => {
                let seconds = inner.latency(w, p);
                self.latencies
                    .lock()
                    .unwrap() // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
                    .entry((w.clone(), p.clone()))
                    .or_insert(seconds);
                seconds
            }
            Mode::Replay => {
                match self.latencies.lock().unwrap().get(&(w.clone(), p.clone())) { // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
                    Some(&seconds) => seconds,
                    None => self.diverge(
                        "latencies",
                        format!(
                            "trace for '{}' has no latency record for workload \
                             {} / program {} — the replayed run diverged from the \
                             recorded one (different model, seed or budget?)",
                            self.spec.name,
                            workload_to_json(w),
                            program_to_json(p)
                        ),
                    ),
                }
            }
        }
    }

    fn measure_batch(
        &self,
        w: &Workload,
        programs: &[&Program],
        rng: &mut Rng,
        repeats: usize,
    ) -> Vec<f64> {
        match &self.mode {
            Mode::Record(inner) => {
                let means = inner.measure_batch(w, programs, rng, repeats);
                let mut batches = self.batches.lock().unwrap(); // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
                for (&p, &mean) in programs.iter().zip(&means) {
                    batches
                        .entry((w.clone(), p.clone(), repeats))
                        .or_default()
                        .push_back(mean);
                }
                means
            }
            Mode::Replay => {
                let mut batches = self.batches.lock().unwrap(); // cprune-lint: allow(CPL005, reason="poisoning only follows a prior panic")
                programs
                    .iter()
                    .map(|&p| {
                        // Burn the contract's jitter draws so every RNG
                        // consumer downstream of this measurement sees
                        // the exact stream the recorded run saw.
                        for _ in 0..repeats {
                            let _ = rng.lognormal(0.0);
                        }
                        match batches.get_mut(&(w.clone(), p.clone(), repeats)) {
                            Some(q) => q.pop_front().unwrap_or_else(|| {
                                self.diverge(
                                    "measurements",
                                    format!(
                                        "trace for '{}' exhausted for workload {} / \
                                         program {} (repeats {repeats}) — the replayed run \
                                         diverged: it measured this program more often \
                                         than the recording",
                                        self.spec.name,
                                        workload_to_json(w),
                                        program_to_json(p)
                                    ),
                                )
                            }),
                            None => self.diverge(
                                "measurements",
                                format!(
                                    "trace for '{}' has no measurements for workload \
                                     {} / program {} (repeats {repeats}) — the replayed run \
                                     diverged from the recorded one",
                                    self.spec.name,
                                    workload_to_json(w),
                                    program_to_json(p)
                                ),
                            ),
                        }
                    })
                    .collect()
            }
        }
    }

    fn overhead_latency(&self, bytes: u64) -> f64 {
        match &self.mode {
            // Delegate while recording (the contract says this is
            // spec-derived, but an inner provider is the authority)...
            Mode::Record(inner) => inner.overhead_latency(bytes),
            // ...and reproduce it from the recorded spec on replay.
            Mode::Replay => {
                bytes as f64 / self.spec.mem_bytes_per_s + self.spec.dispatch_overhead_s
            }
        }
    }

    fn as_replay(&self) -> Option<&ReplayTarget> {
        Some(self)
    }

    fn as_remote(&self) -> Option<&super::remote::RemoteTarget> {
        match &self.mode {
            // Recording a remote pool: let the run layer find the pool's
            // own trace hook, so --record-trace and --remote-trace compose.
            Mode::Record(inner) => inner.as_remote(),
            Mode::Replay => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::target::AnalyticTarget;
    use crate::graph::ops::OpKind;

    fn wl(ff: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec!["bn", "relu"],
        )
    }

    #[test]
    fn record_then_replay_reproduces_values_and_rng_stream() {
        let w = wl(64);
        let p = Program::naive(&w);
        let mut p2 = Program::naive(&w);
        p2.unroll = 4;

        let rec = ReplayTarget::record(Box::new(AnalyticTarget::new(DeviceSpec::kryo385())));
        let mut rng = Rng::new(5);
        let lat = rec.latency(&w, &p);
        let b1 = rec.measure_batch(&w, &[&p, &p2], &mut rng, 2);
        let b2 = rec.measure_batch(&w, &[&p], &mut rng, 2);
        let after_record = rng.next_u64();
        assert_eq!(rec.recorded_measurements(), 3);

        let text = rec.to_json().to_string();
        let rep = ReplayTarget::parse(&text).unwrap();
        assert!(!rep.is_recording());
        assert_eq!(rep.spec().name, "Kryo 385 (Galaxy S9)");
        let mut rng2 = Rng::new(5);
        assert_eq!(rep.latency(&w, &p).to_bits(), lat.to_bits());
        let r1 = rep.measure_batch(&w, &[&p, &p2], &mut rng2, 2);
        let r2 = rep.measure_batch(&w, &[&p], &mut rng2, 2);
        assert_eq!(
            b1.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            r1.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(b2[0].to_bits(), r2[0].to_bits());
        // replay burned exactly the recorded draw count
        assert_eq!(after_record, rng2.next_u64(), "RNG stream diverged after replay");
    }

    #[test]
    fn trace_serialization_is_byte_stable_and_versioned() {
        let w = wl(32);
        let p = Program::naive(&w);
        let rec = ReplayTarget::record(Box::new(AnalyticTarget::new(DeviceSpec::kryo585())));
        let mut rng = Rng::new(1);
        let _ = rec.measure_batch(&w, &[&p], &mut rng, 3);
        let a = rec.to_json().to_string();
        let b = rec.to_json().to_string();
        assert_eq!(a, b);
        let j = json::parse(&a).unwrap();
        assert_eq!(j.get("format").and_then(Json::as_str), Some(TRACE_FORMAT));
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(1));
        // parse → serialize is the identity (canonical writer output)
        assert_eq!(ReplayTarget::parse(&a).unwrap().to_json().to_string(), a);
        // foreign documents are rejected loudly
        assert!(ReplayTarget::parse("{}").is_err());
        assert!(ReplayTarget::parse(
            r#"{"format":"cprune-measure-trace","version":999,"device":{},"noise_sigma":0,"latencies":[],"measurements":[]}"#
        )
        .is_err());
    }

    #[test]
    fn replay_divergence_carries_a_structured_diagnostic() {
        let rec = ReplayTarget::record(Box::new(AnalyticTarget::new(DeviceSpec::kryo385())));
        let rep = ReplayTarget::parse(&rec.to_json().to_string()).unwrap();
        let w = wl(64);
        let p = Program::naive(&w);
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(0);
            let _ = rep.measure_batch(&w, &[&p], &mut rng, 2);
        }))
        .expect_err("divergence must unwind");
        let d = payload.downcast::<Divergence>().expect("payload is a Divergence");
        let text = d.to_string();
        assert_eq!(d.0.code.id(), "CPV124");
        assert!(text.starts_with("<trace>: measurements: CPV124: "), "{text}");
        assert!(text.contains("diverged"), "{text}");

        // ...and the latency path, with the file path as the source
        let path = std::env::temp_dir().join("cprune_replay_divergence_test.json");
        rec.save(&path).unwrap();
        let rep = ReplayTarget::load(&path).unwrap();
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = rep.latency(&w, &p);
        }))
        .expect_err("divergence must unwind");
        let d = payload.downcast::<Divergence>().expect("payload is a Divergence");
        let text = d.to_string();
        assert!(text.contains("cprune_replay_divergence_test.json: latencies: CPV124"), "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn save_load_roundtrip() {
        let w = wl(48);
        let p = Program::naive(&w);
        let rec = ReplayTarget::record(Box::new(AnalyticTarget::new(DeviceSpec::mali_g72())));
        let mut rng = Rng::new(2);
        let vals = rec.measure_batch(&w, &[&p], &mut rng, 2);
        let path = std::env::temp_dir().join("cprune_replay_unit_test.json");
        rec.save(&path).unwrap();
        let rep = ReplayTarget::load(&path).unwrap();
        let mut rng2 = Rng::new(2);
        assert_eq!(
            rep.measure_batch(&w, &[&p], &mut rng2, 2)[0].to_bits(),
            vals[0].to_bits()
        );
        let _ = std::fs::remove_file(&path);
    }
}
