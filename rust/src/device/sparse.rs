//! Per-device pricing of sparse lowerings (DESIGN.md §16).
//!
//! The compiler-informed part of scheme selection: the same mask costs
//! a different fraction of the dense latency on different devices. A
//! lowering that [`crate::tir::sparse::SparseLowering::needs_reorder`]
//! (pattern compaction) is cheap on CPUs — PatDNN's observation that
//! the reorder amortizes across the dense compacted loop — but dear on
//! GPUs, where the gather serializes against wide SIMT loads. N:M block
//! skipping is metadata-light everywhere, slightly cheaper on CPUs.
//! [`scheme_factor`] folds the lowering's compute scale and the
//! device-kind overhead into one multiplier on a subgraph's measured
//! dense latency; [`crate::sparsity::cost::masked_model_latency`]
//! applies it per task.

use crate::device::spec::DeviceKind;
use crate::sparsity::SchemeChoice;
use crate::tir::sparse::SparseLowering;

/// Additive latency overhead (fraction of the dense subgraph latency)
/// the device pays to run the lowering: reorder/gather cost for pattern
/// compaction, group-metadata decode for block skipping.
pub fn reorder_overhead(kind: DeviceKind, lowering: &SparseLowering) -> f64 {
    match lowering {
        SparseLowering::DenseShrink => 0.0,
        SparseLowering::PatternCompact { .. } => match kind {
            DeviceKind::Cpu => 0.05,
            DeviceKind::Gpu => 0.18,
        },
        SparseLowering::BlockSkip { .. } => match kind {
            DeviceKind::Cpu => 0.02,
            DeviceKind::Gpu => 0.04,
        },
    }
}

/// Multiplier on a subgraph's measured dense latency when its anchor
/// conv runs under `choice` on a device of `kind`. Exactly 1.0 for the
/// channel scheme (dense shrink is already priced by the measured
/// latency of the shrunk graph); never above 1.0 — a scheme whose
/// overhead would erase its compute saving is capped at dense cost,
/// and the selection loop then rejects it on the latency gate.
pub fn scheme_factor(kind: DeviceKind, choice: &SchemeChoice) -> f64 {
    let lowering = SparseLowering::for_choice(choice);
    match lowering {
        SparseLowering::DenseShrink => 1.0,
        _ => (lowering.compute_scale() + reorder_overhead(kind, &lowering)).min(1.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::Scheme;

    #[test]
    fn channel_is_exactly_dense() {
        for kind in [DeviceKind::Cpu, DeviceKind::Gpu] {
            assert_eq!(scheme_factor(kind, &SchemeChoice::channel()), 1.0);
        }
    }

    #[test]
    fn devices_rank_schemes_differently() {
        let pat_cpu = scheme_factor(DeviceKind::Cpu, &SchemeChoice::pattern());
        let blk_cpu = scheme_factor(DeviceKind::Cpu, &SchemeChoice::block());
        let pat_gpu = scheme_factor(DeviceKind::Gpu, &SchemeChoice::pattern());
        let blk_gpu = scheme_factor(DeviceKind::Gpu, &SchemeChoice::block());
        // CPUs amortize the pattern reorder; GPUs prefer block skipping.
        assert!(pat_cpu < blk_cpu, "cpu: pattern {pat_cpu} vs block {blk_cpu}");
        assert!(blk_gpu < pat_gpu, "gpu: block {blk_gpu} vs pattern {pat_gpu}");
        // every sparse factor is a genuine speedup, strictly below dense
        for f in [pat_cpu, blk_cpu, pat_gpu, blk_gpu] {
            assert!(f > 0.0 && f < 1.0, "{f}");
        }
    }

    #[test]
    fn factor_never_exceeds_dense() {
        for kind in [DeviceKind::Cpu, DeviceKind::Gpu] {
            for s in Scheme::ALL {
                assert!(scheme_factor(kind, &SchemeChoice::for_scheme(s)) <= 1.0);
            }
        }
    }
}
