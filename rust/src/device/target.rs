//! The measurement plane: one [`Target`] trait, pluggable providers
//! (DESIGN.md §11).
//!
//! The paper's harness swaps freely between Kryo CPUs, a Mali GPU and a
//! desktop GPU over TVM's RPC measurement plane; everything above it (the
//! tuner, CPrune's gates, the experiment harnesses) only ever asks two
//! questions — *"what does this program cost?"* and *"measure this batch
//! for me"*. [`Target`] is that seam. Four providers ship:
//!
//! * [`AnalyticTarget`] — wraps the roofline [`Simulator`]; bit-for-bit
//!   identical to the pre-trait `Simulator` wiring (pinned by
//!   `tests/target_tests.rs`);
//! * [`LutTarget`] — serves calibrated per-layer latency tables
//!   ([`super::lut::LayerLut`], the Tang-style channel-count step data)
//!   with analytic fallback for uncovered workloads;
//! * [`super::ReplayTarget`] — records every measurement to a versioned
//!   JSON trace and replays it byte-identically (deterministic
//!   cross-machine CI, offline debugging of tuner decisions);
//! * [`super::RemoteTarget`] — a pool of out-of-process workers speaking
//!   the `cprune-remote` wire protocol (DESIGN.md §14), bit-identical to
//!   the in-process provider the workers wrap.
//!
//! Devices resolve by name through [`super::TargetRegistry`] — the five
//! built-ins plus user-defined specs loaded from JSON device files.
//!
//! ## Measurement contract
//!
//! All device measurement goes through [`Target::measure_batch`]: repeats
//! and seeded jitter live here, in one place, instead of being
//! re-implemented per caller. Implementations MUST consume exactly
//! `repeats` jitter draws from `rng` per program, in batch order — the
//! provided implementation does — because [`super::ReplayTarget`] keeps a
//! replayed run's RNG stream aligned by burning the same draws. At
//! `noise_sigma() == 0.0` a measurement is *exactly* the deterministic
//! [`Target::latency`] (see `util::rng::Rng::lognormal`).

use super::lut::LayerLut;
use super::replay::ReplayTarget;
use super::sim::Simulator;
use super::spec::DeviceSpec;
use crate::tir::{Program, Workload};
use crate::util::rng::Rng;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One execution target behind the measurement plane.
///
/// Object-safe: the tuner, sessions and the run layer hold `&dyn Target`
/// / `Box<dyn Target>`. `Send + Sync` are supertraits because
/// `TuningSession::tune_graph` measures tasks from scoped worker threads.
pub trait Target: Send + Sync {
    /// Architectural parameters of the device this provider answers for.
    fn spec(&self) -> &DeviceSpec;

    /// Deterministic (noise-free) latency estimate of `p` on this device,
    /// in seconds.
    fn latency(&self, w: &Workload, p: &Program) -> f64;

    /// Log-normal sigma of measurement jitter (0 = noise-free provider).
    fn noise_sigma(&self) -> f64 {
        0.0
    }

    /// Measure every program `repeats` times and return the per-program
    /// mean latencies, in batch order. This is the ONE measurement entry
    /// point: repeats and seeded jitter are implemented here rather than
    /// per caller (see the module-level measurement contract).
    fn measure_batch(
        &self,
        w: &Workload,
        programs: &[&Program],
        rng: &mut Rng,
        repeats: usize,
    ) -> Vec<f64> {
        let sigma = self.noise_sigma();
        programs
            .iter()
            .map(|&p| {
                let base = self.latency(w, p);
                (0..repeats).map(|_| base * rng.lognormal(sigma)).sum::<f64>() / repeats as f64
            })
            .collect()
    }

    /// Mean of `repeats` noisy measurements of one program (a one-element
    /// [`Target::measure_batch`]).
    fn measure_avg(&self, w: &Workload, p: &Program, rng: &mut Rng, repeats: usize) -> f64 {
        self.measure_batch(w, &[p], rng, repeats)[0]
    }

    /// Latency of a non-tunable overhead op that moves `bytes` of data
    /// (pooling, flatten): pure memory movement + dispatch. Spec-derived;
    /// providers should not override it (the replay provider reproduces
    /// it from the recorded spec alone).
    fn overhead_latency(&self, bytes: u64) -> f64 {
        bytes as f64 / self.spec().mem_bytes_per_s + self.spec().dispatch_overhead_s
    }

    /// Display name of the device (the spec's name).
    fn name(&self) -> &'static str {
        self.spec().name
    }

    /// Downcast hook for the replay provider, so the run layer can
    /// persist a recording target's trace without `Any` plumbing.
    fn as_replay(&self) -> Option<&ReplayTarget> {
        None
    }

    /// Downcast hook for the remote provider, so the run layer can
    /// persist a pool's `cprune-remote-trace` recording without `Any`
    /// plumbing. [`super::ReplayTarget`] delegates to its inner target
    /// while recording, so `--record-trace` and `--remote-trace` compose.
    fn as_remote(&self) -> Option<&super::remote::RemoteTarget> {
        None
    }
}

/// The roofline simulator IS a measurement provider: existing
/// `&Simulator` call sites coerce straight onto the plane, and the
/// provided `measure_batch` reproduces the historical
/// `Simulator::measure_avg` loop draw-for-draw.
impl Target for Simulator {
    fn spec(&self) -> &DeviceSpec {
        &self.spec
    }

    fn latency(&self, w: &Workload, p: &Program) -> f64 {
        Simulator::latency(self, w, p)
    }

    fn noise_sigma(&self) -> f64 {
        self.noise_sigma
    }
}

/// The analytic provider: today's roofline [`Simulator`] behind the
/// [`Target`] seam. Output is bit-for-bit identical to using the
/// simulator directly (both run the same roofline and the same provided
/// `measure_batch`), which `tests/target_tests.rs` pins.
#[derive(Clone, Debug)]
pub struct AnalyticTarget {
    sim: Simulator,
}

impl AnalyticTarget {
    pub fn new(spec: DeviceSpec) -> AnalyticTarget {
        AnalyticTarget { sim: Simulator::new(spec) }
    }

    /// Wrap an existing simulator (keeps its noise sigma).
    pub fn from_simulator(sim: Simulator) -> AnalyticTarget {
        AnalyticTarget { sim }
    }

    /// Override the measurement jitter (0 disables noise).
    pub fn with_noise(mut self, sigma: f64) -> AnalyticTarget {
        self.sim.noise_sigma = sigma;
        self
    }

    /// The wrapped roofline simulator.
    pub fn simulator(&self) -> &Simulator {
        &self.sim
    }
}

impl Target for AnalyticTarget {
    fn spec(&self) -> &DeviceSpec {
        &self.sim.spec
    }

    fn latency(&self, w: &Workload, p: &Program) -> f64 {
        self.sim.latency(w, p)
    }

    fn noise_sigma(&self) -> f64 {
        self.sim.noise_sigma
    }
}

/// The family key a LUT covers: every extent of the workload except the
/// filter count (the dimension pruning sweeps and the table samples).
fn family_key(w: &Workload) -> Workload {
    let mut key = w.clone();
    key.ff = 0;
    key
}

/// The lookup-table provider: calibrated per-layer latency tables
/// ([`LayerLut`], NetAdapt §3's actual mechanism / the Tang et al. step
/// data) served through the measurement plane, with analytic fallback
/// for workloads no table covers.
///
/// Semantics: a covered workload answers with the *tuned* latency of the
/// layer at its channel count, regardless of the candidate program —
/// tuning a covered task degenerates to an O(1) table query, exactly the
/// saving NetAdapt's tables buy. Workloads outside every table family
/// (and all overhead queries) fall back to the wrapped roofline
/// simulator. A workload is in a table's family iff every extent except
/// `ff` matches — pruning a layer's *own* filters stays covered;
/// workloads whose input channels were changed by upstream pruning fall
/// back (the table was not measured for them).
pub struct LutTarget {
    sim: Simulator,
    /// (family key, table) pairs; linear scan (models have tens of
    /// distinct conv families).
    tables: Vec<(Workload, LayerLut)>,
    lut_hits: AtomicUsize,
    fallbacks: AtomicUsize,
}

impl LutTarget {
    /// A table-less target: pure analytic fallback until tables are
    /// installed with [`LutTarget::insert_table`].
    pub fn new(spec: DeviceSpec) -> LutTarget {
        LutTarget {
            sim: Simulator::new(spec),
            tables: Vec::new(),
            lut_hits: AtomicUsize::new(0),
            fallbacks: AtomicUsize::new(0),
        }
    }

    /// A target whose spec was scaled by a fitted
    /// [`super::calibration::Calibration`] (anchoring absolute latencies
    /// to real measurements) before any table is built.
    pub fn calibrated(spec: &DeviceSpec, cal: &super::calibration::Calibration) -> LutTarget {
        LutTarget::new(super::calibration::apply(spec, cal))
    }

    /// Install a latency table for `base`'s workload family (replacing
    /// any existing table for the same family).
    pub fn insert_table(&mut self, base: &Workload, lut: LayerLut) {
        let key = family_key(base);
        if let Some(slot) = self.tables.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = lut;
        } else {
            self.tables.push((key, lut));
        }
    }

    /// Build tables for every prunable conv family of `model` by tuning
    /// each at {25, 50, 75, 100}% of its width (the sampling
    /// [`super::lut::ModelLut`] uses) — this is what finally wires the
    /// calibrated step-function data into the tuner: CPrune's candidate
    /// measurements for covered layers become table queries.
    pub fn for_model(
        spec: DeviceSpec,
        model: &crate::graph::model_zoo::Model,
        opts: &crate::tuner::TuneOptions,
        seed: u64,
    ) -> LutTarget {
        let sim = Simulator::new(spec);
        let part = crate::relay::partition::partition(&model.graph);
        let mut tables: Vec<(Workload, LayerLut)> = Vec::new();
        for sg in &part.subgraphs {
            if !model.prunable.contains(&sg.anchor) {
                continue;
            }
            let key = family_key(&sg.workload);
            if tables.iter().any(|(k, _)| *k == key) {
                continue;
            }
            let ff = sg.workload.ff;
            let samples: Vec<usize> = [ff / 4, ff / 2, ff * 3 / 4, ff]
                .iter()
                .map(|&c| c.max(2))
                .collect::<std::collections::BTreeSet<_>>()
                .into_iter()
                .collect();
            let lut = LayerLut::build(&sg.workload, &sim, opts, &samples, seed);
            tables.push((key, lut));
        }
        LutTarget {
            sim,
            tables,
            lut_hits: AtomicUsize::new(0),
            fallbacks: AtomicUsize::new(0),
        }
    }

    fn table_for(&self, w: &Workload) -> Option<&LayerLut> {
        let key = family_key(w);
        self.tables.iter().find(|(k, _)| *k == key).map(|(_, lut)| lut)
    }

    /// True when a table covers `w`'s family.
    pub fn covers(&self, w: &Workload) -> bool {
        self.table_for(w).is_some()
    }

    /// Number of installed tables.
    pub fn num_tables(&self) -> usize {
        self.tables.len()
    }

    /// Latency queries answered from a table so far.
    pub fn lut_hits(&self) -> usize {
        self.lut_hits.load(Ordering::Relaxed)
    }

    /// Latency queries that fell back to the analytic roofline.
    pub fn fallbacks(&self) -> usize {
        self.fallbacks.load(Ordering::Relaxed)
    }
}

impl Target for LutTarget {
    fn spec(&self) -> &DeviceSpec {
        &self.sim.spec
    }

    fn latency(&self, w: &Workload, p: &Program) -> f64 {
        match self.table_for(w) {
            Some(lut) => {
                self.lut_hits.fetch_add(1, Ordering::Relaxed);
                lut.latency(w.ff)
            }
            None => {
                self.fallbacks.fetch_add(1, Ordering::Relaxed);
                self.sim.latency(w, p)
            }
        }
    }

    fn noise_sigma(&self) -> f64 {
        self.sim.noise_sigma
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::OpKind;
    use crate::tuner::TuneOptions;

    fn wl(ff: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec!["bn", "relu"],
        )
    }

    #[test]
    fn analytic_target_matches_simulator_bit_for_bit() {
        let w = wl(64);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let target = AnalyticTarget::new(DeviceSpec::kryo385());
        let p = Program::naive(&w);
        assert_eq!(
            Target::latency(&sim, &w, &p).to_bits(),
            target.latency(&w, &p).to_bits()
        );
        // the measurement plane draws the same noise as the legacy
        // Simulator::measure_avg loop, draw for draw
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let legacy = sim.measure_avg(&w, &p, &mut r1, 3);
        let plane = Target::measure_avg(&target, &w, &p, &mut r2, 3);
        assert_eq!(legacy.to_bits(), plane.to_bits());
        assert_eq!(r1.next_u64(), r2.next_u64(), "RNG streams diverged");
    }

    #[test]
    fn measure_batch_equals_sequential_measure_avg() {
        let w = wl(96);
        let target = AnalyticTarget::new(DeviceSpec::mali_g72());
        let a = Program::naive(&w);
        let mut b = Program::naive(&w);
        b.unroll = 4;
        let mut r1 = Rng::new(4);
        let batch = target.measure_batch(&w, &[&a, &b], &mut r1, 2);
        let mut r2 = Rng::new(4);
        let s1 = Target::measure_avg(&target, &w, &a, &mut r2, 2);
        let s2 = Target::measure_avg(&target, &w, &b, &mut r2, 2);
        assert_eq!(batch[0].to_bits(), s1.to_bits());
        assert_eq!(batch[1].to_bits(), s2.to_bits());
    }

    #[test]
    fn lut_target_serves_tables_and_falls_back() {
        let base = wl(64);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let lut = LayerLut::build(&base, &sim, &TuneOptions::quick(), &[16, 32, 48, 64], 0);
        let mut t = LutTarget::new(DeviceSpec::kryo385());
        assert!(!t.covers(&base));
        t.insert_table(&base, lut.clone());
        assert!(t.covers(&base));
        assert_eq!(t.num_tables(), 1);

        // covered: pruned channel counts of the same family hit the table
        let mut pruned = base.clone();
        pruned.ff = 32;
        let p = Program::naive(&pruned);
        assert_eq!(t.latency(&pruned, &p), lut.latency(32));
        assert_eq!(t.lut_hits(), 1);
        assert_eq!(t.fallbacks(), 0);
        // covered queries ignore the program (table = tuned latency)
        let mut p2 = Program::naive(&pruned);
        p2.unroll = 4;
        assert_eq!(t.latency(&pruned, &p2), t.latency(&pruned, &p));

        // uncovered: a different ic (upstream pruning) falls back
        let mut foreign = base.clone();
        foreign.ic = 16;
        let pf = Program::naive(&foreign);
        assert_eq!(t.latency(&foreign, &pf), t.sim.latency(&foreign, &pf));
        assert!(t.fallbacks() >= 1);
    }

    #[test]
    fn lut_step_function_is_monotone_at_sampled_points() {
        // Tang-style channel-count step function: the tuned latency the
        // table stores must be (weakly) monotone in the channel count.
        let base = wl(128);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let lut = LayerLut::build(&base, &sim, &TuneOptions::quick(), &[32, 64, 96, 128], 1);
        for pair in lut.points.windows(2) {
            assert!(
                pair[0].1 <= pair[1].1 * 1.05,
                "step function not monotone: {:?} then {:?}",
                pair[0],
                pair[1]
            );
        }
        // interpolation stays within the bracketing samples
        let mut t = LutTarget::new(DeviceSpec::kryo385());
        t.insert_table(&base, lut.clone());
        let mut q = base.clone();
        q.ff = 80;
        let p = Program::naive(&q);
        let mid = t.latency(&q, &p);
        let lo = lut.latency(64).min(lut.latency(96));
        let hi = lut.latency(64).max(lut.latency(96));
        assert!(mid >= lo && mid <= hi);
    }

    #[test]
    fn lut_for_model_covers_every_prunable_family() {
        use crate::graph::model_zoo::{Model, ModelKind};
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let t = LutTarget::for_model(DeviceSpec::kryo385(), &m, &TuneOptions::quick(), 0);
        assert!(t.num_tables() > 0);
        let part = crate::relay::partition::partition(&m.graph);
        for sg in &part.subgraphs {
            if m.prunable.contains(&sg.anchor) {
                assert!(t.covers(&sg.workload), "family of conv {} uncovered", sg.anchor);
            }
        }
    }

    #[test]
    fn zero_noise_target_measures_exact_latency() {
        let w = wl(64);
        let t = AnalyticTarget::new(DeviceSpec::kryo280()).with_noise(0.0);
        let p = Program::naive(&w);
        let base = t.latency(&w, &p);
        let mut rng = Rng::new(0);
        let m = t.measure_batch(&w, &[&p], &mut rng, 1);
        assert_eq!(m[0].to_bits(), base.to_bits());
    }
}
