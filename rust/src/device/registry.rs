//! Device registry: resolve execution targets by name (DESIGN.md §11).
//!
//! The five built-in specs register under their CLI short names
//! (`kryo280 kryo385 kryo585 mali-g72 rtx3080`); user-defined specs load
//! from versioned JSON device files ([`DEVICES_FORMAT`]
//! v[`DEVICES_VERSION`]) via `--device-file` or the PATH-style
//! [`DEVICES_ENV`] environment variable, and resolve exactly like the
//! built-ins — `cprune run --target <name>` tunes for them end-to-end.
//!
//! A device-file entry is a [`DeviceSpec`] JSON object plus an optional
//! `"short"` lookup key (defaulting to the spec's display name):
//!
//! ```json
//! {"format": "cprune-devices", "version": 1, "devices": [
//!   {"short": "pixel9", "name": "Tensor G4 (Pixel 9)", "kind": "cpu",
//!    "cores": 8, "peak_macs_per_core": 1.1e10, "simd_lanes": 4,
//!    "l1_bytes": 65536, "l2_bytes": 4194304,
//!    "mem_bytes_per_s": 5.1e10, "dispatch_overhead_s": 6e-6}
//! ]}
//! ```
//!
//! Later registrations win: a device file may deliberately shadow a
//! built-in short name (e.g. a recalibrated `kryo385`).

use super::spec::DeviceSpec;
use super::target::{AnalyticTarget, Target};
use crate::util::json::{self, Json};
use std::path::Path;

/// Format tag of a device-file header.
pub const DEVICES_FORMAT: &str = "cprune-devices";
/// Bump when the entry schema changes; `load_file` rejects other versions.
pub const DEVICES_VERSION: u64 = 1;
/// PATH-style (`:`-separated) list of device files loaded by
/// [`TargetRegistry::from_env`] before any `--device-file`.
pub const DEVICES_ENV: &str = "CPRUNE_DEVICES";

/// One resolvable device.
#[derive(Clone, Debug)]
pub struct RegisteredDevice {
    /// Primary lookup key (what `--target`/`--device` match).
    pub short: String,
    /// Extra lookup keys (e.g. `mali` for `mali-g72`).
    pub aliases: Vec<String>,
    pub spec: DeviceSpec,
    /// Where the entry came from: `builtin` or the device-file path.
    pub source: String,
}

/// Name → spec resolution for the measurement plane.
#[derive(Clone, Debug, Default)]
pub struct TargetRegistry {
    devices: Vec<RegisteredDevice>,
}

impl TargetRegistry {
    /// Just the five built-in devices.
    pub fn builtin() -> TargetRegistry {
        let mut r = TargetRegistry { devices: Vec::new() };
        let b = |short: &str, aliases: &[&str], spec: DeviceSpec| RegisteredDevice {
            short: short.to_string(),
            aliases: aliases.iter().map(|a| a.to_string()).collect(),
            spec,
            source: "builtin".to_string(),
        };
        r.devices.push(b("kryo280", &[], DeviceSpec::kryo280()));
        r.devices.push(b("kryo385", &[], DeviceSpec::kryo385()));
        r.devices.push(b("kryo585", &[], DeviceSpec::kryo585()));
        r.devices.push(b("mali-g72", &["mali"], DeviceSpec::mali_g72()));
        r.devices.push(b("rtx3080", &[], DeviceSpec::rtx3080()));
        r
    }

    /// Built-ins plus every device file named by [`DEVICES_ENV`]
    /// (missing variable = built-ins only; unreadable files are loud).
    pub fn from_env() -> Result<TargetRegistry, String> {
        match std::env::var(DEVICES_ENV) { // cprune-lint: allow(CPL003, reason="explicit config entry point, not a measurement path")
            Ok(paths) => TargetRegistry::from_paths(&paths),
            Err(_) => Ok(TargetRegistry::builtin()),
        }
    }

    /// Built-ins plus a `:`-separated list of device-file paths (what
    /// [`DEVICES_ENV`] holds); empty segments are skipped.
    pub fn from_paths(paths: &str) -> Result<TargetRegistry, String> {
        let mut r = TargetRegistry::builtin();
        for path in paths.split(':').filter(|p| !p.is_empty()) {
            r.load_file(path)?;
        }
        Ok(r)
    }

    /// Register (or shadow) a device under `short`.
    pub fn add(&mut self, short: &str, spec: DeviceSpec, source: &str) {
        self.devices.push(RegisteredDevice {
            short: short.to_string(),
            aliases: Vec::new(),
            spec,
            source: source.to_string(),
        });
    }

    /// Load a `cprune-devices` JSON file; returns how many devices it
    /// added. Every entry must parse — a half-loaded registry would make
    /// "unknown device" errors lie about what is available.
    pub fn load_file(&mut self, path: impl AsRef<Path>) -> Result<usize, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        self.load_str(&text, &path.display().to_string())
            .map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Parse a device-file document, tagging entries with `source`.
    pub fn load_str(&mut self, text: &str, source: &str) -> Result<usize, String> {
        let j = json::parse(text)?;
        match j.get("format").and_then(Json::as_str) {
            Some(DEVICES_FORMAT) => {}
            other => return Err(format!("not a device file (format {other:?})")),
        }
        match j.get("version").and_then(Json::as_usize) {
            Some(v) if v as u64 == DEVICES_VERSION => {}
            other => {
                return Err(format!(
                    "unsupported device-file version {other:?} (want {DEVICES_VERSION})"
                ))
            }
        }
        let entries = j
            .get("devices")
            .and_then(Json::as_arr)
            .ok_or("device file missing devices array")?;
        // Parse everything before registering anything, so a bad entry
        // cannot leave a half-loaded registry behind.
        let mut parsed: Vec<(String, DeviceSpec)> = Vec::with_capacity(entries.len());
        for e in entries {
            let spec = DeviceSpec::from_json(e)?;
            let short = e
                .get("short")
                .and_then(Json::as_str)
                .unwrap_or(spec.name)
                .to_string();
            parsed.push((short, spec));
        }
        let added = parsed.len();
        for (short, spec) in parsed {
            self.add(&short, spec, source);
        }
        Ok(added)
    }

    /// All registered devices, in registration order (shadowed entries
    /// included — `cprune devices` shows the whole picture).
    pub fn devices(&self) -> &[RegisteredDevice] {
        &self.devices
    }

    /// Sorted, deduplicated lookup names (shorts only, not aliases) —
    /// what "unknown device" diagnostics list.
    pub fn names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.devices.iter().map(|d| d.short.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        names
    }

    /// Look up a spec by short name or alias; later registrations shadow
    /// earlier ones.
    pub fn spec(&self, name: &str) -> Option<&DeviceSpec> {
        self.devices
            .iter()
            .rev()
            .find(|d| d.short == name || d.aliases.iter().any(|a| a == name))
            .map(|d| &d.spec)
    }

    /// Resolve a name to an analytic measurement provider (an optional
    /// `analytic:` prefix is accepted); richer providers (LUT tables,
    /// record/replay) wrap the result — see `run::RunBuilder::target_name`
    /// and the CLI's `--record-trace`/`--replay-trace`.
    pub fn resolve(&self, name: &str) -> Result<Box<dyn Target>, String> {
        let bare = name.strip_prefix("analytic:").unwrap_or(name);
        match self.spec(bare) {
            Some(spec) => Ok(Box::new(AnalyticTarget::new(spec.clone()))),
            None => Err(self.unknown_device_error(bare)),
        }
    }

    /// The diagnostic every unknown-name path shows: names the registry's
    /// valid devices, including any loaded from device files.
    pub fn unknown_device_error(&self, name: &str) -> String {
        format!(
            "unknown device '{name}'. known devices: {}",
            self.names().join(" ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_names_resolve_to_their_specs() {
        let r = TargetRegistry::builtin();
        assert_eq!(r.spec("kryo385").unwrap().name, "Kryo 385 (Galaxy S9)");
        assert_eq!(r.spec("mali-g72").unwrap().name, "Mali-G72 (Galaxy S9 GPU)");
        assert_eq!(r.spec("mali").unwrap().name, "Mali-G72 (Galaxy S9 GPU)");
        assert_eq!(r.spec("rtx3080").unwrap().kind, crate::device::DeviceKind::Gpu);
        assert!(r.spec("galaxy-s10").is_none());
        assert_eq!(r.names(), vec!["kryo280", "kryo385", "kryo585", "mali-g72", "rtx3080"]);
    }

    #[test]
    fn unknown_device_error_lists_every_valid_name() {
        let mut r = TargetRegistry::builtin();
        let e = r.unknown_device_error("galaxy-s10");
        assert!(e.contains("galaxy-s10"), "{e}");
        for name in ["kryo280", "kryo385", "kryo585", "mali-g72", "rtx3080"] {
            assert!(e.contains(name), "{e} missing {name}");
        }
        // names loaded from device files join the diagnostic
        let mut custom = DeviceSpec::kryo385();
        custom.name = "Custom Phone";
        r.add("custom-phone", custom, "test");
        let e = r.unknown_device_error("galaxy-s10");
        assert!(e.contains("custom-phone"), "{e}");
    }

    #[test]
    fn device_file_roundtrip_and_resolution() {
        let doc = r#"{"format":"cprune-devices","version":1,"devices":[
            {"short":"pixel9","name":"Tensor G4 (Pixel 9)","kind":"cpu",
             "cores":8,"peak_macs_per_core":1.1e10,"simd_lanes":4,
             "l1_bytes":65536,"l2_bytes":4194304,
             "mem_bytes_per_s":5.1e10,"dispatch_overhead_s":6e-6}]}"#;
        let mut r = TargetRegistry::builtin();
        assert_eq!(r.load_str(doc, "inline").unwrap(), 1);
        let spec = r.spec("pixel9").expect("loaded device resolves");
        assert_eq!(spec.name, "Tensor G4 (Pixel 9)");
        assert_eq!(spec.cores, 8);
        let target = r.resolve("pixel9").unwrap();
        assert_eq!(target.spec().cores, 8);
        // analytic: prefix accepted
        assert!(r.resolve("analytic:pixel9").is_ok());
        assert!(r.resolve("nope").unwrap_err().contains("pixel9"));
    }

    #[test]
    fn later_registrations_shadow_earlier_ones() {
        let mut r = TargetRegistry::builtin();
        let mut faster = DeviceSpec::kryo385();
        faster.peak_macs_per_core *= 2.0;
        r.add("kryo385", faster, "recalibration");
        assert_eq!(
            r.spec("kryo385").unwrap().peak_macs_per_core,
            DeviceSpec::kryo385().peak_macs_per_core * 2.0
        );
        // names() stays deduplicated
        assert_eq!(r.names().iter().filter(|n| **n == "kryo385").count(), 1);
    }

    #[test]
    fn malformed_device_files_fail_loudly() {
        let mut r = TargetRegistry::builtin();
        assert!(r.load_str("{}", "x").is_err());
        assert!(r
            .load_str(r#"{"format":"other","version":1,"devices":[]}"#, "x")
            .is_err());
        assert!(r
            .load_str(r#"{"format":"cprune-devices","version":9,"devices":[]}"#, "x")
            .is_err());
        // an entry missing fields poisons the whole load
        assert!(r
            .load_str(
                r#"{"format":"cprune-devices","version":1,"devices":[{"short":"x"}]}"#,
                "x"
            )
            .is_err());
        assert!(r.load_file("/nonexistent/devices.json").is_err());
    }

    #[test]
    fn from_paths_loads_each_file() {
        let dir = std::env::temp_dir();
        let path = dir.join("cprune_registry_unit_test_devices.json");
        let doc = r#"{"format":"cprune-devices","version":1,"devices":[
            {"short":"tdev","name":"Test Device","kind":"gpu","cores":2,
             "peak_macs_per_core":1e9,"simd_lanes":8,"l1_bytes":1024,
             "l2_bytes":2048,"mem_bytes_per_s":1e9,"dispatch_overhead_s":1e-6}]}"#;
        crate::util::io::atomic_write(&path, doc, "devices").unwrap();
        let r = TargetRegistry::from_paths(&path.display().to_string()).unwrap();
        assert!(r.spec("tdev").is_some());
        assert!(TargetRegistry::from_paths("").unwrap().spec("tdev").is_none());
        let _ = std::fs::remove_file(&path);
    }
}
