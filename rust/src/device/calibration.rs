//! Device-model calibration against known anchor measurements.
//!
//! The simulator's absolute scale is set by public specs; when a real
//! measurement exists (e.g. the paper's Table 1 "Original (TVM)" FPS per
//! device), this module fits a single per-device scale factor so simulated
//! FPS matches the anchor — preserving all *relative* behaviour (which is
//! what every search decision consumes) while pinning absolutes.

use super::sim::Simulator;
use super::spec::DeviceSpec;
use crate::compiler;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::tuner::{TuneOptions, TuningSession};
use crate::util::json::{self, Json};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;

/// Format tag of a persisted calibration table.
pub const CALIBRATION_FORMAT: &str = "cprune-calibration";
/// Bump when the entry schema changes; `parse` rejects other versions.
pub const CALIBRATION_VERSION: u64 = 1;

/// One anchor: the paper measured `fps` for `model` on this device.
#[derive(Clone, Debug)]
pub struct Anchor {
    pub model: ModelKind,
    pub fps: f64,
}

/// The paper's Table 1 "Original" rows, usable as calibration anchors.
pub fn paper_anchors(device_name: &str) -> Vec<Anchor> {
    match device_name {
        n if n.contains("Kryo 385") => vec![
            Anchor { model: ModelKind::ResNet18ImageNet, fps: 18.86 },
            Anchor { model: ModelKind::MobileNetV2ImageNet, fps: 28.20 },
        ],
        n if n.contains("Mali") => vec![
            Anchor { model: ModelKind::ResNet18ImageNet, fps: 15.65 },
            Anchor { model: ModelKind::MobileNetV2ImageNet, fps: 68.68 },
        ],
        n if n.contains("Kryo 585") => vec![
            Anchor { model: ModelKind::MnasNet10ImageNet, fps: 42.92 },
        ],
        n if n.contains("Kryo 280") => vec![
            // Table 2 CIFAR anchor
            Anchor { model: ModelKind::ResNet18Cifar, fps: 33.82 },
        ],
        _ => Vec::new(),
    }
}

/// Result of a calibration fit.
#[derive(Clone, Debug, PartialEq)]
pub struct Calibration {
    /// Multiply `peak_macs_per_core` and `mem_bytes_per_s` by this.
    pub scale: f64,
    /// Geometric-mean |log error| after calibration.
    pub residual: f64,
}

/// Fit the single scale factor minimizing log-FPS error over the anchors.
pub fn calibrate(spec: &DeviceSpec, anchors: &[Anchor], seed: u64) -> Calibration {
    if anchors.is_empty() {
        return Calibration { scale: 1.0, residual: 0.0 };
    }
    let sim = Simulator::new(spec.clone());
    let session = TuningSession::new(&sim, TuneOptions::quick(), seed);
    // Simulated FPS scales ~linearly with the scale factor (both roofline
    // terms scale), so the optimal log-scale is the mean log-ratio.
    let mut log_ratios = Vec::new();
    for a in anchors {
        let model = Model::build(a.model, seed);
        let fps = compiler::compile_tuned(&model.graph, &session, &HashMap::new()).fps();
        log_ratios.push((a.fps / fps).ln());
    }
    let mean = log_ratios.iter().sum::<f64>() / log_ratios.len() as f64;
    let residual = (log_ratios.iter().map(|r| (r - mean).abs()).sum::<f64>()
        / log_ratios.len() as f64)
        .exp()
        - 1.0;
    Calibration { scale: mean.exp(), residual }
}

/// Apply a calibration to a spec.
pub fn apply(spec: &DeviceSpec, cal: &Calibration) -> DeviceSpec {
    let mut s = spec.clone();
    s.peak_macs_per_core *= cal.scale;
    s.mem_bytes_per_s *= cal.scale;
    // dispatch overhead scales inversely with device speed-class
    s.dispatch_overhead_s /= cal.scale.max(0.25);
    s
}

/// Persistable per-device calibration fits (device name → [`Calibration`]),
/// so an expensive [`calibrate`] run is done once and reloaded by later
/// sessions (`cprune calibrate --save`, [`super::LutTarget::calibrated`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CalibrationTable {
    pub entries: BTreeMap<String, Calibration>,
}

impl CalibrationTable {
    pub fn new() -> CalibrationTable {
        CalibrationTable::default()
    }

    pub fn insert(&mut self, device: &str, cal: Calibration) {
        self.entries.insert(device.to_string(), cal);
    }

    pub fn get(&self, device: &str) -> Option<&Calibration> {
        self.entries.get(device)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Versioned JSON document (byte-stable: BTreeMap ordering).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::Str(CALIBRATION_FORMAT.to_string())),
            ("version", Json::Num(CALIBRATION_VERSION as f64)),
            (
                "entries",
                Json::Arr(
                    self.entries
                        .iter()
                        .map(|(device, cal)| {
                            Json::obj(vec![
                                ("device", Json::Str(device.clone())),
                                ("scale", Json::Num(cal.scale)),
                                ("residual", Json::Num(cal.residual)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse a document produced by [`CalibrationTable::to_json`].
    pub fn parse(text: &str) -> Result<CalibrationTable, String> {
        let j = json::parse(text)?;
        match j.get("format").and_then(Json::as_str) {
            Some(CALIBRATION_FORMAT) => {}
            other => return Err(format!("not a calibration table (format {other:?})")),
        }
        match j.get("version").and_then(Json::as_usize) {
            Some(v) if v as u64 == CALIBRATION_VERSION => {}
            other => {
                return Err(format!(
                    "unsupported calibration version {other:?} (want {CALIBRATION_VERSION})"
                ))
            }
        }
        let mut table = CalibrationTable::new();
        for e in j
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or("calibration table missing entries")?
        {
            let device = e
                .get("device")
                .and_then(Json::as_str)
                .ok_or("entry missing device")?;
            let scale = e
                .get("scale")
                .and_then(Json::as_f64)
                .filter(|s| s.is_finite() && *s > 0.0)
                .ok_or("entry missing positive scale")?;
            let residual = e
                .get("residual")
                .and_then(Json::as_f64)
                .ok_or("entry missing residual")?;
            table.insert(device, Calibration { scale, residual });
        }
        Ok(table)
    }

    /// Write the table atomically ([`crate::util::io::atomic_write`],
    /// DESIGN.md §15).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<(), String> {
        crate::util::io::atomic_write(path, &self.to_json().to_string(), "calibration")
    }

    /// Load a table previously written by [`CalibrationTable::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<CalibrationTable, String> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| format!("{}: {e}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_moves_fps_toward_anchor() {
        let spec = DeviceSpec::kryo385();
        let anchors = paper_anchors(spec.name);
        assert!(!anchors.is_empty());
        let cal = calibrate(&spec, &anchors, 0);
        let spec2 = apply(&spec, &cal);
        let sim2 = Simulator::new(spec2);
        let session = TuningSession::new(&sim2, TuneOptions::quick(), 0);
        let model = Model::build(ModelKind::ResNet18ImageNet, 0);
        let fps = compiler::compile_tuned(&model.graph, &session, &HashMap::new()).fps();
        // within 2x of the paper's 18.86 after calibration
        assert!(
            (9.0..40.0).contains(&fps),
            "calibrated FPS {fps} still far from anchor 18.86"
        );
    }

    #[test]
    fn empty_anchor_list_is_identity() {
        let cal = calibrate(&DeviceSpec::rtx3080(), &[], 0);
        assert_eq!(cal.scale, 1.0);
    }

    #[test]
    fn calibration_table_roundtrips_through_disk() {
        let mut table = CalibrationTable::new();
        table.insert(
            "Kryo 385 (Galaxy S9)",
            Calibration { scale: 0.8312345678901234, residual: 0.042 },
        );
        table.insert("Mali-G72 (Galaxy S9 GPU)", Calibration { scale: 1.25, residual: 0.0 });
        let path = std::env::temp_dir().join("cprune_calibration_unit_test.json");
        table.save(&path).unwrap();
        let back = CalibrationTable::load(&path).unwrap();
        assert_eq!(back, table);
        // f64 survives the text round trip exactly (shortest-repr writer)
        assert_eq!(
            back.get("Kryo 385 (Galaxy S9)").unwrap().scale.to_bits(),
            0.8312345678901234f64.to_bits()
        );
        let _ = std::fs::remove_file(&path);
        // foreign/versioned documents are rejected
        assert!(CalibrationTable::parse("{}").is_err());
        assert!(CalibrationTable::parse(
            r#"{"format":"cprune-calibration","version":9,"entries":[]}"#
        )
        .is_err());
        // a fitted calibration applies to a LutTarget's spec
        let cal = back.get("Kryo 385 (Galaxy S9)").unwrap();
        let t = crate::device::LutTarget::calibrated(&DeviceSpec::kryo385(), cal);
        use crate::device::Target as _;
        assert!(t.spec().peak_macs() < DeviceSpec::kryo385().peak_macs());
    }

    #[test]
    fn relative_ordering_preserved_by_calibration() {
        let spec = DeviceSpec::kryo385();
        let cal = Calibration { scale: 0.5, residual: 0.0 };
        let spec2 = apply(&spec, &cal);
        assert!(spec2.peak_macs() < spec.peak_macs());
        // cores/lanes/cache untouched → schedule preferences unchanged
        assert_eq!(spec2.cores, spec.cores);
        assert_eq!(spec2.simd_lanes, spec.simd_lanes);
        assert_eq!(spec2.l1_bytes, spec.l1_bytes);
    }
}
