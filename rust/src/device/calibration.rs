//! Device-model calibration against known anchor measurements.
//!
//! The simulator's absolute scale is set by public specs; when a real
//! measurement exists (e.g. the paper's Table 1 "Original (TVM)" FPS per
//! device), this module fits a single per-device scale factor so simulated
//! FPS matches the anchor — preserving all *relative* behaviour (which is
//! what every search decision consumes) while pinning absolutes.

use super::sim::Simulator;
use super::spec::DeviceSpec;
use crate::compiler;
use crate::graph::model_zoo::{Model, ModelKind};
use crate::tuner::{TuneOptions, TuningSession};
use std::collections::HashMap;

/// One anchor: the paper measured `fps` for `model` on this device.
#[derive(Clone, Debug)]
pub struct Anchor {
    pub model: ModelKind,
    pub fps: f64,
}

/// The paper's Table 1 "Original" rows, usable as calibration anchors.
pub fn paper_anchors(device_name: &str) -> Vec<Anchor> {
    match device_name {
        n if n.contains("Kryo 385") => vec![
            Anchor { model: ModelKind::ResNet18ImageNet, fps: 18.86 },
            Anchor { model: ModelKind::MobileNetV2ImageNet, fps: 28.20 },
        ],
        n if n.contains("Mali") => vec![
            Anchor { model: ModelKind::ResNet18ImageNet, fps: 15.65 },
            Anchor { model: ModelKind::MobileNetV2ImageNet, fps: 68.68 },
        ],
        n if n.contains("Kryo 585") => vec![
            Anchor { model: ModelKind::MnasNet10ImageNet, fps: 42.92 },
        ],
        n if n.contains("Kryo 280") => vec![
            // Table 2 CIFAR anchor
            Anchor { model: ModelKind::ResNet18Cifar, fps: 33.82 },
        ],
        _ => Vec::new(),
    }
}

/// Result of a calibration fit.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Multiply `peak_macs_per_core` and `mem_bytes_per_s` by this.
    pub scale: f64,
    /// Geometric-mean |log error| after calibration.
    pub residual: f64,
}

/// Fit the single scale factor minimizing log-FPS error over the anchors.
pub fn calibrate(spec: &DeviceSpec, anchors: &[Anchor], seed: u64) -> Calibration {
    if anchors.is_empty() {
        return Calibration { scale: 1.0, residual: 0.0 };
    }
    let sim = Simulator::new(spec.clone());
    let session = TuningSession::new(&sim, TuneOptions::quick(), seed);
    // Simulated FPS scales ~linearly with the scale factor (both roofline
    // terms scale), so the optimal log-scale is the mean log-ratio.
    let mut log_ratios = Vec::new();
    for a in anchors {
        let model = Model::build(a.model, seed);
        let fps = compiler::compile_tuned(&model.graph, &session, &HashMap::new()).fps();
        log_ratios.push((a.fps / fps).ln());
    }
    let mean = log_ratios.iter().sum::<f64>() / log_ratios.len() as f64;
    let residual = (log_ratios.iter().map(|r| (r - mean).abs()).sum::<f64>()
        / log_ratios.len() as f64)
        .exp()
        - 1.0;
    Calibration { scale: mean.exp(), residual }
}

/// Apply a calibration to a spec.
pub fn apply(spec: &DeviceSpec, cal: &Calibration) -> DeviceSpec {
    let mut s = spec.clone();
    s.peak_macs_per_core *= cal.scale;
    s.mem_bytes_per_s *= cal.scale;
    // dispatch overhead scales inversely with device speed-class
    s.dispatch_overhead_s /= cal.scale.max(0.25);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_moves_fps_toward_anchor() {
        let spec = DeviceSpec::kryo385();
        let anchors = paper_anchors(spec.name);
        assert!(!anchors.is_empty());
        let cal = calibrate(&spec, &anchors, 0);
        let spec2 = apply(&spec, &cal);
        let sim2 = Simulator::new(spec2);
        let session = TuningSession::new(&sim2, TuneOptions::quick(), 0);
        let model = Model::build(ModelKind::ResNet18ImageNet, 0);
        let fps = compiler::compile_tuned(&model.graph, &session, &HashMap::new()).fps();
        // within 2x of the paper's 18.86 after calibration
        assert!(
            (9.0..40.0).contains(&fps),
            "calibrated FPS {fps} still far from anchor 18.86"
        );
    }

    #[test]
    fn empty_anchor_list_is_identity() {
        let cal = calibrate(&DeviceSpec::rtx3080(), &[], 0);
        assert_eq!(cal.scale, 1.0);
    }

    #[test]
    fn relative_ordering_preserved_by_calibration() {
        let spec = DeviceSpec::kryo385();
        let cal = Calibration { scale: 0.5, residual: 0.0 };
        let spec2 = apply(&spec, &cal);
        assert!(spec2.peak_macs() < spec.peak_macs());
        // cores/lanes/cache untouched → schedule preferences unchanged
        assert_eq!(spec2.cores, spec.cores);
        assert_eq!(spec2.simd_lanes, spec.simd_lanes);
        assert_eq!(spec2.l1_bytes, spec.l1_bytes);
    }
}
