//! Architectural parameters of the paper's target devices.
//!
//! Numbers are public-spec approximations (clock × FMA width × pipes for
//! peak, LPDDR4/4X/5 for bandwidth). The simulator consumes ratios, so
//! modest absolute errors do not change any experiment's *shape*.

use crate::util::json::Json;

/// CPU vs GPU execution model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    Cpu,
    Gpu,
}

impl DeviceKind {
    /// Stable string used by the device-file / measurement-trace schemas.
    pub fn as_str(&self) -> &'static str {
        match self {
            DeviceKind::Cpu => "cpu",
            DeviceKind::Gpu => "gpu",
        }
    }

    pub fn parse(s: &str) -> Result<DeviceKind, String> {
        match s {
            "cpu" => Ok(DeviceKind::Cpu),
            "gpu" => Ok(DeviceKind::Gpu),
            other => Err(format!("unknown device kind '{other}' (want cpu|gpu)")),
        }
    }
}

/// One execution target.
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub kind: DeviceKind,
    /// CPU cores or GPU shader cores usable for one inference.
    pub cores: usize,
    /// Peak f32 multiply-accumulates per second *per core*.
    pub peak_macs_per_core: f64,
    /// Preferred f32 vector width (NEON lanes / GPU vec unit).
    pub simd_lanes: usize,
    /// Per-core fast memory (L1 D-cache / GPU local memory), bytes.
    pub l1_bytes: usize,
    /// Shared last-level cache, bytes.
    pub l2_bytes: usize,
    /// DRAM bandwidth, bytes/second.
    pub mem_bytes_per_s: f64,
    /// Fixed per-subgraph dispatch overhead, seconds (kernel launch /
    /// function call + scheduling).
    pub dispatch_overhead_s: f64,
}

impl DeviceSpec {
    /// Samsung Galaxy S8 — Kryo 280 (4 big A73-class @ 2.35 GHz, 128-bit NEON).
    pub fn kryo280() -> DeviceSpec {
        DeviceSpec {
            name: "Kryo 280 (Galaxy S8)",
            kind: DeviceKind::Cpu,
            cores: 4,
            peak_macs_per_core: 2.35e9 * 4.0, // 1 FMA pipe x 4 lanes
            simd_lanes: 4,
            l1_bytes: 64 * 1024,
            l2_bytes: 2 * 1024 * 1024,
            mem_bytes_per_s: 14.9e9,
            dispatch_overhead_s: 8e-6,
        }
    }

    /// Galaxy S9 / Pixel 3 XL — Kryo 385 (4 big A75-class @ 2.8 GHz).
    pub fn kryo385() -> DeviceSpec {
        DeviceSpec {
            name: "Kryo 385 (Galaxy S9)",
            kind: DeviceKind::Cpu,
            cores: 4,
            peak_macs_per_core: 2.8e9 * 4.0 * 1.4, // wider issue than A73
            simd_lanes: 4,
            l1_bytes: 64 * 1024,
            l2_bytes: 3 * 1024 * 1024,
            mem_bytes_per_s: 24.0e9,
            dispatch_overhead_s: 7e-6,
        }
    }

    /// Galaxy S20+ — Kryo 585 (A77-class @ 2.73 GHz, 2 FMA pipes).
    pub fn kryo585() -> DeviceSpec {
        DeviceSpec {
            name: "Kryo 585 (Galaxy S20+)",
            kind: DeviceKind::Cpu,
            cores: 4,
            peak_macs_per_core: 2.73e9 * 4.0 * 2.0, // 2 x 128-bit FMA
            simd_lanes: 4,
            l1_bytes: 64 * 1024,
            l2_bytes: 4 * 1024 * 1024,
            mem_bytes_per_s: 34.1e9,
            dispatch_overhead_s: 6e-6,
        }
    }

    /// Galaxy S9 GPU — Mali-G72 MP18 @ 850 MHz.
    pub fn mali_g72() -> DeviceSpec {
        DeviceSpec {
            name: "Mali-G72 (Galaxy S9 GPU)",
            kind: DeviceKind::Gpu,
            cores: 18,
            peak_macs_per_core: 0.85e9 * 8.0, // 8 f32 FMA / core / clk
            simd_lanes: 8,
            l1_bytes: 32 * 1024, // per-core local
            l2_bytes: 1024 * 1024,
            mem_bytes_per_s: 24.0e9, // shared with CPU
            dispatch_overhead_s: 40e-6, // GL/CL kernel launch dominates
        }
    }

    /// Desktop-class GPU host for the Fig. 1 motivation experiment
    /// (RTX 3080-like: the experiment only needs "a very fast device
    /// whose schedule preferences differ wildly from mobile").
    pub fn rtx3080() -> DeviceSpec {
        DeviceSpec {
            name: "RTX 3080 (host)",
            kind: DeviceKind::Gpu,
            cores: 68,             // SMs
            peak_macs_per_core: 219e9, // ~29.8 TFLOPs total
            simd_lanes: 32,        // warp
            l1_bytes: 128 * 1024,
            l2_bytes: 5 * 1024 * 1024,
            mem_bytes_per_s: 760e9,
            dispatch_overhead_s: 5e-6,
        }
    }

    /// All mobile targets used in the paper's tables.
    pub fn mobile_targets() -> Vec<DeviceSpec> {
        vec![
            DeviceSpec::kryo280(),
            DeviceSpec::kryo385(),
            DeviceSpec::kryo585(),
            DeviceSpec::mali_g72(),
        ]
    }

    /// Aggregate peak MACs/s across cores.
    pub fn peak_macs(&self) -> f64 {
        self.peak_macs_per_core * self.cores as f64
    }

    /// JSON encoding shared by the device-file schema
    /// (`cprune-devices`, see [`super::TargetRegistry`]) and the
    /// measurement-trace header (`cprune-measure-trace`,
    /// [`super::ReplayTarget`]).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.to_string())),
            ("kind", Json::Str(self.kind.as_str().to_string())),
            ("cores", Json::Num(self.cores as f64)),
            ("peak_macs_per_core", Json::Num(self.peak_macs_per_core)),
            ("simd_lanes", Json::Num(self.simd_lanes as f64)),
            ("l1_bytes", Json::Num(self.l1_bytes as f64)),
            ("l2_bytes", Json::Num(self.l2_bytes as f64)),
            ("mem_bytes_per_s", Json::Num(self.mem_bytes_per_s)),
            ("dispatch_overhead_s", Json::Num(self.dispatch_overhead_s)),
        ])
    }

    /// Parse a spec from [`DeviceSpec::to_json`] output (or a
    /// hand-written device-file entry). Names matching a built-in are
    /// reused; novel names are interned (leaked once per distinct name
    /// per process — specs are loaded a handful of times, not in loops).
    pub fn from_json(j: &Json) -> Result<DeviceSpec, String> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .ok_or("device spec missing name")?;
        let kind = DeviceKind::parse(
            j.get("kind")
                .and_then(Json::as_str)
                .ok_or("device spec missing kind")?,
        )?;
        let usize_field = |key: &str| -> Result<usize, String> {
            j.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| format!("device spec missing {key}"))
        };
        let f64_field = |key: &str| -> Result<f64, String> {
            j.get(key)
                .and_then(Json::as_f64)
                .filter(|v| v.is_finite() && *v > 0.0)
                .ok_or_else(|| format!("device spec missing positive {key}"))
        };
        Ok(DeviceSpec {
            name: intern_device_name(name),
            kind,
            cores: usize_field("cores")?.max(1),
            peak_macs_per_core: f64_field("peak_macs_per_core")?,
            simd_lanes: usize_field("simd_lanes")?.max(1),
            l1_bytes: usize_field("l1_bytes")?.max(1),
            l2_bytes: usize_field("l2_bytes")?.max(1),
            mem_bytes_per_s: f64_field("mem_bytes_per_s")?,
            dispatch_overhead_s: j
                .get("dispatch_overhead_s")
                .and_then(Json::as_f64)
                .filter(|v| v.is_finite() && *v >= 0.0)
                .ok_or("device spec missing dispatch_overhead_s")?,
        })
    }
}

/// Map a parsed device name back onto a `'static` str: built-in names are
/// reused, novel ones are leaked once per distinct name per process (the
/// same pattern `tir::jsonio` uses for epilogue tags).
fn intern_device_name(name: &str) -> &'static str {
    for spec in [
        DeviceSpec::kryo280(),
        DeviceSpec::kryo385(),
        DeviceSpec::kryo585(),
        DeviceSpec::mali_g72(),
        DeviceSpec::rtx3080(),
    ] {
        if spec.name == name {
            return spec.name;
        }
    }
    Box::leak(name.to_string().into_boxed_str())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_ordering_matches_generation() {
        // Newer Kryo generations are faster.
        let k280 = DeviceSpec::kryo280().peak_macs();
        let k385 = DeviceSpec::kryo385().peak_macs();
        let k585 = DeviceSpec::kryo585().peak_macs();
        assert!(k280 < k385 && k385 < k585);
    }

    #[test]
    fn gpu_has_more_cores_and_higher_dispatch() {
        let g = DeviceSpec::mali_g72();
        let c = DeviceSpec::kryo385();
        assert!(g.cores > c.cores);
        assert!(g.dispatch_overhead_s > c.dispatch_overhead_s);
    }

    #[test]
    fn host_gpu_dwarfs_mobile() {
        assert!(DeviceSpec::rtx3080().peak_macs() > 50.0 * DeviceSpec::kryo585().peak_macs());
    }

    #[test]
    fn spec_json_roundtrip_is_exact() {
        for spec in [DeviceSpec::kryo385(), DeviceSpec::mali_g72(), DeviceSpec::rtx3080()] {
            let j = spec.to_json();
            let back = DeviceSpec::from_json(&j).unwrap();
            assert_eq!(back.name, spec.name);
            assert_eq!(back.kind, spec.kind);
            assert_eq!(back.cores, spec.cores);
            assert_eq!(back.peak_macs_per_core.to_bits(), spec.peak_macs_per_core.to_bits());
            assert_eq!(back.simd_lanes, spec.simd_lanes);
            assert_eq!(back.l1_bytes, spec.l1_bytes);
            assert_eq!(back.l2_bytes, spec.l2_bytes);
            assert_eq!(back.mem_bytes_per_s.to_bits(), spec.mem_bytes_per_s.to_bits());
            assert_eq!(back.dispatch_overhead_s.to_bits(), spec.dispatch_overhead_s.to_bits());
            // built-in names intern to the same 'static str, no leak
            assert!(std::ptr::eq(back.name, spec.name));
        }
        assert!(DeviceSpec::from_json(&Json::obj(vec![])).is_err());
    }
}
