//! Per-layer latency lookup tables (NetAdapt's actual mechanism).
//!
//! NetAdapt §3 precomputes, per layer, a table `latency(#filters)` from
//! on-device measurements, then answers every candidate query from the
//! table instead of re-measuring. This module builds the same table from
//! our simulator (tuned per sampled channel count, interpolated between),
//! giving the NetAdapt baseline its authentic O(1) inner-loop queries and
//! making the Fig. 11 search-cost comparison faithful.
//!
//! These tables also back the [`super::LutTarget`] measurement provider
//! (DESIGN.md §11), which serves them through the uniform
//! [`super::Target`] plane — `cprune run --target lut:<device>` tunes
//! against the tables with analytic fallback for uncovered workloads.

use super::target::Target;
use crate::tir::Workload;
use crate::tuner::{tune_task, TuneOptions};
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Latency table for one layer: sampled (channels, seconds) points.
#[derive(Clone, Debug)]
pub struct LayerLut {
    /// Ascending by channel count.
    pub points: Vec<(usize, f64)>,
}

impl LayerLut {
    /// Build by tuning the workload at `samples` channel counts on any
    /// measurement provider (typically an analytic or calibrated target).
    pub fn build(
        base: &Workload,
        target: &dyn Target,
        opts: &TuneOptions,
        samples: &[usize],
        seed: u64,
    ) -> LayerLut {
        let mut points: Vec<(usize, f64)> = samples
            .iter()
            .map(|&ff| {
                let mut w = base.clone();
                w.ff = ff;
                let mut rng = Rng::with_stream(seed, ff as u64 | 1);
                let r = tune_task(&w, target, opts, &mut rng, None);
                (ff, r.latency)
            })
            .collect();
        points.sort_by_key(|&(ff, _)| ff);
        LayerLut { points }
    }

    /// Interpolated latency at an arbitrary channel count.
    pub fn latency(&self, channels: usize) -> f64 {
        let pts = &self.points;
        if pts.is_empty() {
            return 0.0;
        }
        if channels <= pts[0].0 {
            return pts[0].1 * channels as f64 / pts[0].0.max(1) as f64;
        }
        if channels >= pts[pts.len() - 1].0 {
            let (c, l) = pts[pts.len() - 1];
            return l * channels as f64 / c as f64;
        }
        let i = pts.partition_point(|&(c, _)| c < channels);
        let (c0, l0) = pts[i - 1];
        let (c1, l1) = pts[i];
        if c0 == channels {
            return l0;
        }
        let t = (channels - c0) as f64 / (c1 - c0) as f64;
        l0 + t * (l1 - l0)
    }
}

/// Latency tables for every prunable conv of a model.
pub struct ModelLut {
    pub layers: HashMap<usize, LayerLut>,
}

impl ModelLut {
    /// Sample each layer at {25, 50, 75, 100}% of its original width.
    pub fn build(
        model: &crate::graph::model_zoo::Model,
        target: &dyn Target,
        opts: &TuneOptions,
        seed: u64,
    ) -> ModelLut {
        let part = crate::relay::partition::partition(&model.graph);
        let mut layers = HashMap::new();
        for sg in &part.subgraphs {
            if !model.prunable.contains(&sg.anchor) {
                continue;
            }
            let ff = sg.workload.ff;
            let samples: Vec<usize> = [4usize, 2, 4 / 3, 1]
                .iter()
                .map(|&d| (ff * 3 / (d * 3)).max(2)) // 25/50/75/100%
                .collect::<std::collections::BTreeSet<_>>()
                .into_iter()
                .collect();
            layers.insert(
                sg.anchor,
                LayerLut::build(&sg.workload, target, opts, &samples, seed),
            );
        }
        ModelLut { layers }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::{Model, ModelKind};
    use crate::graph::ops::OpKind;

    fn wl(ff: usize) -> Workload {
        Workload::from_conv(
            &OpKind::Conv2d { kh: 3, kw: 3, cin: 32, cout: ff, stride: 1, padding: 1, groups: 1 },
            [1, 14, 14, ff],
            vec!["bn", "relu"],
        )
    }

    #[test]
    fn lut_latency_is_monotone_ish_and_interpolates() {
        let sim = Simulator::new(DeviceSpec::kryo385());
        let lut = LayerLut::build(&wl(128), &sim, &TuneOptions::quick(), &[32, 64, 96, 128], 0);
        assert_eq!(lut.points.len(), 4);
        // exact sample points round-trip
        for &(c, l) in &lut.points {
            assert_eq!(lut.latency(c), l);
        }
        // interpolated mid-point lies between neighbours
        let mid = lut.latency(80);
        let lo = lut.latency(64).min(lut.latency(96));
        let hi = lut.latency(64).max(lut.latency(96));
        assert!(mid >= lo && mid <= hi);
        // fewer channels never slower at the sampled resolution
        assert!(lut.latency(32) <= lut.latency(128) * 1.05);
    }

    #[test]
    fn model_lut_covers_prunable_layers() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let lut = ModelLut::build(&m, &sim, &TuneOptions::quick(), 1);
        for &conv in &m.prunable {
            assert!(lut.layers.contains_key(&conv), "no LUT for conv {conv}");
            assert!(lut.layers[&conv].latency(8) > 0.0);
        }
    }
}
