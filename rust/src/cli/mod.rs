//! Command-line interface (hand-rolled parsing; clap is unavailable in
//! this offline environment).
//!
//! Subcommands:
//!   run      — run any pruner (CPrune or a baseline) by name, with the
//!              typed event stream (DESIGN.md §9)
//!   prune    — run CPrune on a zoo model for a device
//!   tune     — auto-tune a model without pruning (the TVM baseline)
//!   fleet    — tune one model for several devices in one session
//!   serve    — simulate SLO-bound traffic against the Pareto frontier
//!   compare  — method comparison for one (model, device) cell
//!   report   — regenerate a paper experiment (fig1..fig11, table1, table2)
//!   check    — sweep persisted artifacts through the semantic verifier
//!              (DESIGN.md §13; exits nonzero on findings)
//!   worker   — serve `cprune-remote` measurement frames (DESIGN.md §14)
//!              over stdin/stdout or TCP for a `--target remote:...` run
//!   e2e-info — show the AOT artifact inventory the e2e path consumes
//!
//! `run`/`prune`/`tune` accept `--cache FILE` and `fleet` accepts
//! `--cache-dir DIR`: tuned programs persist as versioned JSON, so a
//! repeated run warm-starts and re-measures (close to) nothing.
//!
//! `run`/`prune` also accept `--target remote:NAME` (spawning `--workers`
//! `cprune worker` subprocesses) or `remote:NAME@HOST:PORT,...` (TCP),
//! and `fleet --workers N` measures every device on its own remote pool —
//! both bit-identical to in-process measurement (DESIGN.md §14).

use crate::compiler;
use crate::device::remote::{worker, RemoteOptions, RemoteTarget};
use crate::device::{AnalyticTarget, DeviceSpec, Simulator, Target, TargetRegistry};
use crate::exp::{self, Scale};
use crate::graph::model_zoo::{Model, ModelKind};
use crate::run::{
    pruner_by_name, CPrune, JsonlSink, ProgressPrinter, RegistryPublisher, RunBuilder,
    PRUNER_NAMES,
};
use crate::serve::{Registry, ServeOptions, Simulator as ServeSimulator};
use crate::tuner::{
    FleetDeviceResult, FleetOptions, FleetSession, TuneCache, TuneOptions, TuningSession,
};
use crate::util::bench::print_table;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// Parsed flags: `--key value` / `--key=value` pairs plus positional
/// arguments.
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

/// True for the flag names this CLI can ever define: letters, digits and
/// hyphens. Anything else after `--` is almost certainly a value that
/// lost its flag (e.g. `--events --foo.jsonl`), and silently turning it
/// into a boolean flag would swallow it.
fn is_flag_name(s: &str) -> bool {
    !s.is_empty() && s.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'-')
}

/// Parse `argv` into positionals and `--key value` / `--key=value`
/// flags. A bare `--key` not followed by a value parses as the boolean
/// `"true"`; values that themselves begin with `--` must be attached
/// with `=` (`--events=--weird.jsonl`). A lone `--` ends flag parsing.
pub fn parse_args(argv: &[String]) -> Result<Args, String> {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if a == "--" {
            positional.extend(argv[i + 1..].iter().cloned());
            break;
        }
        if let Some(body) = a.strip_prefix("--") {
            if let Some((key, value)) = body.split_once('=') {
                if !is_flag_name(key) {
                    return Err(format!("malformed flag '{a}'"));
                }
                flags.insert(key.to_string(), value.to_string());
                i += 1;
            } else {
                if !is_flag_name(body) {
                    return Err(format!(
                        "'{a}' is not a valid flag; to pass it as a value, attach it \
                         with '=': --<flag>={a}"
                    ));
                }
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(body.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(body.to_string(), "true".to_string());
                    i += 1;
                }
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Ok(Args { positional, flags })
}

pub fn model_by_name(name: &str) -> ModelKind {
    match name {
        "vgg16-cifar" => ModelKind::Vgg16Cifar,
        "resnet18" | "resnet18-imagenet" => ModelKind::ResNet18ImageNet,
        "resnet18-cifar" => ModelKind::ResNet18Cifar,
        "resnet34" | "resnet34-imagenet" => ModelKind::ResNet34ImageNet,
        "mobilenetv1" => ModelKind::MobileNetV1ImageNet,
        "mobilenetv2" => ModelKind::MobileNetV2ImageNet,
        "mnasnet" | "mnasnet1.0" => ModelKind::MnasNet10ImageNet,
        "resnet8-cifar" => ModelKind::ResNet8Cifar,
        other => {
            eprintln!("unknown model '{other}'. options: vgg16-cifar, resnet18-imagenet, resnet18-cifar, mobilenetv2, mnasnet1.0, resnet8-cifar");
            std::process::exit(2);
        }
    }
}

/// Build a tuning session, warm-started from `--cache FILE` when the file
/// exists. `Err` carries the process exit code (corrupt cache files fail
/// loudly rather than silently re-tuning from cold).
fn open_session<'a>(
    target: &'a dyn Target,
    opts: TuneOptions,
    seed: u64,
    cache_path: Option<&String>,
) -> Result<TuningSession<'a>, i32> {
    match cache_path {
        Some(p) if std::path::Path::new(p).exists() => {
            match TuneCache::load(p, target.spec().name) {
                Ok(c) => {
                    println!("cache: warm-start from {p} ({} programs)", c.len());
                    Ok(TuningSession::with_cache(target, opts, seed, c))
                }
                Err(e) => {
                    eprintln!("cache {p}: {e}");
                    Err(1)
                }
            }
        }
        _ => Ok(TuningSession::new(target, opts, seed)),
    }
}

/// Parse `--devices d1,d2,...` (falling back to `default`) into specs,
/// shared by `fleet` and `serve`. `Err` carries the process exit code —
/// unknown names (diagnosed with the registry's full name list, device
/// files included) and empty lists already printed their diagnostics.
fn parse_devices(
    args: &Args,
    registry: &TargetRegistry,
    default: &str,
) -> Result<Vec<DeviceSpec>, i32> {
    let device_list = args
        .flags
        .get("devices")
        .cloned()
        .unwrap_or_else(|| default.to_string());
    let mut specs: Vec<DeviceSpec> = Vec::new();
    for name in device_list.split(',').filter(|s| !s.is_empty()) {
        match registry.spec(name) {
            Some(spec) => specs.push(spec.clone()),
            None => {
                eprintln!("{}", registry.unknown_device_error(name));
                return Err(2);
            }
        }
    }
    if specs.is_empty() {
        eprintln!("--devices needs at least one device");
        return Err(2);
    }
    Ok(specs)
}

/// Parse `--key value` as a `T`, falling back to `default` when the flag
/// is absent; `Err` carries a user-facing message for malformed values.
fn flag_or<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> Result<T, String> {
    match args.flags.get(key) {
        Some(v) => v
            .parse()
            .map_err(|_| format!("--{key} wants a number, got '{v}'")),
        None => Ok(default),
    }
}

/// Shared wiring of the `run`/`prune` subcommands: a [`RunBuilder`] from
/// the common flags (`--iters`, `--target-acc`, `--seed`, `--cache`,
/// `--events`, `--target`, `--record-trace`, `--replay-trace`,
/// `--workers`, `--remote-trace`). `Err` carries the process exit code —
/// diagnostics are already printed.
fn run_builder_from_flags(
    args: &Args,
    model_kind: ModelKind,
    registry: &TargetRegistry,
    device: &DeviceSpec,
    seed: u64,
) -> Result<RunBuilder, i32> {
    let iters = match flag_or(args, "iters", 20usize) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("{e}");
            return Err(2);
        }
    };
    let mut builder = RunBuilder::new(model_kind)
        .with_registry(registry.clone())
        .seed(seed)
        .tune_opts(TuneOptions::quick())
        .max_iterations(iters);
    // Provider selection: a replay trace overrides everything (its spec
    // travels in the trace); --target picks provider:name; otherwise the
    // already-resolved --device spec rides the analytic provider.
    if let Some(path) = args.flags.get("replay-trace") {
        builder = builder.replay_trace(path);
    } else if let Some(t) = args.flags.get("target") {
        builder = builder.target_name(t);
    } else {
        builder = builder.device_spec(device.clone());
    }
    if let Some(path) = args.flags.get("record-trace") {
        builder = builder.record_trace(path);
    }
    match flag_or(args, "workers", 1usize) {
        Ok(n) => builder = builder.workers(n),
        Err(e) => {
            eprintln!("{e}");
            return Err(2);
        }
    }
    if let Some(path) = args.flags.get("remote-trace") {
        builder = builder.remote_trace(path);
    }
    if let Some(path) = args.flags.get("calibration") {
        match crate::device::calibration::CalibrationTable::load(path) {
            Ok(table) => builder = builder.calibration(table),
            Err(e) => {
                eprintln!("{e}");
                return Err(1);
            }
        }
    }
    if let Some(v) = args.flags.get("target-acc") {
        match v.parse::<f64>() {
            Ok(a) => builder = builder.accuracy_budget(a),
            Err(_) => {
                eprintln!("--target-acc wants a number, got '{v}'");
                return Err(2);
            }
        }
    }
    if let Some(path) = args.flags.get("cache") {
        builder = builder.cache(path);
    }
    if let Some(path) = args.flags.get("events") {
        match JsonlSink::create(path) {
            Ok(sink) => builder = builder.observer(Box::new(sink)),
            Err(e) => {
                eprintln!("{e}");
                return Err(1);
            }
        }
    }
    Ok(builder)
}

/// Persist the session cache when `--cache` was given; returns the exit code.
fn close_session(session: &TuningSession, cache_path: Option<&String>) -> i32 {
    if let Some(p) = cache_path {
        if let Err(e) = session.cache.save(p, session.device_name()) {
            eprintln!("saving cache {p}: {e}");
            return 1;
        }
        println!("cache: saved {} programs to {p}", session.cache.len());
    }
    0
}

/// Print one perf suite's table + machine-grepable BENCH lines and save
/// its `BENCH_<suite>.json`; `Some(exit_code)` on failure.
fn emit_bench_report(report: &crate::perf::PerfReport, seed: u64, out_dir: &str) -> Option<i32> {
    let rows: Vec<Vec<String>> = report.records.iter().map(|r| r.table_row()).collect();
    print_table(
        &format!("{} suite ({} tier, seed {})", report.suite, report.tier.name(), seed),
        &["benchmark", "wall s", "programs measured"],
        &rows,
    );
    for r in &report.records {
        println!("BENCH {} wall_s {:.3} measured {}", r.name, r.wall_s, r.programs_measured);
        for (k, v) in &r.metrics {
            println!("BENCH {}.{k} {v:.3}", r.name);
        }
    }
    match report.save(out_dir) {
        Ok(path) => {
            println!("bench: wrote {}", path.display());
            None
        }
        Err(e) => {
            eprintln!("{e}");
            Some(1)
        }
    }
}

const USAGE: &str = "cprune — compiler-informed model pruning (paper reproduction)

USAGE:
  cprune run       [--pruner P] [--model M] [--device D | --target T] [--target-acc A] [--iters N]
                   [--scheme auto|channel|pattern|block] [--masks FILE.json]
                   [--seed S] [--cache FILE] [--events FILE.jsonl] [--registry FILE]
                   [--record-trace FILE] [--replay-trace FILE] [--device-file FILE]
                   [--calibration FILE] [--workers N] [--remote-trace FILE]
                   [--journal FILE | --resume FILE] [--faults SPEC]
                   [--verbose] [--quiet]
  cprune prune     [--model M] [--device D | --target T] [--target-acc A] [--iters N] [--seed S]
                   [--out FILE.json] [--cache FILE] [--events FILE.jsonl]
                   [--record-trace FILE] [--replay-trace FILE] [--workers N]
                   [--remote-trace FILE]
  cprune tune      [--model M] [--device D] [--seed S] [--cache FILE]
  cprune fleet     [--model M] [--devices d1,d2,...] [--seed S] [--threads N] [--quick] [--cache-dir DIR]
                   [--workers N]
  cprune worker    [--stdio | --listen ADDR] [--device D]     # remote measurement worker (DESIGN.md §14)
  cprune serve     [--model M] [--devices d1,d2,...] [--rps R] [--requests N] [--slo-ms T]
                   [--accuracy-floor A] [--trace-seed S] [--max-batch B] [--iters N]
                   [--registry FILE] [--no-search] [--seed S]
  cprune compare   [--model M] [--device D] [--seed S]
  cprune bench     [--tier quick|full] [--seed S] [--out-dir DIR]
  cprune check     [PATH ...] [--codes]           # semantic artifact sweep (DESIGN.md §13)
  cprune report    <fig1|fig6|fig7|fig8|fig9|fig10|fig11|table1|table2|schemes> [--scale smoke|full]
  cprune devices   [--device-file FILE]           # list the target registry
  cprune dot       [--model M]                    # graphviz of graph+subgraphs+tasks
  cprune calibrate [--device D] [--save FILE]     # fit sim scale to paper anchors
  cprune e2e-info

  pruners: cprune magnitude fpgm netadapt amc pqf pattern block scheme-select
  models:  vgg16-cifar resnet18-imagenet resnet18-cifar resnet34 mobilenetv1
           mobilenetv2 mnasnet1.0 resnet8-cifar
  devices: kryo280 kryo385 kryo585 mali-g72 rtx3080, plus any spec loaded
           from --device-file / CPRUNE_DEVICES (see `cprune devices`)

  Flags take '--key value' or '--key=value'; values that begin with '--'
  must use the '=' form.

TARGETS (DESIGN.md §11):
  Every measurement flows through one `device::Target` plane. --device D
  picks the analytic roofline for a registry device; `run`/`prune` also
  accept --target with a provider prefix: `analytic:D` (default),
  `lut:D` (per-layer latency tables built for the model at startup,
  analytic fallback for uncovered workloads), or `remote:D` (below);
  --calibration FILE applies
  a `cprune calibrate --save` table to the device spec first.
  --record-trace FILE saves
  every measurement as a versioned `cprune-measure-trace` JSON;
  --replay-trace FILE re-runs against a recorded trace, reproducing the
  recorded run's results and event stream byte-for-byte on any machine
  (same model/seed/budget flags). User-defined devices load from
  `cprune-devices` JSON files via --device-file or CPRUNE_DEVICES.

REMOTE (DESIGN.md §14):
  --target remote:D measures on a pool of out-of-process workers:
  --workers N spawns N `cprune worker --stdio` subprocesses of this
  binary; `remote:D@HOST:PORT[,HOST:PORT...]` connects one TCP worker
  per address (each running `cprune worker --listen ADDR --device D`).
  Results are bit-identical to in-process measurement for any worker
  count — partitioning, completion order, worker death and retries never
  change values. --remote-trace FILE records every remote measurement
  (with its jitter draws) as a `cprune-remote-trace` JSON that
  --replay-trace replays offline; `fleet --workers N` gives every device
  its own pool.

RUN:
  `run` executes any pruning algorithm through the uniform run layer
  (DESIGN.md §9): --pruner selects it by name, --events streams the typed
  event log (one JSON object per line, schema 'cprune-run-events' v1),
  --registry auto-publishes every emitted checkpoint frontier for the
  serving layer, and the default progress printer narrates baseline
  tuning, accepted/rejected iterations and task bans (--quiet silences
  it, --verbose adds per-candidate measurements).

SPARSITY (DESIGN.md §16):
  --pruner scheme-select runs the CPrune loop with per-layer sparsity
  scheme selection: each selected task first offers pattern (PatDNN
  4-of-9) and block (2:4) mask candidates, priced analytically on the
  target device over the tuned dense schedule, before falling back to
  channel pruning; --scheme narrows the choices (auto = pattern+block,
  channel = plain channel moves, or one scheme name). The one-shot
  'pattern'/'block' pruners mask every applicable conv as single-scheme
  reference points; `report schemes` prints the schemes × devices table.
  --masks FILE writes the fastest checkpoint's scheme assignment as a
  versioned 'cprune-sparsity-masks' JSON document (`cprune check`
  verifies it, CPV17x). --scheme does not combine with
  --journal/--resume (the journal config does not record it).

WARM START:
  --cache FILE persists tuned programs (versioned JSON) across runs: the
  first run measures and saves, a repeated identical run loads the cache
  and re-measures (close to) nothing — watch the 'programs measured' line.
  `fleet` tunes one model for several devices in a single session: the
  first device (the pilot) tunes natively and its best programs seed every
  other device's search; --cache-dir keeps one cache file per device.

SERVING:
  `serve` runs CPrune per device (unless --registry already holds the
  frontier, or --no-search forbids backfilling), publishes each run's
  latency/accuracy Pareto set to the registry, then replays a seeded
  synthetic trace through the serving simulator: batching queue,
  per-device dispatch, and an SLO-aware policy that serves the fastest
  frontier model meeting --accuracy-floor and degrades down the frontier
  under load. Reports p50/p95/p99 latency, throughput and SLO-violation
  rate — byte-identical across runs with the same seeds. --registry FILE
  persists the Pareto sets (versioned JSON).

BENCH:
  `bench` runs the perf-trajectory harness (DESIGN.md §10): the tuner
  hot-path and end-to-end CPrune workloads with pinned seeds, writing
  versioned BENCH_tuner.json / BENCH_e2e.json into --out-dir (default:
  the current directory). Wall times are host-dependent; the
  programs-measured counts are deterministic for a pinned seed, which CI
  smoke-checks. --tier quick is CI-sized; --tier full is trajectory-grade.

CRASH SAFETY (DESIGN.md §15):
  `run --journal FILE` appends a fsync'd `cprune-run-journal` record at
  every accepted iteration; after a crash, `run --resume FILE` restores
  the original configuration (seed, pruner, model, device, budgets) from
  the journal, preloads every journaled tuned program, and re-executes —
  pre-crash iterations replay as pure cache hits, so the resumed event
  stream is byte-identical to an uninterrupted run's. Every versioned
  artifact is written atomically (temp + fsync + rename), so a crash
  leaves the old file or the new one, never a torn half.
  --faults SPEC injects deterministic failures for testing: comma-
  separated clauses seed:S, fail@SITE[:K], torn@SITE[:K],
  abort@BARRIER (baseline | iter:N | finish), die@worker:N,
  hang@worker:N. Write sites: cache registry trace remote-trace
  calibration devices report out events journal. An abort@ clause exits
  the process with code 86 at the matching journal barrier.

CHECK:
  `check` sweeps each PATH (directories recursively, default '.') for
  cprune-format JSON/JSONL artifacts — tune caches, measurement traces,
  Pareto registries, device files, calibration tables, bench reports and
  run-event logs — and re-verifies their semantic invariants: canonical
  keys, sorted entries, programs legal for their workloads, non-dominated
  frontiers, event schemas. Findings print as `file: context: CPVnnn:
  message` and the exit code is 1 when any are found; --codes prints the
  diagnostic catalog. CI runs `cprune check .` over the committed tree.

FEATURES:
  The optional `pjrt` cargo feature (cargo build --features pjrt) enables
  the XLA/PJRT runtime behind `e2e-info`'s artifacts (runtime/, train/).
  Default builds are pure-Rust, offline and dependency-free.";

pub fn run(argv: Vec<String>) -> i32 {
    let mut args = match parse_args(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // Fault injection (DESIGN.md §15): install the plan first so every
    // write site, journal barrier and loopback worker spawned below sees
    // it. The guard keeps the thread-local hook alive for the whole
    // command.
    let _fault_guard = match args.flags.get("faults") {
        Some(spec) => match crate::util::fault::FaultPlan::parse(spec) {
            Ok(plan) => Some(crate::util::fault::install(Box::new(plan))),
            Err(e) => {
                eprintln!("--faults: {e}");
                return 2;
            }
        },
        None => None,
    };
    // --resume JOURNAL restores the crashed run's configuration from the
    // journal's config record before any flag resolution, so a bare
    // `cprune run --resume FILE` reproduces the original invocation
    // (seed, pruner, model, device, budgets). Output flags (--events,
    // --cache, --quiet, ...) still come from this command line.
    if let Some(path) = args.flags.get("resume").cloned() {
        if args.positional.first().map(String::as_str) != Some("run") {
            eprintln!("--resume is only supported by `run`");
            return 2;
        }
        let cfg = match crate::run::journal::read_config(&path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("--resume {path}: {e}");
                return 1;
            }
        };
        args.flags.insert("seed".to_string(), cfg.seed.to_string());
        args.flags.insert("iters".to_string(), cfg.iters.to_string());
        args.flags.insert("pruner".to_string(), cfg.pruner);
        args.flags.insert("model".to_string(), cfg.model);
        match cfg.target_acc {
            Some(a) => args.flags.insert("target-acc".to_string(), a.to_string()),
            None => args.flags.remove("target-acc"),
        };
        // The journaled device token is whatever --target/--device was
        // given originally; provider-prefixed tokens go back to --target.
        if cfg.device.contains(':') {
            args.flags.insert("target".to_string(), cfg.device);
            args.flags.remove("device");
        } else {
            args.flags.insert("device".to_string(), cfg.device);
            args.flags.remove("target");
        }
    }
    let args = args;
    let Some(cmd) = args.positional.first() else {
        println!("{USAGE}");
        return 0;
    };
    let seed: u64 = args.flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(0);
    // Device registry: the five built-ins, plus device files from
    // CPRUNE_DEVICES, plus --device-file (later registrations shadow).
    let mut registry = match TargetRegistry::from_env() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    if let Some(path) = args.flags.get("device-file") {
        if let Err(e) = registry.load_file(path) {
            eprintln!("{e}");
            return 1;
        }
    }
    // The spec subcommands consume (default Kryo 385). --target may carry
    // a provider prefix (analytic:/lut:/remote:); only run/prune build
    // non-analytic providers, so a lut:/remote: request anywhere else is
    // an error, not a silent analytic downgrade — and --device never
    // takes a prefix.
    let device = {
        let (name, from_target) = match (args.flags.get("target"), args.flags.get("device")) {
            (Some(t), _) => (t.as_str(), true),
            (None, Some(d)) => (d.as_str(), false),
            (None, None) => ("kryo385", false),
        };
        let bare = match name.split_once(':') {
            Some(("analytic", rest)) | Some(("lut", rest)) if from_target => {
                if name.starts_with("lut:") && !matches!(cmd.as_str(), "run" | "prune") {
                    eprintln!(
                        "--target lut:... is only supported by `run`/`prune` \
                         (other commands use the analytic provider); got '{name}'"
                    );
                    return 2;
                }
                rest
            }
            Some(("remote", rest)) if from_target => {
                if !matches!(cmd.as_str(), "run" | "prune") {
                    eprintln!(
                        "--target remote:... is only supported by `run`/`prune` \
                         (fleet takes --workers instead); got '{name}'"
                    );
                    return 2;
                }
                // remote:NAME@HOST:PORT,... — the registry only sees NAME
                rest.split_once('@').map_or(rest, |(b, _)| b)
            }
            Some((provider, _)) => {
                if from_target {
                    eprintln!(
                        "unknown target provider '{provider}:' in '{name}' \
                         (want analytic:NAME, lut:NAME or remote:NAME[@HOST:PORT,...])"
                    );
                } else {
                    eprintln!(
                        "--device takes a bare registry name, got '{name}'; \
                         provider prefixes go with --target"
                    );
                }
                return 2;
            }
            None => name,
        };
        match registry.spec(bare) {
            Some(spec) => spec.clone(),
            None => {
                eprintln!("{}", registry.unknown_device_error(bare));
                return 2;
            }
        }
    };
    let model_kind = args
        .flags
        .get("model")
        .map(|m| model_by_name(m))
        .unwrap_or(ModelKind::ResNet18ImageNet);

    match cmd.as_str() {
        "run" => {
            let pruner_name = args
                .flags
                .get("pruner")
                .map(String::as_str)
                .unwrap_or("cprune");
            let Some(mut pruner) = pruner_by_name(pruner_name) else {
                eprintln!("unknown pruner '{pruner_name}'. options: {PRUNER_NAMES}");
                return 2;
            };
            // --scheme narrows the scheme-select search space (DESIGN.md
            // §16). The journal config does not record it, so a resumed
            // or journaled run must not depend on it.
            if let Some(flag) = args.flags.get("scheme") {
                if pruner_name != "scheme-select" {
                    eprintln!("--scheme is only supported by --pruner scheme-select");
                    return 2;
                }
                if args.flags.contains_key("journal") || args.flags.contains_key("resume") {
                    eprintln!(
                        "--scheme cannot be combined with --journal/--resume \
                         (the journal config does not record the scheme restriction)"
                    );
                    return 2;
                }
                match crate::sparsity::SchemeSelect::from_scheme_flag(flag) {
                    Ok(sel) => pruner = Box::new(sel),
                    Err(e) => {
                        eprintln!("{e}");
                        return 2;
                    }
                }
            }
            let mut builder =
                match run_builder_from_flags(&args, model_kind, &registry, &device, seed) {
                    Ok(b) => b,
                    Err(code) => return code,
                };
            // Crash-safety journal (DESIGN.md §15): --resume continues an
            // interrupted journal; --journal starts a fresh one recording
            // this invocation's configuration tokens.
            if let Some(path) = args.flags.get("resume") {
                builder = builder.resume(path);
            } else if let Some(path) = args.flags.get("journal") {
                let config = crate::run::journal::JournalConfig {
                    seed,
                    pruner: pruner_name.to_string(),
                    model: args
                        .flags
                        .get("model")
                        .cloned()
                        .unwrap_or_else(|| "resnet18-imagenet".to_string()),
                    device: args
                        .flags
                        .get("target")
                        .or_else(|| args.flags.get("device"))
                        .cloned()
                        .unwrap_or_else(|| "kryo385".to_string()),
                    iters: flag_or(&args, "iters", 20usize).unwrap_or(20),
                    target_acc: args.flags.get("target-acc").and_then(|v| v.parse().ok()),
                };
                builder = builder.journal(path, config);
            }
            if !args.flags.contains_key("quiet") {
                let printer = if args.flags.contains_key("verbose") {
                    ProgressPrinter::new().verbose()
                } else {
                    ProgressPrinter::new()
                };
                builder = builder.observer(Box::new(printer));
            }
            if let Some(path) = args.flags.get("registry") {
                let registry = if std::path::Path::new(path).exists() {
                    match Registry::load(path) {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("registry {path}: {e}");
                            return 1;
                        }
                    }
                } else {
                    Registry::new()
                };
                let publisher = RegistryPublisher::shared(
                    Rc::new(RefCell::new(registry)),
                    model_kind.name(),
                    device.name,
                )
                .saving_to(path);
                builder = builder.observer(Box::new(publisher));
            }
            let mut run = match builder.build() {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            let out = match run.execute(pruner.as_ref()) {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            println!(
                "{} on {} via {}: {:.2}x FPS ({:.1} -> {:.1}), {:.0}M MACs, {:.2}M params, top-1 {:.2}%",
                out.model,
                out.device,
                out.method,
                out.fps_increase_rate,
                1.0 / out.baseline_latency,
                out.final_fps,
                out.macs as f64 / 1e6,
                out.params as f64 / 1e6,
                out.top1 * 100.0
            );
            println!(
                "search cost: {} candidates, {} programs measured ({} cache hits avoided {} measurements)",
                out.search_candidates,
                out.programs_measured,
                run.cache().hits(),
                run.cache().saved()
            );
            if let Some(path) = args.flags.get("events") {
                println!("events: wrote {path}");
            }
            // The fastest checkpoint's scheme assignment as a versioned
            // mask artifact (DESIGN.md §16). Pattern parameters derive
            // from the run's own weight bank (same model seed).
            if let Some(path) = args.flags.get("masks") {
                let schemes = out
                    .pareto
                    .fastest()
                    .map(|c| c.schemes.clone())
                    .unwrap_or_default();
                let model = Model::build(model_kind, seed);
                let set = crate::sparsity::MaskSet::from_schemes(
                    &schemes,
                    &model.graph,
                    &model.weights,
                );
                if let Err(e) = set.save(path) {
                    eprintln!("masks {path}: {e}");
                    return 1;
                }
                println!("masks: wrote {}-entry scheme mask set to {path}", set.masks.len());
            }
            if let Some(path) = args.flags.get("registry") {
                println!("registry: published {}-point frontier to {path}", out.pareto.len());
            }
            if let Some(path) = args.flags.get("record-trace") {
                println!("trace: recorded measurement trace to {path}");
            }
            if let Some(path) = args.flags.get("remote-trace") {
                println!("trace: recorded remote measurement trace to {path}");
            }
            if let Some(path) = args.flags.get("replay-trace") {
                println!("trace: replayed measurements from {path}");
            }
            0
        }
        "worker" => {
            // Stdout is the wire in --stdio mode: anything human goes to
            // stderr (serve_listen logs there too).
            let target = AnalyticTarget::new(device);
            let outcome = match args.flags.get("listen") {
                Some(addr) => worker::serve_listen(addr, &target),
                None => worker::serve_stdio(&target),
            };
            match outcome {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("cprune worker: {e}");
                    1
                }
            }
        }
        "prune" => {
            let builder =
                match run_builder_from_flags(&args, model_kind, &registry, &device, seed) {
                    Ok(b) => b,
                    Err(code) => return code,
                };
            let mut run = match builder.build() {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            let pruner = CPrune::default();
            let out = match run.execute(&pruner) {
                Ok(o) => o,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            if let Some(path) = args.flags.get("out") {
                let j = crate::pruner::report::outcome_to_json(&out);
                if let Err(e) = crate::util::io::atomic_write(path, &j.to_string(), "out") {
                    eprintln!("writing {path}: {e}");
                    return 1;
                }
                println!("wrote {path}");
            }
            println!(
                "{} on {}: {:.2}x FPS ({:.1} -> {:.1}), {:.0}M MACs, {:.2}M params, top-1 {:.2}%",
                out.model,
                out.device,
                out.fps_increase_rate,
                1.0 / out.baseline_latency,
                out.final_fps,
                out.macs as f64 / 1e6,
                out.params as f64 / 1e6,
                out.top1 * 100.0
            );
            println!(
                "search cost: {} programs measured ({} cache hits avoided {} measurements)",
                out.programs_measured,
                run.cache().hits(),
                run.cache().saved()
            );
            0
        }
        "tune" => {
            let model = Model::build(model_kind, seed);
            let sim = Simulator::new(device);
            let session =
                match open_session(&sim, TuneOptions::default(), seed, args.flags.get("cache")) {
                    Ok(s) => s,
                    Err(code) => return code,
                };
            let c = compiler::compile_tuned(&model.graph, &session, &HashMap::new());
            let fallback = compiler::compile_fallback(&model.graph, &sim);
            println!(
                "{} on {}: tuned {:.2} FPS vs library-default {:.2} FPS ({} tasks, {} programs measured, {} cache hits)",
                model.kind.name(),
                sim.spec.name,
                c.fps(),
                fallback.fps(),
                c.table.len(),
                session.measured_count(),
                session.cache.hits()
            );
            close_session(&session, args.flags.get("cache"))
        }
        "fleet" => {
            let model = Model::build(model_kind, seed);
            let device_list = args
                .flags
                .get("devices")
                .cloned()
                .unwrap_or_else(|| "kryo280,kryo385,kryo585,mali-g72".to_string());
            let specs = match parse_devices(&args, &registry, "kryo280,kryo385,kryo585,mali-g72") {
                Ok(s) => s,
                Err(code) => return code,
            };
            let workers = match flag_or(&args, "workers", 0usize) {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let threads = match args.flags.get("threads") {
                Some(t) => match t.parse() {
                    Ok(n) => n,
                    Err(_) => {
                        eprintln!("--threads wants a number, got '{t}'");
                        return 2;
                    }
                },
                None => 0,
            };
            let opts = FleetOptions {
                tune: if args.flags.contains_key("quick") {
                    TuneOptions::quick()
                } else {
                    TuneOptions::default()
                },
                threads,
                cross_seed: true,
            };
            // --workers N: one remote pool of N subprocess workers per
            // device (DESIGN.md §14) — same results as in-process, the
            // registry names resolve again inside each worker process.
            let mut fleet = if workers > 0 {
                let mut targets: Vec<Box<dyn Target>> = Vec::new();
                for name in device_list.split(',').filter(|s| !s.is_empty()) {
                    match RemoteTarget::spawn(name, workers, RemoteOptions::default()) {
                        Ok(t) => targets.push(Box::new(t)),
                        Err(e) => {
                            eprintln!("remote pool for '{name}': {e}");
                            return 1;
                        }
                    }
                }
                println!(
                    "fleet: {} remote worker(s) per device across {} device(s)",
                    workers,
                    targets.len()
                );
                FleetSession::from_targets(targets, opts, seed)
            } else {
                FleetSession::new(specs, opts, seed)
            };
            if let Some(dir) = args.flags.get("cache-dir") {
                match fleet.load_caches(dir) {
                    Ok(n) if n > 0 => println!("cache: warm-started {n} device(s) from {dir}"),
                    Ok(_) => {}
                    Err(e) => {
                        eprintln!("cache-dir {dir}: {e}");
                        return 1;
                    }
                }
            }
            let r = fleet.tune_graph(&model.graph);
            let rows: Vec<Vec<String>> = r.devices.iter().map(|d| d.table_row()).collect();
            print_table(
                &format!("{} fleet tuning ({} devices)", model.kind.name(), r.devices.len()),
                &FleetDeviceResult::TABLE_HEADERS,
                &rows,
            );
            println!(
                "fleet: {} programs measured, {} cache hits ({:.0}% hit rate) avoided {} measurements",
                r.total_measured(),
                r.total_cache_hits(),
                r.hit_rate() * 100.0,
                r.total_measured_saved()
            );
            if let Some(dir) = args.flags.get("cache-dir") {
                if let Err(e) = fleet.save_caches(dir) {
                    eprintln!("saving caches to {dir}: {e}");
                    return 1;
                }
                println!("cache: saved {} device cache(s) to {dir}", fleet.num_devices());
            }
            0
        }
        "serve" => {
            let specs = match parse_devices(&args, &registry, "kryo385,kryo585") {
                Ok(s) => s,
                Err(code) => return code,
            };
            let parsed = (|| -> Result<(ServeOptions, usize), String> {
                let opts = ServeOptions {
                    rps: flag_or(&args, "rps", 50.0)?,
                    requests: flag_or(&args, "requests", 2000)?,
                    slo_ms: flag_or(&args, "slo-ms", 50.0)?,
                    accuracy_floor: flag_or(&args, "accuracy-floor", 0.0)?,
                    trace_seed: flag_or(&args, "trace-seed", seed)?,
                    max_batch: flag_or(&args, "max-batch", 8)?,
                };
                Ok((opts, flag_or(&args, "iters", 6)?))
            })();
            let (opts, iters) = match parsed {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            };
            let model_name = model_kind.name();

            // Frontier per device: from the registry file when it already
            // holds one, otherwise produced by a CPrune run and published
            // (unless --no-search forbids backfilling).
            let registry_path = args.flags.get("registry");
            let no_search = args.flags.contains_key("no-search");
            let mut registry = match registry_path {
                Some(p) if std::path::Path::new(p).exists() => match Registry::load(p) {
                    Ok(r) => {
                        println!("registry: warm-start from {p} ({} frontiers)", r.len());
                        r
                    }
                    Err(e) => {
                        eprintln!("registry {p}: {e}");
                        return 1;
                    }
                },
                _ => Registry::new(),
            };
            for spec in &specs {
                if no_search || registry.get(model_name, spec.name).is_some() {
                    continue;
                }
                let mut run = match RunBuilder::new(model_kind)
                    .device_spec(spec.clone())
                    .seed(seed)
                    .tune_opts(TuneOptions::quick())
                    .max_iterations(iters)
                    .build()
                {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("{e}");
                        return 1;
                    }
                };
                let out = match run.execute(&CPrune::default()) {
                    Ok(o) => o,
                    Err(e) => {
                        eprintln!("{e}");
                        return 1;
                    }
                };
                let n = registry.publish(model_name, spec.name, &out.pareto);
                println!(
                    "registry: published {n}-point frontier for {model_name} on {}",
                    spec.name
                );
            }
            if let Some(p) = registry_path {
                if let Err(e) = registry.save(p) {
                    eprintln!("saving registry {p}: {e}");
                    return 1;
                }
                println!("registry: saved {} frontiers to {p}", registry.len());
            }

            let mut ssim = ServeSimulator::new(opts);
            for spec in &specs {
                let Some(set) = registry.get(model_name, spec.name) else {
                    eprintln!(
                        "registry has no frontier for {model_name} on {}; run without \
                         --no-search to let `cprune serve` build it, or publish one first \
                         with `cprune run --registry <FILE> --device {}`",
                        spec.name, spec.name
                    );
                    return 1;
                };
                if let Err(e) = ssim.add_device(spec.name, set) {
                    eprintln!("{e}");
                    return 1;
                }
            }
            match ssim.run() {
                Ok(report) => {
                    print!("{}", report.render());
                    0
                }
                Err(e) => {
                    eprintln!("{e}");
                    1
                }
            }
        }
        "bench" => {
            let tier_name = args.flags.get("tier").map(String::as_str).unwrap_or("quick");
            let Some(tier) = crate::perf::Tier::parse(tier_name) else {
                eprintln!("unknown tier '{tier_name}'. options: quick, full");
                return 2;
            };
            let out_dir = args.flags.get("out-dir").cloned().unwrap_or_else(|| ".".to_string());
            // Run, print and persist each suite as it completes, so the
            // tuner results reach the terminal and disk even if the
            // (later, slower) e2e suite fails.
            let tuner = crate::perf::run_tuner_suite(tier, seed);
            if let Some(code) = emit_bench_report(&tuner, seed, &out_dir) {
                return code;
            }
            let e2e = match crate::perf::run_e2e_suite(tier, seed) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("{e}");
                    return 1;
                }
            };
            if let Some(code) = emit_bench_report(&e2e, seed, &out_dir) {
                return code;
            }
            0
        }
        "check" => {
            if args.flags.contains_key("codes") {
                for c in crate::verify::Code::ALL {
                    println!("{}  {}", c.id(), c.summary());
                }
                return 0;
            }
            let paths: Vec<String> = if args.positional.len() > 1 {
                args.positional[1..].to_vec()
            } else {
                vec![".".to_string()]
            };
            let mut artifacts = 0usize;
            let mut findings = 0usize;
            for p in &paths {
                let path = std::path::Path::new(p);
                let results = if path.is_dir() {
                    match crate::verify::sweep(path) {
                        Ok(r) => r,
                        Err(e) => {
                            eprintln!("{e}");
                            return 1;
                        }
                    }
                } else {
                    match crate::verify::check_file(path) {
                        Ok(Some(diags)) => vec![(p.clone(), diags)],
                        Ok(None) => {
                            println!("{p}: not a cprune artifact (skipped)");
                            Vec::new()
                        }
                        Err(e) => {
                            eprintln!("{e}");
                            return 1;
                        }
                    }
                };
                for (file, diags) in results {
                    artifacts += 1;
                    for d in &diags {
                        println!("{file}: {d}");
                        findings += 1;
                    }
                }
            }
            println!("check: {artifacts} artifact(s) verified, {findings} finding(s)");
            if findings > 0 {
                1
            } else {
                0
            }
        }
        "compare" => {
            let block = exp::table1::run_cell(model_kind, device, Scale::Smoke, seed);
            let rows: Vec<Vec<String>> = block
                .rows
                .iter()
                .map(|r| {
                    vec![
                        r.method.clone(),
                        format!("{:.2} ({:.2}x)", r.fps, r.fps_increase_rate),
                        format!("{:.2}%", r.top1 * 100.0),
                    ]
                })
                .collect();
            print_table(
                &format!("{} on {}", block.model, block.device),
                &["method", "FPS (rate)", "top-1"],
                &rows,
            );
            0
        }
        "report" => {
            let which = args.positional.get(1).cloned().unwrap_or_default();
            let scale = match args.flags.get("scale").map(|s| s.as_str()) {
                Some("full") => Scale::Full,
                _ => Scale::Smoke,
            };
            report(&which, scale, seed)
        }
        "devices" => {
            let rows: Vec<Vec<String>> = registry
                .devices()
                .iter()
                .map(|d| {
                    vec![
                        d.short.clone(),
                        d.spec.name.to_string(),
                        d.spec.kind.as_str().to_string(),
                        d.spec.cores.to_string(),
                        format!("{:.1}", d.spec.peak_macs() / 1e9),
                        format!("{:.1}", d.spec.mem_bytes_per_s / 1e9),
                        d.source.clone(),
                    ]
                })
                .collect();
            print_table(
                &format!("device registry ({} entries)", rows.len()),
                &["name", "device", "kind", "cores", "peak GMAC/s", "DRAM GB/s", "source"],
                &rows,
            );
            println!(
                "\nresolve with --device/--target (run/prune also take lut:NAME, \
                 analytic:NAME or remote:NAME[@HOST:PORT,...]); add devices via \
                 --device-file FILE or the CPRUNE_DEVICES environment variable \
                 (':'-separated files)."
            );
            0
        }
        "dot" => {
            let model = Model::build(model_kind, seed);
            println!("{}", crate::graph::dot::to_dot(&model.graph));
            0
        }
        "calibrate" => {
            let anchors = crate::device::calibration::paper_anchors(device.name);
            if anchors.is_empty() {
                eprintln!("no paper anchors known for {}", device.name);
                return 1;
            }
            let cal = crate::device::calibration::calibrate(&device, &anchors, seed);
            println!(
                "{}: scale={:.3} residual={:.1}% over {} anchors",
                device.name,
                cal.scale,
                cal.residual * 100.0,
                anchors.len()
            );
            if let Some(path) = args.flags.get("save") {
                use crate::device::calibration::CalibrationTable;
                let mut table = if std::path::Path::new(path).exists() {
                    match CalibrationTable::load(path) {
                        Ok(t) => t,
                        Err(e) => {
                            eprintln!("{e}");
                            return 1;
                        }
                    }
                } else {
                    CalibrationTable::new()
                };
                table.insert(device.name, cal);
                if let Err(e) = table.save(path) {
                    eprintln!("{e}");
                    return 1;
                }
                println!("calibration: saved {} device(s) to {path}", table.len());
            }
            0
        }
        "e2e-info" => {
            let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            if !dir.join("manifest.json").exists() {
                println!("no artifacts — run `make artifacts`");
                return 1;
            }
            match crate::train::Manifest::load(dir.join("manifest.json")) {
                Ok(m) => {
                    println!(
                        "artifacts at {}: train_batch={}, eval_batch={}, {} params, {} masked convs",
                        dir.display(),
                        m.train_batch,
                        m.eval_batch,
                        m.params.len(),
                        m.convs.len()
                    );
                    0
                }
                Err(e) => {
                    eprintln!("manifest error: {e}");
                    1
                }
            }
        }
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            2
        }
    }
}

fn report(which: &str, scale: Scale, seed: u64) -> i32 {
    match which {
        "fig1" => {
            let r = exp::fig1::run(scale, 20, seed);
            println!(
                "fig1: best-before=v{} best-after=v{} pearson={:.3} spearman={:.3}",
                r.best_before, r.best_after, r.pearson_r, r.spearman_rho
            );
        }
        "fig6" => {
            let r = exp::fig6::run(scale, seed);
            for (it, rate, acc) in &r.series {
                println!("fig6: iter={it} rate={rate:.2} acc={:.4}", acc);
            }
        }
        "fig7" => {
            for row in exp::fig7::run(scale, seed) {
                println!(
                    "fig7: {} {} tflite={:.1} tvm={:.1} cprune={:.1}",
                    row.model, row.device, row.fps_tflite, row.fps_tvm, row.fps_cprune
                );
            }
        }
        "fig8" => {
            for row in exp::fig8::run(scale, seed) {
                println!(
                    "fig8: tuned_for={} run_on={} fps={:.1} vs_native={:.2}",
                    row.tuned_for, row.run_on, row.fps, row.relative_to_native
                );
            }
        }
        "fig9" | "fig10" => {
            for row in exp::fig9_10::run(scale, seed) {
                println!(
                    "{which}: {} fps={:.1} rate={:.2} top1={:.4} time={:.1}s candidates={}",
                    row.variant, row.fps, row.fps_increase_rate, row.top1,
                    row.main_step_seconds, row.candidates_tried
                );
            }
        }
        "fig11" => {
            let r = exp::fig11::run(scale, seed);
            println!(
                "fig11: cprune fps={:.1} candidates={} | exhaustive fps={:.1} candidates={}",
                r.cprune_fps, r.cprune_candidates, r.exhaustive_fps, r.exhaustive_candidates
            );
        }
        "table1" => {
            for (kind, spec) in exp::table1::paper_cells() {
                let block = exp::table1::run_cell(kind, spec, scale, seed);
                for r in &block.rows {
                    println!(
                        "table1: {} {} {} fps={:.2} rate={:.2} top1={:.4}",
                        block.model, block.device, r.method, r.fps, r.fps_increase_rate, r.top1
                    );
                }
            }
        }
        "table2" => {
            for block in exp::table2::run(scale, seed) {
                for r in &block.rows {
                    println!(
                        "table2: {} {} fps={:.2} rate={:.2} top1={:.4}",
                        block.device, r.method, r.fps, r.fps_increase_rate, r.top1
                    );
                }
            }
        }
        "schemes" => {
            for (kind, spec) in exp::schemes::paper_cells() {
                let block = exp::schemes::run_cell(kind, spec, scale, seed);
                for r in &block.rows {
                    println!(
                        "schemes: {} {} {} fps={:.2} rate={:.2} top1={:.4}",
                        block.model, block.device, r.method, r.fps, r.fps_increase_rate, r.top1
                    );
                }
            }
        }
        other => {
            eprintln!("unknown report '{other}'");
            return 2;
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Result<Args, String> {
        let argv: Vec<String> = argv.iter().map(|s| s.to_string()).collect();
        parse_args(&argv)
    }

    #[test]
    fn parse_args_flags_and_positionals() {
        let a = parse(&["prune", "--model", "resnet18", "--iters", "5", "--verbose"]).unwrap();
        assert_eq!(a.positional, vec!["prune"]);
        assert_eq!(a.flags.get("model").unwrap(), "resnet18");
        assert_eq!(a.flags.get("iters").unwrap(), "5");
        assert_eq!(a.flags.get("verbose").unwrap(), "true");
    }

    #[test]
    fn parse_args_supports_key_equals_value() {
        let a = parse(&["run", "--model=resnet18", "--iters=5", "--events=out.jsonl"]).unwrap();
        assert_eq!(a.flags.get("model").unwrap(), "resnet18");
        assert_eq!(a.flags.get("iters").unwrap(), "5");
        assert_eq!(a.flags.get("events").unwrap(), "out.jsonl");
        // empty value and values containing '=' survive
        let a = parse(&["run", "--out=", "--expr=a=b"]).unwrap();
        assert_eq!(a.flags.get("out").unwrap(), "");
        assert_eq!(a.flags.get("expr").unwrap(), "a=b");
    }

    #[test]
    fn parse_args_equals_syntax_carries_values_that_begin_with_dashes() {
        let a = parse(&["run", "--events=--weird.jsonl"]).unwrap();
        assert_eq!(a.flags.get("events").unwrap(), "--weird.jsonl");
    }

    #[test]
    fn parse_args_rejects_flag_lookalike_values_instead_of_swallowing_them() {
        // Legacy behavior silently made `--events` a boolean and invented a
        // `foo.jsonl` flag; now it is a loud error pointing at '='.
        let e = parse(&["run", "--events", "--foo.jsonl"]).unwrap_err();
        assert!(e.contains("--foo.jsonl"), "{e}");
        assert!(e.contains("="), "{e}");
        // adjacent valid flags still parse as booleans
        let a = parse(&["run", "--quiet", "--quick"]).unwrap();
        assert_eq!(a.flags.get("quiet").unwrap(), "true");
        assert_eq!(a.flags.get("quick").unwrap(), "true");
    }

    #[test]
    fn parse_args_double_dash_ends_flag_parsing() {
        let a = parse(&["run", "--seed", "3", "--", "--not-a-flag", "pos"]).unwrap();
        assert_eq!(a.flags.get("seed").unwrap(), "3");
        assert_eq!(a.positional, vec!["run", "--not-a-flag", "pos"]);
    }

    #[test]
    fn parse_args_rejects_malformed_flags() {
        assert!(parse(&["run", "--ev!l=x"]).is_err());
        assert!(parse(&["run", "--=x"]).is_err());
    }

    #[test]
    fn model_names_resolve() {
        assert_eq!(model_by_name("mobilenetv2"), ModelKind::MobileNetV2ImageNet);
        assert_eq!(model_by_name("resnet8-cifar"), ModelKind::ResNet8Cifar);
    }
}
