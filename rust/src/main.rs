//! `cprune` CLI — leader entrypoint. See `cprune help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(cprune::cli::run(argv));
}
