//! The run layer: one [`Pruner`] trait, one [`RunBuilder`], one typed
//! event stream for every pruning run (DESIGN.md §9). Sparsity-scheme
//! pruners (`pattern`, `block`, `scheme-select`; [`crate::sparsity`],
//! DESIGN.md §16) run behind the same trait, and scheme-carrying events
//! and checkpoints stay v1-compatible (the field is omitted when absent).
//!
//! The paper's headline result is a *comparison* — CPrune against
//! magnitude, FPGM, NetAdapt, AMC and PQF under identical device, tuning
//! and accuracy budgets. This module is where that uniformity lives:
//!
//! * [`Pruner`] — the one interface every algorithm implements
//!   ([`pruners::CPrune`] plus all five baselines), selectable by name
//!   via [`pruner_by_name`];
//! * [`PruneOutcome`] — the one result type, unifying
//!   [`crate::pruner::CPruneResult`] and [`crate::baselines::Outcome`]:
//!   final latency/FPS, top-1/top-5, the channel map, and a
//!   [`ParetoSet`] frontier (one-shot baselines emit their end state as
//!   a one-point frontier, so *everything* is servable through
//!   [`crate::serve::Registry`]);
//! * [`RunContext`] — the cross-cutting wiring (model, tuning session,
//!   accuracy oracle, observers) a pruner runs against;
//! * [`RunBuilder`]/[`Run`] (in [`builder`]) — fluent construction of
//!   that wiring: model, device, tune budget, seed, warm-start cache
//!   path, accuracy budget, observers;
//! * [`RunEvent`]/[`RunObserver`] (in [`events`]) — the typed event
//!   stream with three shipped observers (JSONL sink, CLI progress
//!   printer, registry auto-publisher).
//!
//! Devices reach a run through the measurement plane (DESIGN.md §11):
//! [`RunBuilder`] resolves names via [`crate::device::TargetRegistry`],
//! accepts any [`crate::device::Target`] provider directly, and wraps
//! runs in the record/replay provider for byte-identical cross-machine
//! replays of the event stream.
//!
//! The legacy free functions (`pruner::cprune`, `baselines::*`) remain
//! as thin shims over the trait, so both spellings stay byte-identical
//! for a fixed seed (pinned by `tests/run_api_tests.rs`).
//!
//! Runs are crash-safe (DESIGN.md §15): [`RunBuilder::journal`] appends
//! a fsync'd [`journal::RunJournal`] barrier per accepted iteration, and
//! [`RunBuilder::resume`] rebuilds an interrupted run from its journal,
//! replaying to a byte-identical [`RunEvent`] stream.

pub mod builder;
pub mod events;
pub mod journal;
pub mod pruners;

pub use builder::{Run, RunBuilder};
pub use events::{
    JsonlSink, NullObserver, ProgressPrinter, RegistryPublisher, RejectReason, RunEvent,
    RunObserver, EVENTS_FORMAT, EVENTS_VERSION,
};
pub use journal::{IterationRecord, JournalConfig, RunJournal, JOURNAL_FORMAT, JOURNAL_VERSION};
pub use pruners::{pruner_by_name, Amc, CPrune, Fpgm, Magnitude, NetAdapt, Pqf, PRUNER_NAMES};

use crate::accuracy::{AccuracyOracle, Criterion, TrainPhase};
use crate::baselines::Outcome;
use crate::compiler;
use crate::graph::model_zoo::Model;
use crate::graph::ops::NodeId;
use crate::graph::prune::PruneState;
use crate::graph::stats;
use crate::pruner::IterationLog;
use crate::serve::{Checkpoint, ParetoSet};
use crate::tuner::TuningSession;
use std::collections::{BTreeMap, HashMap};

/// A pruning algorithm runnable under the uniform run layer.
///
/// Implementations narrate their search through [`RunContext::emit`] and
/// return a [`PruneOutcome`]; the surrounding [`Run`] appends the
/// [`RunEvent::Finished`] event so every observer sees a complete stream
/// regardless of which algorithm ran.
pub trait Pruner {
    /// Registry name (`cprune`, `magnitude`, `fpgm`, `netadapt`, `amc`,
    /// `pqf`, `pattern`, `block`, `scheme-select`) — what
    /// `cprune run --pruner <name>` selects.
    fn name(&self) -> &str;

    /// Run the algorithm against the context's model/session/oracle.
    fn run(&self, ctx: &mut RunContext) -> PruneOutcome;
}

/// Everything a [`Pruner`] needs to run: the model, the device-bound
/// tuning session, the accuracy oracle, optional budget overrides, and
/// the observers receiving the event stream.
///
/// Built by [`Run::execute`]; the legacy free functions build a bare one
/// via [`RunContext::standalone`].
pub struct RunContext<'s> {
    pub model: &'s Model,
    pub session: &'s TuningSession<'s>,
    pub oracle: &'s mut dyn AccuracyOracle,
    /// Overrides the pruner's own accuracy budget (`a_g`) when set.
    pub accuracy_budget: Option<f64>,
    /// Overrides the pruner's own iteration cap when set.
    pub max_iterations: Option<usize>,
    baseline_latency: Option<f64>,
    observers: &'s mut [Box<dyn RunObserver>],
    /// Crash-safety journal (DESIGN.md §15), attached by [`Run::execute`]
    /// for journaled runs; barriers are appended at baseline and at each
    /// accepted iteration.
    journal: Option<journal::RunJournal>,
    /// Events delivered through [`RunContext::emit`] so far — journaled
    /// at each barrier for audit (`cprune check` cross-checks it).
    events_emitted: usize,
}

impl<'s> RunContext<'s> {
    /// Full wiring (what [`Run::execute`] builds).
    pub fn new(
        model: &'s Model,
        session: &'s TuningSession<'s>,
        oracle: &'s mut dyn AccuracyOracle,
        observers: &'s mut [Box<dyn RunObserver>],
    ) -> RunContext<'s> {
        RunContext {
            model,
            session,
            oracle,
            accuracy_budget: None,
            max_iterations: None,
            baseline_latency: None,
            observers,
            journal: None,
            events_emitted: 0,
        }
    }

    /// Observer-less context for the legacy free-function shims.
    pub fn standalone(
        model: &'s Model,
        session: &'s TuningSession<'s>,
        oracle: &'s mut dyn AccuracyOracle,
    ) -> RunContext<'s> {
        Self::new(model, session, oracle, &mut [])
    }

    /// Pre-seed the baseline latency (legacy shims receive it as an
    /// argument instead of measuring it) — [`RunContext::baseline_latency`]
    /// then returns this value without compiling anything.
    pub fn with_baseline(mut self, latency: f64) -> RunContext<'s> {
        self.baseline_latency = Some(latency);
        self
    }

    /// Display name of the session's target device.
    pub fn device(&self) -> &'static str {
        self.session.device_name()
    }

    /// Deliver an event to every observer, in registration order.
    pub fn emit(&mut self, event: &RunEvent) {
        self.events_emitted += 1;
        for obs in self.observers.iter_mut() {
            obs.on_event(event);
        }
    }

    /// Attach the crash-safety journal ([`Run::execute`] does this for
    /// journaled runs before handing the context to the pruner).
    pub(crate) fn attach_journal(&mut self, journal: journal::RunJournal) {
        self.journal = Some(journal);
    }

    /// Take the journal back out (so [`Run::execute`] can append the
    /// `finished` record after dispatching the final event).
    pub(crate) fn detach_journal(&mut self) -> Option<journal::RunJournal> {
        self.journal.take()
    }

    /// Events delivered through [`RunContext::emit`] so far.
    pub(crate) fn events_emitted(&self) -> usize {
        self.events_emitted
    }

    /// Journal barrier for an accepted iteration (DESIGN.md §15): a
    /// no-op when the run is unjournaled, or when the iteration was
    /// already journaled before a crash (resume replay).
    pub fn journal_accept(&mut self, rec: journal::IterationRecord) {
        if let Some(j) = self.journal.as_mut() {
            let measured = self.session.measured_count();
            j.record_iteration(&rec, measured, self.events_emitted, &self.session.cache);
        }
    }

    /// Latency of the tuned-but-unpruned model on this session's device —
    /// the denominator of every FPS-increase rate. Measured (and the
    /// [`RunEvent::BaselineTuned`] event emitted) at most once per context.
    pub fn baseline_latency(&mut self) -> f64 {
        if let Some(l) = self.baseline_latency {
            return l;
        }
        let compiled = compiler::compile_tuned(&self.model.graph, self.session, &HashMap::new());
        let latency = compiled.latency();
        self.set_baseline(latency, compiled.fps());
        latency
    }

    /// Record an externally measured baseline and emit
    /// [`RunEvent::BaselineTuned`] (CPrune measures the baseline itself
    /// as Alg. 1 line 1). For journaled runs this is also the `baseline`
    /// journal barrier (DESIGN.md §15).
    pub fn set_baseline(&mut self, latency: f64, fps: f64) {
        self.baseline_latency = Some(latency);
        self.emit(&RunEvent::BaselineTuned { latency, fps });
        if let Some(j) = self.journal.as_mut() {
            j.record_baseline(latency, fps, self.events_emitted, &self.session.cache);
        }
    }
}

/// The uniform result of any [`Pruner`] run — what Table 1/2 print per
/// row and what the serving layer publishes.
#[derive(Clone, Debug)]
pub struct PruneOutcome {
    /// Registry name of the algorithm ([`Pruner::name`]).
    pub pruner: String,
    /// Display label (Table 1/2's method column, e.g. `"FPGM+TVM"`).
    pub method: String,
    pub model: String,
    pub device: String,
    /// Tuned-but-unpruned latency (seconds) the rate is relative to.
    pub baseline_latency: f64,
    pub final_latency: f64,
    pub final_fps: f64,
    pub fps_increase_rate: f64,
    /// MACs of the final model (the tables' "FLOPS" column convention).
    pub macs: u64,
    pub params: u64,
    pub top1: f64,
    pub top5: f64,
    /// Remaining output channels per prunable conv — enough to rebuild
    /// the deployable graph via [`crate::graph::prune::apply`].
    pub channels: BTreeMap<NodeId, usize>,
    /// The run's non-dominated latency/accuracy frontier. One-shot
    /// baselines contribute a single point; iterative searches (CPrune,
    /// NetAdapt) contribute every accepted iteration.
    pub pareto: ParetoSet,
    /// Accepted iterations (empty for one-shot baselines).
    pub iterations: Vec<IterationLog>,
    /// Candidate models compiled+measured during the search (0 = one-shot).
    pub search_candidates: usize,
    /// Wall-clock seconds of the search's main step.
    pub main_step_seconds: f64,
    /// Programs measured by the tuner on this context's session — an
    /// honest per-`measure_avg`-call counter (DESIGN.md §10), the
    /// paper's Fig. 11 search-cost metric.
    pub programs_measured: usize,
}

impl PruneOutcome {
    /// Collapse to the legacy Table-1 row type.
    pub fn to_outcome(&self) -> Outcome {
        Outcome {
            method: self.method.clone(),
            fps: self.final_fps,
            fps_increase_rate: self.fps_increase_rate,
            macs: self.macs,
            params: self.params,
            top1: self.top1,
            top5: self.top5,
            search_candidates: self.search_candidates,
            main_step_seconds: self.main_step_seconds,
        }
    }

    /// The [`RunEvent::Finished`] event mirroring this outcome.
    pub fn finished_event(&self) -> RunEvent {
        RunEvent::Finished {
            pruner: self.pruner.clone(),
            method: self.method.clone(),
            model: self.model.clone(),
            device: self.device.clone(),
            final_latency: self.final_latency,
            final_fps: self.final_fps,
            fps_increase_rate: self.fps_increase_rate,
            top1: self.top1,
            top5: self.top5,
            macs: self.macs,
            params: self.params,
            iterations: self.iterations.len(),
            search_candidates: self.search_candidates,
            pareto_points: self.pareto.len(),
        }
    }
}

/// What a finished search hands to [`finalize`]: the end state plus the
/// per-algorithm counters the shared evaluation cannot know.
pub(crate) struct SearchEnd {
    pub pruner: &'static str,
    pub method: String,
    pub state: PruneState,
    pub criterion: Criterion,
    pub search_candidates: usize,
    pub main_step_seconds: f64,
    pub iterations: Vec<IterationLog>,
    /// Checkpoints already emitted during the search (iterative
    /// algorithms); the final end-state checkpoint is added here.
    pub checkpoints: Vec<Checkpoint>,
}

/// Shared tail of every structural pruner: rebuild the pruned graph,
/// compile+measure it tuned, query the oracle's final accuracies, emit
/// the end-state checkpoint, and assemble the [`PruneOutcome`].
///
/// Mirrors the legacy [`crate::baselines::evaluate`] step for step so
/// trait runs reproduce free-function runs bit-for-bit.
pub(crate) fn finalize(ctx: &mut RunContext, end: SearchEnd) -> PruneOutcome {
    let model = ctx.model;
    let session = ctx.session;
    let baseline_latency = ctx.baseline_latency();
    let graph =
        crate::graph::prune::apply(&model.graph, &end.state.cout).expect("valid pruned graph"); // cprune-lint: allow(CPL005, reason="pruners emit only valid states")
    let compiled = compiler::compile_tuned(&graph, session, &HashMap::new());
    let (flops, params) = stats::flops_params(&graph);
    let summary = crate::pruner::summarize(model, &end.state, end.criterion);
    let top1 = ctx.oracle.top1(&summary, TrainPhase::Final);
    let top5 = ctx.oracle.top5(&summary, TrainPhase::Final);
    let final_latency = compiled.latency();

    let mut pareto = ParetoSet::new();
    for c in &end.checkpoints {
        pareto.insert(c.clone());
    }
    let final_checkpoint = Checkpoint {
        iteration: end.iterations.len().max(1),
        latency: final_latency,
        accuracy: top1,
        channels: end.state.cout.clone(),
        schemes: BTreeMap::new(),
    };
    ctx.emit(&RunEvent::CheckpointEmitted { checkpoint: final_checkpoint.clone() });
    pareto.insert(final_checkpoint);

    PruneOutcome {
        pruner: end.pruner.to_string(),
        method: end.method,
        model: model.kind.name().to_string(),
        device: ctx.device().to_string(),
        baseline_latency,
        final_latency,
        final_fps: compiled.fps(),
        fps_increase_rate: baseline_latency / final_latency,
        macs: flops / 2,
        params,
        top1,
        top5,
        channels: end.state.cout,
        pareto,
        iterations: end.iterations,
        search_candidates: end.search_candidates,
        main_step_seconds: end.main_step_seconds,
        programs_measured: session.measured_count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::ProxyOracle;
    use crate::device::{DeviceSpec, Simulator};
    use crate::graph::model_zoo::ModelKind;
    use crate::tuner::TuneOptions;

    #[test]
    fn standalone_context_measures_baseline_once() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 0);
        let mut oracle = ProxyOracle::new();
        let mut ctx = RunContext::standalone(&m, &session, &mut oracle);
        let a = ctx.baseline_latency();
        let b = ctx.baseline_latency();
        assert!(a > 0.0 && a.is_finite());
        assert_eq!(a, b);
        // the device name is the spec's display name (the same string
        // the serve registry and fleet results key on)
        assert_eq!(ctx.device(), "Kryo 385 (Galaxy S9)");
    }

    #[test]
    fn with_baseline_short_circuits_measurement() {
        let m = Model::build(ModelKind::ResNet8Cifar, 0);
        let sim = Simulator::new(DeviceSpec::kryo385());
        let session = TuningSession::new(&sim, TuneOptions::quick(), 0);
        let mut oracle = ProxyOracle::new();
        let mut ctx = RunContext::standalone(&m, &session, &mut oracle).with_baseline(0.125);
        assert_eq!(ctx.baseline_latency(), 0.125);
        assert_eq!(session.measured_count(), 0, "pre-seeded baseline must not tune");
    }
}
